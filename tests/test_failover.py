"""Crash-failover gate: wire-format snapshots + journal replay reconstruct
every stream bit-identically to an uninterrupted single-engine reference.

The tentpole assertion (``test_bit_exact_recovery_matrix``) kills a shard
at every tick phase x every fleet width and compares the *complete*
per-stream event history — kinds, steps, predictions, raw logits bytes —
against the no-crash reference.  Not "close": byte-equal.  The paper's
determinism contract (Sec. VI-B, 100% agreement) is what makes this
assertable.
"""
import dataclasses

import jax
import numpy as np
import pytest

from faultharness import (assert_counters_conserved, assert_logs_identical,
                          collect_log, make_streams, reference_log,
                          run_crash_schedule)
from repro.core import fastgrnn as fg
from repro.core.qruntime import QRuntime
from repro.core.quantization import QuantConfig, quantize_params
from repro.serve.fleet import (PHASES, FleetConfig, FleetEngine,
                               ScheduledFaults, WireCorruptError)
from repro.serve.streaming import StreamingConfig, StreamingEngine


@pytest.fixture(scope="module")
def qp():
    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    return quantize_params(fg.init_params(cfg, jax.random.PRNGKey(0)),
                           QuantConfig())


@pytest.fixture(scope="module")
def input_dim(qp):
    return StreamingEngine(qp, StreamingConfig(max_slots=1)).kernel.input_dim


@pytest.fixture(scope="module")
def streams(input_dim):
    # 24 finite streams x 300 steps: spans two full windows plus a
    # partial, so the schedule crosses window emissions, completions and
    # slot recycling while a crash lands mid-flight
    return make_streams(24, 300, input_dim, seed=1)


@pytest.fixture(scope="module")
def ref_log(qp, streams):
    return reference_log(qp, streams)


# ---------------------------------------------------------------------------
# The tentpole gate: crash at each tick phase x each fleet width
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("phase", PHASES)
@pytest.mark.parametrize("shards", [1, 2, 4, 8])
def test_bit_exact_recovery_matrix(qp, streams, ref_log, phase, shards):
    """Shard 0 dies at tick 140 (between checkpoints; mid-window) at the
    given phase; every stream's full event history must stay byte-equal
    to the uninterrupted reference, and fleet counters must conserve."""
    inj = ScheduledFaults(schedule=[(140, phase, 0)])
    log, stats = run_crash_schedule(
        qp, streams, shards=shards, slots_per_shard=8, injector=inj,
        snapshot_every=64)
    assert_logs_identical(log, ref_log)
    assert_counters_conserved(stats)
    assert stats["failovers"] == 1
    assert stats["replayed_samples"] > 0


def test_failover_ci_smoke(qp, streams, ref_log):
    """The CI fault-injection smoke: one forced crash, bit-exact recovery
    (selected by name in the workflow's fault-injection step)."""
    inj = ScheduledFaults(schedule=[(140, "pre_tick", 0)])
    log, stats = run_crash_schedule(
        qp, streams, shards=2, slots_per_shard=8, injector=inj)
    assert_logs_identical(log, ref_log)
    assert stats["failovers"] == 1


def test_bit_exact_recovery_batch_events(qp, streams, ref_log):
    """The columnar-emission fleet path recovers identically: a batched
    event log folds to the same per-stream histories."""
    inj = ScheduledFaults(schedule=[(140, "post_emit", 1)])
    log, stats = run_crash_schedule(
        qp, streams, shards=4, slots_per_shard=8, injector=inj,
        batch_events=True)
    assert_logs_identical(log, ref_log)
    assert_counters_conserved(stats)


# ---------------------------------------------------------------------------
# Replay semantics
# ---------------------------------------------------------------------------

def test_replay_suppression_counts_and_no_duplicates(qp, streams, ref_log):
    """A crash after a window emission replays through that window again;
    the re-emission must be swallowed (counted, not delivered)."""
    inj = ScheduledFaults(schedule=[(140, "pre_tick", 0)])
    log, stats = run_crash_schedule(
        qp, streams, shards=2, slots_per_shard=8, injector=inj,
        snapshot_every=64)
    # the snapshot at tick 128 predates the window event at step 128, so
    # recovery re-crosses the boundary for every recovered stream
    assert stats["replay_suppressed"] > 0
    # no duplicates is implied by byte-equality, but assert it directly:
    for sid, history in log.items():
        steps = [h[1] for h in history]
        assert len(steps) == len(set(steps)), f"{sid}: duplicate emission"
    assert_logs_identical(log, ref_log)


def test_journal_only_recovery_when_snapshots_dropped(qp, streams, ref_log):
    """Every snapshot dropped in flight: recovery replays each stream's
    whole history from the journal (zero state) — still bit-exact."""
    inj = ScheduledFaults(schedule=[(150, "pre_tick", 0)],
                          drop_snapshots=frozenset(streams))
    log, stats = run_crash_schedule(
        qp, streams, shards=2, slots_per_shard=8, injector=inj,
        snapshot_every=64)
    assert_logs_identical(log, ref_log)
    assert stats["snapshots"]["dropped"] > 0
    assert stats["snapshots"]["protected_streams"] == 0
    assert_counters_conserved(stats)


def test_duplicated_snapshots_are_idempotent(qp, streams, ref_log):
    """A duplicated checkpoint delivery must not corrupt recovery (last
    write wins; the duplicates are byte-identical anyway)."""
    inj = ScheduledFaults(schedule=[(140, "pre_tick", 0)],
                          dup_snapshots=frozenset(streams))
    log, stats = run_crash_schedule(
        qp, streams, shards=2, slots_per_shard=8, injector=inj)
    assert_logs_identical(log, ref_log)
    assert stats["snapshots"]["duplicated"] > 0


def test_corrupt_snapshot_fails_loudly(qp, input_dim):
    """A bit-flipped snapshot must raise the wire format's typed error at
    recovery — never silently resume a stream from garbage state."""
    inj = ScheduledFaults(corrupt_snapshots=frozenset(["st000"]))
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=8), snapshot_every=4),
        faults=inj)
    w = make_streams(1, 64, input_dim)["st000"]
    fleet.attach("st000", w, total_steps=None)
    for _ in range(8):
        fleet.step()
    with pytest.raises(WireCorruptError):
        fleet.crash_shard(fleet.shard_of("st000"))


def test_crash_requires_failover_enabled(qp):
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=4)))
    with pytest.raises(ValueError, match="failover is disabled"):
        fleet.crash_shard(0)
    with pytest.raises(ValueError, match="failover is disabled"):
        fleet.snapshot_now()
    fleet2 = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=4), snapshot_every=8))
    with pytest.raises(ValueError, match="no such shard"):
        fleet2.crash_shard(7)


# ---------------------------------------------------------------------------
# Interactions with the other fleet verbs
# ---------------------------------------------------------------------------

def test_crash_then_migrate_then_crash(qp, streams, ref_log):
    """Failover composes with live migration: crash shard 0, migrate a
    recovered stream mid-replay, crash its destination too — the event
    history still matches the uninterrupted reference byte-for-byte."""
    fleet = FleetEngine(qp, FleetConfig(
        shards=4, stream=StreamingConfig(max_slots=8), snapshot_every=32))
    log = {}
    for sid, w in streams.items():
        fleet.attach(sid, w, total_steps=len(w))
    for _ in range(140):
        collect_log(fleet.step(), log)
    fleet.crash_shard(0, phase="manual")
    for _ in range(5):
        collect_log(fleet.step(), log)
    moved = next(sid for sid, o in fleet._owner.items()
                 if o == 0 and sid in fleet.shards[0]._sessions)
    dst = fleet.migrate(moved)
    assert dst in ("active", "pending")
    fleet.crash_shard(fleet.shard_of(moved), phase="manual")
    collect_log(fleet.drain(), log)
    assert_logs_identical(log, ref_log)
    stats = fleet.stats()
    assert stats["failovers"] == 2
    assert_counters_conserved(stats)


def test_trajectory_survives_failover(qp, input_dim):
    """A tapped stream's recorded trajectory spans the crash: snapshot
    prefix + replayed continuation equals the scalar reference tap."""
    w = make_streams(1, 128, input_dim, seed=3)["st000"]
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=4), snapshot_every=16))
    fleet.attach("st000", w, total_steps=None, record_trajectory=True)
    for _ in range(70):
        fleet.step()
    fleet.crash_shard(fleet.shard_of("st000"), phase="manual")
    fleet.drain()
    traj = fleet.trajectory("st000")
    _, ref = QRuntime(qp).run_window(w, return_trajectory=True)
    np.testing.assert_array_equal(traj.view(np.int32), ref.view(np.int32))


def test_snapshot_now_counts_and_open_streams(qp, input_dim):
    """Manual checkpointing: snapshot_now() stores one blob per live
    shard-held stream; an open (total=None) stream recovered mid-flight
    keeps accepting samples after the crash."""
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=8), snapshot_every=1000))
    feeds = make_streams(6, 200, input_dim, seed=5)
    for sid, w in feeds.items():
        fleet.attach(sid, w[:100], total_steps=None)
    for _ in range(50):
        fleet.step()
    assert fleet.snapshot_now() == 6
    for _ in range(20):
        fleet.step()
    report = fleet.crash_shard(0, phase="manual")
    assert report["streams_recovered"] >= 0
    fleet.drain()
    # open streams still accept post-crash feeds, wherever they live
    ref_eng = StreamingEngine(qp, StreamingConfig(max_slots=8))
    ref_log, got_log = {}, {}
    for sid, w in feeds.items():
        ref_eng.attach(sid, w, total_steps=None)
        fleet.feed(sid, w[100:])
    collect_log(ref_eng.drain(), ref_log)
    collect_log(fleet.drain(), got_log)
    for sid in feeds:
        assert got_log.get(sid, []) == ref_log.get(sid, [])


def test_snapshot_cadence_runs_on_schedule(qp, input_dim):
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=4), snapshot_every=10))
    w = make_streams(2, 64, input_dim)["st000"]
    fleet.attach("a", w, total_steps=None)
    fleet.attach("b", w, total_steps=None)
    for _ in range(30):
        fleet.step()
    stats = fleet.stats()
    assert stats["snapshots"]["taken"] == 2 * 3     # ticks 10, 20, 30
    assert stats["failover_enabled"]


# ---------------------------------------------------------------------------
# Seed sweep: Sec. VI-B parity protocol through a crashing fleet (slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_seed_sweep_parity_with_injected_failover():
    """Paper Sec. VI-B protocol over 5 seeds and the full 3,399-window
    test split, each run suffering one injected shard crash mid-stream:
    the fleet's predictions must stay bit-identical to the uninterrupted
    single-engine reference, so the fp32-agreement numbers match the
    no-failure protocol *exactly* — failover does not cost agreement."""
    from repro.data import hapt
    from repro.deploy import goldens
    from repro.deploy.verify import _fp32_predict
    from repro.serve.streaming import classify_windows

    windows = hapt.load("test").windows
    assert len(windows) == 3399
    for seed in range(5):
        art = goldens.build_reference_artifact(seed=seed)
        qp = art.qp
        eng = StreamingEngine.from_artifact(
            art, StreamingConfig(max_slots=1024))
        ref_preds = classify_windows(eng, windows)
        fleet = FleetEngine.from_artifact(art, FleetConfig(
            shards=4, stream=StreamingConfig(max_slots=1024),
            snapshot_every=32),
            faults=ScheduledFaults(schedule=[(60, "pre_tick", 1)]))
        preds = classify_windows(fleet, windows)
        np.testing.assert_array_equal(preds, ref_preds)
        fp32 = _fp32_predict(qp, windows)
        agree_ref = float(np.mean(ref_preds == fp32))
        agree_fleet = float(np.mean(preds == fp32))
        assert agree_fleet == agree_ref, (seed, agree_fleet, agree_ref)
        assert fleet.stats()["failovers"] == 1, seed
