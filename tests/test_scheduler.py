"""Unit tests for the engine-agnostic slot scheduler (serve/scheduler.py):
placement, FIFO admission, recycling, cancellation, counters, and the
window-boundary baseline policy — exercised against a toy SlotProgram so
the contract is pinned independently of both real engines."""
import numpy as np
import pytest

from repro.serve.scheduler import SlotScheduler, TickReport


class CountdownProgram:
    """Toy workload: each request runs for ``payload`` ticks, then finishes.
    Records every hook call so tests can assert the exact protocol."""

    def __init__(self, n_slots):
        self.remaining = np.zeros(n_slots, np.int64)
        self.resets = []          # (slot, request_id) admissions with reset
        self.admitted = []        # admission order
        self.released = []        # (slot, request_id, reason)

    def admit(self, slot, request_id, payload, reset):
        self.remaining[slot] = payload
        self.admitted.append(request_id)
        if reset:
            self.resets.append((slot, request_id))

    def step(self, resident):
        rows = np.nonzero(resident & (self.remaining > 0))[0]
        self.remaining[rows] -= 1
        done = [int(s) for s in np.nonzero(resident)[0]
                if self.remaining[s] == 0]
        return TickReport(events=[("tick", len(rows))], finished=done,
                          advanced=int(rows.size))

    def release(self, slot, request_id, reason):
        self.released.append((slot, request_id, reason))
        if reason == "cancelled":
            return ("partial", request_id)
        return None


def _sched(n_slots=2, **kw):
    prog = CountdownProgram(n_slots)
    return SlotScheduler(n_slots, prog, **kw), prog


def test_submit_places_until_full_then_queues():
    sched, prog = _sched(2)
    assert sched.submit("a", 3) == "active"
    assert sched.submit("b", 3) == "active"
    assert sched.submit("c", 3) == "pending"
    assert (sched.n_active, sched.n_pending) == (2, 1)
    st = sched.stats()
    assert st["admissions"] == 2 and st["spills"] == 1
    assert st["occupancy"] == 1.0


def test_fifo_admission_and_recycling():
    sched, prog = _sched(1)
    sched.submit("a", 2)
    sched.submit("b", 1)
    sched.submit("c", 1)
    while sched.has_work():
        sched.tick()
    assert prog.admitted == ["a", "b", "c"]        # strict FIFO
    # b and c reused a's slot -> reset flag raised on both admissions
    assert [r for _, r in prog.resets] == ["b", "c"]
    st = sched.stats()
    assert st["admissions"] == 3 and st["recycles"] == 2
    assert st["completed"] == 3 and st["active"] == 0


def test_finished_slot_refilled_next_tick_not_same_tick():
    sched, prog = _sched(1)
    sched.submit("a", 1)
    sched.submit("b", 1)
    sched.tick()                       # a finishes, slot freed at tick end
    assert sched.slot_of("b") == -1    # b not yet admitted
    sched.tick()                       # admission happens at tick start
    assert sched.stats()["completed"] == 2


def test_cancel_pending_and_resident():
    sched, prog = _sched(1)
    sched.submit("a", 5)
    sched.submit("b", 5)
    assert sched.cancel("b") is None               # pending: just dequeued
    assert sched.cancel("a") == ("partial", "a")   # resident: program hook
    assert prog.released == [(0, "a", "cancelled")]
    st = sched.stats()
    assert st["cancelled"] == 2 and st["active"] == 0 and st["pending"] == 0
    with pytest.raises(KeyError):
        sched.cancel("a")


def test_duplicate_submit_rejected():
    sched, _ = _sched(2)
    sched.submit("a", 1)
    with pytest.raises(ValueError):
        sched.submit("a", 1)


def test_all_free_policy_is_window_boundary_batching():
    """admit_policy='all_free' only admits when no slot is resident — the
    old LM engine's behaviour, kept as the serve_bench baseline."""
    sched, prog = _sched(2, admit_policy="all_free")
    for rid, n in [("a", 1), ("b", 3), ("c", 1)]:
        sched.submit(rid, n)
    sched.tick()                       # a finishes; b still running
    sched.tick()
    assert sched.slot_of("c") == -1    # free slot exists, but not ALL free
    while sched.has_work():
        sched.tick()
    assert prog.admitted == ["a", "b", "c"]
    assert sched.stats()["completed"] == 3


def test_ticks_count_only_productive_rounds():
    sched, prog = _sched(1)
    sched.submit("a", 2)
    while sched.has_work():
        sched.tick()
    ticks_done = sched.stats()["ticks"]
    sched.tick()                       # empty round: nothing resident
    assert sched.stats()["ticks"] == ticks_done


def test_peak_active_and_request_at():
    sched, prog = _sched(4)
    for i in range(3):
        sched.submit(f"s{i}", 1)
    assert sched.stats()["peak_active"] == 3
    slot = sched.slot_of("s1")
    assert sched.request_at(slot) == "s1"
    while sched.has_work():
        sched.tick()
    assert sched.stats()["peak_active"] == 3


def test_invalid_construction():
    with pytest.raises(ValueError):
        SlotScheduler(0, CountdownProgram(1))
    with pytest.raises(ValueError):
        SlotScheduler(1, CountdownProgram(1), admit_policy="nope")
