"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import compression as comp
from repro.core import quantization as q
from repro.core import lut, warmup
from repro.core import fastgrnn as fg

_settings = settings(max_examples=25, deadline=None)


@_settings
@given(rows=st.integers(1, 12), cols=st.integers(1, 12),
       frac=st.floats(0.0, 1.0), seed=st.integers(0, 1000))
def test_topk_mask_count_invariant(rows, cols, frac, seed):
    x = np.random.default_rng(seed).normal(size=(rows, cols)).astype(np.float32)
    keep = int(round(rows * cols * frac))
    m = comp.topk_mask(jnp.asarray(x), keep)
    assert int(m.sum()) == keep
    # kept values dominate dropped values in magnitude
    kept = np.abs(x)[np.asarray(m)]
    dropped = np.abs(x)[~np.asarray(m)]
    if kept.size and dropped.size:
        assert kept.min() >= dropped.max() - 1e-6


@_settings
@given(scale=st.floats(1e-3, 100.0), seed=st.integers(0, 1000),
       bits=st.sampled_from([8, 16]))
def test_quantize_roundtrip_error_bound(scale, seed, bits):
    qmax = (1 << (bits - 1)) - 1
    w = (np.random.default_rng(seed).normal(size=(17,)) * scale).astype(np.float32)
    qi, s = q.quantize_tensor(jnp.asarray(w), qmax)
    deq = np.asarray(q.dequantize_tensor(qi, s))
    assert np.max(np.abs(deq - w)) <= float(s) / 2 * 1.001 + 1e-12
    assert np.max(np.abs(np.asarray(qi))) <= qmax + 1


@_settings
@given(x=st.floats(-50, 50))
def test_lut_bounded_and_saturates(x):
    t = jnp.asarray(lut.make_lut("tanh"))
    y = float(lut.lut_eval(t, jnp.asarray(x, jnp.float32)))
    assert -1.0 <= y <= 1.0
    if abs(x) >= 8:
        assert abs(y - np.tanh(x)) < 2e-3


@_settings
@given(seed=st.integers(0, 500), T=st.integers(2, 40))
def test_stabilization_step_invariants(seed, T):
    preds = np.random.default_rng(seed).integers(0, 3, T)
    t = warmup.stabilization_step(preds)
    assert 1 <= t <= T
    # by definition, everything from t-1 (0-based) onward equals final
    assert (preds[t - 1:] == preds[-1]).all()
    # and t is minimal: entry t-2 differs (when t > 1)
    if t > 1:
        assert preds[t - 2] != preds[-1]


@_settings
@given(seed=st.integers(0, 100))
def test_hidden_state_bounded_by_gate_algebra(seed):
    """|h_t| <= (zeta + nu) * t * 1 + ... : the two-scalar gate bounds the
    per-step growth of |h| by max(|h_{t-1}|, zeta+nu+|h_{t-1}|) — i.e. h
    cannot blow up faster than linearly in t."""
    cfg = fg.FastGRNNConfig()
    p = fg.init_params(cfg, jax.random.PRNGKey(seed))
    xs = jnp.asarray(np.random.default_rng(seed).normal(
        size=(30, 1, 3)).astype(np.float32) * 5)
    _, traj = fg.run_sequence(p, xs, return_trajectory=True)
    traj = np.asarray(traj)
    zeta = float(jax.nn.sigmoid(p["zeta"]))
    nu = float(jax.nn.sigmoid(p["nu"]))
    bound = (zeta + nu) * np.arange(1, 31) + 1e-4
    assert (np.abs(traj[:, 0]).max(-1) <= bound).all()


@_settings
@given(m=st.integers(1, 40), k=st.integers(1, 64), n=st.integers(1, 40),
       seed=st.integers(0, 100))
def test_q15_matmul_shape_property(m, k, n, seed):
    from repro.kernels.q15_matmul.ops import q15_matmul
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    wq = jnp.asarray(rng.integers(-100, 100, (k, n)), jnp.int8)
    out = q15_matmul(x, wq, 0.01)
    assert out.shape == (m, n)
    assert np.isfinite(np.asarray(out)).all()


@_settings
@given(seed=st.integers(0, 50), b=st.integers(1, 3), s=st.integers(1, 33))
def test_flash_attention_matches_naive(seed, b, s):
    from repro.models.attention import chunked_attention, attention_scores
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q_ = jax.random.normal(ks[0], (b, s, 2, 8))
    k_ = jax.random.normal(ks[1], (b, s, 2, 8))
    v_ = jax.random.normal(ks[2], (b, s, 2, 8))
    ref = attention_scores(q_, k_, v_, causal=True)
    got = chunked_attention(q_, k_, v_, True, None, 8, 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-5)
