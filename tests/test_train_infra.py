"""Training substrate: optimizer, checkpointing (+resharding semantics),
trainer fault tolerance, straggler monitor."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optimizer as opt
from repro.train import checkpoint as ckpt
from repro.train.straggler import StragglerMonitor
from repro.train.trainer import Trainer, TrainerConfig


def test_adam_converges_quadratic():
    cfg = opt.AdamConfig(lr=0.1, warmup_steps=1, weight_decay=0.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params, cfg)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state, m = opt.update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["x"]))) < 0.05


def test_adam_bf16_states_still_converge():
    cfg = opt.AdamConfig(lr=0.1, warmup_steps=1, state_dtype="bfloat16")
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params, cfg)
    assert state["m"]["x"].dtype == jnp.bfloat16
    for _ in range(200):
        params, state, _ = opt.update(params, {"x": 2 * params["x"]}, state, cfg)
    assert float(jnp.max(jnp.abs(params["x"]))) < 0.1


def test_grad_clip_reported():
    cfg = opt.AdamConfig(grad_clip=1.0)
    params = {"x": jnp.zeros(3)}
    state = opt.init(params, cfg)
    _, _, m = opt.update(params, {"x": jnp.asarray([100.0, 0, 0])}, state, cfg)
    assert float(m["grad_norm"]) > 99


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.asarray(3)}}
    d = str(tmp_path)
    ckpt.save(d, 5, tree, metadata={"next_step": 5})
    assert ckpt.latest_step(d) == 5
    out = ckpt.restore(d, 5, jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert ckpt.read_metadata(d, 5)["next_step"] == 5


def test_checkpoint_keep_last(tmp_path):
    d = str(tmp_path)
    for s in range(6):
        ckpt.save(d, s, {"x": jnp.asarray(s)}, keep_last=2)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(steps) == 2 and steps[-1] == "step_00000005"


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 0, {"x": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore(d, 0, {"x": jnp.zeros((3, 3))})


def test_straggler_monitor_flags_outlier():
    m = StragglerMonitor(min_samples=4, abs_floor_s=0.0)
    for _ in range(20):
        m.observe(0.1)
    v = m.observe(0.9)
    assert v["straggler"]
    v2 = m.observe(5.0)
    assert v2["hard_fault"]


def _toy_trainer(tmp_path, fault_at=None, total=12):
    calls = {"n": 0}

    def init_params():
        return {"w": jnp.zeros(4)}

    def step_fn(params, opt_state, batch):
        grads = {"w": params["w"] - batch}
        p, s, m = opt.update(params, grads, opt_state,
                             opt.AdamConfig(lr=0.2, warmup_steps=1))
        return p, s, {"loss": jnp.sum(jnp.square(params["w"] - batch))}

    def batch_fn(step):
        return jnp.full(4, 1.0)

    def fault_hook(step):
        if fault_at is not None and step == fault_at and calls["n"] == 0:
            calls["n"] = 1
            raise RuntimeError("simulated node failure")

    cfg = TrainerConfig(total_steps=total, checkpoint_every=4,
                        checkpoint_dir=str(tmp_path), max_restarts=2,
                        adam=opt.AdamConfig(lr=0.2, warmup_steps=1))
    return Trainer(cfg, init_params_fn=init_params, step_fn=step_fn,
                   batch_fn=batch_fn, fault_hook=fault_hook)


def test_trainer_runs_and_checkpoints(tmp_path):
    t = _toy_trainer(tmp_path)
    hist = t.run()
    steps = [h["step"] for h in hist if "step" in h]
    assert steps == list(range(12))
    assert ckpt.latest_step(str(tmp_path)) == 12


def test_trainer_fault_restart_resumes_exactly(tmp_path):
    t = _toy_trainer(tmp_path, fault_at=6)
    hist = t.run()
    events = [h for h in hist if h.get("event") == "restart"]
    assert len(events) == 1
    steps = [h["step"] for h in hist if "step" in h]
    # steps 0..5 ran, fault at 6, restart resumes from checkpoint at 4
    assert steps == list(range(0, 6)) + list(range(4, 12))
    assert t.restarts == 1
