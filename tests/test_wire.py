"""Wire-format gate for StreamState (``serve/fleet/wire.py``).

The CI-gated determinism contract: encode -> decode -> encode is
byte-identical, and *every* truncation or single-bit corruption of a
valid blob raises a typed :class:`WireError` — the format can refuse,
but it can never hand back silently-wrong stream state.
"""
import struct

import jax
import numpy as np
import pytest

from repro.core import fastgrnn as fg
from repro.core.quantization import QuantConfig, quantize_params
from repro.serve.fleet import wire
from repro.serve.fleet.wire import (WireCorruptError, WireError,
                                    WireTruncatedError, WireVersionError,
                                    decode_stream_state, encode_stream_state)
from repro.serve.streaming import (StreamState, StreamingConfig,
                                   StreamingEngine)


def _state(samples_rows=7, traj_rows=3, total=300, record=True,
           seed=0) -> StreamState:
    rng = np.random.default_rng(seed)
    H, d = 16, 3
    return StreamState(
        stream_id=f"sensor-{seed}",
        h=rng.standard_normal(H).astype(np.float32),
        steps=131, wstep=3, total=total,
        samples=rng.standard_normal((samples_rows, d)).astype(np.float32),
        record_trajectory=record,
        trajectory=[rng.standard_normal(H).astype(np.float32)
                    for _ in range(traj_rows)])


def _assert_states_equal(a: StreamState, b: StreamState) -> None:
    assert a.stream_id == b.stream_id
    assert a.steps == b.steps and a.wstep == b.wstep and a.total == b.total
    assert a.record_trajectory == b.record_trajectory
    np.testing.assert_array_equal(a.h.view(np.int32), b.h.view(np.int32))
    np.testing.assert_array_equal(a.samples.view(np.int32),
                                  b.samples.view(np.int32))
    assert len(a.trajectory) == len(b.trajectory)
    for ra, rb in zip(a.trajectory, b.trajectory):
        np.testing.assert_array_equal(np.asarray(ra).view(np.int32),
                                      np.asarray(rb).view(np.int32))


# ---------------------------------------------------------------------------
# Round trip + determinism (the CI double-encode gate)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("state", [
    _state(),
    _state(samples_rows=0, traj_rows=0, total=None, record=False, seed=1),
    _state(samples_rows=1, traj_rows=0, total=128, seed=2),
], ids=["full", "empty-buffers-open", "one-sample"])
def test_round_trip_bit_exact(state):
    blob = encode_stream_state(state)
    decoded = decode_stream_state(blob)
    _assert_states_equal(decoded, state)
    assert encode_stream_state(decoded) == blob, \
        "double-encode must be byte-identical"


def test_double_encode_of_live_engine_snapshot():
    """The gate on real state: a snapshot taken off a running engine
    double-encodes byte-identically (this is what CI pins)."""
    qp = quantize_params(
        fg.init_params(fg.FastGRNNConfig(rank_w=2, rank_u=8),
                       jax.random.PRNGKey(0)), QuantConfig())
    eng = StreamingEngine(qp, StreamingConfig(max_slots=4))
    rng = np.random.default_rng(0)
    eng.attach("s", rng.standard_normal(
        (40, eng.kernel.input_dim)).astype(np.float32),
        record_trajectory=True)
    for _ in range(17):
        eng.step()
    blob = encode_stream_state(eng.snapshot_stream("s"))
    assert encode_stream_state(decode_stream_state(blob)) == blob
    # snapshotting is non-destructive and stable: same engine state,
    # same bytes
    assert encode_stream_state(eng.snapshot_stream("s")) == blob


def test_snapshot_restores_bit_exact_engine():
    """decode -> import on a fresh engine continues bit-identically —
    the wire format composes with the migration machinery."""
    qp = quantize_params(
        fg.init_params(fg.FastGRNNConfig(rank_w=2, rank_u=8),
                       jax.random.PRNGKey(1)), QuantConfig())
    rng = np.random.default_rng(3)
    cfg = StreamingConfig(max_slots=4)
    a = StreamingEngine(qp, cfg)
    w = rng.standard_normal((200, a.kernel.input_dim)).astype(np.float32)
    a.attach("s", w, total_steps=200)
    for _ in range(90):
        a.step()
    blob = encode_stream_state(a.snapshot_stream("s"))
    b = StreamingEngine(qp, cfg)
    b.import_stream(decode_stream_state(blob))
    rest_a = [e for _ in range(200) for e in a.step()]
    rest_b = [e for _ in range(200) for e in b.step()]
    assert [(e.kind, e.step, e.logits.tobytes()) for e in rest_a] == \
           [(e.kind, e.step, e.logits.tobytes()) for e in rest_b]


# ---------------------------------------------------------------------------
# Refusal: truncation, corruption, versions, trailing bytes
# ---------------------------------------------------------------------------

def test_every_truncation_raises():
    blob = encode_stream_state(_state())
    for n in range(len(blob)):
        with pytest.raises(WireError):
            decode_stream_state(blob[:n])


def test_every_single_bit_flip_raises():
    """Flip each bit of every byte of a valid blob: all 8*len variants
    must raise a typed WireError — no silent garbage state."""
    blob = bytearray(encode_stream_state(
        _state(samples_rows=2, traj_rows=1)))
    for i in range(len(blob)):
        for bit in range(8):
            blob[i] ^= 1 << bit
            with pytest.raises(WireError):
                decode_stream_state(bytes(blob))
            blob[i] ^= 1 << bit
    # sanity: restored blob still decodes
    decode_stream_state(bytes(blob))


def test_trailing_bytes_rejected():
    blob = encode_stream_state(_state())
    with pytest.raises(WireError, match="trailing"):
        decode_stream_state(blob + b"\x00")


def test_wrong_magic_rejected():
    blob = encode_stream_state(_state())
    with pytest.raises(WireError, match="magic"):
        decode_stream_state(b"FGAR" + blob[4:])


def _repack_version(blob: bytes, major: int, minor: int) -> bytes:
    _, _, _, hlen, hcrc = wire._PREAMBLE.unpack_from(blob, 0)
    return wire._PREAMBLE.pack(wire.MAGIC, major, minor, hlen,
                               hcrc) + blob[wire._PREAMBLE.size:]


def test_future_minor_version_rejected_with_clear_message():
    blob = _repack_version(encode_stream_state(_state()),
                           wire.WIRE_MAJOR, wire.WIRE_MINOR + 1)
    with pytest.raises(WireVersionError, match="newer minor.*upgrade"):
        decode_stream_state(blob)


def test_other_major_version_rejected():
    blob = _repack_version(encode_stream_state(_state()),
                           wire.WIRE_MAJOR + 1, 0)
    with pytest.raises(WireVersionError, match="major"):
        decode_stream_state(blob)


def test_header_corruption_is_not_a_payload_error():
    """Flipping a counter bit inside the JSON header trips the *header*
    crc — proving header fields are integrity-checked independently of
    the tensor payload."""
    blob = bytearray(encode_stream_state(_state()))
    idx = bytes(blob).index(b'"steps":131') + len('"steps":13')
    blob[idx] ^= 0x01      # 131 -> 130 in the ASCII digits
    with pytest.raises(WireCorruptError, match="header crc32"):
        decode_stream_state(bytes(blob))


def test_truncated_payload_names_the_shortfall():
    blob = encode_stream_state(_state())
    with pytest.raises(WireTruncatedError, match="payload"):
        decode_stream_state(blob[:-8])
