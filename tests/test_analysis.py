"""repro.analysis: interval domain, qlint prover, detlint linter, report.

Tier-1 pins the same contract CI's static-analysis job gates on:

* the interval domain is exact (checked by brute-force enumeration);
* qlint proves the reference Q15 and Q7 images overflow-free end to
  end, with exactly the two designed load-bearing saturations;
* the live tree is detlint-clean, with every intentional exception a
  recorded suppression rather than silence;
* every seeded-defect mutation fixture is caught by the check it
  targets (a gate that cannot fire gates nothing);
* the report is canonical, byte-deterministic, schema-valid, and the
  committed ``ANALYSIS_report.json`` matches a fresh run.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis import (Assumptions, DETLINT_CHECKS, Interval, Machine,
                            analyze_image, build_report, dumps, lint_source,
                            lint_tree, reference_targets, run_selftest)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def ref_targets():
    return reference_targets()


@pytest.fixture(scope="module")
def tree():
    return lint_tree()


# ---------------------------------------------------------------------------
# interval domain: exact by enumeration
# ---------------------------------------------------------------------------

def test_interval_ops_exact_by_enumeration():
    a, b = Interval(-5, 3), Interval(-2, 7)
    xs = range(a.lo, a.hi + 1)
    ys = range(b.lo, b.hi + 1)
    for op, ref in (("add", lambda x, y: x + y),
                    ("sub", lambda x, y: x - y),
                    ("mul", lambda x, y: x * y)):
        got = getattr(a, op)(b)
        vals = [ref(x, y) for x in xs for y in ys]
        assert (got.lo, got.hi) == (min(vals), max(vals)), op
    for n in (0, 1, 2, 5):
        got = a.shr(n)
        vals = [int(np.int64(x) >> n) for x in xs]   # arithmetic/floor
        assert (got.lo, got.hi) == (min(vals), max(vals)), n
    got = a.neg()
    assert (got.lo, got.hi) == (-3, 5)
    got = a.clip(-2, 1)
    assert (got.lo, got.hi) == (-2, 1)


def test_interval_width_boundaries():
    assert Interval.const(I16 := 32767).bits_needed() == 16
    assert Interval.const(-32768).bits_needed() == 16
    assert Interval.const(I16 + 1).bits_needed() == 17
    assert Interval.of_width(16).fits(16)
    assert not Interval(-32769, 0).fits(16)
    assert Interval(0, 0).bits_needed() == 1
    assert Interval(-(2 ** 62), 2 ** 62).fits(64)
    assert not Interval(-(2 ** 63) - 1, 0).fits(64)


def test_matvec_bound_is_exact():
    """Per-row coefficient-sign bound equals the brute-force corner
    optimum (each v_j chosen independently at an endpoint)."""
    w = np.array([[1, -2], [3, 4]], np.int64)
    v = Interval(-1, 5)
    got = Machine().matvec("t", w, v)
    best_hi = best_lo = None
    for v0 in (v.lo, v.hi):
        for v1 in (v.lo, v.hi):
            for row in w:
                val = int(row[0]) * v0 + int(row[1]) * v1
                best_hi = val if best_hi is None else max(best_hi, val)
                best_lo = val if best_lo is None else min(best_lo, val)
    assert (got.lo, got.hi) == (best_lo, best_hi)


# ---------------------------------------------------------------------------
# qlint: the reference images are proven safe
# ---------------------------------------------------------------------------

def test_reference_q15_and_q7_proved_overflow_free(ref_targets):
    assert {t["name"] for t in ref_targets} == \
        {"reference-q15-s0", "reference-q7-s0"}
    for t in ref_targets:
        assert t["proved_overflow_free"], t["findings"]
        assert t["state_closed"]
        assert t["n_sites"] > 30
        # exactly the two designed load-bearing saturations: the int16
        # state store and the pre-store int64->int32-range bound
        assert t["saturation"]["reachable"] == ["gate.hf_clip", "h_next"]
        for s in t["sites"]:
            assert s["margin_bits"] >= 0, s


def test_acc_width_downgrade_detected():
    """The required accumulator-width-downgrade mutation: the same
    image, declared int32 accumulators — proof must fail."""
    from repro.deploy.goldens import build_reference_artifact
    from repro.deploy.image import build_image
    img = build_image(build_reference_artifact(seed=0, bits=15))
    rec = analyze_image(img, Assumptions(widths={"acc": 32}))
    assert not rec["proved_overflow_free"]
    assert any(f["check"] == "q-acc-width" for f in rec["findings"])


# ---------------------------------------------------------------------------
# detlint: live tree clean, checks fire, suppressions recorded
# ---------------------------------------------------------------------------

def test_live_tree_is_detlint_clean(tree):
    assert tree["findings"] == []
    assert len(DETLINT_CHECKS) == 8
    assert list(tree["checks"]) == list(DETLINT_CHECKS)


def test_live_tree_suppressions_are_the_known_exceptions(tree):
    """Every recorded suppression is one of the two reviewed exception
    families — training/dryrun donation and block-padded window
    kernels — and each carries a reason."""
    sups = tree["suppressions"]
    by_check = {}
    for s in sups:
        by_check.setdefault(s["check"], []).append(s)
        assert s["reason"], s
    assert set(by_check) == {"det-donate-argnums", "det-jit-pallas"}
    assert len(by_check["det-donate-argnums"]) == 5
    assert len(by_check["det-jit-pallas"]) == 4
    assert all(s["where"].startswith(("launch/",))
               for s in by_check["det-donate-argnums"])
    assert all(s["where"].startswith(("kernels/",))
               for s in by_check["det-jit-pallas"])


def test_unsuppressed_defect_found_suppressed_defect_recorded():
    src = ("import jax\n"
           "f = jax.jit(g, donate_argnums=(0,))\n")
    findings, sups = lint_source(src, "serve/x.py")
    assert [f.check for f in findings] == ["det-donate-argnums"]
    src_ok = ("import jax\n"
              "f = jax.jit(g, donate_argnums=(0,))"
              "  # detlint: ignore[det-donate-argnums] reviewed\n")
    findings, sups = lint_source(src_ok, "serve/x.py")
    assert findings == []
    assert len(sups) == 1 and sups[0].reason == "reviewed"


def test_selftest_every_mutation_caught():
    result = run_selftest()
    assert result["ok"], result
    fixtures = result["fixtures"]
    assert len(fixtures) >= 8
    # the two fixtures the acceptance gate names explicitly
    assert fixtures["acc-width-downgrade"]["caught"]
    assert fixtures["seeded-det-donate-argnums"]["caught"]


# ---------------------------------------------------------------------------
# report: canonical, valid, committed copy current
# ---------------------------------------------------------------------------

def test_report_byte_deterministic_and_schema_valid(ref_targets, tree,
                                                    tmp_path):
    from benchmarks.validate_bench import validate
    r1 = dumps(build_report(ref_targets, tree))
    r2 = dumps(build_report(reference_targets(), lint_tree()))
    assert r1 == r2
    p = tmp_path / "ANALYSIS.json"
    p.write_text(r1)
    kind, errors = validate(str(p))
    assert kind == "analysis_report"
    assert errors == []


def test_committed_report_matches_fresh_run(ref_targets, tree):
    """The committed artifact is regenerated by CI and cmp'd; tier-1
    pins the same so a drift is caught before push.  Regenerate with:
    PYTHONPATH=src python -m repro.analysis --report ANALYSIS_report.json
    """
    committed = os.path.join(REPO, "ANALYSIS_report.json")
    assert os.path.exists(committed), "ANALYSIS_report.json not committed"
    with open(committed) as f:
        assert f.read() == dumps(build_report(ref_targets, tree))


def test_cli_detlint_smoke(tmp_path):
    out = tmp_path / "r.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--detlint-only",
         "--fail-on-findings", "--report", str(out)],
        capture_output=True, text=True,
        cwd=REPO, env={**os.environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr
    assert out.exists()
    assert "detlint: " in proc.stderr
