"""repro.compress: composable passes + the versioned ModelArtifact.

Covers the PR-4 acceptance contract:
  * artifact lifecycle — save/load byte-identical round-trip, per-pass
    provenance recorded, pipeline determinism (double-run -> identical
    bytes);
  * Q15 bit-exactness — the artifact path reproduces the historical
    ``(QuantizedParams, act_scales)`` handoff and the checked-in golden
    image byte-for-byte;
  * Q7 generality proof — a ``QuantizePTQ(bits=7)`` artifact exports,
    round-trips through the wire image, and matches the float oracle's
    argmax through the pure-integer qvm;
  * every runtime consumes the artifact (QRuntime / StreamingEngine /
    build_image / run_parity) with identical numerics;
  * the one-release deprecation shims (``quantize_for_serving`` /
    ``dequantize_params`` / legacy 2-arg ``build_image``) are gone and the
    migration path reproduces identical bytes;
  * the ``python -m repro.compress`` CLI smoke + size-report schema.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.compress import (CalibrateActivations, IHTSparsify, LowRankFactor,
                            ModelArtifact, PackLUT, Pipeline, QuantizePTQ,
                            default_deploy_pipeline, dequantize_tree,
                            pipeline_from_config, quantize_tree)
from repro.core import fastgrnn as fg
from repro.core.qruntime import QRuntime, calibrate, calibrate_deploy
from repro.core.quantization import QuantConfig, quantize_params
from repro.data import hapt

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "goldens",
                           "qvm_reference_s0.npz")


def _params(seed=0, low_rank=True):
    import jax
    cfg = fg.FastGRNNConfig(rank_w=2 if low_rank else None,
                            rank_u=8 if low_rank else None)
    return fg.init_params(cfg, jax.random.PRNGKey(seed))


@pytest.fixture(scope="module")
def artifact():
    return default_deploy_pipeline(bits=15).run(
        ModelArtifact.from_params(_params()))


@pytest.fixture(scope="module")
def windows():
    return hapt.load("test", n=96).windows


# ---------------------------------------------------------------------------
# Artifact lifecycle: round-trip, determinism, provenance
# ---------------------------------------------------------------------------

def test_save_load_byte_identical_roundtrip(artifact, tmp_path):
    path = str(tmp_path / "model.fgar")
    blob = artifact.save(path)
    art2 = ModelArtifact.load(path)
    assert art2.to_bytes() == blob
    # and the reloaded artifact re-serializes identically again
    assert ModelArtifact.from_bytes(art2.to_bytes()).to_bytes() == blob
    # contents survive: qp tensors, scales, act scales, luts, provenance
    assert art2.qp.bits == artifact.qp.bits
    for n in artifact.qp.q:
        np.testing.assert_array_equal(np.asarray(art2.qp.q[n]),
                                      np.asarray(artifact.qp.q[n]))
        assert float(np.float32(art2.qp.scales[n])) == \
            float(np.float32(artifact.qp.scales[n]))
    assert art2.act_scales == artifact.act_scales
    assert art2.provenance == artifact.provenance
    for k in artifact.luts:
        np.testing.assert_array_equal(art2.luts[k], artifact.luts[k])


def test_pipeline_double_run_is_byte_identical():
    params = _params()
    pipe = default_deploy_pipeline(bits=15)
    a = pipe.run(ModelArtifact.from_params(params))
    b = pipe.run(ModelArtifact.from_params(params))
    assert a.to_bytes() == b.to_bytes()
    assert a.sha256() == b.sha256()


def test_provenance_records_every_pass(artifact):
    assert artifact.passes_applied() == [
        "source", "quantize_ptq", "calibrate_activations", "pack_lut"]
    recs = {r["pass"]: r for r in artifact.provenance}
    assert recs["source"]["metrics"]["param_count"] > 0
    qrec = recs["quantize_ptq"]
    assert qrec["metrics"]["q_format"] == "Q15"
    assert set(qrec["metrics"]["scales"]) == set(artifact.qp.scales)
    crec = recs["calibrate_activations"]
    assert crec["metrics"]["scope"] == "deploy"
    assert crec["metrics"]["scales"] == dict(sorted(artifact.act_scales.items()))
    assert crec["config"]["windows"] == "hapt:train:5"
    assert recs["pack_lut"]["metrics"]["lut_bytes"] == 2 * 256 * (4 + 2)


def test_sparsify_pass_records_masks_and_sparsity():
    art = Pipeline((IHTSparsify(sparsity=0.5), QuantizePTQ(bits=15))).run(
        ModelArtifact.from_params(_params()))
    rec = [r for r in art.provenance if r["pass"] == "iht_sparsify"][0]
    assert rec["metrics"]["achieved_sparsity"] == pytest.approx(0.5, abs=0.02)
    for name in ("W1", "U1", "U2"):
        m = art.masks[name]
        assert m.dtype == bool
        # masked positions really are zero in the params AND the q tensors
        assert not np.any(np.asarray(art.params[name])[~m])
        assert not np.any(np.asarray(art.qp.q[name])[~m])
    srep = art.size_report()
    assert srep["weight_sparsity"] > 0.3
    assert srep["weight_bytes_packed"] <= srep["weight_bytes_dense"]
    # masks stay boolean through a serialization round-trip (a loaded
    # sparse artifact must support ~mask / boolean fancy-indexing)
    art2 = ModelArtifact.from_bytes(art.to_bytes())
    for name in ("W1", "U1"):
        assert art2.masks[name].dtype == bool
        np.testing.assert_array_equal(art2.masks[name], art.masks[name])


def test_low_rank_pass_factors_dense_checkpoint():
    art = LowRankFactor(rank_w=2, rank_u=8).apply(
        ModelArtifact.from_params(_params(low_rank=False)))
    assert set(art.params) >= {"W1", "W2", "U1", "U2"}
    assert "W" not in art.params and "U" not in art.params
    assert art.params["W1"].shape == (16, 2)
    assert art.params["U1"].shape == (16, 8)
    rec = art.provenance[-1]["metrics"]
    assert rec["rel_err_U"] < 1.0
    # already-factored checkpoints pass through untouched
    art2 = LowRankFactor().apply(ModelArtifact.from_params(_params()))
    assert art2.provenance[-1]["metrics"] == {"skipped": "already factored"}


def test_pass_ordering_errors_are_loud():
    art = ModelArtifact.from_params(_params())
    with pytest.raises(ValueError, match="QuantizePTQ"):
        CalibrateActivations().apply(art)
    with pytest.raises(ValueError, match="bits"):
        QuantizePTQ(bits=4).apply(art)
    with pytest.raises(ValueError, match="unknown pass"):
        pipeline_from_config([{"pass": "nope"}])


# ---------------------------------------------------------------------------
# Q15 bit-exactness across the API migration
# ---------------------------------------------------------------------------

def test_artifact_path_matches_legacy_handoff_bitwise(artifact):
    """The pass pipeline must reproduce the historical direct
    quantize_params + calibrate_deploy handoff exactly."""
    params = _params()
    qp = quantize_params(params, QuantConfig())
    act = calibrate_deploy(QRuntime(qp), hapt.load("train", n=5).windows)
    for n in qp.q:
        np.testing.assert_array_equal(np.asarray(qp.q[n]),
                                      np.asarray(artifact.qp.q[n]))
        assert float(np.float32(qp.scales[n])) == \
            float(np.float32(artifact.qp.scales[n]))
    assert {k: float(v) for k, v in act.items()} == artifact.act_scales


def test_artifact_image_matches_golden_fixture(artifact):
    """build_image(artifact) must be byte-identical to the checked-in
    golden image (produced pre-migration by build_image(qp, act_scales))."""
    from repro.deploy.goldens import load_goldens
    from repro.deploy.image import build_image
    g = load_goldens(GOLDEN_PATH)
    assert build_image(artifact).to_bytes() == \
        bytes(np.asarray(g["image_bytes"], np.uint8))


def test_qruntime_from_artifact_bit_identical(artifact, windows):
    rt_art = QRuntime.from_artifact(artifact)
    rt_leg = QRuntime(artifact.qp)
    for w in windows[:4]:
        a, ta = rt_art.run_window(w, return_trajectory=True)
        b, tb = rt_leg.run_window(w, return_trajectory=True)
        np.testing.assert_array_equal(a.view(np.int32), b.view(np.int32))
        np.testing.assert_array_equal(ta.view(np.int32), tb.view(np.int32))


def test_qruntime_from_artifact_storage_scales(windows):
    """quantized_acts consumes the storage-scope calibration; deploy
    scales alone must not silently enable activation storage quant."""
    art = Pipeline((
        QuantizePTQ(bits=15),
        CalibrateActivations(windows="hapt:train:5", scope="storage"),
    )).run(ModelArtifact.from_params(_params()))
    rt = QRuntime.from_artifact(art, quantized_acts=True)
    legacy = QRuntime(art.qp, act_scales=calibrate(
        QRuntime(art.qp), hapt.load("train", n=5).windows))
    np.testing.assert_array_equal(
        rt.run_window(windows[0]).view(np.int32),
        legacy.run_window(windows[0]).view(np.int32))
    # deploy-scoped artifact has no storage scales -> loud error
    art_deploy = default_deploy_pipeline(bits=15).run(
        ModelArtifact.from_params(_params()))
    with pytest.raises(ValueError, match="storage_scales"):
        QRuntime.from_artifact(art_deploy, quantized_acts=True)


def test_streaming_engine_from_artifact_bit_identical(artifact, windows):
    from repro.serve.streaming import StreamingEngine, StreamingConfig
    eng = StreamingEngine.from_artifact(
        artifact, StreamingConfig(max_slots=8))
    eng.attach("s", windows[0], total_steps=128, record_trajectory=True)
    events = eng.drain()
    rt = QRuntime.from_artifact(artifact)
    lg, traj = rt.run_window(windows[0], return_trajectory=True)
    np.testing.assert_array_equal(events[-1].logits.view(np.int32),
                                  lg.view(np.int32))
    np.testing.assert_array_equal(eng.trajectory("s").view(np.int32),
                                  traj.view(np.int32))


def test_core_pipeline_deploy_matches_legacy(windows):
    """core.pipeline.deploy (now built on the pass API) is numerically
    identical to the historical direct handoff in all three act modes."""
    from repro.core import pipeline as pl
    params = _params()
    calib = hapt.load("train", n=5).windows
    qp = quantize_params(params, QuantConfig())
    legacy = {
        "fp32": QRuntime(qp),
        "naive": QRuntime(qp, naive_acts=True),
        "calibrated": QRuntime(qp, act_scales=calibrate(QRuntime(qp), calib)),
    }
    new = {
        "fp32": pl.deploy(params, calib),
        "naive": pl.deploy(params, calib, naive_activations=True),
        "calibrated": pl.deploy(params, calib, quantize_activations=True),
    }
    for mode in legacy:
        np.testing.assert_array_equal(
            new[mode].run_window(windows[0]).view(np.int32),
            legacy[mode].run_window(windows[0]).view(np.int32), err_msg=mode)


# ---------------------------------------------------------------------------
# Q7: the redesign's generality proof
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def artifact_q7():
    return default_deploy_pipeline(bits=7).run(
        ModelArtifact.from_params(_params()))


def test_q7_artifact_exports_and_roundtrips(artifact_q7):
    from repro.deploy.image import DeployImage, build_image
    assert artifact_q7.qp.bits == 8
    assert artifact_q7.size_report()["q_format"] == "Q7"
    img = build_image(artifact_q7)
    assert img.bits == 8
    blob = img.to_bytes()
    img2 = DeployImage.from_bytes(blob)
    assert img2.bits == 8
    assert img2.to_bytes() == blob
    # Q7 weights halve the packed byte count vs the Q15 artifact
    q15 = default_deploy_pipeline(bits=15).run(
        ModelArtifact.from_params(_params()))
    assert artifact_q7.size_report()["weight_bytes_packed"] * 2 == \
        q15.size_report()["weight_bytes_dense"]


def test_q7_artifact_qvm_argmax_parity(artifact_q7, windows):
    """The Q7 image runs through the UNCHANGED pure-integer qvm (scales
    absorb the weight width) and matches the Q7 float oracle's argmax on
    every confident window."""
    from repro.deploy.qvm import QVM
    from repro.deploy.image import build_image
    vm = QVM(build_image(artifact_q7))
    xq = vm.quantize_input(windows)
    xdeq = vm.dequantize_input(xq)
    preds = np.argmax(vm.run_windows(xq), axis=1)
    rt = QRuntime.from_artifact(artifact_q7)
    ref_lg = np.stack([rt.run_window(w) for w in xdeq])
    ref = np.argmax(ref_lg, axis=1)
    srt = np.sort(ref_lg, axis=1)
    confident = (srt[:, -1] - srt[:, -2]) > 5e-3
    assert confident.sum() > 0
    np.testing.assert_array_equal(preds[confident], ref[confident])
    assert float(np.mean(preds == ref)) >= 0.97


def test_q7_emitted_c_bit_identical_to_qvm(artifact_q7, windows):
    """The C generator needs no Q7 fork either: same plan, same twin."""
    from repro.deploy import emit_c
    from repro.deploy.image import build_image
    from repro.deploy.qvm import QVM
    if emit_c.find_cc() is None:
        pytest.skip("no C compiler")
    import tempfile
    img = build_image(artifact_q7)
    vm = QVM(img)
    xq = vm.quantize_input(windows[:16])
    lg, traces = vm.run_windows(xq, return_trajectory=True)
    with tempfile.TemporaryDirectory() as td:
        binary = emit_c.compile_host(img, td, engine="int")
        cm = emit_c.CHostModel(binary, img.H, img.C, engine="int")
        ctr, clg, _ = cm.trace(xq)
    np.testing.assert_array_equal(ctr, traces)
    np.testing.assert_array_equal(clg, lg)


@pytest.mark.slow
def test_q7_full_protocol_argmax_parity():
    """Acceptance gate: a Q7 artifact of the pinned parity-protocol model
    (verify.PROTOCOL seed) runs through the qvm with near-total argmax
    agreement against its float oracle over the full 3,399-window split."""
    from repro.deploy import verify
    from repro.deploy.goldens import build_reference_artifact
    from repro.deploy.image import build_image
    from repro.deploy.qvm import QVM
    params, calib = verify.protocol_model()
    art = build_reference_artifact(params=params, calib=calib, bits=7)
    vm = QVM(build_image(art))
    test = hapt.load("test")
    assert len(test.windows) == 3399
    xq = vm.quantize_input(test.windows)
    preds = np.argmax(vm.run_windows(xq), axis=1)
    rt = QRuntime.from_artifact(art)
    ref = rt.predict_batch(vm.dequantize_input(xq))
    assert float(np.mean(preds == ref)) >= 0.999


# ---------------------------------------------------------------------------
# Post-deprecation surface (the one-release shims are gone)
# ---------------------------------------------------------------------------

def test_serve_engine_shims_removed():
    """quantize_for_serving / dequantize_params served their one release
    as DeprecationWarning shims; the canonical home is repro.compress."""
    import repro.serve.engine as se
    assert not hasattr(se, "quantize_for_serving")
    assert not hasattr(se, "dequantize_params")


def test_quantize_tree_accepts_q_format_names():
    w = {"w": np.linspace(-2, 2, 8, dtype=np.float32).reshape(2, 4)}
    for alias, width in ((7, np.int8), (8, np.int8), (15, np.int16),
                         (16, np.int16)):
        qt, _ = quantize_tree(w, alias)
        assert np.asarray(qt["w"]).dtype == width


def test_legacy_build_image_pair_rejected(artifact):
    """The 2-arg build_image(qp, act_scales) shim is gone: a bare
    QuantizedParams is rejected with a migration hint, and wrapping the
    pair in a ModelArtifact reproduces the image byte-for-byte."""
    from repro.compress import ModelArtifact
    from repro.deploy.image import build_image
    with pytest.raises(TypeError, match="ModelArtifact"):
        build_image(artifact.qp)
    wrapped = ModelArtifact(qp=artifact.qp,
                            act_scales=dict(artifact.act_scales))
    assert build_image(wrapped).to_bytes() == build_image(artifact).to_bytes()


# ---------------------------------------------------------------------------
# CLI + config loader
# ---------------------------------------------------------------------------

def test_pipeline_from_config_roundtrip():
    cfg = {"name": "custom", "passes": [
        {"pass": "iht_sparsify", "sparsity": 0.25},
        {"pass": "quantize_ptq", "bits": 7},
        {"pass": "calibrate_activations", "windows": "hapt:train:2",
         "scope": "deploy"},
        {"pass": "pack_lut"},
    ]}
    pipe = pipeline_from_config(cfg)
    assert pipe.name == "custom"
    art = pipe.run(ModelArtifact.from_params(_params()))
    assert art.qp.bits == 8
    assert art.passes_applied() == ["source", "iht_sparsify", "quantize_ptq",
                                    "calibrate_activations", "pack_lut"]


def test_cli_emits_deterministic_artifact_and_valid_report(tmp_path):
    """The CI artifact-determinism gate in miniature: two CLI runs produce
    byte-identical artifacts, and the report validates under the
    benchmarks schema."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    outs = []
    for i in (1, 2):
        a, r = str(tmp_path / f"a{i}.fgar"), str(tmp_path / f"r{i}.json")
        res = subprocess.run(
            [sys.executable, "-m", "repro.compress", "--preset", "q15-deploy",
             "--out", a, "--report", r],
            env=env, cwd=repo, capture_output=True, text=True, timeout=300)
        assert res.returncode == 0, res.stderr
        outs.append((a, r))
    blobs = [open(a, "rb").read() for a, _ in outs]
    assert blobs[0] == blobs[1]
    report = json.load(open(outs[0][1]))
    assert report["benchmark"] == "compress_artifact"
    if repo not in sys.path:
        sys.path.insert(0, repo)
    from benchmarks.validate_bench import validate
    kind, errors = validate(outs[0][1])
    assert kind == "compress_artifact" and errors == [], errors
    art = ModelArtifact.load(outs[0][0])
    assert report["sha256"] == art.sha256()
