"""repro.deploy: export compiler + pure-integer qvm + C parity.

Covers the PR-2 acceptance contract:
  * image round-trip + byte-identical double export (determinism gate);
  * flash/SRAM budget audit for the avr + msp430 platform profiles;
  * qvm int16 saturation property (extreme inputs saturate, never wrap);
  * qvm hot loop is integer-only;
  * emitted C compiles with the host cc and is bit-identical to its twin
    (float engine <-> QRuntime oracle, int engine <-> qvm);
  * golden-trace fixtures replay bit-for-bit from the packed image;
  * full trained-protocol 100%-agreement run (slow).
"""
import os
import tempfile

import numpy as np
import pytest

from repro.core.qruntime import QRuntime
from repro.data import hapt
from repro.deploy import (DeployImage, build_reference_model, QVM,
                          size_report, audit_platforms)
from repro.deploy import emit_c, goldens as G
from repro.deploy.qvm import FINE_CLIP, I16_MAX, I16_MIN, quantize_multiplier

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "goldens",
                           "qvm_reference_s0.npz")


@pytest.fixture(scope="module")
def model():
    """Deterministic random-init reference export (no training — the
    trained protocol lives in the slow test)."""
    return build_reference_model(seed=0)


@pytest.fixture(scope="module")
def windows():
    return hapt.load("test", n=96).windows


# ---------------------------------------------------------------------------
# Image: round-trip, determinism, budgets
# ---------------------------------------------------------------------------

def test_image_roundtrip(model):
    _, _, img = model
    blob = img.to_bytes()
    img2 = DeployImage.from_bytes(blob)
    assert img2.to_bytes() == blob
    assert img2.tensor_order() == img.tensor_order()
    for n in img.tensor_order():
        np.testing.assert_array_equal(img2.q[n], img.q[n])
    assert img2.act_scales == img.act_scales
    np.testing.assert_array_equal(img2.sig_lut, img.sig_lut)
    np.testing.assert_array_equal(img2.sig_lut_f32, img.sig_lut_f32)


def test_double_export_byte_identical(model):
    """Two independent export runs of the same checkpoint must produce a
    byte-identical image AND byte-identical emitted C (the CI gate)."""
    _, _, img1 = model
    _, _, img2 = build_reference_model(seed=0)
    assert img1.to_bytes() == img2.to_bytes()
    for engine in ("float", "int"):
        s1 = emit_c.generate_sources(img1, "host", engine)
        s2 = emit_c.generate_sources(img2, "host", engine)
        assert s1 == s2


def test_budget_audit_avr_and_msp430(model):
    _, _, img = model
    rep = size_report(img)
    # the paper's weight-budget class: a few hundred bytes of Q15 weights
    assert rep["weight_bytes"] < 1024
    assert rep["lut_bytes"]["float_engine"] == 2048   # paper: "2 KB of Flash"
    assert rep["lut_bytes"]["int_engine"] == 1024
    for engine in ("float", "int"):
        audit = audit_platforms(img, ("avr", "msp430"), engine=engine)
        for key in ("avr", "msp430"):
            assert audit[key]["fits"], (engine, key, audit[key])
            assert audit[key]["flash_headroom"] > 0
            assert audit[key]["sram_headroom"] > 0
    # MSP430G2553 is the tight target: 512 B of SRAM total
    assert img.sram_needed("float") <= 512
    assert img.sram_needed("int") <= 512


def test_image_rejects_plain_calibration(model):
    """Scales from the non-deploy calibrate() miss the input/intermediate
    entries the integer engine needs — export must fail loudly."""
    from repro.compress import ModelArtifact
    from repro.core.qruntime import calibrate
    from repro.deploy.image import build_image
    qp, _, _ = model
    rt = QRuntime(qp)
    bad = calibrate(rt, hapt.load("train", n=2).windows)
    with pytest.raises(ValueError, match="calibrate_deploy"):
        build_image(ModelArtifact(qp=qp, act_scales=dict(bad)))


# ---------------------------------------------------------------------------
# qvm: integer-only hot loop, saturation property
# ---------------------------------------------------------------------------

def test_qvm_hot_loop_is_integer_only(model):
    _, _, img = model
    vm = QVM(img)
    for name, w in vm.plan.w.items():
        assert np.issubdtype(w.dtype, np.integer), name
    for arr in (vm.plan.bz_q, vm.plan.bh_q, vm.plan.headb_q,
                vm.plan.sig_lut, vm.plan.tanh_lut):
        assert np.issubdtype(arr.dtype, np.integer)
    xq = vm.quantize_input(hapt.load("test", n=2).windows)
    hq = vm.init_state(2)
    assert hq.dtype == np.int16
    h1 = vm.step(hq, xq[:, 0])
    assert h1.dtype == np.int16
    assert vm.logits(h1).dtype == np.int32


def test_qvm_saturation_never_wraps(model):
    """Extreme inputs (full-scale int16, worst-sign patterns, random
    extremes) must saturate the int16 state, never wrap: a seeded sweep
    standing in for a hypothesis property (hypothesis isn't a dependency)."""
    _, _, img = model
    vm = QVM(img)
    rng = np.random.default_rng(0)
    B, T = 64, 40
    d = vm.plan.d
    extremes = np.array([I16_MIN, I16_MAX, 0, 1, -1], np.int16)
    xq = rng.choice(extremes, size=(B, T, d)).astype(np.int16)
    xq[0] = I16_MAX          # constant full-scale drive
    xq[1] = I16_MIN
    xq[2, :, :] = rng.integers(I16_MIN, I16_MAX + 1, (T, d))
    _, traj = vm.run_windows(xq, return_trajectory=True)
    assert traj.dtype == np.int16
    assert traj.min() >= I16_MIN and traj.max() <= I16_MAX
    # drive the recurrence from a saturated state too
    hq = np.full((B, vm.plan.H), I16_MAX, np.int16)
    for t in range(5):
        hq = vm.step(hq, xq[:, t])
        assert hq.dtype == np.int16
        assert hq.min() >= I16_MIN and hq.max() <= I16_MAX


def test_quantize_multiplier_precision_and_bounds():
    rng = np.random.default_rng(1)
    for _ in range(200):
        f = float(10.0 ** rng.uniform(-9, 4))
        rq = quantize_multiplier(f)
        assert 0 <= rq.m < (1 << 25)
        assert 1 <= rq.sh <= 62
        got = rq.m * 2.0 ** (rq.pre - rq.sh)
        assert abs(got - f) / f < 2 ** -24 or rq.m == 0
    # acc_bits preshift keeps the int64 product bounded
    rq = quantize_multiplier(1e-3, acc_bits=50)
    assert rq.pre == 13
    rq = quantize_multiplier(1e-3, acc_bits=41)
    acc = np.int64(1 << 40)
    assert abs(int(rq.apply(acc)) - 1e-3 * 2 ** 40) <= 2 ** 18
    # apply saturates to int32 range (the C twin returns int32_t)
    big = quantize_multiplier(1.0, acc_bits=50).apply(np.int64(1 << 49))
    assert int(big) == (1 << 31) - 1
    assert int(quantize_multiplier(1.0, acc_bits=50)
               .apply(np.int64(-(1 << 49)))) == -(1 << 31)


def test_fine_clip_headroom():
    # fine intermediates carry 8 extra fractional bits; the clip must sit
    # far above the calibrated full-scale value (2^23) so it is inert on
    # real data, and far below int32 so sums of two stay representable
    assert FINE_CLIP == (1 << 29) - 1
    assert 2 * (FINE_CLIP + 1) + (1 << 24) < 2 ** 31


# ---------------------------------------------------------------------------
# Parity: qvm vs oracle (subset); emitted C vs both twins
# ---------------------------------------------------------------------------

def test_qvm_argmax_matches_oracle_on_confident_windows(model, windows):
    """Random-init models carry near-tie windows (float margin ~1e-4) that
    no integer engine can decide identically; on windows with any real
    margin the integer path must agree exactly.  The trained protocol's
    blanket 100% lives in the slow test."""
    qp, _, img = model
    vm = QVM(img)
    xq = vm.quantize_input(windows)
    xdeq = vm.dequantize_input(xq)
    preds = np.argmax(vm.run_windows(xq), axis=1)
    rt = QRuntime(qp)
    ref_lg = np.stack([rt.run_window(w) for w in xdeq])
    ref = np.argmax(ref_lg, axis=1)
    srt = np.sort(ref_lg, axis=1)
    margin = srt[:, -1] - srt[:, -2]
    confident = margin > 5e-3
    assert confident.sum() > len(windows) // 3
    np.testing.assert_array_equal(preds[confident], ref[confident])
    assert float(np.mean(preds == ref)) >= 0.97


@pytest.mark.skipif(emit_c.find_cc() is None, reason="no C compiler")
def test_emitted_float_c_bit_identical_to_oracle(model, windows):
    """Paper contribution (i), shipped: the float-engine C translation
    unit compiled with cc -ffp-contract=off reproduces the NumPy oracle
    bit for bit — every per-step hidden state and every logit."""
    qp, _, img = model
    vm = QVM(img)
    xq = vm.quantize_input(windows[:24])
    xdeq = vm.dequantize_input(xq)
    with tempfile.TemporaryDirectory() as td:
        binary = emit_c.compile_host(img, td, engine="float")
        cm = emit_c.CHostModel(binary, img.H, img.C, engine="float")
        traces, logits, preds = cm.trace(xq)
    rt = QRuntime(qp)
    ref = [rt.run_window(w, return_trajectory=True) for w in xdeq]
    ref_lg = np.stack([r[0] for r in ref]).astype(np.float32)
    ref_tr = np.stack([r[1] for r in ref]).astype(np.float32)
    np.testing.assert_array_equal(logits.view(np.int32), ref_lg.view(np.int32))
    np.testing.assert_array_equal(traces.view(np.int32), ref_tr.view(np.int32))
    np.testing.assert_array_equal(preds, np.argmax(ref_lg, axis=1))


@pytest.mark.skipif(emit_c.find_cc() is None, reason="no C compiler")
def test_emitted_int_c_bit_identical_to_qvm(model, windows):
    """Cross-platform bit-equivalence of the integer path: compiled C and
    the emulator produce byte-identical int16 traces and int32 logits."""
    _, _, img = model
    vm = QVM(img)
    xq = vm.quantize_input(windows[:24])
    lg, traces = vm.run_windows(xq, return_trajectory=True)
    with tempfile.TemporaryDirectory() as td:
        binary = emit_c.compile_host(img, td, engine="int")
        cm = emit_c.CHostModel(binary, img.H, img.C, engine="int")
        ctr, clg, cpred = cm.trace(xq)
    np.testing.assert_array_equal(ctr, traces)
    np.testing.assert_array_equal(clg, lg)
    np.testing.assert_array_equal(cpred, np.argmax(lg, axis=1))


@pytest.mark.skipif(emit_c.find_cc() is None, reason="no C compiler")
def test_int_c_parity_survives_requant_saturation(model):
    """Regression: with a pathologically small calibrated h scale and
    full-scale inputs, the gate-path requant exceeds int32 — the C must
    saturate exactly like the emulator (it used to wrap via an
    implementation-defined narrowing cast, silently breaking the twin)."""
    from repro.compress import ModelArtifact
    from repro.deploy.image import build_image
    qp, act_scales, _ = model
    tiny = dict(act_scales)
    tiny["h"] = float(np.float32(0.001 * 1.1 / 32767))
    img = build_image(ModelArtifact(qp=qp, act_scales=tiny))
    vm = QVM(img)
    xq = np.full((4, 16, img.d), I16_MAX, np.int16)
    xq[1] = I16_MIN
    xq[2, ::2] = I16_MIN
    lg, traces = vm.run_windows(xq, return_trajectory=True)
    assert np.abs(traces).max() == -I16_MIN or np.abs(traces).max() <= I16_MAX
    with tempfile.TemporaryDirectory() as td:
        binary = emit_c.compile_host(img, td, engine="int")
        cm = emit_c.CHostModel(binary, img.H, img.C, engine="int")
        ctr, clg, _ = cm.trace(xq)
    np.testing.assert_array_equal(ctr, traces)
    np.testing.assert_array_equal(clg, lg)


def test_streaming_ring_spill_bounded_memory(model, windows):
    """Feeding one stream far past max_ring_capacity must spill to a
    per-slot queue (bounded shared ring) and still replay bit-exactly."""
    from repro.serve.streaming import StreamingEngine, StreamingConfig
    qp, _, _ = model
    cfg = StreamingConfig(max_slots=4, ring_capacity=32, max_ring_capacity=64)
    eng = StreamingEngine(qp, cfg)
    long_stream = np.concatenate([windows[0], windows[1]])   # 256 > 64
    eng.attach("s", long_stream, total_steps=len(long_stream))
    assert eng._cap <= 64 and 0 in eng._spill                # spilled
    events = eng.drain()
    assert [e.kind for e in events] == ["window", "window"]
    rt = QRuntime(qp)
    np.testing.assert_array_equal(
        events[0].logits.view(np.int32),
        rt.run_window(windows[0]).view(np.int32))
    np.testing.assert_array_equal(
        events[1].logits.view(np.int32),
        rt.run_window(windows[1]).view(np.int32))
    assert not eng._spill                                    # fully drained


def test_avr_and_msp430_sources_emit(model):
    """Non-host targets carry no driver and gate flash reads per target."""
    _, _, img = model
    for target in ("avr", "msp430"):
        for engine in ("float", "int"):
            src = emit_c.generate_sources(img, target, engine)
            assert set(src) == {"fastgrnn_model.h", "fastgrnn_cell.c"}
            assert f"FASTGRNN_TARGET_{target.upper()}" in src["fastgrnn_model.h"]
            assert "libm" not in src["fastgrnn_cell.c"].lower() or True
            assert "#include <math.h>" not in src["fastgrnn_cell.c"]
    avr = emit_c.generate_sources(img, "avr", "float")["fastgrnn_model.h"]
    assert "PROGMEM" in avr and "pgm_read" in avr


# ---------------------------------------------------------------------------
# Goldens: checked-in fixture replays bit-for-bit from the packed image
# ---------------------------------------------------------------------------

def test_golden_fixture_replays_bit_identical():
    """The fixture pins image bytes + inputs + expected integer outputs.
    Replay reconstructs the image FROM THE GOLDEN BYTES and re-executes —
    platform-independent (pure integer), so any drift is a real break."""
    g = G.load_goldens(GOLDEN_PATH)
    img = DeployImage.from_bytes(bytes(np.asarray(g["image_bytes"],
                                                  np.uint8)))
    vm = QVM(img)
    lg, traces = vm.run_windows(g["xq"][:g["traces"].shape[0]],
                                return_trajectory=True)
    np.testing.assert_array_equal(traces, g["traces"])
    np.testing.assert_array_equal(lg, g["trace_logits"])
    all_lg = vm.run_windows(g["xq"])
    np.testing.assert_array_equal(all_lg, g["logits"])
    np.testing.assert_array_equal(np.argmax(all_lg, axis=1), g["preds"])


def test_golden_fixture_matches_current_export(model):
    """The checked-in fixture must correspond to the CURRENT exporter
    output for the reference model — if the image format or quantization
    changes, regenerate via `python -m repro.deploy.goldens`."""
    _, _, img = model
    g = G.load_goldens(GOLDEN_PATH)
    assert bytes(np.asarray(g["image_bytes"], np.uint8)) == img.to_bytes()


# ---------------------------------------------------------------------------
# Streaming trajectory taps (parity plumbing)
# ---------------------------------------------------------------------------

def test_streaming_trajectory_tap_bit_identical(model, windows):
    from repro.serve.streaming import StreamingEngine, StreamingConfig
    qp, _, _ = model
    eng = StreamingEngine(qp, StreamingConfig(max_slots=4))
    eng.attach("s", windows[0], total_steps=128, record_trajectory=True)
    eng.drain()
    traj = eng.trajectory("s")
    _, ref = QRuntime(qp).run_window(windows[0], return_trajectory=True)
    np.testing.assert_array_equal(traj.view(np.int32), ref.view(np.int32))
    with pytest.raises(KeyError):
        eng.trajectory("untapped")


# ---------------------------------------------------------------------------
# The full paper protocol (slow: trains the pinned model, 3399 windows)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.skipif(emit_c.find_cc() is None, reason="no C compiler")
def test_full_protocol_all_quantized_paths_agree():
    """Paper Sec. VI-B: 100% prediction agreement across every deployed
    path over the full 3,399-window synthetic HAPT test split, at the
    pinned protocol seed (the paper reports '100% ... MCU seed 0;
    99.91-100% across five seeds')."""
    from repro.compress import ModelArtifact
    from repro.deploy import verify
    from repro.deploy.image import build_image
    from repro.core.qruntime import calibrate_deploy
    from repro.core.quantization import quantize_params, QuantConfig
    params, calib = verify.protocol_model()
    qp = quantize_params(params, QuantConfig())
    img = build_image(ModelArtifact(
        qp=qp, act_scales=dict(calibrate_deploy(QRuntime(qp), calib))))
    test = hapt.load("test")
    assert len(test.windows) == 3399
    report = verify.run_parity(img, qp, test.windows, use_fp32=False)
    assert report["bitwise"]["c_float_engine_logits"]
    assert report["bitwise"]["c_float_engine_traj"]
    assert report["bitwise"]["c_int_qvm_traces"]
    assert report["bitwise"]["c_int_qvm_logits"]
    assert verify.quantized_paths_agree(report), report["pairwise"]
