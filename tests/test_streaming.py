"""Multi-stream streaming engine: slot-based continuous batching over the
batched Q15 single-step kernel, with the paper's bit-exactness contract
(Sec. IV-D / Table VI) lifted to batch scale — every stream must match the
scalar C-equivalent ``core/qruntime.QRuntime`` bit for bit."""
import jax
import numpy as np
import pytest

from repro.core import fastgrnn as fg
from repro.core.qruntime import QRuntime, calibrate
from repro.core.quantization import quantize_params, QuantConfig
from repro.data import hapt
from repro.serve.streaming import (StreamingEngine, StreamingConfig,
                                   classify_windows)


def _model(low_rank=True, seed=0):
    cfg = fg.FastGRNNConfig(rank_w=2 if low_rank else None,
                            rank_u=8 if low_rank else None)
    params = fg.init_params(cfg, jax.random.PRNGKey(seed))
    return quantize_params(params, QuantConfig())


@pytest.fixture(scope="module")
def qp():
    return _model()


@pytest.fixture(scope="module")
def windows():
    return hapt.load("test", n=1100).windows


# ---------------------------------------------------------------------------
# Acceptance: >= 1024 concurrent streams, bit-identical to the scalar path
# ---------------------------------------------------------------------------

def test_1024_concurrent_streams_bit_identical(qp, windows):
    w = windows[:1024]
    eng = StreamingEngine(qp, StreamingConfig(max_slots=1024))
    for i in range(1024):
        eng.attach(f"s{i}", w[i], total_steps=len(w[i]))
    assert eng.n_active == 1024              # all resident at once
    events = eng.drain()
    by_id = {e.stream_id: e for e in events}
    assert len(by_id) == 1024

    rt = QRuntime(qp)
    ref_logits = np.stack([rt.run_window(x) for x in w])
    got_logits = np.stack([by_id[f"s{i}"].logits for i in range(1024)])
    # bit-identical logits -> bit-identical predictions (the paper's
    # cross-platform agreement contract at batch scale)
    np.testing.assert_array_equal(got_logits.view(np.int32),
                                  ref_logits.view(np.int32))
    got_pred = np.array([by_id[f"s{i}"].prediction for i in range(1024)])
    np.testing.assert_array_equal(got_pred, np.argmax(ref_logits, axis=-1))
    assert eng.stats()["stream_steps"] == 1024 * 128


def test_full_rank_bit_identical(windows):
    qp = _model(low_rank=False)
    w = windows[:40]
    eng = StreamingEngine(qp, StreamingConfig(max_slots=40))
    preds = classify_windows(eng, w)
    np.testing.assert_array_equal(preds, QRuntime(qp).predict_batch(w))


# ---------------------------------------------------------------------------
# Continuous batching: slot recycling through the pending queue
# ---------------------------------------------------------------------------

def test_slot_recycling_pending_queue(qp, windows):
    w = windows[:96]
    eng = StreamingEngine(qp, StreamingConfig(max_slots=32))
    statuses = [eng.attach(f"s{i}", w[i], total_steps=128) for i in range(96)]
    assert statuses.count("active") == 32 and statuses.count("pending") == 64
    events = eng.drain()
    preds = {e.stream_id: e.prediction for e in events}
    ref = QRuntime(qp).predict_batch(w)
    np.testing.assert_array_equal(
        np.array([preds[f"s{i}"] for i in range(96)]), ref)
    st = eng.stats()
    assert st["peak_active"] == 32           # never exceeded the slot budget
    assert st["completed"] == 96             # every queued stream finished
    assert st["ticks"] == 3 * 128            # 3 generations of 32 windows


def test_attach_respects_pending_fifo(qp, windows):
    """A new attach must not jump the queue when a slot frees up while
    earlier streams are still pending."""
    eng = StreamingEngine(qp, StreamingConfig(max_slots=1))
    eng.attach("a", windows[0], total_steps=128)
    assert eng.attach("b", windows[1], total_steps=128) == "pending"
    for _ in range(128):
        eng.step()                       # "a" finishes, slot frees
    assert eng.attach("c", windows[2], total_steps=128) == "pending"
    eng.step()                           # admission happens at tick start
    assert eng._sessions["b"].slot >= 0  # b (FIFO head) got the slot
    assert eng._sessions["c"].slot == -1


def test_attach_beyond_slots_is_pending_until_free(qp, windows):
    eng = StreamingEngine(qp, StreamingConfig(max_slots=2))
    assert eng.attach("a", windows[0], total_steps=128) == "active"
    assert eng.attach("b", windows[1], total_steps=128) == "active"
    assert eng.attach("c", windows[2], total_steps=128) == "pending"
    assert (eng.n_active, eng.n_pending) == (2, 1)
    eng.drain()
    assert (eng.n_active, eng.n_pending) == (0, 0)


# ---------------------------------------------------------------------------
# Session lifecycle
# ---------------------------------------------------------------------------

def test_detach_midwindow_emits_partial_final(qp, windows):
    eng = StreamingEngine(qp, StreamingConfig(max_slots=4))
    eng.attach("s", windows[0][:50])
    eng.drain()
    ev = eng.detach("s")
    assert ev is not None and ev.kind == "final"
    assert ev.step == 50 and ev.window_step == 50
    assert not ev.warm                        # below the 74-sample warm-up
    # the partial-window logits equal the scalar trajectory at t=50
    rt = QRuntime(qp)
    h = np.zeros(16, np.float32)
    for t in range(50):
        h = rt.step(h, windows[0][t])
    from repro.core.qruntime import _matvec
    ref = _matvec(rt._w["head_w"].T, h) + rt._head_b
    np.testing.assert_array_equal(ev.logits.view(np.int32), ref.view(np.int32))


def test_idle_slots_hold_state_bit_for_bit(qp, windows):
    """A stream fed in chunks with idle ticks in between must be
    indistinguishable from an uninterrupted replay."""
    eng = StreamingEngine(qp, StreamingConfig(max_slots=4))
    eng.attach("s", windows[0][:30], total_steps=128)
    eng.attach("busy", windows[1], total_steps=128)  # keeps ticks running
    for _ in range(70):                      # 30 real steps + 40 idle ticks
        eng.step()
    eng.feed("s", windows[0][30:])
    events = eng.drain()
    ev = [e for e in events if e.stream_id == "s"][0]
    ref = QRuntime(qp).run_window(windows[0])
    np.testing.assert_array_equal(ev.logits.view(np.int32), ref.view(np.int32))


def test_warmup_counter_and_flags(qp, windows):
    cfgs = StreamingConfig(max_slots=2, warmup_samples=74)
    eng = StreamingEngine(qp, cfgs)
    eng.attach("cold", windows[0][:40], total_steps=40)
    eng.attach("warmish", windows[1], total_steps=128)
    events = eng.drain()
    cold = [e for e in events if e.stream_id == "cold"][0]
    warm = [e for e in events if e.stream_id == "warmish"][0]
    assert cold.kind == "final" and cold.step == 40 and not cold.warm
    assert warm.kind == "window" and warm.step == 128 and warm.warm


def test_multi_window_stream_tumbling(qp, windows):
    """An open-ended stream emits one window event per 128 samples; with
    reset_on_emit each window matches an independent scalar window."""
    eng = StreamingEngine(qp, StreamingConfig(max_slots=2))
    eng.attach("s")
    for k in range(3):
        eng.feed("s", windows[k])
    events = eng.drain()
    assert [e.kind for e in events] == ["window"] * 3
    assert [e.step for e in events] == [128, 256, 384]
    rt = QRuntime(qp)
    for k, e in enumerate(events):
        np.testing.assert_array_equal(
            e.logits.view(np.int32), rt.run_window(windows[k]).view(np.int32))
    eng.detach("s")
    assert eng.n_active == 0


def test_duplicate_attach_rejected(qp):
    eng = StreamingEngine(qp, StreamingConfig(max_slots=2))
    eng.attach("s")
    with pytest.raises(ValueError):
        eng.attach("s")


# ---------------------------------------------------------------------------
# Edge cases the scheduler refactor must not break
# ---------------------------------------------------------------------------

def test_feed_after_detach_raises(qp, windows):
    eng = StreamingEngine(qp, StreamingConfig(max_slots=2))
    eng.attach("s", windows[0][:10])
    eng.drain()
    eng.detach("s")
    with pytest.raises(KeyError):
        eng.feed("s", windows[0][10:20])
    with pytest.raises(KeyError):
        eng.detach("s")                      # double detach


def test_duplicate_attach_rejected_while_pending(qp, windows):
    """A stream waiting in the pending queue still owns its id."""
    eng = StreamingEngine(qp, StreamingConfig(max_slots=1))
    eng.attach("a", windows[0], total_steps=128)
    assert eng.attach("b", windows[1], total_steps=128) == "pending"
    with pytest.raises(ValueError):
        eng.attach("b", windows[1])
    ev = eng.detach("b")                     # detach while pending: no event
    assert ev is None
    eng.attach("b", windows[1], total_steps=128)   # id reusable afterwards
    events = eng.drain()
    by_id = {e.stream_id: e for e in events}
    ref = QRuntime(qp)
    np.testing.assert_array_equal(
        by_id["b"].logits.view(np.int32),
        ref.run_window(windows[1]).view(np.int32))


def test_ring_growth_under_spill_pressure(qp, windows):
    """Feed one stream far beyond max_ring_capacity: the ring grows to its
    cap, the overflow spills to the chunk queue, drains back as the ring
    frees — and the result is still bit-identical to the scalar replay."""
    cfg = StreamingConfig(max_slots=2, ring_capacity=8, max_ring_capacity=32)
    eng = StreamingEngine(qp, cfg)
    stream = np.concatenate([windows[k] for k in range(3)])   # 384 samples
    eng.attach("s")
    eng.feed("s", stream)                    # 384 >> 32: deep backlog
    st = eng.stats()
    assert st["ring_capacity"] == 32         # grew 8 -> 32 and capped
    assert st["ring_spills"] >= 1            # overflow hit the spill queue
    events = eng.drain()
    assert [e.kind for e in events] == ["window"] * 3
    rt = QRuntime(qp)
    for k, e in enumerate(events):
        np.testing.assert_array_equal(
            e.logits.view(np.int32), rt.run_window(windows[k]).view(np.int32))
    assert eng.stats()["stream_steps"] == 384


def test_drain_with_empty_pending_queue(qp, windows):
    eng = StreamingEngine(qp, StreamingConfig(max_slots=2))
    assert eng.drain() == []                 # nothing attached at all
    eng.attach("idle")                       # attached but never fed
    assert eng.drain() == []
    assert eng.n_active == 1 and eng.n_pending == 0


def test_scheduler_counters_surfaced_in_stats(qp, windows):
    eng = StreamingEngine(qp, StreamingConfig(max_slots=2))
    for i in range(4):
        eng.attach(f"s{i}", windows[i], total_steps=128)
    eng.drain()
    st = eng.stats()
    sched = st["scheduler"]
    assert sched["admissions"] == 4
    assert sched["recycles"] == 2            # generation 2 reused slots
    assert sched["spills"] == 2              # two streams had to queue
    assert sched["completed"] == 4
    assert sched["occupancy"] == 0.0         # everything finished
    assert st["completed"] == 4 and st["peak_active"] == 2


# ---------------------------------------------------------------------------
# Activation-storage modes (Table V) ride through the batched path
# ---------------------------------------------------------------------------

def test_calibrated_act_quant_matches_scalar(qp, windows):
    rt = QRuntime(qp)
    scales = calibrate(rt, windows[:5])
    eng = StreamingEngine(qp, StreamingConfig(max_slots=8), act_scales=scales)
    preds = classify_windows(eng, windows[:8])
    ref = QRuntime(qp, act_scales=scales).predict_batch(windows[:8])
    np.testing.assert_array_equal(preds, ref)


def test_naive_act_quant_matches_scalar(qp, windows):
    eng = StreamingEngine(qp, StreamingConfig(max_slots=8), naive_acts=True)
    preds = classify_windows(eng, windows[:8])
    ref = QRuntime(qp, naive_acts=True).predict_batch(windows[:8])
    np.testing.assert_array_equal(preds, ref)


# ---------------------------------------------------------------------------
# Fast backends: same predictions, relaxed bit contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jit", "pallas"])
def test_fast_backends_agree_on_predictions(qp, windows, backend):
    n = 48 if backend == "jit" else 16
    eng = StreamingEngine(
        qp, StreamingConfig(max_slots=16, backend=backend))
    preds = classify_windows(eng, windows[:n])
    ref = QRuntime(qp).predict_batch(windows[:n])
    assert float(np.mean(preds == ref)) == 1.0


def test_float_params_quantized_on_entry(windows):
    """The engine accepts a float param pytree and applies Appendix-B PTQ."""
    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    params = fg.init_params(cfg, jax.random.PRNGKey(1))
    eng = StreamingEngine(params, StreamingConfig(max_slots=4))
    preds = classify_windows(eng, windows[:4])
    qp = quantize_params(params, QuantConfig())
    np.testing.assert_array_equal(preds, QRuntime(qp).predict_batch(windows[:4]))
