"""Observability subsystem gate (repro.obs): tracer, metrics registry,
flight recorder, conservation invariant, and their engine integrations.

Contracts locked in here:

* **NullTracer is free** — zero allocations per hot-path call, so the
  default (untraced) serving path is untouched to the byte.
* **Histogram bucket edges** — the fixed log2 ladder is exact at edges
  (``searchsorted side="left"``: an observation equal to an edge lands
  in that edge's bucket).
* **Deterministic snapshots** — ``snapshot(deterministic=True)`` /
  ``FlightRecorder.dumps(deterministic=True)`` are byte-identical across
  identical runs (wall-clock fields stripped), including under the full
  phase x shard ``crash_matrix`` fault schedule.
* **Conservation invariant** — the shared production implementation
  (``obs.invariants``) both powers the fault-harness assertion and
  trips loudly in debug-mode ``FleetEngine.stats()``.
* **O(shards) stats** — ``FleetEngine.stats()`` never walks per-stream
  containers (regression test poisons them).
"""
import json
import tracemalloc

import jax
import numpy as np
import pytest

from faultharness import make_streams, run_crash_schedule
from repro.core import fastgrnn as fg
from repro.core.quantization import QuantConfig, quantize_params
from repro.obs import (BUCKET_EDGES_US, NULL_OBS, NULL_TRACER, FlightRecorder,
                       Histogram, MetricsRegistry, Observability, Tracer,
                       check_conservation, merge_histogram_counts,
                       validate_snapshot)
from repro.serve.fleet import FleetConfig, FleetEngine, crash_matrix
from repro.serve.streaming import StreamingConfig, StreamingEngine


@pytest.fixture(scope="module")
def qp():
    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    return quantize_params(fg.init_params(cfg, jax.random.PRNGKey(0)),
                           QuantConfig())


@pytest.fixture(scope="module")
def input_dim(qp):
    return StreamingEngine(qp, StreamingConfig(max_slots=1)).kernel.input_dim


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_tracer_records_spans_and_phase_stats():
    tr = Tracer(capacity=16)
    tr.set_tick(3)
    for _ in range(5):
        t0 = tr.t()
        tr.rec("phase.a", t0, shard=1)
    t0 = tr.t()
    tr.rec("phase.b", t0)
    st = tr.phase_stats()
    assert set(st) == {"phase.a", "phase.b"}
    assert st["phase.a"]["count"] == 5
    assert st["phase.b"]["count"] == 1
    for s in st.values():
        assert s["p50_us"] >= 0 and s["p99_us"] >= s["p50_us"] >= 0
    fl = tr.flight()
    assert len(fl) == 6
    assert fl[0]["phase"] == "phase.a" and fl[0]["shard"] == 1
    assert all(rec["tick"] == 3 for rec in fl)
    assert [rec["seq"] for rec in fl] == list(range(6))


def test_tracer_ring_wraps_without_growth():
    tr = Tracer(capacity=8)
    for i in range(50):
        tr.rec("p", tr.t())
    assert len(tr.flight()) == 8                      # bounded
    assert [r["seq"] for r in tr.flight()] == list(range(42, 50))
    assert tr.phase_stats()["p"]["count"] == 50       # monotonic total


def test_tracer_deterministic_flight_strips_wallclock():
    tr = Tracer(capacity=8)
    tr.rec("p", tr.t(), shard=2)
    det = tr.flight(deterministic=True)[0]
    assert set(det) == {"seq", "tick", "phase", "shard"}
    full = tr.flight()[0]
    assert "t0_us" in full and "dur_us" in full


def test_tracer_span_context_manager():
    tr = Tracer()
    with tr.span("ctx.phase", shard=4) as sp:
        pass
    assert sp.dur_ns > 0
    assert tr.flight()[-1]["phase"] == "ctx.phase"
    assert tr.flight()[-1]["shard"] == 4
    assert tr.totals_s()["ctx.phase"] > 0


def test_null_tracer_is_allocation_free():
    """The disabled path must not allocate: this is what keeps the
    bit-exact fast path untouched when obs is off."""
    tr = NULL_TRACER
    # warm up (interned small ints, method caches)
    for _ in range(10):
        tr.rec("x", tr.t(), 0)
        tr.set_tick(1)
        with tr.span("x"):
            pass
    def burst(n):
        for _ in range(n):
            t0 = tr.t()
            tr.rec("engine.tick", t0, 3)
            tr.set_tick(7)

    def leaked_by(n):
        before, _ = tracemalloc.get_traced_memory()
        burst(n)
        after, _ = tracemalloc.get_traced_memory()
        return after - before

    tracemalloc.start()
    try:
        burst(100)                            # warm tracemalloc itself
        small, big = leaked_by(1000), leaked_by(10000)
    finally:
        tracemalloc.stop()
    # a constant few-bytes residue (interpreter internals) is tolerated;
    # what is forbidden is growth proportional to the number of calls
    assert big <= small + 64, (
        f"NullTracer allocates per call: {small}B/1k vs {big}B/10k calls")


# ---------------------------------------------------------------------------
# Metrics: histogram edges, registry, snapshots, exporters
# ---------------------------------------------------------------------------

def test_histogram_bucket_edges_exact():
    h = Histogram("t")
    # an observation exactly on an edge lands in that edge's bucket
    for k, edge in enumerate(BUCKET_EDGES_US):
        h2 = Histogram("e")
        h2.observe_us(edge)
        assert h2.counts[k] == 1, f"edge {edge} fell in bucket {np.argmax(h2.counts)}"
    # just above an edge -> next bucket; overflow -> +inf bucket
    h.observe_us(BUCKET_EDGES_US[0] + 0.5)
    assert h.counts[1] == 1
    h.observe_us(BUCKET_EDGES_US[-1] * 10)
    assert h.counts[-1] == 1
    # 0 lands in the first bucket
    h.observe_us(0.0)
    assert h.counts[0] == 1


def test_histogram_vectorized_matches_scalar():
    rng = np.random.default_rng(0)
    vals = rng.uniform(0, 4e6, 500)
    a, b = Histogram("a"), Histogram("b")
    for v in vals:
        a.observe_us(float(v))
    b.observe_many_us(vals)
    np.testing.assert_array_equal(a.counts, b.counts)
    assert a.count == b.count == 500
    assert abs(a.sum_us - b.sum_us) < 1e-6 * a.sum_us


def test_histogram_quantiles_bucket_resolution():
    h = Histogram("q")
    h.observe_many_us(np.full(99, 3.0))       # bucket edge 4
    h.observe_us(5e6)                         # overflow
    assert h.quantile_us(0.5) == 4.0
    assert h.quantile_us(0.99) == 4.0
    assert h.quantile_us(1.0) == float(BUCKET_EDGES_US[-1] * 2)


def test_registry_get_or_create_and_kind_clash():
    reg = MetricsRegistry()
    c = reg.counter("a.count")
    assert reg.counter("a.count") is c
    with pytest.raises(TypeError):
        reg.gauge("a.count")
    c.inc()
    c.inc(5)
    reg.gauge("a.g").set(2.5)
    reg.histogram("a.h").observe_us(100)
    assert "a.count" in reg and reg.names() == ["a.count", "a.g", "a.h"]


def test_snapshot_schema_validates_and_roundtrips():
    reg = MetricsRegistry()
    reg.counter("c").inc(3)
    reg.gauge("g").set(1.25)
    reg.histogram("h").observe_many_us(np.array([1.0, 100.0, 1e7]))
    snap = reg.snapshot()
    assert validate_snapshot(snap) == []
    assert validate_snapshot(json.loads(json.dumps(snap))) == []
    assert snap["counters"]["c"] == 3
    assert snap["histograms"]["h"]["count"] == 3
    # broken snapshots are rejected
    bad = json.loads(json.dumps(snap))
    bad["histograms"]["h"]["counts"][0] += 1
    assert any("counts sum" in e for e in validate_snapshot(bad))
    assert any("missing top-level" in e
               for e in validate_snapshot({"benchmark": "metrics_snapshot"}))


def test_deterministic_snapshot_bytes_stable_across_runs():
    def run():
        reg = MetricsRegistry()
        reg.counter("steps").inc(128)
        reg.histogram("warm", wallclock=False).observe_many_us(
            np.arange(1, 65, dtype=np.float64))
        reg.histogram("tick_us", wallclock=True).observe_us(
            float(np.random.default_rng().uniform(1, 1e5)))  # wall-clock noise
        reg.counter("missed", wallclock=True).inc(
            int(np.random.default_rng().integers(1, 100)))
        return reg.dumps(deterministic=True)
    a, b = run(), run()
    assert a == b
    snap = json.loads(a)
    assert "tick_us" not in snap["histograms"]      # wallclock dropped
    assert "missed" not in snap["counters"]
    assert "warm" in snap["histograms"]             # deterministic kept


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("fleet.ticks", "total ticks").inc(7)
    reg.gauge("fleet.occupancy").set(0.5)
    h = reg.histogram("fleet.tick_us")
    h.observe_us(3.0)
    h.observe_us(1e9)
    text = reg.prometheus()
    assert "# TYPE fleet_ticks counter\nfleet_ticks 7" in text
    assert "fleet_occupancy 0.5" in text
    assert 'fleet_tick_us_bucket{le="4"} 1' in text
    assert 'fleet_tick_us_bucket{le="+Inf"} 2' in text
    assert "fleet_tick_us_count 2" in text
    # cumulative buckets are monotone
    cums = [int(l.rsplit(" ", 1)[1]) for l in text.splitlines()
            if l.startswith("fleet_tick_us_bucket")]
    assert cums == sorted(cums)


def test_prometheus_exposition_conformance():
    """Exporter conformance beyond the happy path: metric names must
    match ``[a-zA-Z_:][a-zA-Z0-9_:]*``, HELP text must escape backslash
    and newline, and output ordering must be stable (sorted by source
    name) so scrapes diff cleanly."""
    import re
    reg = MetricsRegistry()
    reg.counter("50hz.deadline-miss", "misses @ 50Hz").inc(1)
    reg.gauge("numerics.drift.h", 'help with \\ backslash\nand newline')
    reg.counter("fleet.shard0.ticks", "plain").inc(2)
    reg.gauge("weird~name!", "").set(1.0)
    text = reg.prometheus()
    name_re = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*$")
    for line in text.splitlines():
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            name = line.split(" ", 3)[2]
        else:
            name = line.split("{", 1)[0].split(" ", 1)[0]
        assert name_re.match(name), f"bad metric name {name!r} in {line!r}"
    # leading digit gets a prefix instead of producing an invalid name
    assert "_50hz_deadline_miss 1" in text
    # HELP payload is single-line with escaped backslash / newline
    help_line = next(l for l in text.splitlines()
                     if l.startswith("# HELP numerics_drift_h"))
    assert help_line == \
        "# HELP numerics_drift_h help with \\\\ backslash\\nand newline"
    # stable ordering: families appear in sorted source-name order
    fams = [l.split(" ", 3)[2] for l in text.splitlines()
            if l.startswith("# TYPE ")]
    assert fams == [_prom_name_ref(n) for n in sorted(
        ("50hz.deadline-miss", "numerics.drift.h", "fleet.shard0.ticks",
         "weird~name!"))]
    # two identical registries render byte-identically
    reg2 = MetricsRegistry()
    reg2.counter("50hz.deadline-miss", "misses @ 50Hz").inc(1)
    reg2.gauge("numerics.drift.h", 'help with \\ backslash\nand newline')
    reg2.counter("fleet.shard0.ticks", "plain").inc(2)
    reg2.gauge("weird~name!", "").set(1.0)
    assert reg2.prometheus() == text


def _prom_name_ref(name: str) -> str:
    from repro.obs.metrics import _prom_name
    return _prom_name(name)


def test_merge_histogram_counts():
    a, b = Histogram("a"), Histogram("b")
    a.observe_many_us(np.array([1.0, 5.0]))
    b.observe_many_us(np.array([5.0, 1e9]))
    merged = merge_histogram_counts([a.counts, b.counts])
    assert sum(merged) == 4
    with pytest.raises(ValueError):
        merge_histogram_counts([[1, 2, 3]])


# ---------------------------------------------------------------------------
# Conservation invariant (shared test/production implementation)
# ---------------------------------------------------------------------------

def _toy_stats():
    shard = {"active": 1, "pending": 0, "completed": 2, "stream_steps": 10,
             "ring_spills": 0, "replay_suppressed": 0,
             "scheduler": {"admissions": 3, "recycles": 1, "spills": 0,
                           "completed": 2, "cancelled": 0, "evictions": 0,
                           "ticks": 5}}
    retired = {"completed": 1, "stream_steps": 4, "ring_spills": 0,
               "replay_suppressed": 0,
               "scheduler": {"admissions": 1, "recycles": 0, "spills": 0,
                             "completed": 1, "cancelled": 0, "evictions": 0,
                             "ticks": 2}}
    return {"active": 1, "pending": 0, "completed": 3, "stream_steps": 14,
            "ring_spills": 0, "replay_suppressed": 0,
            "scheduler": {"admissions": 4, "recycles": 1, "spills": 0,
                          "completed": 3, "cancelled": 0, "evictions": 0,
                          "ticks": 7},
            "per_shard": [shard], "retired": retired}


def test_check_conservation_passes_and_catches_drift():
    assert check_conservation(_toy_stats()) == []
    broken = _toy_stats()
    broken["completed"] += 1
    errs = check_conservation(broken)
    assert len(errs) == 1 and "completed" in errs[0]
    broken2 = _toy_stats()
    broken2["scheduler"]["ticks"] -= 1
    assert any("scheduler.ticks" in e for e in check_conservation(broken2))
    broken3 = _toy_stats()
    broken3["active"] += 1                        # gauge absorbed retired
    assert any("gauge" in e for e in check_conservation(broken3))


def test_debug_mode_stats_asserts_conservation(qp, input_dim,
                                               monkeypatch):
    """``debug=True`` routes every ``stats()`` roll-up through the shared
    conservation checker (guarding the accumulation-pass keys against
    refactoring drift); ``debug=False`` never pays for it."""
    import repro.serve.fleet.engine as fleet_mod
    checked = []
    monkeypatch.setattr(
        fleet_mod, "assert_conservation",
        lambda stats: checked.append(stats["completed"]))
    streams = make_streams(4, 40, input_dim)

    def run(debug):
        fleet = FleetEngine(qp, FleetConfig(
            shards=2, stream=StreamingConfig(max_slots=4)),
            obs=Observability(debug=debug))
        for sid, w in streams.items():
            fleet.attach(sid, w, total_steps=len(w))
        fleet.drain()
        return fleet.stats()

    st = run(debug=True)
    assert checked == [st["completed"] == 4 and 4]
    run(debug=False)
    assert len(checked) == 1                   # not called off the debug path
    # and the real checker passes on a genuine roll-up
    assert check_conservation(st) == []


# ---------------------------------------------------------------------------
# Engine integration: spans, metrics, deadline + warm-up accounting
# ---------------------------------------------------------------------------

def test_fleet_traced_run_bit_identical_to_untraced(qp, input_dim):
    """Full instrumentation must not perturb a single output bit."""
    streams = make_streams(12, 150, input_dim, seed=3)

    def run(obs):
        fleet = FleetEngine(qp, FleetConfig(
            shards=2, stream=StreamingConfig(max_slots=8)), obs=obs)
        for sid, w in streams.items():
            fleet.attach(sid, w, total_steps=len(w))
        from faultharness import collect_log
        return collect_log(fleet.drain())

    assert run(NULL_OBS) == run(Observability.full(debug=True))


def test_fleet_tick_phases_traced(qp, input_dim):
    obs = Observability.full()
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=8)), obs=obs)
    for sid, w in make_streams(8, 140, input_dim).items():
        fleet.attach(sid, w, total_steps=len(w))
    fleet.drain()
    st = obs.tracer.phase_stats()
    # fused fleet ticks: the kernel dispatch is the fleet.dispatch span
    # (one fused call for all shards); engine.kernel appears only on the
    # single-engine/unfused path, asserted separately below
    for phase in ("fleet.tick", "fleet.begin", "fleet.dispatch",
                  "fleet.finish", "fleet.deliver", "engine.gather",
                  "engine.emit", "engine.finish", "sched.admit",
                  "sched.release"):
        assert phase in st, f"missing phase {phase}: have {sorted(st)}"
    # the tick envelope dominates its parts
    assert st["fleet.tick"]["total_us"] >= st["fleet.dispatch"]["total_us"]
    # spans are tagged with real shard indices
    shards = {r["shard"] for r in obs.tracer.flight()
              if r["phase"] == "engine.gather"}
    assert shards <= {0, 1} and shards


def test_single_engine_kernel_span_and_tick(qp, input_dim):
    obs = Observability.full()
    eng = StreamingEngine(qp, StreamingConfig(max_slots=4), obs=obs)
    for sid, w in make_streams(4, 140, input_dim).items():
        eng.attach(sid, w, total_steps=len(w))
    eng.drain()
    st = obs.tracer.phase_stats()
    for phase in ("engine.tick", "engine.kernel", "engine.gather",
                  "engine.finish", "sched.admit"):
        assert phase in st, f"missing phase {phase}: have {sorted(st)}"
    assert "engine.tick_us" in obs.metrics.snapshot()["histograms"]


def test_fleet_metrics_counters_and_warmup(qp, input_dim):
    obs = Observability.full()
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=8, warmup_samples=64)),
        obs=obs)
    n, steps = 8, 140
    for sid, w in make_streams(n, steps, input_dim).items():
        fleet.attach(sid, w, total_steps=steps)
    fleet.drain()
    snap = obs.metrics.snapshot()
    assert validate_snapshot(snap) == []
    assert snap["counters"]["fleet.ticks"] == fleet.stats()["ticks"]
    # every stream crosses warm-up exactly once, at its first emission
    # (window=128 >= warmup=64), so the histogram has n observations of
    # 128 samples each
    wh = snap["histograms"]["stream.warmup_samples"]
    assert wh["count"] == n and wh["sum_us"] == n * 128
    assert snap["counters"]["stream.warm_emissions"] == n * 2  # window+final
    assert snap["counters"]["stream.cold_emissions"] == 0
    # occupancy gauges drained to zero
    assert snap["gauges"]["fleet.active"] == 0
    assert snap["gauges"]["fleet.occupancy"] == 0


def test_deadline_miss_accounting(qp, input_dim):
    # deadline_ms=0: every productive tick misses, counted in stream-ticks
    obs = Observability.full(deadline_ms=0.0)
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=4)), obs=obs)
    for sid, w in make_streams(4, 50, input_dim).items():
        fleet.attach(sid, w, total_steps=50)
    fleet.drain()
    snap = obs.metrics.snapshot()
    st = fleet.stats()
    assert snap["counters"]["fleet.deadline_miss_ticks"] == st["ticks"]
    assert snap["counters"]["fleet.deadline_miss_stream_ticks"] == \
        st["stream_steps"]
    per_shard = sum(snap["counters"][f"fleet.shard{i}."
                                     "deadline_miss_stream_ticks"]
                    for i in range(2))
    assert per_shard == st["stream_steps"]
    # default deadline (50 Hz -> 20 ms) on the same tiny workload: ticks
    # run in far under 20 ms, so no misses
    obs2 = Observability.full()
    fleet2 = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=4)), obs=obs2)
    for sid, w in make_streams(4, 50, input_dim).items():
        fleet2.attach(sid, w, total_steps=50)
    fleet2.drain()
    assert obs2.metrics.snapshot()["counters"][
        "fleet.deadline_miss_ticks"] == 0


def test_warmup_histogram_survives_migration(qp, input_dim):
    obs = Observability.full()
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=4, warmup_samples=64)),
        obs=obs)
    streams = make_streams(2, 150, input_dim)
    for sid, w in streams.items():
        fleet.attach(sid, w, total_steps=150)
    for _ in range(40):                       # pre-warm-up (< 64 steps)
        fleet.step()
    sid0 = next(iter(streams))
    fleet.migrate(sid0, (fleet.shard_of(sid0) + 1) % 2)
    fleet.drain()
    wh = obs.metrics.snapshot()["histograms"]["stream.warmup_samples"]
    assert wh["count"] == 2                   # once per stream, not re-counted
    assert wh["sum_us"] == 2 * 128


# ---------------------------------------------------------------------------
# Flight recorder + crash matrix byte-stability
# ---------------------------------------------------------------------------

def test_flight_recorder_truncates_event_tail():
    tr = Tracer(capacity=8)
    rec = FlightRecorder(tr, events_per_shard=4)
    rec.note_events(0, tick=1, summaries=[(f"s{i}", "window", i)
                                          for i in range(10)])
    rec.note_events(0, tick=2, summaries=[("x", "final", 99)], total=500)
    dump = rec.record_crash({"shard": 0, "phase": "pre_tick"}, tick=3)
    ev = dump["recent_events"]["0"]
    assert ev["total_events"] == 510          # true count, not tail length
    assert len(ev["tail"]) == 4               # bounded
    assert ev["tail"][-1] == {"tick": 2, "stream": "x", "kind": "final",
                              "step": 99}


def test_flight_recorder_dump_on_crash(qp, input_dim):
    obs = Observability.full()
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, snapshot_every=16,
        stream=StreamingConfig(max_slots=8)), obs=obs)
    for sid, w in make_streams(8, 200, input_dim).items():
        fleet.attach(sid, w, total_steps=200)
    for _ in range(140):                      # past first window emission
        fleet.step()
    fleet.crash_shard(1)
    assert obs.recorder.n_crashes == 1
    d = obs.recorder.last()
    assert d["artifact"] == "flight_record" and d["shard"] == 1
    assert d["recovery"]["streams_recovered"] > 0
    assert d["counters"]["failovers"] == 1
    # the span tail captures the exact pre-crash tick phases, in order,
    # and nothing from after the crash tick
    phases_seen = {r["phase"] for r in d["trace"]}
    assert {"fleet.tick", "fleet.begin", "fleet.dispatch",
            "fleet.finish"} <= phases_seen
    assert all(r["tick"] <= d["tick"] for r in d["trace"])
    assert [r["seq"] for r in d["trace"]] == sorted(
        r["seq"] for r in d["trace"])
    assert any(ev["total_events"] > 0 for ev in d["recent_events"].values())
    fleet.drain()


@pytest.mark.parametrize("shards", [2, 4])
def test_crash_matrix_flight_dumps_byte_stable(qp, input_dim, shards):
    """Identical runs under the full phase x shard crash matrix produce
    byte-identical deterministic flight-recorder dumps."""
    streams = make_streams(12, 300, input_dim, seed=5)

    def run():
        obs = Observability.full()
        log, stats = run_crash_schedule(
            qp, streams, shards=shards, slots_per_shard=8,
            injector=crash_matrix(shards), obs=obs)
        return obs, log, stats

    obs_a, log_a, stats_a = run()
    obs_b, log_b, stats_b = run()
    assert obs_a.recorder.n_crashes == 3 * shards      # every phase x shard
    dump_a = obs_a.recorder.dumps(deterministic=True)
    assert dump_a == obs_b.recorder.dumps(deterministic=True)
    assert log_a == log_b
    # nondeterministic dumps still parse and carry wall-clock spans
    full = json.loads(obs_a.recorder.dumps())
    assert any("dur_us" in r for c in full["crashes"] for r in c["trace"])


# ---------------------------------------------------------------------------
# O(shards) stats regression
# ---------------------------------------------------------------------------

class _PoisonDict(dict):
    """Raises if anybody iterates it — the O(streams) tripwire."""

    def __iter__(self):
        raise AssertionError("stats() iterated a per-stream container")

    def keys(self):
        raise AssertionError("stats() iterated a per-stream container")

    def values(self):
        raise AssertionError("stats() iterated a per-stream container")

    def items(self):
        raise AssertionError("stats() iterated a per-stream container")


def test_fleet_stats_is_o_shards_not_o_streams(qp, input_dim):
    fleet = FleetEngine(qp, FleetConfig(
        shards=4, stream=StreamingConfig(max_slots=8)))
    for sid, w in make_streams(16, 60, input_dim).items():
        fleet.attach(sid, w, total_steps=60)
    for _ in range(10):
        fleet.step()
    # poison every stream-keyed container: owner map, replay cursors,
    # failover stores, per-shard session maps
    saved = (fleet._owner, fleet._cursor, fleet._snapshots, fleet._journal,
             [sh._sessions for sh in fleet.shards])
    fleet._owner = _PoisonDict(fleet._owner)
    fleet._cursor = _PoisonDict(fleet._cursor)
    fleet._snapshots = _PoisonDict(fleet._snapshots)
    fleet._journal = _PoisonDict(fleet._journal)
    for sh in fleet.shards:
        sh._sessions = _PoisonDict(sh._sessions)
    calls = {"n": 0}
    orig = type(fleet.shards[0]).stats

    def counting_stats(self):
        calls["n"] += 1
        return orig(self)

    try:
        type(fleet.shards[0]).stats = counting_stats
        st = fleet.stats()
    finally:
        type(fleet.shards[0]).stats = orig
        fleet._owner, fleet._cursor, fleet._snapshots, fleet._journal, \
            sessions = saved
        for sh, sess in zip(fleet.shards, sessions):
            sh._sessions = sess
    assert calls["n"] == 4                    # exactly one call per shard
    assert st["active"] == 16
    fleet.drain()


# ---------------------------------------------------------------------------
# LM engine spans
# ---------------------------------------------------------------------------

def test_lm_engine_obs_spans():
    import repro.configs as C
    from repro.models import transformer as T
    from repro.serve.engine import Engine, ServeConfig
    cfg = C.reduced(C.get("deepseek-7b"), compute_dtype="float32",
                    param_dtype="float32")
    params = T.init(cfg, jax.random.PRNGKey(0))
    obs = Observability.full()
    eng = Engine(cfg, params, ServeConfig(max_len=32, max_slots=2), obs=obs)
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.submit(rng.integers(0, cfg.vocab_size, 4).astype(np.int32), 4)
    eng.run()
    st = obs.tracer.phase_stats()
    assert st["lm.prefill"]["count"] == 3
    assert st["lm.decode"]["count"] >= 3
    assert "lm.tick" in st and "sched.admit" in st
    snap = obs.metrics.snapshot()
    assert snap["counters"]["lm.tokens_generated"] == \
        eng.stats()["tokens_generated"] - 3   # prefill tokens not decode-counted


# ---------------------------------------------------------------------------
# span phase-name registry (repro.obs.phases)
# ---------------------------------------------------------------------------

def test_every_serving_span_phase_is_registered():
    """Every phase literal recorded through the tracer API anywhere in
    the serving and deploy trees must be registered in
    repro.obs.phases.PHASES — a typo'd phase would silently intern a new
    ring and split that phase's latency history (det-span-registry lints
    the same property; this pins it from the runtime side)."""
    import ast
    import os
    from repro.obs.phases import PHASES

    src_root = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    used = {}
    for sub in ("serve", "deploy"):
        for dirpath, _, files in os.walk(os.path.join(src_root, sub)):
            for fn in sorted(files):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path) as f:
                    tree = ast.parse(f.read(), filename=path)
                for node in ast.walk(tree):
                    if (isinstance(node, ast.Call)
                            and isinstance(node.func, ast.Attribute)
                            and node.func.attr in ("rec", "span")
                            and node.args
                            and isinstance(node.args[0], ast.Constant)
                            and isinstance(node.args[0].value, str)):
                        used.setdefault(node.args[0].value, []).append(
                            f"{path}:{node.lineno}")
    unregistered = {p: w for p, w in used.items() if p not in PHASES}
    assert not unregistered, unregistered
    # sanity: the scan actually sees the serving spans (an empty `used`
    # would mean the extractor broke, not that the tree is clean)
    assert {"fleet.tick", "engine.kernel", "lm.prefill"} <= set(used)


def test_phase_registry_api():
    from repro.obs import PHASES, assert_registered, registered
    assert registered("fleet.dispatch") and not registered("fleet.dispach")
    assert_registered("engine.tick")
    with pytest.raises(ValueError):
        assert_registered("engine.tick_typo")
    # registry names are unique across subsystem groups and non-empty
    from repro.obs import phases as P
    groups = (P.ENGINE_PHASES + P.FLEET_PHASES + P.LM_PHASES
              + P.SCHED_PHASES + P.VERIFY_PHASES)
    assert len(groups) == len(set(groups)) == len(PHASES)
