"""Serving-path correctness: token-by-token decode must reproduce the
full forward pass (dense/MoE/SSM/hybrid), and prefill->decode must be
continuous.  Run in f32 to make the comparison exact."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import transformer as T


def _setup(arch):
    cfg = C.reduced(C.get(arch), compute_dtype="float32", param_dtype="float32")
    if cfg.family == "moe":   # no-drop so the oracle matches serving
        cfg = dataclasses.replace(cfg, capacity_factor=cfg.num_experts / cfg.top_k)
    params = T.init(cfg, jax.random.PRNGKey(0))
    toks = np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 12))
    return cfg, params, toks


@pytest.mark.parametrize("arch", ["deepseek-7b", "olmoe-1b-7b",
                                  "mamba2-780m", "zamba2-1.2b"])
def test_decode_matches_forward(arch):
    cfg, params, toks = _setup(arch)
    full, _, _ = T.forward(cfg, params, {"tokens": jnp.asarray(toks)})
    cache = T.init_cache(cfg, 2, 16, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t: T.decode_step(cfg, p, c, t))
    for t in range(toks.shape[1]):
        lg, cache = step(params, cache, jnp.asarray(toks[:, t:t + 1]))
        err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, t])))
        assert err < 1e-4, (arch, t, err)


@pytest.mark.parametrize("arch", ["deepseek-7b", "mamba2-780m", "zamba2-1.2b"])
def test_prefill_then_decode_continuous(arch):
    cfg, params, toks = _setup(arch)
    full, _, _ = T.forward(cfg, params, {"tokens": jnp.asarray(toks)})
    half = 6
    _, cache = T.prefill(cfg, params, {"tokens": jnp.asarray(toks[:, :half])},
                         max_len=16)
    step = jax.jit(lambda p, c, t: T.decode_step(cfg, p, c, t))
    for t in range(half, toks.shape[1]):
        lg, cache = step(params, cache, jnp.asarray(toks[:, t:t + 1]))
        err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, t])))
        assert err < 1e-4, (arch, t, err)


def test_sliding_window_decode_matches_windowed_forward():
    cfg, params, toks = _setup("zamba2-1.2b")
    w = 4
    full, _, _ = T.forward(cfg, params, {"tokens": jnp.asarray(toks)}, window=w)
    cache = T.init_cache(cfg, 2, 16, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t: T.decode_step(cfg, p, c, t, window=w))
    for t in range(toks.shape[1]):
        lg, cache = step(params, cache, jnp.asarray(toks[:, t:t + 1]))
        err = float(jnp.max(jnp.abs(lg[:, 0] - full[:, t])))
        assert err < 1e-4, (t, err)


def test_serving_engine_generate():
    from repro.serve.engine import Engine, ServeConfig
    cfg, params, toks = _setup("deepseek-7b")
    eng = Engine(cfg, params, ServeConfig(max_len=32))
    out = eng.generate(toks[:, :6], max_new=5)
    assert out.shape == (2, 5)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_quantized_serving_engine_close_to_fp():
    from repro.serve.engine import Engine, ServeConfig
    cfg, params, toks = _setup("deepseek-7b")
    fp = Engine(cfg, params, ServeConfig(max_len=32))
    q8 = Engine(cfg, params, ServeConfig(max_len=32, quant_bits=8))
    a = fp.generate(toks[:, :6], max_new=4)
    b = q8.generate(toks[:, :6], max_new=4)
    # random-init logits are near-uniform; just require the quantized
    # engine runs and emits valid tokens (accuracy tested on trained HAR)
    assert b.shape == a.shape
