"""Energy model (Tables VIII-IX) and MCU latency model (Table VII) —
every derived number in the paper must fall out of the encoded constants."""
from repro.core import energy as en
from repro.core import mcu
from repro.core.fastgrnn import FastGRNNConfig

CFG = FastGRNNConfig(rank_w=2, rank_u=8)


def test_active_power_17_7mw():
    assert abs(en.MSP430_LUT.p_active_mw - 17.7) < 0.1


def test_energy_per_inference_246uj():
    assert abs(en.LUT_BUILD.e_inference_uj - 246) < 2


def test_energy_per_window_31_5mj():
    assert abs(en.LUT_BUILD.e_window_mj - 31.5) < 0.3


def test_no_lut_energy_7440uj():
    assert abs(en.NO_LUT_BUILD.e_inference_uj - 7440) < 20


def test_battery_life_602h_streaming_417h_continuous():
    assert abs(en.LUT_BUILD.battery_hours(continuous=False) - 602) < 5
    assert abs(en.LUT_BUILD.battery_hours(continuous=True) - 417) < 3


def test_lut_speedup_30_5x():
    assert abs(en.lut_speedup() - 30.5) < 0.5


def test_window_energy_reduction_96_7pct():
    assert abs(en.window_energy_reduction() - 0.967) < 0.002


def test_no_lut_misses_50hz_deadline():
    assert en.LUT_BUILD.meets_50hz
    assert not en.NO_LUT_BUILD.meets_50hz


# ---- MCU cycle model (Table VII) -----------------------------------------

def test_arduino_latency_9_21ms():
    t = mcu.step_latency_s(CFG, mcu.ARDUINO, lut=True)
    assert abs(t * 1e3 - 9.21) < 0.15


def test_msp430_latency_13_9ms():
    t = mcu.step_latency_s(CFG, mcu.MSP430, lut=True)
    assert abs(t * 1e3 - 13.87) < 0.2


def test_msp430_no_lut_421ms():
    t = mcu.step_latency_s(CFG, mcu.MSP430, lut=False)
    assert abs(t * 1e3 - 421) < 5


def test_arduino_lut_speedup_1_51x():
    assert abs(mcu.lut_speedup(CFG, mcu.ARDUINO) - 1.51) < 0.05


def test_msp430_lut_speedup_30x():
    assert abs(mcu.lut_speedup(CFG, mcu.MSP430) - 30.4) < 1.0


def test_budget_use_46_65_pct():
    assert abs(mcu.budget_use(CFG, mcu.ARDUINO) - 0.46) < 0.02
    assert abs(mcu.budget_use(CFG, mcu.MSP430) - 0.69) < 0.05


def test_flash_and_sram_budgets():
    # deployed: 283 nonzero * 2B + 2 KB LUTs << 16 KB Flash
    assert mcu.flash_bytes(CFG, nonzero_params=283) == 566 + 2048
    assert mcu.flash_bytes(CFG, nonzero_params=283) < 16 * 1024
    assert mcu.sram_bytes(CFG) < 512                 # MSP430G2553 SRAM


def test_h32_would_still_fit_but_slower():
    """Model predicts unmeasured configs: H=32 full-rank."""
    big = FastGRNNConfig(hidden_dim=32)
    t16 = mcu.step_latency_s(FastGRNNConfig(), mcu.MSP430)
    t32 = mcu.step_latency_s(big, mcu.MSP430)
    assert t32 > 2.5 * t16                          # ~4x MACs, 2x acts
