"""End-to-end behaviour: the paper's full pipeline (train -> L -> S -> Q ->
deterministic deploy -> warm-up characterization) on synthetic HAPT, plus
the LM-scale trainer loop on a reduced arch."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import pipeline as pl, compression as comp
from repro.core.warmup import characterize


def test_har_end_to_end_lsq(trained_har):
    cfg, params, tr, te = trained_har
    # trained model beats chance materially
    pred = pl.predict_fp32(params, te.windows)
    f1 = pl.macro_f1(te.labels, pred)
    assert f1 > 0.5, f1

    # sparsify (S stage) and deploy (Q stage)
    icfg = comp.IHTConfig(target_sparsity=0.5)
    masks = comp.compute_masks(params, icfg, 0.5)
    sparse = comp.apply_masks(params, masks)
    assert comp.deployed_param_count(params, masks) == 283
    rt = pl.deploy(sparse, tr.windows[:5])
    qpred = rt.predict_batch(te.windows[:200])
    fpred = pl.predict_fp32(sparse, te.windows[:200])
    agree = pl.agreement(qpred, fpred)
    assert agree > 0.95, agree          # paper: 99.91-100%


def test_warmup_characterization_runs(trained_har):
    cfg, params, tr, te = trained_har
    rt = pl.deploy(params, tr.windows[:5])
    preds = []
    for w in te.windows[:30]:
        logits, traj = rt.run_window(w, return_trajectory=True)
        step_logits = traj @ np.asarray(rt._w["head_w"]) + np.asarray(rt._head_b)
        preds.append(np.argmax(step_logits, axis=-1))
    stats = characterize(np.stack(preds))
    assert 1 <= stats.median_samples <= 128
    assert stats.worst_case <= 128
    assert stats.iqr_lo <= stats.median_samples <= stats.iqr_hi


def test_lm_trainer_smoke(tmp_path):
    """Reduced qwen2 through the real Trainer: loss falls, checkpoints land."""
    import repro.configs as C
    from repro.models import registry
    from repro.train.trainer import Trainer, TrainerConfig
    from repro.train.optimizer import AdamConfig
    from repro.data import tokens

    cfg = C.reduced(C.get("qwen2-1.5b"))
    tcfg = tokens.TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8)
    acfg = AdamConfig(lr=3e-3, warmup_steps=5)
    step = jax.jit(registry.make_train_step(cfg, acfg))

    def batch_fn(s):
        b = tokens.lm_batch(tcfg, s)
        return {k: jnp.asarray(v) for k, v in b.items()}

    tr = Trainer(
        TrainerConfig(total_steps=30, checkpoint_every=10, adam=acfg,
                      checkpoint_dir=str(tmp_path)),
        init_params_fn=lambda: registry.init(cfg, jax.random.PRNGKey(0)),
        step_fn=step, batch_fn=batch_fn)
    hist = tr.run()
    losses = [h["loss"] for h in hist if "loss" in h]
    assert len(losses) == 30
    assert losses[-1] < losses[0]       # it learns the motif structure
    from repro.train import checkpoint as ck
    assert ck.latest_step(str(tmp_path)) == 30
