"""LUT activations (paper Sec. III-E, Appendix C)."""
import math

import jax.numpy as jnp
import numpy as np

from repro.core import lut


def test_table_values_match_appendix_c():
    t = lut.make_lut("sigmoid")
    bw = 16.0 / 256
    for i in [0, 17, 128, 255]:
        x = -8.0 + (i + 0.5) * bw          # bucket-center sampling
        assert abs(t[i] - 1 / (1 + math.exp(-x))) < 1e-6


def test_saturation_exact_in_tails():
    """Paper: outside [-8, 8] saturation is 'exact to floating-point
    precision' for sigma and tanh."""
    for fn, f in [("sigmoid", lambda x: 1 / (1 + np.exp(-x))), ("tanh", np.tanh)]:
        t = jnp.asarray(lut.make_lut(fn))
        for x in [9.0, 20.0, -9.0, -100.0]:
            got = float(lut.lut_eval(t, jnp.asarray(x)))
            assert abs(got - f(x)) < 2e-3   # table[0]/[255] vs true tail


def test_flash_budget_2kb():
    assert lut.flash_bytes() == 2048        # paper: 'two tables ... 2 KB'


def test_max_error_small_inside_domain():
    for fn in ("sigmoid", "tanh"):
        e_near = lut.max_abs_error(fn, "nearest")
        e_lerp = lut.max_abs_error(fn, "lerp")
        # nearest-bucket worst case ~ max|f'| * bw/2 (= 0.031 for tanh,
        # f'(0)=1, bw=1/16); lerp is ~bw^2/8 * max|f''| — 1-2 orders better
        assert e_near <= 0.04, (fn, e_near)
        assert e_lerp < e_near / 10         # lerp strictly better
        assert e_lerp < 5e-4, (fn, e_lerp)


def test_linear_tail_functions():
    x = jnp.asarray([-20.0, 20.0])
    y = lut.LUTActivations(mode="nearest")("silu", x)
    assert abs(float(y[0]) - 0.0) < 1e-6
    assert abs(float(y[1]) - 20.0) < 1e-6


def test_monotonicity_nearest():
    xs = jnp.linspace(-8, 8, 4096)
    for fn in ("sigmoid", "tanh"):
        t = jnp.asarray(lut.make_lut(fn))
        ys = np.asarray(lut.lut_eval(t, xs))
        assert np.all(np.diff(ys) >= 0)
