"""Cross-platform deterministic inference (paper Sec. IV-D, V-F):
three execution paths — (1) FP32+LUT jnp reference, (2) NumPy
'C-equivalent' integer runtime, (3) Pallas fastgrnn_cell kernel — must
agree on predictions, mirroring the paper's FP32/NumPy/bare-metal triple.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fastgrnn as fg, pipeline as pl
from repro.core.lut import lut_sigmoid, lut_tanh
from repro.core.qruntime import QRuntime, calibrate
from repro.core.quantization import quantize_params, QuantConfig
from repro.kernels.fastgrnn_cell.ops import fastgrnn_window_kernel


def test_three_path_agreement(trained_har):
    cfg, params, tr, te = trained_har
    windows = te.windows[:80]
    rt = pl.deploy(params, tr.windows[:5])

    # path 1: jnp FP32 with nearest-LUT activations
    p1 = pl.predict_fp32(params, windows,
                         sigma=lambda x: lut_sigmoid(x, "nearest"),
                         tanh=lambda x: lut_tanh(x, "nearest"))
    # path 2: integer C-equivalent runtime
    p2 = rt.predict_batch(windows)
    # path 3: Pallas kernel (effective dequantized weights)
    deq = rt.qp.dequantize()
    xs = jnp.asarray(np.transpose(windows, (1, 0, 2)))
    h, _ = fastgrnn_window_kernel(deq, xs)
    logits = np.asarray(h) @ np.asarray(deq["head_w"]) + np.asarray(deq["head_b"])
    p3 = np.argmax(logits, axis=-1)

    assert pl.agreement(p2, p3) == 1.0         # integer vs kernel: exact
    assert pl.agreement(p1, p2) >= 0.97        # fp32 vs Q15: paper >=99.9%


def test_hidden_trajectory_determinism(trained_har):
    """Paper Table VI: identical hidden trajectories across platforms.
    Run the integer runtime twice (simulating two ISAs: the arithmetic is
    fixed-order) and the Pallas kernel; h_0 samples must match."""
    cfg, params, tr, te = trained_har
    rt = pl.deploy(params, tr.windows[:5])
    w = te.windows[0]
    _, traj_a = rt.run_window(w, return_trajectory=True)
    _, traj_b = rt.run_window(w.copy(), return_trajectory=True)
    np.testing.assert_array_equal(traj_a, traj_b)   # bit-equal
    deq = rt.qp.dequantize()
    _, traj_k = fastgrnn_window_kernel(deq, jnp.asarray(w[:, None, :]))
    np.testing.assert_allclose(traj_a, np.asarray(traj_k[:, 0]),
                               rtol=0, atol=2e-5)


def test_naive_quantization_degrades(trained_har):
    """Fig. 5 mechanism: naive Q15 acts must do materially worse than
    calibrated; calibrated must track the deployed path."""
    cfg, params, tr, te = trained_har
    windows, labels = te.windows[:150], te.labels[:150]
    rt = pl.deploy(params, tr.windows[:5])
    rt_naive = pl.deploy(params, tr.windows[:5], naive_activations=True)
    rt_cal = pl.deploy(params, tr.windows[:5], quantize_activations=True)
    f1 = pl.macro_f1(labels, rt.predict_batch(windows))
    f1_naive = pl.macro_f1(labels, rt_naive.predict_batch(windows))
    f1_cal = pl.macro_f1(labels, rt_cal.predict_batch(windows))
    assert f1_naive < f1 - 0.1          # collapse
    assert f1_cal > f1 - 0.05           # calibration recovers


def test_calibration_covers_hidden_range(trained_har):
    cfg, params, tr, te = trained_har
    rt = pl.deploy(params, tr.windows[:5])
    scales = calibrate(rt, tr.windows[:5])
    # the hidden-state scale must cover more than naive [-1, 1)
    assert scales["h"] > 1.0 / 32767
