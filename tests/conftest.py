import os
import subprocess
import sys

# Fake 8 XLA host devices for the whole tier-1 run (must be set before
# jax initializes, hence module scope here rather than a fixture body).
# CPU-only runners then exercise the multi-device paths in-process: the
# fleet's per-device shard placement (tests/test_fleet.py) and the
# in-process smokes in tests/test_distributed.py.  Honors a pre-set
# XLA_FLAGS that already pins a device count (e.g. an external harness).
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
sys.path.insert(0, SRC)


def run_subprocess(code: str, devices: int = 8, timeout: int = 480) -> str:
    """Run ``code`` in a fresh python with N fake XLA host devices.
    Needed because the pytest process locks jax to 1 CPU device."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{out.stdout}\n{out.stderr}")
    return out.stdout


@pytest.fixture(scope="session")
def har_data():
    from repro.data import hapt
    tr = hapt.load("train", n=1500)
    te = hapt.load("test", n=400)
    return tr, te


@pytest.fixture(scope="session")
def trained_har(har_data):
    """A small-but-real trained low-rank FastGRNN shared across tests."""
    from repro.core import fastgrnn as fg, pipeline as pl
    tr, te = har_data
    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    res = pl.train_fastgrnn(cfg, tr.windows, tr.labels, epochs=70, seed=0)
    return cfg, res.params, tr, te
