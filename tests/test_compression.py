"""IHT sparsification: cubic schedule (Eq. 7), exact top-k masks, the
283-nonzero deployment arithmetic."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp
from repro.core import fastgrnn as fg


def test_cubic_schedule_eq7():
    cfg = comp.IHTConfig(target_sparsity=0.5, ramp_epochs=50)
    assert comp.sparsity_at_epoch(cfg, 0) == 0.0
    assert abs(comp.sparsity_at_epoch(cfg, 25) - 0.5 * 0.5 ** 3) < 1e-9
    assert comp.sparsity_at_epoch(cfg, 50) == 0.5
    assert comp.sparsity_at_epoch(cfg, 80) == 0.5   # frozen at target


def test_topk_mask_exact_count():
    x = jnp.asarray(np.random.randn(37, 11).astype(np.float32))
    for keep in [0, 1, 50, 200, 37 * 11]:
        m = comp.topk_mask(x, keep)
        assert int(m.sum()) == keep


def test_topk_mask_keeps_largest():
    x = jnp.asarray([[0.1, -5.0, 2.0], [0.0, 3.0, -0.2]])
    m = comp.topk_mask(x, 2)
    assert bool(m[0, 1]) and bool(m[1, 1])


def test_deployed_nonzero_arithmetic_283():
    """Paper Table II/X: s=0.5 over the 294 factor weights -> 147 kept;
    +32 biases +2 scalars +102 head = 283 nonzero, 566 B at Q15."""
    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    params = fg.init_params(cfg, jax.random.PRNGKey(0))
    icfg = comp.IHTConfig(target_sparsity=0.5)
    masks = comp.compute_masks(params, icfg, 0.5)
    nz = comp.deployed_param_count(params, masks)
    assert nz == 283
    assert nz * 2 == 566                      # deployed bytes


def test_mask_freeze_semantics():
    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    params = fg.init_params(cfg, jax.random.PRNGKey(1))
    icfg = comp.IHTConfig()
    masks = comp.compute_masks(params, icfg, 0.5)
    sp = comp.apply_masks(params, masks)
    # re-applying the same mask is idempotent
    sp2 = comp.apply_masks(sp, masks)
    for k in sp:
        np.testing.assert_array_equal(np.asarray(sp[k]), np.asarray(sp2[k]))


def test_tree_masks_for_lm_pytree():
    tree = {"a": {"w": jnp.asarray(np.random.randn(8, 8).astype(np.float32))},
            "b": jnp.asarray(np.random.randn(5).astype(np.float32))}
    masks = comp.compute_masks_tree(tree, 0.75)
    sp = comp.apply_masks_tree(tree, masks)
    assert int(jnp.sum(sp["a"]["w"] != 0)) == 16     # 25% of 64
    assert int(jnp.sum(sp["b"] != 0)) == 5           # 1-D left dense
