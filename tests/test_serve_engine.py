"""Continuous-batching LM engine (serve/engine.py on serve/scheduler.py):
the rewritten engine must reproduce the pre-refactor window-boundary
engine's greedy generations exactly, recycle slots per step, keep its
output buffers preallocated (the old O(T^2) concatenate regression), and
drive the quantized weights through the real q15_matmul head."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import transformer as T
from repro.compress import quantize_tree
from repro.serve.engine import Engine, ServeConfig


def _setup(arch, batch=4, prompt=8):
    cfg = C.reduced(C.get(arch), compute_dtype="float32", param_dtype="float32")
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=cfg.num_experts / cfg.top_k)
    params = T.init(cfg, jax.random.PRNGKey(0))
    toks = np.random.default_rng(1).integers(0, cfg.vocab_size, (batch, prompt))
    return cfg, params, toks


def _pre_refactor_generate(cfg, params, toks, max_new, max_len):
    """The pre-refactor Engine loop, verbatim semantics: one joint prefill,
    then single-token decode_step over the whole batch (greedy)."""
    logits, cache = T.prefill(cfg, params, {"tokens": jnp.asarray(toks)},
                              max_len=max_len)
    step = jax.jit(lambda p, c, t: T.decode_step(cfg, p, c, t))
    nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    out = [np.asarray(nxt)]
    for _ in range(max_new - 1):
        lg, cache = step(params, cache, nxt)
        nxt = jnp.argmax(lg[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(nxt))
    return np.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Acceptance: identical greedy generations to the pre-refactor engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["deepseek-7b", "mamba2-780m"])
def test_greedy_identical_to_pre_refactor(arch):
    cfg, params, toks = _setup(arch)
    ref = _pre_refactor_generate(cfg, params, toks, 12, 32)
    eng = Engine(cfg, params, ServeConfig(max_len=32, max_slots=4))
    np.testing.assert_array_equal(eng.generate(toks, max_new=12), ref)


@pytest.mark.parametrize("arch", ["deepseek-7b", "mamba2-780m"])
def test_continuous_batching_through_fewer_slots_identical(arch):
    """B=4 prompts through 2 slots: admission order + per-step recycling
    must not change any sequence's tokens."""
    cfg, params, toks = _setup(arch)
    ref = _pre_refactor_generate(cfg, params, toks, 12, 32)
    eng = Engine(cfg, params, ServeConfig(max_len=32, max_slots=2))
    np.testing.assert_array_equal(eng.generate(toks, max_new=12), ref)
    st = eng.stats()
    assert st["scheduler"]["recycles"] == 2      # slots reused per step
    assert st["scheduler"]["spills"] == 2        # two prompts had to queue
    assert st["prefills"] == 4
    assert st["peak_active"] == 2


def test_mixed_budgets_recycle_slots_per_step():
    """Mixed max_new: short requests free their slots mid-flight and the
    queue refills them while long requests keep decoding — the behaviour
    the old window-boundary engine could not express."""
    cfg, params, toks = _setup("deepseek-7b", batch=6)
    budgets = [3, 10, 3, 10, 3, 3]
    eng = Engine(cfg, params, ServeConfig(max_len=32, max_slots=2))
    rids = [eng.submit(toks[i], budgets[i]) for i in range(6)]
    eng.run()
    for rid, b, row in zip(rids, budgets, toks):
        got = eng.result(rid)
        assert got.shape == (b,)
        # each sequence's tokens match its solo window-boundary reference
        ref = _pre_refactor_generate(cfg, params, row[None, :], b, 32)[0]
        np.testing.assert_array_equal(got, ref)
    st = eng.stats()["scheduler"]
    assert st["completed"] == 6 and st["recycles"] == 4
    # continuous batching beats the window baseline on scheduler ticks:
    # total work 32 tokens over 2 slots -> 16 perfectly-packed decode
    # rounds is the floor; the all_free baseline needs >= 3 x 10
    assert eng.stats()["decode_ticks"] < 30


def test_long_decode_uses_preallocated_buffer():
    """O(T^2) regression guard: a long decode writes into the same
    preallocated (S, max_len) buffer — no per-token reallocation — and
    still matches the pre-refactor generation."""
    cfg, params, toks = _setup("deepseek-7b", batch=2)
    eng = Engine(cfg, params, ServeConfig(max_len=256, max_slots=2))
    buf_before = eng._out
    assert buf_before.shape == (2, 256)
    out = eng.generate(toks, max_new=200)
    assert eng._out is buf_before                # never reallocated
    ref = _pre_refactor_generate(cfg, params, toks, 200, 256)
    np.testing.assert_array_equal(out, ref)


# ---------------------------------------------------------------------------
# Lifecycle details
# ---------------------------------------------------------------------------

def test_cancel_returns_partial_result():
    cfg, params, toks = _setup("deepseek-7b", batch=1)
    eng = Engine(cfg, params, ServeConfig(max_len=32, max_slots=1))
    rid = eng.submit(toks[0], 10)
    eng.tick()
    eng.tick()
    ev = eng.cancel(rid)
    assert ev is not None and not ev.finished
    assert 1 <= ev.tokens.shape[0] < 10
    np.testing.assert_array_equal(eng.result(rid), ev.tokens)


def test_cancel_pending_request_yields_empty_result():
    """Cancelling a request the scheduler never admitted must behave like
    a resident cancel: result() works and returns what was emitted (here,
    nothing) — callers cannot observe admission timing."""
    cfg, params, toks = _setup("deepseek-7b", batch=2)
    eng = Engine(cfg, params, ServeConfig(max_len=32, max_slots=1))
    eng.submit(toks[0], 10, request_id="resident")
    eng.submit(toks[1], 10, request_id="queued")
    ev = eng.cancel("queued")
    assert not ev.finished and ev.tokens.shape == (0,)
    np.testing.assert_array_equal(eng.result("queued"), np.zeros(0, np.int32))


def test_submit_validation():
    cfg, params, toks = _setup("deepseek-7b", batch=1)
    eng = Engine(cfg, params, ServeConfig(max_len=16, max_slots=1))
    with pytest.raises(ValueError):
        eng.submit(toks, 4)                      # 2-D prompt
    with pytest.raises(ValueError):
        eng.submit(toks[0], 0)                   # empty budget
    with pytest.raises(ValueError):
        eng.submit(toks[0], 16)                  # prompt + new > max_len


def test_window_boundary_policy_matches_continuous_tokens():
    """admit_policy='all_free' (the serve_bench baseline) produces the same
    tokens, just with worse packing."""
    cfg, params, toks = _setup("deepseek-7b")
    ref = _pre_refactor_generate(cfg, params, toks, 8, 32)
    eng = Engine(cfg, params, ServeConfig(max_len=32, max_slots=2,
                                          admit_policy="all_free"))
    np.testing.assert_array_equal(eng.generate(toks, max_new=8), ref)
    assert eng.stats()["scheduler"]["admit_policy"] == "all_free"


# ---------------------------------------------------------------------------
# Satellites: quantize_tree API, config hygiene, quantized head
# ---------------------------------------------------------------------------

def test_quantize_tree_returns_qtree_and_scales():
    """The engine quantizes through repro.compress.quantize_tree (the
    serve.engine.quantize_for_serving shim is gone); the contract is a
    2-tuple (qtree, scales) with a 0-d zero scale for every leaf left in
    floating point."""
    cfg, params, _ = _setup("deepseek-7b")
    out = quantize_tree(params, 8)
    assert isinstance(out, tuple) and len(out) == 2
    qt, sc = out
    flat_q = jax.tree_util.tree_leaves(qt)
    flat_s = jax.tree_util.tree_leaves(sc)
    assert len(flat_q) == len(flat_s)
    for ql, s in zip(flat_q, flat_s):
        if jnp.issubdtype(ql.dtype, jnp.integer) and ql.ndim >= 2:
            assert float(s) > 0.0                # real dequant scale
        else:
            assert s.ndim == 0 and float(s) == 0.0


def test_serve_config_not_shared_between_engines():
    """Regression: the old default `serve_cfg=ServeConfig()` was a single
    mutable instance shared by every Engine."""
    cfg, params, _ = _setup("deepseek-7b")
    e1 = Engine(cfg, params)
    e2 = Engine(cfg, params)
    assert e1.scfg is not e2.scfg
    e1.scfg.temperature = 0.7
    assert e2.scfg.temperature == 0.0


def test_quantized_head_runs_integer_weights():
    """quant_bits routes the sampling head through the q15_matmul kernel on
    the actual int8 leaves (previously dead qparams/scales)."""
    cfg, params, toks = _setup("deepseek-7b", batch=2, prompt=6)
    eng = Engine(cfg, params, ServeConfig(max_len=32, max_slots=2,
                                          quant_bits=8))
    assert eng.qparams is not None
    assert eng.qparams["lm_head"]["w"].dtype == jnp.int8
    out = eng.generate(toks, max_new=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_temperature_sampling_batched_and_seeded():
    cfg, params, toks = _setup("deepseek-7b", batch=3)
    a = Engine(cfg, params, ServeConfig(max_len=32, max_slots=3,
                                        temperature=0.8, seed=7))
    b = Engine(cfg, params, ServeConfig(max_len=32, max_slots=3,
                                        temperature=0.8, seed=7))
    out_a = a.generate(toks, max_new=6)
    out_b = b.generate(toks, max_new=6)
    np.testing.assert_array_equal(out_a, out_b)   # same seed, same stream
    assert (out_a >= 0).all() and (out_a < cfg.vocab_size).all()
