"""Q15 PTQ + activation calibration (paper Sec. III-D, Appendix B)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantization as q
from repro.core import fastgrnn as fg


def test_scale_formula_appendix_b():
    w = jnp.asarray([[0.5, -1.3], [0.2, 0.9]])
    qi, s = q.quantize_tensor(w, q.Q15_MAX)
    assert abs(float(s) - 1.3 / 32767) < 1e-9
    assert int(jnp.max(jnp.abs(qi))) == 32767


def test_roundtrip_error_bounded_by_half_scale():
    # seeded: the bound sits exactly at the rounding boundary, so an
    # unseeded draw makes this test flaky.  The slack must be eps-scaled:
    # w/s and q*s are float32 ops, so |deq - w| <= s/2 + O(eps32 * |w|).
    w = jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)),
                    jnp.float32)
    qi, s = q.quantize_tensor(w, q.Q15_MAX)
    err = jnp.max(jnp.abs(q.dequantize_tensor(qi, s) - w))
    slack = 4 * np.finfo(np.float32).eps * float(jnp.max(jnp.abs(w)))
    assert float(err) <= float(s) / 2 + slack


def test_quantize_params_roundtrip_and_bytes():
    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    params = fg.init_params(cfg, jax.random.PRNGKey(0))
    qp = q.quantize_params(params, q.QuantConfig())
    deq = qp.dequantize()
    for k in params:
        d = float(jnp.max(jnp.abs(deq[k] - params[k])))
        assert d < 1e-3, k
    # quantized matrices: W1,W2,U1,U2,head_w = 390 params * 2B
    assert qp.nbytes() == 390 * 2


def test_q7_mode():
    w = jnp.asarray(np.random.randn(32, 32).astype(np.float32))
    qi, s = q.quantize_tensor(w, q.Q7_MAX)
    assert int(jnp.max(jnp.abs(qi))) <= 128
    err = float(jnp.max(jnp.abs(q.dequantize_tensor(qi, s) - w)))
    assert err <= float(s) / 2 + 1e-9


def test_calibration_headroom():
    acts = [{"h": jnp.asarray([1.0, -3.0])}, {"h": jnp.asarray([5.0, 0.1])}]
    scales = q.calibrate_activations(lambda b: b, acts, headroom=0.10)
    assert abs(scales["h"] - (1.1 * 5.0) / q.Q15_MAX) < 1e-9


def test_naive_activation_quant_clips_out_of_range():
    """The paper's collapse mechanism: |h| ~ 62 >> 1 is unrepresentable in
    naive Q15 [-1, 1): fake-quant clips it to ~1."""
    h = jnp.asarray([62.0, -0.5, 0.9])
    out = q.fake_quant_activation(h, q.NAIVE_ACT_SCALE)
    assert abs(float(out[0]) - 1.0) < 1e-3          # catastrophically clipped
    assert abs(float(out[1]) + 0.5) < 1e-4          # in-range preserved
    # calibrated scale covers the range
    cal_scale = (1.1 * 62.0) / q.Q15_MAX
    out2 = q.fake_quant_activation(h, cal_scale)
    assert abs(float(out2[0]) - 62.0) < 0.01
