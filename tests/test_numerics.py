"""Numeric-health observability: saturation counters, drift, crosscheck.

The contract under test (see docs/observability.md):

* monitoring is byte-invisible — monitored and unmonitored runs produce
  identical predictions / logits / trajectories on every backend and
  fleet shape, because monitors only *read* intermediates the engines
  already materialize;
* the qvm's per-site saturation counters and the
  ``-DFG_NUMERIC_COUNTERS`` C build's agree exactly on shared windows;
* dynamic witnesses are contained in the statically reachable site set
  (:mod:`repro.analysis.crosscheck`), with the x8 stress segment
  proving the counters actually count;
* fleet crash/rebuild conserves every site counter (live + retired ==
  totals) and the flight recorder captures the dead shard's last
  numeric-health snapshot.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np
import pytest

from faultharness import (assert_counters_conserved, make_streams,
                          reference_log, run_crash_schedule)
from repro.core import fastgrnn as fg
from repro.core.quantization import QuantConfig, quantize_params
from repro.data import hapt
from repro.deploy import emit_c
from repro.deploy.goldens import build_reference_artifact
from repro.deploy.image import build_image
from repro.deploy.qvm import QVM
from repro.obs import (MetricsRegistry, Observability,
                       check_numerics_conservation)
from repro.obs.numerics import (NumericsMonitor, limits_from_scales,
                                merge_site_counts, site_order)
from repro.serve.fleet import FleetConfig, FleetEngine, crash_matrix
from repro.serve.streaming import StreamingConfig, StreamingEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: Gain that drives h_next saturation on the reference model.
STRESS = 8


@pytest.fixture(scope="module")
def art():
    return build_reference_artifact(seed=0)


@pytest.fixture(scope="module")
def img(art):
    return build_image(art)


@pytest.fixture(scope="module")
def windows():
    return hapt.load("test", n=32).windows


@pytest.fixture(scope="module")
def qp():
    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    return quantize_params(fg.init_params(cfg, jax.random.PRNGKey(0)),
                           QuantConfig())


@pytest.fixture(scope="module")
def input_dim(qp):
    return StreamingEngine(qp, StreamingConfig(max_slots=1)).kernel.input_dim


def mon_obs(**kw) -> Observability:
    return Observability(metrics=MetricsRegistry(),
                         numerics=NumericsMonitor(), **kw)


# ---------------------------------------------------------------------------
# Monitor unit behavior
# ---------------------------------------------------------------------------

def test_site_vocabulary_matches_qlint_classification():
    """Every site the static analyzer classifies exists in the runtime
    counter vocabulary, in both low-rank and dense shapes."""
    with open(os.path.join(REPO, "ANALYSIS_report.json")) as f:
        report = json.load(f)
    lr = set(site_order(True))
    for t in report["qlint"]["targets"]:
        sat = t["saturation"]
        assert set(sat["reachable"]) | set(sat["dead"]) <= lr
    assert "w.out" in site_order(False) and "w1.out" not in site_order(False)


def test_monitor_counts_limits_and_snapshot_determinism():
    mon = NumericsMonitor()
    mon.declare(("h_next", "gate.hf_clip"))
    mon.count("h_next", 3)
    mon.count_events({"h_next": 2})
    mon.set_default_limits({"h": 1.0})
    mon.observe("h", np.array([0.5, -2.5, 0.25], np.float32))
    snap = mon.snapshot()
    assert snap["sites"]["h_next"] == 5 and snap["sites"]["gate.hf_clip"] == 0
    t = snap["tensors"]["h"]
    assert t["n"] == 3 and t["n_over"] == 1
    assert t["min"] == -2.5 and t["max"] == 0.5
    assert mon.drift() > 0
    assert json.dumps(snap, sort_keys=True) == json.dumps(mon.snapshot(),
                                                          sort_keys=True)


def test_shard_children_share_late_bound_limits(art):
    mon = NumericsMonitor()
    child = mon.shard(0)
    mon.set_default_limits(limits_from_scales(art.act_scales))
    assert child.limit("h") == mon.limit("h") and child.limit("h")
    child.count("h_next", 2)
    other = mon.shard(1)
    other.count("h_next", 1)
    assert mon.snapshot()["sites"]["h_next"] == 3       # parent aggregates
    assert mon.snapshot(per_shard=True)["per_shard"]["0"]["sites"][
        "h_next"] == 2


def test_merge_site_counts():
    acc = {"a": 1}
    out = merge_site_counts(acc, {"a": 2, "b": 5})
    assert out is acc and acc == {"a": 3, "b": 5}


# ---------------------------------------------------------------------------
# qvm: byte-identity + witnesses
# ---------------------------------------------------------------------------

def test_monitored_qvm_byte_identical_and_clean_on_goldens(img, windows):
    vm = QVM(img)
    xq = vm.quantize_input(windows)
    logits, traces = vm.run_windows(xq, return_trajectory=True)
    mon = NumericsMonitor()
    mvm = QVM(img, monitor=mon)
    mxq = mvm.quantize_input(windows)       # x telemetry rides quantize
    np.testing.assert_array_equal(xq, mxq)
    mlogits, mtraces = mvm.run_windows(mxq, return_trajectory=True)
    np.testing.assert_array_equal(logits, mlogits)
    np.testing.assert_array_equal(traces, mtraces)
    snap = mon.snapshot()
    assert all(v == 0 for v in snap["sites"].values())
    assert snap["tensors"]["h"]["n"] > 0                # telemetry flowed
    assert snap["tensors"]["x"]["n_over"] == 0


def test_stress_gain_witnesses_h_next_saturation(img, windows):
    mon = NumericsMonitor()
    vm = QVM(img, monitor=mon)
    vm.run_windows(vm.quantize_input(
        np.asarray(windows, np.float32) * STRESS))
    sites = mon.snapshot()["sites"]
    assert sites["h_next"] > 0                          # the witness
    assert sites["gate.hf_clip"] == 0                   # still unreachable
    dead = [s for s in sites if s not in ("h_next", "gate.hf_clip")]
    assert all(sites[s] == 0 for s in dead)             # containment


# ---------------------------------------------------------------------------
# C twin: exact counter parity
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not emit_c.find_cc(), reason="no host C compiler")
def test_c_counter_parity_with_qvm(img, windows, tmp_path):
    vm = QVM(img)
    order = site_order(bool(img.low_rank))
    binary = emit_c.compile_host(img, str(tmp_path), engine="int",
                                 numeric_counters=True)
    cm = emit_c.CHostModel(binary, img.H, img.C, engine="int")
    for gain in (1, STRESS):
        xq = vm.quantize_input(np.asarray(windows, np.float32) * gain)
        mon = NumericsMonitor()
        q_logits = QVM(img, monitor=mon).run_windows(xq)
        q_counts = np.array([mon.snapshot()["sites"][s] for s in order],
                            np.uint64)
        c_preds, c_counts = cm.counters(xq)
        np.testing.assert_array_equal(
            c_preds, np.argmax(q_logits, axis=1).astype(np.int32))
        np.testing.assert_array_equal(c_counts, q_counts)
    assert c_counts[order.index("h_next")] > 0          # stress witnessed


@pytest.mark.skipif(not emit_c.find_cc(), reason="no host C compiler")
def test_plain_c_build_refuses_counter_mode(img, windows, tmp_path):
    """A binary compiled WITHOUT -DFG_NUMERIC_COUNTERS must die loudly on
    the counter protocol, not emit garbage."""
    vm = QVM(img)
    binary = emit_c.compile_host(img, str(tmp_path), engine="int")
    cm = emit_c.CHostModel(binary, img.H, img.C, engine="int")
    with pytest.raises(Exception):
        cm.counters(vm.quantize_input(windows[:2]))


# ---------------------------------------------------------------------------
# Engine: monitoring is byte-invisible on every backend
# ---------------------------------------------------------------------------

def _engine_log(art, windows, backend, obs):
    eng = StreamingEngine.from_artifact(
        art, StreamingConfig(max_slots=len(windows), backend=backend),
        obs=obs)
    for i, w in enumerate(windows):
        eng.attach(f"w{i}", w, total_steps=len(w),
                   record_trajectory=(i < 2))
    events = eng.drain()
    log = [(e.stream_id, e.kind, int(e.step), int(e.prediction),
            np.asarray(e.logits, np.float32).tobytes()) for e in events]
    trajs = [np.asarray(eng.trajectory(f"w{i}")).tobytes() for i in range(2)]
    return log, trajs, eng


@pytest.mark.parametrize("backend", ["exact", "jit", "pallas"])
def test_monitored_engine_byte_identical(art, windows, backend):
    n = 16 if backend == "pallas" else 24
    w = windows[:n]
    log0, trajs0, _ = _engine_log(art, w, backend, None)
    obs = mon_obs()
    log1, trajs1, eng = _engine_log(art, w, backend, obs)
    assert log0 == log1
    assert trajs0 == trajs1
    snap = eng.stats()["numerics"]
    # device-resident backends skip per-tick pre tallies by design
    # (zero-h-copy contract); input + emission telemetry always flows
    if not eng._device_resident:
        assert snap["tensors"]["pre"]["n"] > 0
    assert snap["tensors"]["x"]["n"] > 0
    assert snap["tensors"]["h"]["n"] > 0
    assert snap["tensors"]["h"]["limit"] is not None    # limits late-bound
    # throttled publish still exported the counter series
    assert any(k.startswith("numerics.sat.")
               for k in obs.metrics.snapshot()["counters"])


@pytest.mark.parametrize("shards", [1, 2, 4, 8])
def test_monitored_fleet_byte_identical_with_crash(qp, input_dim, shards):
    streams = make_streams(16, 300, input_dim, seed=3)
    want = reference_log(qp, streams)
    obs = mon_obs(debug=True)                # debug => conservation asserted
    log, stats = run_crash_schedule(
        qp, streams, shards=shards, slots_per_shard=16,
        injector=crash_matrix(shards), obs=obs)
    assert log == want                       # byte-identical through crashes
    assert_counters_conserved(stats)
    num = stats["numerics"]
    assert num["tensors"]["pre"]["n"] > 0
    # engines declare the two kernel-side LUT sites on their shard child
    assert {"act.z.idx", "act.ht.idx"} <= set(num["sites"])


def test_numerics_conservation_check_catches_drift():
    stats = {
        "numerics": {"sites": {"h_next": 5}, "retired_sites": {"h_next": 2}},
        "per_shard": [{"numerics": {"sites": {"h_next": 3}}}],
    }
    assert check_numerics_conservation(stats) == []
    stats["numerics"]["sites"]["h_next"] = 6
    errs = check_numerics_conservation(stats)
    assert len(errs) == 1 and "h_next" in errs[0]
    assert check_numerics_conservation({"per_shard": []}) == []


# ---------------------------------------------------------------------------
# Fleet crash: retirement + flight recorder
# ---------------------------------------------------------------------------

def test_crash_folds_numerics_into_flight_dump(qp, input_dim):
    obs = Observability.full(numerics=True, debug=True)
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, snapshot_every=16,
        stream=StreamingConfig(max_slots=8)), obs=obs)
    for sid, w in make_streams(8, 200, input_dim).items():
        fleet.attach(sid, w, total_steps=200)
    for _ in range(60):
        fleet.step()
    fleet.crash_shard(1)
    dump = obs.recorder.last()
    num = dump["counters"]["numerics"]
    assert num is not None and num["tensors"]["pre"]["n"] > 0
    assert dump["counters"]["retired_numerics"] == dict(
        sorted(num["sites"].items()))
    stats = fleet.stats()                    # debug => conservation holds
    assert stats["numerics"]["retired_sites"] == dump[
        "counters"]["retired_numerics"]
    fleet.drain()
    assert_counters_conserved(fleet.stats())


def test_crash_matrix_numeric_dumps_byte_stable(qp, input_dim):
    """Identical monitored runs under the full phase x shard crash matrix
    produce byte-identical deterministic flight dumps, numeric-health
    snapshots included."""
    streams = make_streams(12, 300, input_dim, seed=5)

    def run():
        obs = Observability.full(numerics=True)
        log, stats = run_crash_schedule(
            qp, streams, shards=2, slots_per_shard=8,
            injector=crash_matrix(2), obs=obs)
        return obs, log, stats

    obs_a, log_a, stats_a = run()
    obs_b, log_b, stats_b = run()
    assert log_a == log_b
    assert obs_a.recorder.dumps(deterministic=True) == \
        obs_b.recorder.dumps(deterministic=True)
    dump = json.loads(obs_a.recorder.dumps(deterministic=True))
    assert all("numerics" in c["counters"] for c in dump["crashes"])
    assert json.dumps(stats_a["numerics"], sort_keys=True) == \
        json.dumps(stats_b["numerics"], sort_keys=True)
    assert_counters_conserved(stats_a)


# ---------------------------------------------------------------------------
# Crosscheck gate + drift
# ---------------------------------------------------------------------------

def test_crosscheck_reference_images(img, windows):
    from repro.analysis import crosscheck, target_by_name
    from repro.analysis.qlint import analyze_image
    with open(os.path.join(REPO, "ANALYSIS_report.json")) as f:
        report = json.load(f)
    target = target_by_name(report, "reference-q15-s0")
    # committed report matches a fresh analysis of the same image
    fresh = analyze_image(img, name="reference-q15-s0")
    assert fresh["saturation"] == target["saturation"]
    vm = QVM(img)
    for bits_target in (target, target_by_name(report, "reference-q7-s0")):
        mon = NumericsMonitor()
        QVM(img, monitor=mon).run_windows(vm.quantize_input(windows))
        v = crosscheck(bits_target, mon.snapshot())
        assert v["ok"] and v["witnessed"] == []
        assert "h_next" in v["unwitnessed_reachable"]
    # stress run: witnessed, still contained, expect_nonzero satisfied
    mon = NumericsMonitor()
    QVM(img, monitor=mon).run_windows(vm.quantize_input(
        np.asarray(windows, np.float32) * STRESS))
    v = crosscheck(target, mon.snapshot(), expect_nonzero=("h_next",))
    assert v["ok"] and v["witnessed"] == ["h_next"]


def test_crosscheck_flags_violations():
    from repro.analysis import crosscheck, target_by_name
    with open(os.path.join(REPO, "ANALYSIS_report.json")) as f:
        target = target_by_name(json.load(f), "reference-q15-s0")
    zeros = {s: 0 for s in site_order(True)}
    v = crosscheck(target, {"sites": {**zeros, "w1.out": 3}})
    assert not v["ok"] and "dead" in v["violations"][0]
    v = crosscheck(target, {"sites": {**zeros, "head.logits": 1}})
    assert not v["ok"] and "never" in v["violations"][0]
    v = crosscheck(target, {"sites": zeros}, expect_nonzero=("h_next",))
    assert not v["ok"] and "witness" in v["violations"][0]
    with pytest.raises(KeyError):
        target_by_name({"qlint": {"targets": []}}, "nope")


def test_drift_score_monotone_under_gain(img, windows):
    scores = []
    vm = QVM(img)
    for gain in (1, 2, 8):
        mon = NumericsMonitor()
        QVM(img, monitor=mon).run_windows(vm.quantize_input(
            np.asarray(windows[:8], np.float32) * gain))
        scores.append(mon.drift())
    assert scores == sorted(scores) and scores[-1] > scores[0]


def test_verify_parity_report_carries_numerics(art, windows):
    from repro.deploy.verify import quantized_paths_agree, run_parity
    report = run_parity(art, windows=windows[:8], n_scalar=2, n_trace=2,
                        use_fp32=False)
    if emit_c.find_cc():
        assert report["bitwise"]["c_int_qvm_counters"]
        assert report["bitwise"]["numerics_crosscheck"]
        assert report["numerics"]["crosscheck"]["ok"]
    assert quantized_paths_agree(report)
