"""Hypothesis property tests for the fleet: HRW routing stability, wire
round-trip fuzzing, and randomized crash/recover/migrate lifecycles.

Skipped when hypothesis is not installed (the CI tests job installs it);
the deterministic gates live in ``tests/test_fleet.py``,
``tests/test_wire.py`` and ``tests/test_failover.py``.
"""
import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from faultharness import (assert_counters_conserved, assert_logs_identical,
                          collect_log, make_streams, reference_log)
from repro.core import fastgrnn as fg
from repro.core.quantization import QuantConfig, quantize_params
from repro.serve.fleet import (PHASES, FleetConfig, FleetEngine,
                               ScheduledFaults, route)
from repro.serve.fleet.wire import decode_stream_state, encode_stream_state
from repro.serve.streaming import (StreamState, StreamingConfig,
                                   StreamingEngine)

_settings = settings(max_examples=25, deadline=None)
_ids = st.sets(st.text(st.characters(min_codepoint=33, max_codepoint=126),
                       min_size=1, max_size=12), min_size=1, max_size=50)


@pytest.fixture(scope="module")
def qp():
    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    return quantize_params(fg.init_params(cfg, jax.random.PRNGKey(0)),
                           QuantConfig())


# ---------------------------------------------------------------------------
# HRW routing: the stated invariant behind drain/decommission
# ---------------------------------------------------------------------------

@_settings
@given(ids=_ids, n=st.integers(2, 9), removed=st.data())
def test_hrw_removing_a_shard_remaps_only_its_streams(ids, n, removed):
    """For any stream set: masking out one shard moves ONLY the streams
    whose home was that shard; everyone else's route is unchanged."""
    keys = [f"shard-{i}" for i in range(n)]
    gone = removed.draw(st.integers(0, n - 1), label="removed shard")
    home = {sid: route(sid, keys) for sid in ids}
    eligible = [i != gone for i in range(n)]
    for sid in ids:
        new = route(sid, keys, eligible)
        if home[sid] == gone:
            assert new != gone
        else:
            assert new == home[sid], (
                f"stream {sid!r} moved {home[sid]} -> {new} although its "
                f"home shard was not the one removed ({gone})")


@_settings
@given(ids=_ids, n=st.integers(1, 8))
def test_hrw_adding_a_shard_only_pulls_streams_to_it(ids, n):
    """Growing the fleet by one shard never shuffles streams between the
    existing shards — a stream either stays home or moves to the new
    shard (the elastic scale-out half of the HRW invariant)."""
    keys = [f"shard-{i}" for i in range(n)]
    home = {sid: route(sid, keys) for sid in ids}
    grown = keys + [f"shard-{n}"]
    for sid in ids:
        new = route(sid, grown)
        assert new == home[sid] or new == n, (
            f"stream {sid!r} moved {home[sid]} -> {new}, not to the "
            f"added shard {n}")


# ---------------------------------------------------------------------------
# Wire format: round-trip fuzz
# ---------------------------------------------------------------------------

@_settings
@given(seed=st.integers(0, 2**31 - 1),
       sid=st.text(max_size=24),
       k=st.integers(0, 9), t=st.integers(0, 5),
       steps=st.integers(0, 10**9), wstep=st.integers(0, 127),
       total=st.none() | st.integers(0, 10**9),
       record=st.booleans())
def test_wire_round_trip_fuzz(seed, sid, k, t, steps, wstep, total, record):
    rng = np.random.default_rng(seed)
    state = StreamState(
        stream_id=sid,
        h=rng.standard_normal(16).astype(np.float32),
        steps=steps, wstep=wstep, total=total,
        samples=rng.standard_normal((k, 3)).astype(np.float32),
        record_trajectory=record,
        trajectory=[rng.standard_normal(16).astype(np.float32)
                    for _ in range(t)])
    blob = encode_stream_state(state)
    decoded = decode_stream_state(blob)
    assert encode_stream_state(decoded) == blob
    assert (decoded.stream_id, decoded.steps, decoded.wstep,
            decoded.total, decoded.record_trajectory) == \
           (sid, steps, wstep, total, record)
    np.testing.assert_array_equal(decoded.h.view(np.int32),
                                  state.h.view(np.int32))
    np.testing.assert_array_equal(decoded.samples.view(np.int32),
                                  state.samples.view(np.int32))


# ---------------------------------------------------------------------------
# Randomized crash/recover/migrate lifecycles
# ---------------------------------------------------------------------------

_lifecycle = settings(max_examples=12, deadline=None)
_REF_CACHE: dict = {}   # qp-id -> uninterrupted reference log (built once)


@_lifecycle
@given(data=st.data())
def test_random_crash_recover_migrate_lifecycle_is_bit_exact(qp, data):
    """Any schedule of shard crashes (at any tick phase), live migrations
    and checkpoint cadences yields per-stream event histories
    byte-identical to the uninterrupted single-engine reference, with
    fleet counters conserved (live + retired)."""
    shards = data.draw(st.integers(2, 4), label="shards")
    snapshot_every = data.draw(st.sampled_from([1, 16, 48]),
                               label="snapshot_every")
    crashes = data.draw(st.lists(
        st.tuples(st.integers(1, 320), st.sampled_from(PHASES),
                  st.integers(0, shards - 1)),
        min_size=1, max_size=3), label="crashes")
    migrates = data.draw(st.lists(
        st.tuples(st.integers(1, 320), st.integers(0, 15)),
        max_size=3), label="migrates")

    streams = make_streams(16, 280, 3, seed=7)
    want = _REF_CACHE.get(id(qp))
    if want is None:
        want = reference_log(qp, streams)
        _REF_CACHE[id(qp)] = want

    fleet = FleetEngine(qp, FleetConfig(
        shards=shards, stream=StreamingConfig(max_slots=6),
        snapshot_every=snapshot_every),
        faults=ScheduledFaults(schedule=crashes))
    sids = sorted(streams)
    mig_at = {}
    for tick, k in migrates:
        mig_at.setdefault(tick, []).append(sids[k])
    log = {}
    for sid, w in streams.items():
        fleet.attach(sid, w, total_steps=len(w))
    for tick in range(1, 340):
        for sid in mig_at.get(tick, ()):
            shard = fleet._owner.get(sid)
            if shard is not None and sid in fleet.shards[shard]._sessions:
                try:
                    fleet.migrate(sid)
                except ValueError:
                    pass   # no routable destination — legal no-op
        collect_log(fleet.step(), log)
    collect_log(fleet.drain(), log)
    assert_logs_identical(log, want)
    stats = fleet.stats()
    assert_counters_conserved(stats)
    assert stats["failovers"] == len(crashes)
