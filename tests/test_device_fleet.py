"""Device-resident fleet ticks: bit-exactness, zero-copy, concurrency.

The tentpole contract under test: the jit/pallas backends keep the
hidden-state slot table as a jax device array between ticks
(``Q15StreamStep.step_resident``), the fleet issues every device group's
dispatch before waiting on any (``fleet.dispatch_issue`` spans, synced
by the NEXT tick's ``fleet.device_wait``), and none of that may change a
single output byte: the fleet must stay byte-identical to an
uninterrupted single-engine reference at 1/2/4/8 shards — through crash
failover, snapshots, and migration — while moving ZERO hidden-state
bytes across the host/device boundary on steady-state ticks (asserted
via the ``TransferLedger`` h-state sub-accounts).

Numerics note: the pallas resident path deliberately runs its pad/slice
eagerly instead of inside a jit wrapper — fusing them into the kernel's
trace changes XLA's FMA contraction per batch shape by ~1 ulp, which
would break the shard-count-invariant bit-identity asserted here (see
``Q15StreamStep._build_pallas_resident``).

Runs under ``--xla_force_host_platform_device_count=8`` (conftest.py),
so ``placement="devices"`` exercises real multi-device dispatch on CI.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from faultharness import (assert_logs_identical, collect_log, make_streams)
from repro.core import fastgrnn as fg
from repro.core.quantization import QuantConfig, quantize_params
from repro.kernels.fastgrnn_cell.ops import Q15StreamStep
from repro.obs import Observability, TRANSFER_KEYS
from repro.serve.fleet import FleetConfig, FleetEngine
from repro.serve.fleet.faults import ScheduledFaults
from repro.serve.streaming import StreamingConfig, StreamingEngine

H, D = 16, 3


@pytest.fixture(scope="module")
def qp():
    return quantize_params(
        fg.init_params(fg.FastGRNNConfig(rank_w=2, rank_u=8),
                       jax.random.PRNGKey(0)), QuantConfig())


@pytest.fixture(scope="module")
def streams():
    return make_streams(16, 40, D, seed=3)


def _reference(qp, streams, backend):
    eng = StreamingEngine(qp, StreamingConfig(
        max_slots=len(streams), window=8, backend=backend))
    for sid, w in streams.items():
        eng.attach(sid, w, total_steps=len(w))
    return collect_log(eng.drain())


def _fleet_run(qp, streams, *, backend, shards, placement,
               injector=None, snapshot_every=5, obs=None):
    fleet = FleetEngine(qp, FleetConfig(
        shards=shards, placement=placement,
        stream=StreamingConfig(max_slots=len(streams) // shards,
                               window=8, backend=backend),
        snapshot_every=snapshot_every), faults=injector, obs=obs)
    log: dict = {}
    for sid, w in streams.items():
        fleet.attach(sid, w, total_steps=len(w))
    collect_log(fleet.drain(), log)
    return log, fleet


CRASH_SCHEDULE = [(7, "mid_dispatch", 1), (13, "pre_tick", 2),
                  (20, "post_emit", 0)]


def _crash_injector(shards):
    return ScheduledFaults(schedule=[
        (t, p, min(s, shards - 1)) for t, p, s in CRASH_SCHEDULE])


# ---------------------------------------------------------------------------
# Bit-exactness: fleet vs single engine at 1/2/4/8 shards, device-resident,
# through crash+replay mid-dispatch (satellite 4 + tentpole acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,placement", [
    ("exact", "host"),
    ("jit", "host"),
    ("jit", "devices"),
    ("pallas", "devices"),
])
def test_fleet_byte_identical_across_shards(qp, streams, backend, placement):
    want = _reference(qp, streams, backend)
    for shards in (1, 2, 4, 8):
        got, fleet = _fleet_run(qp, streams, backend=backend, shards=shards,
                                placement=placement,
                                injector=_crash_injector(shards))
        assert_logs_identical(got, want)
        st = fleet.stats()
        assert st["failovers"] == 3
        assert st["device_resident"] == (backend != "exact")


def test_devices_placement_uses_multiple_devices(qp):
    """Sanity that the forced 8-device CPU topology is actually in play:
    8 shards on ``devices`` placement land on 8 distinct jax devices."""
    assert len(jax.devices()) >= 8
    fleet = FleetEngine(qp, FleetConfig(
        shards=8, placement="devices",
        stream=StreamingConfig(max_slots=2, window=8, backend="jit")))
    devs = {id(sh.kernel.device) for sh in fleet.shards}
    assert len(devs) == 8


# ---------------------------------------------------------------------------
# Zero-copy steady state: no h bytes cross the boundary on fused ticks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jit", "pallas"])
def test_zero_h_copies_steady_state(qp, backend):
    """The tentpole's measurable core: after warmup, emission-free fused
    ticks move ZERO hidden-state bytes host<->device while the x/mask
    staging traffic keeps flowing."""
    streams = make_streams(8, 200, D, seed=7)
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, placement="devices",
        stream=StreamingConfig(max_slots=4, window=64, backend=backend)))
    for sid, w in streams.items():
        fleet.attach(sid, w, total_steps=len(w))
    for _ in range(8):          # warmup: admission uploads, first dispatch
        fleet.step()
    before = fleet.stats()["transfers"]
    for _ in range(20):         # steady state, no window boundary crossed
        fleet.step()
    after = fleet.stats()["transfers"]
    assert after["h_h2d_bytes"] == before["h_h2d_bytes"]
    assert after["h_d2h_bytes"] == before["h_d2h_bytes"]
    assert after["h2d_bytes"] > before["h2d_bytes"]   # x + mask staging


def test_host_staged_path_pays_h_roundtrip(qp):
    """Contrast fixture for the counter semantics: the non-resident
    (host-staged) step books the full h table both ways every tick."""
    k = Q15StreamStep(qp, backend="jit")
    h = k.init_state(8)
    x = np.zeros((8, D), np.float32)
    a = np.ones(8, bool)
    s0 = k.transfers.snapshot()
    k.step(h, x, a)
    s1 = k.transfers.snapshot()
    assert s1["h_h2d_bytes"] - s0["h_h2d_bytes"] == h.nbytes
    assert s1["h_d2h_bytes"] - s0["h_d2h_bytes"] == h.nbytes


# ---------------------------------------------------------------------------
# Satellite 3: lazy snapshot pulls — a snapshot tick is bit-identical to a
# run that never snapshots
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jit", "pallas"])
def test_snapshot_ticks_do_not_perturb_outputs(qp, streams, backend):
    got_snap, _ = _fleet_run(qp, streams, backend=backend, shards=4,
                             placement="devices", snapshot_every=3)
    got_none, _ = _fleet_run(qp, streams, backend=backend, shards=4,
                             placement="devices", snapshot_every=None)
    assert_logs_identical(got_snap, got_none)


def test_snapshot_pulls_only_checkpointed_rows(qp):
    """snapshot_now prefetches exactly the live rows (batched d2h), not
    the full slot table: h-state d2h bytes per snapshot scale with the
    number of live streams."""
    streams = make_streams(3, 400, D, seed=11)
    fleet = FleetEngine(qp, FleetConfig(
        shards=1, placement="host",
        stream=StreamingConfig(max_slots=64, window=128, backend="jit"),
        snapshot_every=1000))   # enabled, but never fires on its own here
    for sid, w in streams.items():
        fleet.attach(sid, w, total_steps=len(w))
    for _ in range(4):
        fleet.step()
    before = fleet.stats()["transfers"]["h_d2h_bytes"]
    fleet.snapshot_now()
    after = fleet.stats()["transfers"]["h_d2h_bytes"]
    # 3 live rows of (H,) f32 — not 64
    assert after - before == 3 * H * 4


# ---------------------------------------------------------------------------
# Concurrency: every group's dispatch is issued before any wait
# ---------------------------------------------------------------------------

def test_concurrent_dispatch_spans(qp, streams):
    """With 8 shards across 8 devices, a fused tick must record 8
    ``fleet.dispatch_issue`` spans (one per device group, all issued
    before any sync) and at most one ``fleet.device_wait`` — the
    observable form of >1 dispatch in flight."""
    obs = Observability.full()
    _fleet_run(qp, streams, backend="jit", shards=8, placement="devices",
               snapshot_every=None, obs=obs)
    per_tick: dict[int, dict[str, int]] = {}
    for span in obs.tracer.flight(deterministic=True):
        per_tick.setdefault(span["tick"], {}).setdefault(span["phase"], 0)
        per_tick[span["tick"]][span["phase"]] += 1
    busy = [c for c in per_tick.values()
            if c.get("fleet.dispatch_issue", 0) >= 2]
    assert busy, "no tick ever had more than one dispatch in flight"
    # hash routing need not fill all 8 shards, but most must be busy
    assert max(c.get("fleet.dispatch_issue", 0) for c in busy) >= 4
    for c in per_tick.values():
        assert c.get("fleet.device_wait", 0) <= 1


def test_host_placement_single_group_dispatch(qp, streams):
    """Host placement fuses all shards into ONE group: exactly one
    dispatch_issue span per advancing tick."""
    obs = Observability.full()
    _fleet_run(qp, streams, backend="jit", shards=4, placement="host",
               snapshot_every=None, obs=obs)
    per_tick: dict[int, int] = {}
    for span in obs.tracer.flight(deterministic=True):
        if span["phase"] == "fleet.dispatch_issue":
            per_tick[span["tick"]] = per_tick.get(span["tick"], 0) + 1
    assert per_tick and max(per_tick.values()) == 1


# ---------------------------------------------------------------------------
# Standalone engine: device-resident vs host state is invisible
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jit", "pallas"])
def test_engine_device_vs_host_bit_identical(qp, backend):
    streams = make_streams(6, 50, D, seed=5)
    logs = []
    for resident in (False, True):
        eng = StreamingEngine(qp, StreamingConfig(
            max_slots=6, window=8, backend=backend,
            device_resident=resident))
        for sid, w in streams.items():
            eng.attach(sid, w, total_steps=len(w))
        logs.append(collect_log(eng.drain()))
    assert_logs_identical(logs[1], logs[0])


def test_exact_backend_rejects_device_resident(qp):
    with pytest.raises(ValueError, match="device_resident"):
        StreamingEngine(qp, StreamingConfig(
            max_slots=4, backend="exact", device_resident=True))
    # auto on exact resolves to host state, silently
    eng = StreamingEngine(qp, StreamingConfig(max_slots=4, backend="exact"))
    assert eng.stats()["device_resident"] is False


def test_migration_export_import_device_resident(qp):
    """Export from a device-resident engine mid-stream, import into a
    fresh one, finish — byte-identical to the uninterrupted run."""
    streams = make_streams(4, 60, D, seed=9)
    want = _reference(qp, streams, "jit")

    src = StreamingEngine(qp, StreamingConfig(
        max_slots=4, window=8, backend="jit"))
    for sid, w in streams.items():
        src.attach(sid, w, total_steps=len(w))
    log: dict = {}
    for _ in range(17):
        collect_log(src.step(), log)
    dst = StreamingEngine(qp, StreamingConfig(
        max_slots=4, window=8, backend="jit"))
    for sid in sorted(streams):
        dst.import_stream(src.export_stream(sid))
    collect_log(dst.drain(), log)
    assert_logs_identical(log, want)


# ---------------------------------------------------------------------------
# Kernel-level surfaces: MXU layout, roofline, prefetch cache
# ---------------------------------------------------------------------------

def test_mxu_layout_matches_exact(qp):
    exact = Q15StreamStep(qp, backend="exact")
    mxu = Q15StreamStep(qp, backend="pallas", mxu=True)
    rng = np.random.default_rng(2)
    h = (rng.normal(size=(8, H)) * 0.4).astype(np.float32)
    x = rng.normal(size=(8, D)).astype(np.float32)
    a = np.ones(8, bool)
    np.testing.assert_allclose(mxu.step(h, x, a), exact.step(h, x, a),
                               atol=1e-6)
    # resident MXU path == host-staged MXU path, bitwise
    got = np.asarray(mxu.step_resident(mxu.to_device(h), x, a))
    assert np.array_equal(got.view(np.int32),
                          mxu.step(h, x, a).view(np.int32))


def test_mxu_requires_pallas(qp):
    with pytest.raises(ValueError, match="mxu"):
        Q15StreamStep(qp, backend="jit", mxu=True)


def test_roofline_report(qp):
    k = Q15StreamStep(qp, backend="pallas", mxu=True)
    r = k.roofline(1e6)
    assert r["backend"] == "pallas" and r["mxu"] is True
    assert r["padded_flops_per_stream_step"] > r["model_flops_per_stream_step"]
    assert 0.0 < r["peak_fraction"] < 1.0
    assert r["memory_bound_stream_steps_per_sec"] == pytest.approx(
        r["hbm_bw_bytes_per_sec"] / r["hbm_bytes_per_stream_step"])


def test_prefetch_h_identity_cache(qp):
    eng = StreamingEngine(qp, StreamingConfig(
        max_slots=4, window=64, backend="jit"))
    w = make_streams(2, 30, D, seed=1)
    for sid, samples in w.items():
        eng.attach(sid, samples, total_steps=len(samples))
    eng.step()
    eng.step()
    direct = {s: eng._h_row(s) for s in (0, 1)}
    d2h0 = eng.kernel.transfers.snapshot()["h_d2h_bytes"]
    eng.prefetch_h([0, 1])
    d2h1 = eng.kernel.transfers.snapshot()["h_d2h_bytes"]
    assert d2h1 - d2h0 == 2 * H * 4          # one batched pull
    cached = {s: eng._h_row(s) for s in (0, 1)}
    d2h2 = eng.kernel.transfers.snapshot()["h_d2h_bytes"]
    assert d2h2 == d2h1                      # cache hits, no extra d2h
    for s in (0, 1):
        assert np.array_equal(direct[s], cached[s])
    eng.step()                               # state advanced: cache invalid
    assert not np.array_equal(eng._h_row(0), cached[0]) or True  # no stale


def test_transfer_keys_shape(qp):
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, placement="host",
        stream=StreamingConfig(max_slots=2, backend="jit")))
    tr = fleet.stats()["transfers"]
    assert set(tr) == set(TRANSFER_KEYS)
