"""Multi-device semantics on an 8-fake-device CPU mesh.

Two tiers:

* **In-process smokes** (below, not slow) — conftest.py forces
  ``--xla_force_host_platform_device_count=8`` before jax initializes, so
  the pytest process itself has 8 host devices: device enumeration,
  per-device placement of jitted compute (the substrate the fleet's
  per-shard device placement rides — see tests/test_fleet.py), and a
  pmap collective.  These run on every jax this repo supports.
* **Explicit-sharding suite** (subprocess, slow) — verifies:
  sharded train step == single-device step numerically; vocab-parallel
  CE == plain CE; int8/bf16 compressed psum + error feedback; GPipe
  pipeline == sequential stages; checkpoint resharding across mesh
  shapes.  Drives the modern explicit-sharding APIs (jax.make_mesh with
  axis_types, jax.sharding.AxisType, top-level jax.shard_map); on
  containers pinned to older jax (e.g. 0.4.x) those tests — and only
  those — skip.
"""
import jax
import jax.numpy as jnp
import jax.sharding
import numpy as np
import pytest

from conftest import run_subprocess

_MISSING = [name for name, ok in [
    ("jax.sharding.AxisType", hasattr(jax.sharding, "AxisType")),
    ("jax.shard_map", hasattr(jax, "shard_map")),
    ("jax.make_mesh", hasattr(jax, "make_mesh")),
] if not ok]
needs_explicit_sharding = pytest.mark.skipif(
    bool(_MISSING),
    reason=f"jax {jax.__version__} lacks {', '.join(_MISSING)} "
           "(multi-host sharding suite needs the explicit-sharding APIs)")


# ---------------------------------------------------------------------------
# In-process multi-device smokes (every supported jax; not slow)
# ---------------------------------------------------------------------------

def test_host_devices_forced_in_process():
    """conftest.py's XLA_FLAGS setting took effect: the tier-1 process
    itself has >= 8 host devices, so multi-device paths (fleet shard
    placement included) are exercised without a subprocess."""
    assert jax.device_count() >= 8


def test_per_device_compute_placement():
    """device_put pins data AND the jitted computation that consumes it
    to each fake host device — the mechanism fleet shard placement uses."""
    results = []
    for i, dev in enumerate(jax.devices()[:4]):
        x = jax.device_put(jnp.arange(4.0) + i, dev)
        y = jax.jit(lambda v: (v * 2.0).sum())(x)
        assert y.devices() == {dev}
        results.append(float(y))
    assert results == [12.0, 20.0, 28.0, 36.0]


def test_pmap_collective_across_host_devices():
    n = jax.device_count()
    x = jnp.arange(n, dtype=jnp.float32)
    out = jax.pmap(lambda v: jax.lax.psum(v, "i"), axis_name="i")(x)
    np.testing.assert_allclose(np.asarray(out),
                               np.full(n, float(x.sum())))


# ---------------------------------------------------------------------------
# Explicit-sharding suite (subprocess; needs modern jax APIs)
# ---------------------------------------------------------------------------

@needs_explicit_sharding
@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
import repro.configs as C
from repro.models import registry
from repro.launch import sharding as sh
from repro.launch.mesh import make_host_mesh
from repro.train.optimizer import AdamConfig

cfg = C.reduced(C.get("deepseek-7b"), compute_dtype="float32", param_dtype="float32")
acfg = AdamConfig(state_dtype="float32")
params = registry.init(cfg, jax.random.PRNGKey(0))
import repro.train.optimizer as opt
opt_state = opt.init(params, acfg)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32)}

# single device reference
step1 = registry.make_train_step(cfg, acfg)
p1, o1, m1 = jax.jit(step1)(params, opt_state, batch)

# 4x2 mesh sharded
mesh = make_host_mesh(data=4, model=2)
pspecs = sh.param_pspecs(params, mesh)
n_p = sh.named(mesh, pspecs)
n_o = sh.named(mesh, sh.opt_pspecs(opt_state, pspecs))
bsp = {k: jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data", None)) for k in batch}
step2 = registry.make_train_step(cfg, acfg, mesh=mesh)
jf = jax.jit(step2, in_shardings=(n_p, n_o, bsp), out_shardings=(n_p, n_o, None))
p2, o2, m2 = jf(jax.device_put(params, n_p), jax.device_put(opt_state, n_o),
                jax.device_put(batch, bsp))
d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)-b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
print("param delta", d)
print("loss delta", abs(float(m1["loss"]) - float(m2["loss"])))
assert d < 2e-4, d
assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
print("OK")
""", devices=8)
    assert "OK" in out


@needs_explicit_sharding
@pytest.mark.slow
def test_compressed_psum_error_feedback():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.train.grad_compression import compressed_psum
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
g = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)).astype(np.float32))

def f(g):
    red, err = compressed_psum(g, "data", bits=8, error=None)
    return red, err
red, err = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=(P("data"), P("data")), check_vma=False))(g)
true_mean = g.mean(0)
red_np = np.asarray(red)
# every shard got the same mean; int8 error bounded by scale
for i in range(8):
    assert np.allclose(red_np[i], red_np[0])
q_err = np.abs(red_np[0] - np.asarray(true_mean)).max()
print("int8 psum err", q_err)
assert q_err < np.abs(g).max() / 127 + 1e-6
# error feedback: residual equals what was lost
total = np.asarray(err).sum(0) / 8 + red_np[0] - true_mean
assert np.abs(total).max() < 1e-5
print("OK")
""", devices=8)
    assert "OK" in out


@needs_explicit_sharding
@pytest.mark.slow
def test_vocab_parallel_ce_matches_plain():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import losses, layers as L
mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
rng = np.random.default_rng(0)
B, S, D, V = 4, 8, 16, 32
x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
w = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)   # tied table
y = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
plain = losses.vocab_parallel_ce(x, w, y, mesh=None, tied=True,
                                 z_loss=1e-4, compute_dtype=jnp.float32)
par = jax.jit(lambda x, w, y: losses.vocab_parallel_ce(
    x, w, y, mesh=mesh, tied=True, z_loss=1e-4,
    compute_dtype=jnp.float32))(x, w, y)
print("ce delta", abs(float(plain) - float(par)))
assert abs(float(plain) - float(par)) < 1e-4
# gradients too
g1 = jax.grad(lambda w: losses.vocab_parallel_ce(x, w, y, mesh=None, tied=True, z_loss=0.0, compute_dtype=jnp.float32))(w)
g2 = jax.jit(jax.grad(lambda w: losses.vocab_parallel_ce(x, w, y, mesh=mesh, tied=True, z_loss=0.0, compute_dtype=jnp.float32)))(w)
gd = float(jnp.max(jnp.abs(g1 - g2)))
print("grad delta", gd)
assert gd < 1e-4
print("OK")
""", devices=8)
    assert "OK" in out


@needs_explicit_sharding
@pytest.mark.slow
def test_pipeline_matches_sequential():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.train.pipeline import pipeline_apply
mesh = jax.make_mesh((4,), ("stage",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
P_, M, b, d = 4, 6, 3, 8
Ws = jnp.asarray(rng.normal(size=(P_, d, d)) * 0.3, jnp.float32)
x = jnp.asarray(rng.normal(size=(M, b, d)), jnp.float32)

def stage_fn(w, h):
    return jnp.tanh(h @ w)

out_p = jax.jit(lambda Ws, x: pipeline_apply(stage_fn, Ws, x, mesh=mesh))(Ws, x)
ref = x
for s in range(P_):
    ref = jnp.tanh(ref @ Ws[s])
d_ = float(jnp.max(jnp.abs(out_p - ref)))
print("pipeline delta", d_)
assert d_ < 1e-5
print("OK")
""", devices=4)
    assert "OK" in out


@needs_explicit_sharding
@pytest.mark.slow
def test_checkpoint_elastic_resharding():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.train import checkpoint as ckpt
tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
d = tempfile.mkdtemp()
# save from a 8x1 'mesh' (full arrays — mesh-agnostic by design)
mesh_a = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
tree_a = jax.device_put(tree, {"w": NamedSharding(mesh_a, P("data", None))})
ckpt.save(d, 1, tree_a)
# restore onto a DIFFERENT mesh shape (elastic resize: 8 -> 4 devices x 2 model)
mesh_b = jax.make_mesh((2, 2), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
sh = {"w": NamedSharding(mesh_b, P("data", "model"))}
out = ckpt.restore(d, 1, tree, shardings=sh)
assert out["w"].sharding == sh["w"]
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
print("OK")
""", devices=8)
    assert "OK" in out


@needs_explicit_sharding
@pytest.mark.slow
def test_sp_dense_and_splitkv_match_reference():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
import repro.configs as C
from repro.models import transformer as T
mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)

# Megatron-SP dense (both KV layouts)
for kv in (4, 2):
    cfg = C.reduced(C.get("deepseek-7b"), compute_dtype="float32",
                    param_dtype="float32", num_heads=4, num_kv_heads=kv)
    params = T.init(cfg, jax.random.PRNGKey(0))
    toks = np.random.default_rng(1).integers(0, cfg.vocab_size, (4, 32))
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    ref, _ = T.train_loss(cfg, params, batch)
    sp, _ = jax.jit(lambda p, b: T.train_loss(cfg, p, b, mesh=mesh,
                                              seq_parallel=True)[0:2])(params, batch)
    assert abs(float(ref) - float(sp)) < 1e-4, (kv, float(ref), float(sp))

# flash-decoding split-KV
cfg = C.reduced(C.get("minitron-4b"), compute_dtype="float32",
                param_dtype="float32", num_heads=4, num_kv_heads=1)
params = T.init(cfg, jax.random.PRNGKey(0))
toks = np.random.default_rng(2).integers(0, cfg.vocab_size, (2, 8))
full, _, _ = T.forward(cfg, params, {"tokens": jnp.asarray(toks)})
cache = T.init_cache(cfg, 2, 12, dtype=jnp.float32)
step = jax.jit(lambda p, c, t: T.decode_step(cfg, p, c, t, mesh=mesh, splitkv=True))
for t in range(8):
    lg, cache = step(params, cache, jnp.asarray(toks[:, t:t+1]))
    assert float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))) < 1e-3, t
print("OK")
""", devices=8)
    assert "OK" in out
