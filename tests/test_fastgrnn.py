"""FastGRNN cell: paper Eq. (1)-(4), Table I/IV parameter accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fastgrnn as fg
from repro.models import baselines


def test_param_count_full_rank_matches_paper_eq4():
    cfg = fg.FastGRNNConfig()          # H=16, d=3
    assert cfg.cell_param_count() == 338           # 48 + 256 + 32 + 2
    assert cfg.head_param_count() == 102           # 16*6 + 6
    params = fg.init_params(cfg, jax.random.PRNGKey(0))
    assert fg.count_params(params) == 440          # Table II row 1


def test_param_count_low_rank_matches_table2():
    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    assert cfg.cell_param_count() == 328
    params = fg.init_params(cfg, jax.random.PRNGKey(0))
    assert fg.count_params(params) == 430          # Table II row 2


def test_baseline_param_counts_match_table4():
    assert baselines.mlp_param_count() == 12_518
    assert baselines.lstm_param_count() == 1_280
    assert baselines.gru_param_count() == 960


def test_cell_step_matches_manual_equations():
    cfg = fg.FastGRNNConfig()
    p = fg.init_params(cfg, jax.random.PRNGKey(1))
    x = jnp.asarray(np.random.randn(3).astype(np.float32))
    h = jnp.asarray(np.random.randn(16).astype(np.float32))
    pre = p["W"] @ x + p["U"] @ h
    z = jax.nn.sigmoid(pre + p["b_z"])
    h_t = jnp.tanh(pre + p["b_h"])
    zeta = jax.nn.sigmoid(p["zeta"])
    nu = jax.nn.sigmoid(p["nu"])
    expected = (zeta * (1 - z) + nu) * h_t + z * h
    got = fg.cell_step(p, h, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expected),
                               rtol=1e-6, atol=1e-6)


def test_low_rank_equals_dense_product():
    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    p = fg.init_params(cfg, jax.random.PRNGKey(2))
    dense = dict(p)
    dense["W"] = fg.effective_W(p)
    dense["U"] = fg.effective_U(p)
    for k in ("W1", "W2", "U1", "U2"):
        dense.pop(k)
    x = jnp.asarray(np.random.randn(4, 3).astype(np.float32))
    h = jnp.asarray(np.random.randn(4, 16).astype(np.float32))
    np.testing.assert_allclose(np.asarray(fg.cell_step(p, h, x)),
                               np.asarray(fg.cell_step(dense, h, x)),
                               rtol=1e-5, atol=1e-5)


def test_run_sequence_trajectory_consistent():
    cfg = fg.FastGRNNConfig()
    p = fg.init_params(cfg, jax.random.PRNGKey(3))
    xs = jnp.asarray(np.random.randn(10, 2, 3).astype(np.float32))
    h_final, traj = fg.run_sequence(p, xs, return_trajectory=True)
    np.testing.assert_allclose(np.asarray(traj[-1]), np.asarray(h_final))
    # step-by-step agrees with scan
    h = jnp.zeros((2, 16))
    for t in range(10):
        h = fg.cell_step(p, h, xs[t])
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_final),
                               rtol=1e-5, atol=1e-6)


def test_loss_decreases_with_training_step():
    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    p = fg.init_params(cfg, jax.random.PRNGKey(4))
    xs = jnp.asarray(np.random.randn(16, 8, 3).astype(np.float32))
    ys = jnp.asarray(np.random.randint(0, 6, 8))
    loss0, grads = jax.value_and_grad(fg.loss_fn)(p, xs, ys)
    p2 = jax.tree.map(lambda w, g: w - 0.05 * g, p, grads)
    loss1 = fg.loss_fn(p2, xs, ys)
    assert float(loss1) < float(loss0)


def test_dual_rank_diag_residual():
    """Paper Sec. VI-E direction 1: U_eff = LowRank(r) + diag(alpha)."""
    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=4, diag_residual=True)
    assert cfg.cell_param_count() == 216       # 38 + 128 + 16 + 32 + 2
    p = fg.init_params(cfg, jax.random.PRNGKey(0))
    assert "alpha" in p
    # effective U includes the diagonal
    u = fg.effective_U(p)
    np.testing.assert_allclose(np.diag(np.asarray(u)),
                               np.diag(np.asarray(p["U1"] @ p["U2"].T))
                               + np.asarray(p["alpha"]), rtol=1e-6)
    # cell_step consistent with the dense expansion
    dense = {k: v for k, v in p.items() if k not in ("U1", "U2", "alpha")}
    dense["U"] = u
    x = jnp.asarray(np.random.randn(4, 3).astype(np.float32))
    h = jnp.asarray(np.random.randn(4, 16).astype(np.float32))
    np.testing.assert_allclose(np.asarray(fg.cell_step(p, h, x)),
                               np.asarray(fg.cell_step(dense, h, x)),
                               rtol=1e-5, atol=1e-5)
