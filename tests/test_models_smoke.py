"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of the same family runs one forward/train step on CPU, asserting output
shapes and finiteness.  The FULL configs are exercised only via the
dry-run (launch/dryrun.py, ShapeDtypeStruct — no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import transformer as T

ARCHS = list(C.ARCHS)


def _batch(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    b = {}
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    else:
        b["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.family == "vlm":
        b["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, cfg.d_model)), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = C.reduced(C.get(arch))
    params = T.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    (loss, metrics), grads = jax.jit(
        lambda p, b: jax.value_and_grad(
            lambda q: T.train_loss(cfg, q, b), has_aux=True)(p))(params, batch)
    assert np.isfinite(float(loss)), arch
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if C.get(a).has_decode])
def test_reduced_decode_step(arch):
    cfg = C.reduced(C.get(arch))
    params = T.init(cfg, jax.random.PRNGKey(0))
    B = 2
    cache = T.init_cache(cfg, B, 24)
    logits, cache2 = jax.jit(lambda p, c, t: T.decode_step(cfg, p, c, t))(
        params, cache, jnp.ones((B, 1), jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert int(cache2["len"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes(arch):
    cfg = C.reduced(C.get(arch))
    params = T.init(cfg, jax.random.PRNGKey(1))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, aux, _ = T.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


def test_full_config_parameter_counts_sane():
    """Full configs are never materialized — but eval_shape param counts
    must land in the right ballpark for each architecture."""
    from repro.models import registry
    expected = {  # billions, loose bands from the source papers
        "minitron-4b": (3.5, 5.5), "qwen2-1.5b": (1.2, 2.0),
        "deepseek-7b": (6.0, 8.0), "nemotron-4-340b": (300, 380),
        # moonshot: the assigned config (48L, 64e x swiglu(1408) every
        # layer) counts 28B; the shipping 16B model makes some layers
        # dense/shared-expert, which the assignment spec does not encode.
        "olmoe-1b-7b": (6.0, 8.0), "moonshot-v1-16b-a3b": (14, 30),
        "internvl2-76b": (65, 80), "zamba2-1.2b": (0.9, 1.6),
        "hubert-xlarge": (0.7, 1.3), "mamba2-780m": (0.6, 1.0),
    }
    for arch, (lo, hi) in expected.items():
        n = registry.param_count(C.get(arch)) / 1e9
        assert lo <= n <= hi, (arch, n)
