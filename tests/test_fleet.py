"""Sharded fleet serving (serve/fleet): the bit-exactness gate, routing,
migration, spillover, decommission, counter composition, and device
placement.

The load-bearing contract (ISSUE acceptance): for every stream,
``FleetEngine`` outputs — logits, warm-up flags, step counters,
trajectories — are byte-identical to the single-engine
``StreamingEngine`` reference at 1, 2, 4 and 8 shards, including across
forced mid-stream migrations.  That is what makes the fleet a serving
core rather than a demo."""
import dataclasses
import random

import jax
import numpy as np
import pytest

from repro.core import fastgrnn as fg
from repro.core.qruntime import QRuntime
from repro.core.quantization import quantize_params, QuantConfig
from repro.data import hapt
from repro.serve.fleet import (FleetConfig, FleetEngine, hrw_weight,
                               rank_shards, route, shard_devices)
from repro.serve.streaming import (StreamEventBatch, StreamingConfig,
                                   StreamingEngine, classify_windows)


def _model(seed=0):
    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    return quantize_params(fg.init_params(cfg, jax.random.PRNGKey(seed)),
                           QuantConfig())


@pytest.fixture(scope="module")
def qp():
    return _model()


@pytest.fixture(scope="module")
def windows():
    return hapt.load("test", n=120).windows


@pytest.fixture(scope="module")
def ref_logits(qp, windows):
    rt = QRuntime(qp)
    return np.stack([rt.run_window(w) for w in windows])


def _collect(events):
    """Map stream_id -> last event fields, expanding columnar batches."""
    out = {}
    for e in events:
        if isinstance(e, StreamEventBatch):
            for ev in e.events():
                out[ev.stream_id] = ev
        else:
            out[e.stream_id] = e
    return out


# ---------------------------------------------------------------------------
# Acceptance gate: bit-identical to the single engine at 1/2/4/8 shards
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shards", [1, 2, 4, 8])
def test_fleet_bit_identical_across_shard_counts(qp, windows, ref_logits,
                                                 shards):
    n = 64
    fleet = FleetEngine(qp, FleetConfig(
        shards=shards, stream=StreamingConfig(max_slots=16)))
    for i in range(n):
        fleet.attach(f"s{i}", windows[i], total_steps=len(windows[i]))
    by_id = _collect(fleet.drain())
    assert len(by_id) == n
    got = np.stack([by_id[f"s{i}"].logits for i in range(n)])
    np.testing.assert_array_equal(got.view(np.int32),
                                  ref_logits[:n].view(np.int32))
    for i in range(n):
        ev = by_id[f"s{i}"]
        assert ev.step == 128 and ev.warm     # counters identical too
        assert ev.prediction == int(np.argmax(ref_logits[i]))
    st = fleet.stats()
    assert st["completed"] == n and st["stream_steps"] == n * 128


@pytest.mark.parametrize("shards", [2, 4, 8])
def test_fleet_bit_identical_across_forced_migration(qp, windows,
                                                     ref_logits, shards):
    """Mid-stream migration (hidden state + buffered samples + counters
    move shards) must not perturb a single bit of any stream — migrated
    or bystander."""
    n = 32
    fleet = FleetEngine(qp, FleetConfig(
        shards=shards, stream=StreamingConfig(max_slots=16)))
    for i in range(n):
        fleet.attach(f"s{i}", windows[i], total_steps=128)
    for _ in range(37):                      # advance mid-window
        fleet.step()
    for i in range(0, n, 3):                 # force-migrate a third of them
        src = fleet.shard_of(f"s{i}")
        fleet.migrate(f"s{i}", (src + 1) % shards)
    for _ in range(20):
        fleet.step()
    fleet.migrate("s0")                      # second hop for one stream
    by_id = _collect(fleet.drain())
    got = np.stack([by_id[f"s{i}"].logits for i in range(n)])
    np.testing.assert_array_equal(got.view(np.int32),
                                  ref_logits[:n].view(np.int32))
    assert fleet.stats()["migrations"] == n // 3 + (n % 3 > 0) + 1


def test_fleet_parity_smoke_4x64(qp, windows, ref_logits):
    """The CI fleet-parity smoke: 4 shards x 64 streams vs the single
    engine, via the shared classify_windows driver (which runs unchanged
    against a fleet)."""
    fleet = FleetEngine(qp, FleetConfig(
        shards=4, stream=StreamingConfig(max_slots=16)))
    preds = classify_windows(fleet, windows[:64])
    np.testing.assert_array_equal(preds, np.argmax(ref_logits[:64], axis=1))


def test_migrated_trajectory_bit_identical(qp, windows):
    """detach-state -> migrate -> re-attach must reproduce the single
    engine's per-step hidden trajectory bit-exactly (satellite gate)."""
    fleet = FleetEngine(qp, FleetConfig(
        shards=4, stream=StreamingConfig(max_slots=8)))
    fleet.attach("t", windows[0], total_steps=128, record_trajectory=True)
    for _ in range(50):
        fleet.step()
    src = fleet.shard_of("t")
    fleet.migrate("t", (src + 2) % 4)
    fleet.drain()
    single = StreamingEngine(qp, StreamingConfig(max_slots=4))
    single.attach("t", windows[0], total_steps=128, record_trajectory=True)
    single.drain()
    np.testing.assert_array_equal(fleet.trajectory("t").view(np.int32),
                                  single.trajectory("t").view(np.int32))


# ---------------------------------------------------------------------------
# Rendezvous routing
# ---------------------------------------------------------------------------

def test_hrw_routing_deterministic_and_total():
    keys = [f"shard-{i}" for i in range(8)]
    assert hrw_weight("stream-a", "shard-0") == hrw_weight("stream-a",
                                                           "shard-0")
    homes = [route(f"stream-{i}", keys) for i in range(512)]
    assert homes == [route(f"stream-{i}", keys) for i in range(512)]
    counts = np.bincount(homes, minlength=8)
    assert (counts > 0).all()                # every shard gets traffic
    ranked = rank_shards("stream-x", keys)
    assert sorted(ranked) == list(range(8))  # a permutation
    assert ranked[0] == route("stream-x", keys)


def test_hrw_stable_under_shard_removal():
    """Removing one shard remaps ONLY that shard's streams (each to its
    next-best shard); every other stream keeps its home — the property
    drain/decommission relies on."""
    keys = [f"shard-{i}" for i in range(8)]
    sids = [f"stream-{i}" for i in range(400)]
    before = {s: route(s, keys) for s in sids}
    eligible = [i != 3 for i in range(8)]
    for s in sids:
        after = route(s, keys, eligible)
        if before[s] != 3:
            assert after == before[s]
        else:
            assert after == rank_shards(s, keys)[1]  # next-best


def test_route_requires_eligible_shard():
    with pytest.raises(ValueError):
        route("s", ["a", "b"], [False, False])


# ---------------------------------------------------------------------------
# Admission spillover + lifecycle
# ---------------------------------------------------------------------------

def test_spillover_queue_fifo_and_bit_exact(qp, windows, ref_logits):
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=2),
        max_pending_per_shard=1))
    statuses = [fleet.attach(f"s{i}", windows[i], total_steps=128)
                for i in range(12)]
    assert statuses.count("spilled") >= 1    # the queue was exercised
    assert fleet.n_spilled == statuses.count("spilled")
    by_id = _collect(fleet.drain())
    got = np.stack([by_id[f"s{i}"].logits for i in range(12)])
    np.testing.assert_array_equal(got.view(np.int32),
                                  ref_logits[:12].view(np.int32))
    st = fleet.stats()
    assert st["global_spills"] == statuses.count("spilled")
    assert st["completed"] == 12 and st["spilled"] == 0


def test_feed_and_detach_on_spilled_stream(qp, windows):
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=1),
        max_pending_per_shard=0))
    for i in range(2):
        fleet.attach(f"fill{i}", windows[i])          # open-ended: pin slots
    assert fleet.attach("late", windows[2][:10]) == "spilled"
    fleet.feed("late", windows[2][10:20])             # buffers while spilled
    assert fleet.shard_of("late") == -1
    assert fleet.detach("late") is None               # dequeued, no event
    with pytest.raises(KeyError):
        fleet.feed("late", windows[2])
    fleet.attach("late", windows[2], total_steps=128)  # id reusable


def test_stream_id_reusable_after_completion(qp, windows):
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=4)))
    fleet.attach("s", windows[0], total_steps=128)
    fleet.drain()
    fleet.attach("s", windows[1], total_steps=128)    # stale owner reclaimed
    by_id = _collect(fleet.drain())
    assert by_id["s"].prediction == int(
        np.argmax(QRuntime(qp).run_window(windows[1])))


def test_duplicate_attach_rejected(qp, windows):
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=4)))
    fleet.attach("s", windows[0])
    with pytest.raises(ValueError):
        fleet.attach("s", windows[1])


# ---------------------------------------------------------------------------
# Decommission / recommission
# ---------------------------------------------------------------------------

def test_decommission_drains_shard_and_preserves_results(qp, windows,
                                                         ref_logits):
    fleet = FleetEngine(qp, FleetConfig(
        shards=4, stream=StreamingConfig(max_slots=16)))
    homes = {}
    for i in range(32):
        fleet.attach(f"s{i}", windows[i], total_steps=128)
        homes[f"s{i}"] = fleet.shard_of(f"s{i}")
    for _ in range(11):
        fleet.step()
    moved = fleet.decommission(1)
    assert set(moved) == {s for s, h in homes.items() if h == 1}
    for sid, home in homes.items():
        if home != 1:
            assert fleet.shard_of(sid) == home        # bystanders untouched
        else:
            assert fleet.shard_of(sid) != 1
    assert fleet.attach("new", windows[40], total_steps=128) in ("active",
                                                                 "pending")
    assert fleet.shard_of("new") != 1                 # not routed to drained
    by_id = _collect(fleet.drain())
    got = np.stack([by_id[f"s{i}"].logits for i in range(32)])
    np.testing.assert_array_equal(got.view(np.int32),
                                  ref_logits[:32].view(np.int32))
    fleet.recommission(1)
    assert fleet.stats()["routable"] == [True] * 4


def test_migrate_refuses_decommissioned_destination(qp, windows):
    """A drained shard must stay empty until recommission — an explicit
    migrate onto it is an error, not a silent re-population."""
    fleet = FleetEngine(qp, FleetConfig(
        shards=3, stream=StreamingConfig(max_slots=4)))
    fleet.attach("s", windows[0], total_steps=128)
    src = fleet.shard_of("s")
    dead = next(i for i in range(3) if i != src)
    fleet.decommission(dead)
    with pytest.raises(ValueError, match="decommissioned"):
        fleet.migrate("s", dead)
    fleet.recommission(dead)
    assert fleet.migrate("s", dead) in ("active", "pending")


def test_migrate_without_destination_needs_another_routable_shard(qp,
                                                                  windows):
    fleet = FleetEngine(qp, FleetConfig(
        shards=1, stream=StreamingConfig(max_slots=4)))
    fleet.attach("s", windows[0], total_steps=128)
    with pytest.raises(ValueError, match="no routable destination"):
        fleet.migrate("s")


def test_cannot_decommission_last_shard(qp):
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=2)))
    fleet.decommission(0)
    with pytest.raises(ValueError):
        fleet.decommission(1)


# ---------------------------------------------------------------------------
# Counter composition (satellite: fleet stats == sum of shard counters)
# ---------------------------------------------------------------------------

def test_counters_compose_under_random_lifecycle(qp, windows):
    """Property: after any random admit / feed / migrate / detach / step
    sequence, every composed counter in fleet.stats()['scheduler'] equals
    the sum over per-shard schedulers, and the workload roll-ups equal
    the per-shard sums."""
    rng = random.Random(1234)
    fleet = FleetEngine(qp, FleetConfig(
        shards=3, stream=StreamingConfig(max_slots=4),
        max_pending_per_shard=1))
    live, next_id = [], 0
    for _ in range(220):
        op = rng.random()
        if op < 0.35:
            sid = f"r{next_id}"
            next_id += 1
            k = rng.randrange(0, 64)
            total = rng.choice([None, 32, 128])
            fleet.attach(sid, windows[rng.randrange(len(windows))][:k]
                         if k else None, total_steps=total)
            live.append(sid)
        elif op < 0.5 and live:
            sid = live.pop(rng.randrange(len(live)))
            try:
                fleet.detach(sid)
            except KeyError:
                pass                      # finished on its own: stale id
        elif op < 0.6 and live:
            sid = rng.choice(live)
            try:
                fleet.migrate(sid, rng.randrange(3))
            except (KeyError, ValueError):
                pass                      # spilled / same-shard / finished
        elif op < 0.75 and live:
            fleet.feed(rng.choice(live),
                       windows[rng.randrange(len(windows))][:8])
        else:
            fleet.step()
    st = fleet.stats()
    per = [p["scheduler"] for p in st["per_shard"]]
    for key in ("admissions", "recycles", "spills", "completed",
                "cancelled", "evictions", "ticks", "active", "pending",
                "peak_active"):
        assert st["scheduler"][key] == sum(p[key] for p in per), key
    for key in ("active", "pending", "completed", "stream_steps",
                "ring_spills"):
        assert st[key] == sum(p[key] for p in st["per_shard"]), key
    total_slots = sum(p["max_slots"] for p in per)
    assert st["scheduler"]["occupancy"] == \
        st["scheduler"]["active"] / total_slots


def test_random_lifecycle_matches_reference_predictions(qp, windows):
    """Under a random admit/spill/migrate/release schedule every finished
    window still matches the scalar reference bit for bit."""
    rng = random.Random(7)
    fleet = FleetEngine(qp, FleetConfig(
        shards=4, stream=StreamingConfig(max_slots=3),
        max_pending_per_shard=2))
    events = []
    for i in range(24):
        fleet.attach(f"w{i}", windows[i], total_steps=128)
        for _ in range(rng.randrange(0, 30)):
            events.extend(fleet.step())
        if i % 5 == 0:
            try:
                fleet.migrate(f"w{rng.randrange(i + 1)}")
            except (KeyError, ValueError):
                pass
    events.extend(fleet.drain())
    by_id = _collect(events)
    rt = QRuntime(qp)
    for i in range(24):
        np.testing.assert_array_equal(
            by_id[f"w{i}"].logits.view(np.int32),
            rt.run_window(windows[i]).view(np.int32))


# ---------------------------------------------------------------------------
# Columnar event mode
# ---------------------------------------------------------------------------

def test_batch_events_carry_identical_content(qp, windows):
    cfg = FleetConfig(shards=2,
                      stream=StreamingConfig(max_slots=8, batch_events=True))
    fleet = FleetEngine(qp, cfg)
    for i in range(8):
        fleet.attach(f"s{i}", windows[i][:40], total_steps=40)
    events = fleet.drain()
    assert all(isinstance(e, StreamEventBatch) for e in events)
    by_id = _collect(events)          # expands via StreamEventBatch.events()
    single = StreamingEngine(qp, StreamingConfig(max_slots=8))
    for i in range(8):
        single.attach(f"s{i}", windows[i][:40], total_steps=40)
    ref = {e.stream_id: e for e in single.drain()}
    assert set(by_id) == set(ref)
    for sid, ev in by_id.items():
        assert (ev.kind, ev.step, ev.window_step, ev.prediction, ev.warm) \
            == (ref[sid].kind, ref[sid].step, ref[sid].window_step,
                ref[sid].prediction, ref[sid].warm)
        np.testing.assert_array_equal(ev.logits.view(np.int32),
                                      ref[sid].logits.view(np.int32))


# ---------------------------------------------------------------------------
# Device placement + fast backends
# ---------------------------------------------------------------------------

def test_shard_devices_fallbacks():
    assert shard_devices(4, "host", "jit") == [None] * 4
    assert shard_devices(4, "auto", "exact") == [None] * 4
    with pytest.raises(ValueError):
        shard_devices(2, "nope", "jit")


def test_fleet_on_distinct_devices(qp, windows, ref_logits):
    """jit shards placed on distinct fake host devices (conftest forces
    8) still produce reference predictions; stats reports the placement."""
    devs = shard_devices(4, "devices", "jit")
    assert len({str(d) for d in devs}) == 4      # genuinely distinct
    fleet = FleetEngine(qp, FleetConfig(
        shards=4, placement="devices",
        stream=StreamingConfig(max_slots=8, backend="jit")))
    st_devices = fleet.stats()["devices"]
    assert len(set(st_devices)) == 4
    preds = classify_windows(fleet, windows[:24])
    np.testing.assert_array_equal(preds, np.argmax(ref_logits[:24], axis=1))


@pytest.mark.parametrize("backend", ["jit", "pallas"])
def test_fast_backends_agree_on_predictions(qp, windows, ref_logits,
                                            backend):
    n = 24 if backend == "jit" else 12
    fleet = FleetEngine(qp, FleetConfig(
        shards=3, placement="host",
        stream=StreamingConfig(max_slots=8, backend=backend)))
    preds = classify_windows(fleet, windows[:n])
    np.testing.assert_array_equal(preds, np.argmax(ref_logits[:n], axis=1))


# ---------------------------------------------------------------------------
# Engine-level export/import (the migration primitive)
# ---------------------------------------------------------------------------

def test_export_import_resident_stream_bit_exact(qp, windows):
    a = StreamingEngine(qp, StreamingConfig(max_slots=4))
    b = StreamingEngine(qp, StreamingConfig(max_slots=4))
    a.attach("s", windows[0], total_steps=128)
    busy = [a.attach(f"b{i}", windows[i + 1], total_steps=128)
            for i in range(2)]
    assert busy == ["active"] * 2
    for _ in range(53):
        a.step()
    state = a.export_stream("s")
    assert state.steps == 53 and state.samples.shape == (75, 3)
    assert a.n_active == 2                     # slot freed, no event emitted
    assert b.import_stream(state) == "active"
    ev = [e for e in b.drain() if e.stream_id == "s"][0]
    np.testing.assert_array_equal(
        ev.logits.view(np.int32),
        QRuntime(qp).run_window(windows[0]).view(np.int32))
    sched_a = a.stats()["scheduler"]
    assert sched_a["evictions"] == 1 and sched_a["cancelled"] == 0


def test_export_pending_stream_restores_cleanly(qp, windows):
    a = StreamingEngine(qp, StreamingConfig(max_slots=1))
    a.attach("r", windows[0], total_steps=128)
    assert a.attach("p", windows[1], total_steps=128) == "pending"
    state = a.export_stream("p")
    assert state.steps == 0 and len(state.samples) == 128
    b = StreamingEngine(qp, StreamingConfig(max_slots=1))
    b.import_stream(state)
    ev = b.drain()[0]
    np.testing.assert_array_equal(
        ev.logits.view(np.int32),
        QRuntime(qp).run_window(windows[1]).view(np.int32))


def test_reexport_of_pending_migrated_stream_keeps_state(qp, windows):
    """Regression: a migrated-in stream still waiting in the destination's
    pending queue carries restored state on its session; exporting it
    AGAIN (second migration, decommission of the destination) must carry
    that state onward, not rewind the stream to zero."""
    a = StreamingEngine(qp, StreamingConfig(max_slots=2))
    a.attach("s", windows[0], total_steps=128)
    for _ in range(40):
        a.step()
    state = a.export_stream("s")
    b = StreamingEngine(qp, StreamingConfig(max_slots=1))
    b.attach("pin", windows[1])              # open stream pins the only slot
    assert b.import_stream(state) == "pending"
    state2 = b.export_stream("s")            # second hop while still pending
    assert state2.steps == 40 and len(state2.samples) == 88
    np.testing.assert_array_equal(state2.h.view(np.int32),
                                  state.h.view(np.int32))
    c = StreamingEngine(qp, StreamingConfig(max_slots=1))
    c.import_stream(state2)
    ev = [e for e in c.drain() if e.stream_id == "s"][0]
    np.testing.assert_array_equal(
        ev.logits.view(np.int32),
        QRuntime(qp).run_window(windows[0]).view(np.int32))


def test_owner_map_compacts_in_long_running_fleet(qp, windows):
    """Finished streams must not grow the fleet's owner map forever:
    compaction drops finished ids but keeps live streams and tapped
    (trajectory-recorded) ones, so post-completion ``trajectory()``
    still resolves.  (step() invokes it automatically once the stale
    entries outnumber 2x live + 1024.)"""
    fleet = FleetEngine(qp, FleetConfig(
        shards=2, stream=StreamingConfig(max_slots=8)))
    for g in range(40):
        fleet.attach(f"g{g}", windows[g % len(windows)][:8], total_steps=8)
    fleet.attach("tapped", windows[0][:8], total_steps=8,
                 record_trajectory=True)
    fleet.attach("live", windows[1])             # open-ended, stays attached
    fleet.drain()
    assert fleet.stats()["completed"] == 41
    assert len(fleet._owner) == 42               # finished ids still held...
    fleet._compact_owners()
    assert set(fleet._owner) == {"tapped", "live"}   # ...until compaction
    assert fleet.trajectory("tapped").shape == (8, 16)


def test_export_unknown_stream_raises(qp):
    eng = StreamingEngine(qp, StreamingConfig(max_slots=2))
    with pytest.raises(KeyError):
        eng.export_stream("ghost")
