"""Warm-up characterization (Sec. VI-A) + data pipelines."""
import numpy as np

from repro.core import warmup
from repro.data import hapt, tokens


def test_stabilization_step_cases():
    assert warmup.stabilization_step(np.array([2, 2, 2])) == 1
    assert warmup.stabilization_step(np.array([0, 1, 2, 2, 2])) == 3
    assert warmup.stabilization_step(np.array([1, 1, 1, 0])) == 4
    assert warmup.stabilization_step(np.array([0, 1])) == 2


def test_characterize_stats():
    preds = np.array([[0, 0, 1, 1, 1], [1, 1, 1, 1, 1], [0, 1, 0, 2, 2]])
    st = warmup.characterize(preds)
    assert st.worst_case == 4
    assert st.n_windows == 3
    assert st.median_samples == 3.0
    assert abs(st.median_seconds - 3 / 50) < 1e-9


def test_hapt_shapes_and_counts():
    s = hapt.load("val")
    assert s.windows.shape == (1515, 128, 3)
    assert s.labels.min() >= 0 and s.labels.max() < 6
    assert set(np.unique(s.subjects)) <= set(range(22, 26))


def test_hapt_subject_disjoint_splits():
    tr = hapt.load("train", n=300)
    te = hapt.load("test", n=300)
    assert not (set(np.unique(tr.subjects)) & set(np.unique(te.subjects)))


def test_hapt_deterministic():
    a = hapt.generate_synthetic("test", seed=0, n=50)
    b = hapt.generate_synthetic("test", seed=0, n=50)
    np.testing.assert_array_equal(a.windows, b.windows)
    np.testing.assert_array_equal(a.labels, b.labels)


def test_hapt_classes_distinguishable():
    """Per-class signal statistics must differ (else the task is vacuous)."""
    s = hapt.load("train", n=600)
    stds = [s.windows[s.labels == c][..., 2].std() for c in range(6)]
    assert max(stds) / (min(stds) + 1e-9) > 2.0     # dynamic vs static


def test_token_stream_deterministic_and_seekable():
    cfg = tokens.TokenStreamConfig(vocab_size=100, seq_len=32, global_batch=8)
    a = tokens.batch_at(cfg, step=7, shard=2, num_shards=4)
    b = tokens.batch_at(cfg, step=7, shard=2, num_shards=4)
    np.testing.assert_array_equal(a, b)
    c = tokens.batch_at(cfg, step=8, shard=2, num_shards=4)
    assert not np.array_equal(a, c)


def test_token_stream_shard_disjoint():
    cfg = tokens.TokenStreamConfig(vocab_size=1000, seq_len=64, global_batch=8)
    a = tokens.batch_at(cfg, step=0, shard=0, num_shards=4)
    b = tokens.batch_at(cfg, step=0, shard=1, num_shards=4)
    assert not np.array_equal(a, b)
    assert a.shape == (2, 65)


def test_lm_batch_shift():
    cfg = tokens.TokenStreamConfig(vocab_size=50, seq_len=16, global_batch=2)
    batch = tokens.lm_batch(cfg, 0)
    np.testing.assert_array_equal(batch["tokens"][:, 1:], batch["labels"][:, :-1])
