"""qvm integer edge cases, pinned at the exact boundaries qlint proves.

The abstract interpreter (``repro.analysis.qlint``) proves these
behaviors over intervals; this module pins them concretely, value by
value, and cross-checks the extreme inputs against the emitted-C twin:

* int16 saturation at both boundaries from both sides (±32767, ∓32768);
* the INT16_MIN negation hazard (the qvm computes the gate path in
  int64, so ``-(-32768)`` is representable end-to-end);
* requant round-shift extremes: ``sh=1``, ``sh=62``, the underflow form
  ``m=0``, a nonzero floor preshift, and the too-large-factor rejection
  (``quantize_multiplier`` never emits ``sh=0`` — the round constant
  ``1 << (sh-1)`` requires ``sh >= 1``);
* LUT index clamping to entries 0 and 255 at the fine-scale extremes.
"""
import tempfile

import numpy as np
import pytest

from repro.core.lut import LUT_SIZE
from repro.deploy import QVM, build_reference_model, emit_c
from repro.deploy.qvm import (FINE_CLIP, I16_MAX, I16_MIN, Q15_ONE, Requant,
                              _LUT_IDX0, quantize_multiplier, sat16)


@pytest.fixture(scope="module")
def model():
    return build_reference_model(seed=0)


@pytest.fixture(scope="module")
def vm(model):
    return QVM(model[2])


# ---------------------------------------------------------------------------
# int16 saturation boundaries
# ---------------------------------------------------------------------------

def test_sat16_exact_at_both_boundaries():
    """One step past each boundary clamps; the boundary itself and one
    step inside pass through untouched."""
    v = np.array([I16_MIN - 1, I16_MIN, I16_MIN + 1,
                  I16_MAX - 1, I16_MAX, I16_MAX + 1], np.int64)
    np.testing.assert_array_equal(
        sat16(v),
        [I16_MIN, I16_MIN, I16_MIN + 1, I16_MAX - 1, I16_MAX, I16_MAX])


def test_step_saturates_never_wraps(vm):
    """Driving the recurrence with all four input/state extremes keeps
    every stored state inside int16 — saturation, not wraparound (a wrap
    would flip sign near the boundary)."""
    H, d = vm.plan.H, vm.plan.d
    for hval in (I16_MIN, I16_MAX):
        for xval in (I16_MIN, I16_MAX):
            hq = np.full((2, H), hval, np.int16)
            xq = np.full((2, d), xval, np.int16)
            out = vm.step(hq, xq)
            assert out.dtype == np.int16
            assert int(out.min()) >= I16_MIN and int(out.max()) <= I16_MAX


# ---------------------------------------------------------------------------
# INT16_MIN negation hazard
# ---------------------------------------------------------------------------

def test_int16_min_survives_gate_path(vm):
    """h = -32768 everywhere: the gate terms ``(Q15_ONE - z)`` and
    ``z * h`` are computed in int64 (where 32768 exists), so the step
    must complete without wrap and the next state stays in range."""
    H, d = vm.plan.H, vm.plan.d
    hq = np.full((1, H), I16_MIN, np.int16)
    out = vm.step(hq, np.zeros((1, d), np.int16))
    assert out.dtype == np.int16
    assert int(out.min()) >= I16_MIN and int(out.max()) <= I16_MAX
    # the hazard itself, pinned: int64 negation of INT16_MIN is exact
    assert -np.int64(I16_MIN) == 32768


# ---------------------------------------------------------------------------
# requant shift extremes
# ---------------------------------------------------------------------------

def test_requant_shift_floor_sh1():
    """sh=1 is the minimum legal round shift: round-half-up at the
    smallest rounding granularity, checked against exact integers."""
    rq = Requant(m=1 << 24, sh=1, pre=0)
    for acc in (0, 1, -1, 3, -3, 12345):
        expect = (acc * (1 << 24) + 1) >> 1
        expect = max(min(expect, (1 << 31) - 1), -(1 << 31))
        assert int(rq.apply(np.int64(acc))) == expect


def test_requant_shift_max_sh62():
    """sh=62 is the maximum: a 2^37 accumulator with a 2^24 mantissa
    lands exactly at the rounding boundary and resolves half-up to 1."""
    rq = Requant(m=1 << 24, sh=62, pre=0)
    assert int(rq.apply(np.int64(1 << 37))) == 1
    assert int(rq.apply(np.int64((1 << 37) - 1))) == 0
    assert int(rq.apply(np.int64(-(1 << 37)))) == 0   # round-half-up
    assert int(rq.apply(np.int64(-(1 << 37) - 1))) == -1


def test_requant_underflow_form_is_zero():
    """A factor too small to represent collapses to m=0, sh=62 — the
    documented underflow form maps every accumulator to 0."""
    rq = quantize_multiplier(1e-20)
    assert (rq.m, rq.sh) == (0, 62)
    acc = np.array([0, 1, -1, 1 << 36, -(1 << 36)], np.int64)
    np.testing.assert_array_equal(rq.apply(acc), 0)


def test_requant_preshift_accuracy():
    """acc_bits > 37 folds a floor preshift into the mantissa; the
    represented factor must stay within the 2^-24 mantissa error."""
    factor = 3.14159e-7
    rq = quantize_multiplier(factor, acc_bits=45)
    assert rq.pre == 8
    acc = 1 << 44
    got = int(rq.apply(np.int64(acc)))
    assert abs(got - factor * acc) <= factor * acc * 2 ** -23 + 1


def test_requant_rejects_oversized_factor_and_sh0():
    """sh would go below 1 for a huge factor: rejected, never emitted —
    the round constant ``1 << (sh-1)`` is meaningless at sh=0."""
    with pytest.raises(ValueError):
        quantize_multiplier(2.0 ** 30)
    with pytest.raises(ValueError):
        quantize_multiplier(-1.0)


@pytest.mark.parametrize("bits", [15, 7])
def test_plan_requants_well_formed(bits):
    """Every requant a reference plan actually carries obeys the
    gemmlowp contract qlint checks: m normalized (or the underflow
    form), sh in [1, 62], pre >= 0."""
    from repro.deploy.goldens import build_reference_artifact
    from repro.deploy.image import build_image
    from repro.deploy.qvm import plan_from_image
    p = plan_from_image(build_image(
        build_reference_artifact(seed=0, bits=bits)))
    rqs = dict(p.rq)
    rqs["gate"], rqs["hstore"] = p.rq_gate, p.rq_hstore
    for name, rq in rqs.items():
        assert rq.m == 0 or (1 << 24) <= rq.m < (1 << 25), name
        assert 1 <= rq.sh <= 62, name
        assert rq.pre >= 0, name


# ---------------------------------------------------------------------------
# LUT index extremes
# ---------------------------------------------------------------------------

def test_lut_index_clamps_to_0_and_255(vm):
    """Fine-scale extremes land on table entries 0 and 255 exactly; the
    zero input lands on the center bucket the index bias pins."""
    p = vm.plan
    lo = np.array([[-FINE_CLIP - 1]], np.int64)
    hi = np.array([[FINE_CLIP]], np.int64)
    zero = np.array([[0]], np.int64)
    for table in (p.sig_lut, p.tanh_lut):
        assert int(vm._lut(table, lo)[0, 0]) == int(table[0])
        assert int(vm._lut(table, hi)[0, 0]) == int(table[LUT_SIZE - 1])
        assert int(vm._lut(table, zero)[0, 0]) == int(table[_LUT_IDX0])
    # the raw index arithmetic really does escape [0, 255] pre-clamp,
    # i.e. the clamp is load-bearing at these inputs (qlint: "reachable")
    raw_lo = (int(lo[0, 0]) * p.lut_m + (_LUT_IDX0 << p.lut_sh)) >> p.lut_sh
    raw_hi = (int(hi[0, 0]) * p.lut_m + (_LUT_IDX0 << p.lut_sh)) >> p.lut_sh
    assert raw_lo < 0 and raw_hi > LUT_SIZE - 1


def test_sigmoid_tanh_monotone_tables(vm):
    """The clamped lookup is monotone across the whole fine range —
    a wrapped index would break monotonicity at the seam."""
    p = vm.plan
    v = np.linspace(-FINE_CLIP - 1, FINE_CLIP, 4097).astype(np.int64)[None]
    for table in (p.sig_lut, p.tanh_lut):
        y = vm._lut(table, v)[0]
        assert (np.diff(y.astype(np.int64)) >= 0).all()


# ---------------------------------------------------------------------------
# extreme inputs against the emitted-C twin
# ---------------------------------------------------------------------------

@pytest.mark.skipif(emit_c.find_cc() is None, reason="no C compiler")
def test_extreme_windows_bit_identical_to_c(model):
    """The same edge inputs — both saturation boundaries, INT16_MIN
    runs, alternating extremes — through the compiled int engine: traces
    and logits must match the qvm byte for byte (the C has no saturating
    hardware; divergence here is exactly the UB qlint exists to rule
    out)."""
    _, _, img = model
    vm = QVM(img)
    T, d = 16, img.d
    xq = np.zeros((5, T, d), np.int16)
    xq[0] = I16_MAX
    xq[1] = I16_MIN
    xq[2, :, ::2] = I16_MAX
    xq[2, :, 1::2] = I16_MIN
    xq[3, ::2] = I16_MIN
    xq[3, 1::2] = I16_MAX
    lg, traces = vm.run_windows(xq, return_trajectory=True)
    assert int(traces.min()) >= I16_MIN and int(traces.max()) <= I16_MAX
    with tempfile.TemporaryDirectory() as td:
        binary = emit_c.compile_host(img, td, engine="int")
        cm = emit_c.CHostModel(binary, img.H, img.C, engine="int")
        ctr, clg, cpred = cm.trace(xq)
    np.testing.assert_array_equal(ctr, traces)
    np.testing.assert_array_equal(clg, lg)
    np.testing.assert_array_equal(cpred, np.argmax(lg, axis=1))
