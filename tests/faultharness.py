"""Shared fault-injection harness for the fleet failover tests.

Not a test module — imported by ``tests/test_failover.py`` and
``tests/test_fleet_properties.py``.  The harness's one idea: because the
exact backend is bit-deterministic, "recovered correctly" is assertable
as *byte equality of the full per-stream event history* against an
uninterrupted single-engine reference — no tolerances, no sampling.
Every helper here therefore folds events (single or columnar) into
per-stream ordered logs whose entries include the raw logits bytes.
"""
from __future__ import annotations

import numpy as np

from repro.obs.invariants import assert_conservation
from repro.serve.streaming import (StreamEventBatch, StreamingConfig,
                                   StreamingEngine)
from repro.serve.fleet import FleetConfig, FleetEngine


def collect_log(events, log=None) -> dict:
    """Fold a list of StreamEvent / StreamEventBatch into per-stream
    ordered histories.  Entries carry every event field, with logits as
    raw bytes so comparison is bit-exact, not approximate."""
    log = {} if log is None else log
    for e in events:
        if isinstance(e, StreamEventBatch):
            for sid, fin, st, ws, p, lg, w in zip(
                    e.stream_ids, e.final, e.steps, e.window_steps,
                    e.predictions, e.logits, e.warm):
                log.setdefault(sid, []).append(
                    ("final" if fin else "window", int(st), int(ws),
                     int(p), np.asarray(lg, np.float32).tobytes(), bool(w)))
        else:
            log.setdefault(e.stream_id, []).append(
                (e.kind, int(e.step), int(e.window_step),
                 int(e.prediction),
                 np.asarray(e.logits, np.float32).tobytes(), bool(e.warm)))
    return log


def make_streams(n: int, steps: int, input_dim: int, seed: int = 0) -> dict:
    """Deterministic per-stream sample tensors: ``{id: (steps, d)}``."""
    rng = np.random.default_rng(seed)
    return {f"st{i:03d}": rng.standard_normal(
        (steps, input_dim)).astype(np.float32) for i in range(n)}


def reference_log(qp, streams: dict, *, window: int = 128) -> dict:
    """Uninterrupted single-engine run of every stream to completion —
    the byte-level ground truth all fault schedules must reproduce."""
    eng = StreamingEngine(qp, StreamingConfig(
        max_slots=max(len(streams), 1), window=window))
    for sid, w in streams.items():
        eng.attach(sid, w, total_steps=len(w))
    return collect_log(eng.drain())


def run_crash_schedule(qp, streams: dict, *, shards: int,
                       slots_per_shard: int, injector,
                       snapshot_every: int = 64, window: int = 128,
                       batch_events: bool = False,
                       obs=None) -> tuple[dict, dict]:
    """Drive every stream through a failover-enabled fleet under the
    given fault injector, to completion.  Returns ``(event_log, stats)``.
    Pass ``obs=`` (an :class:`repro.obs.Observability`) to run the same
    schedule with the flight recorder / metrics attached."""
    fleet = FleetEngine(qp, FleetConfig(
        shards=shards,
        stream=StreamingConfig(max_slots=slots_per_shard, window=window,
                               batch_events=batch_events),
        snapshot_every=snapshot_every), faults=injector, obs=obs)
    log: dict = {}
    for sid, w in streams.items():
        fleet.attach(sid, w, total_steps=len(w))
    collect_log(fleet.drain(), log)
    return log, fleet.stats()


def assert_logs_identical(got: dict, want: dict) -> None:
    """Byte-identical per-stream event histories, with a readable diff on
    the first divergence."""
    assert set(got) == set(want), (
        f"stream set differs: extra={sorted(set(got) - set(want))}, "
        f"missing={sorted(set(want) - set(got))}")
    for sid in sorted(want):
        g, w = got[sid], want[sid]
        assert g == w, (
            f"stream {sid!r}: event history diverges "
            f"(got {len(g)} events, want {len(w)}); first difference at "
            f"index {next(i for i in range(min(len(g), len(w)) + 1) if i >= len(g) or i >= len(w) or g[i] != w[i])}")


def assert_counters_conserved(stats: dict) -> None:
    """Fleet counter-conservation invariant — delegates to the shared
    production implementation in :mod:`repro.obs.invariants` so the test
    harness and the debug-mode ``FleetEngine.stats()`` assertion can
    never drift apart."""
    assert_conservation(stats)
