"""Per-kernel allclose vs ref.py oracles, swept over shapes and dtypes
(interpret mode — kernel bodies execute on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fastgrnn as fg
from repro.core.lut import make_lut, lut_eval, _LINEAR_TAILS


# ---- lut_act --------------------------------------------------------------

@pytest.mark.parametrize("fn", ["sigmoid", "tanh", "silu", "gelu"])
@pytest.mark.parametrize("mode", ["nearest", "lerp"])
@pytest.mark.parametrize("shape", [(33,), (7, 129), (2, 3, 64)])
def test_lut_act_kernel(fn, mode, shape):
    from repro.kernels.lut_act.ops import lut_act
    x = jnp.asarray(np.random.default_rng(0).normal(size=shape) * 5,
                    jnp.float32)
    got = lut_act(x, fn, mode=mode)
    ref = lut_eval(jnp.asarray(make_lut(fn)), x, mode=mode,
                   linear_tail=(fn in _LINEAR_TAILS))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=0, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lut_act_dtypes(dtype):
    from repro.kernels.lut_act.ops import lut_tanh
    x = jnp.asarray(np.linspace(-10, 10, 257), dtype)
    y = lut_tanh(x)
    assert y.dtype == dtype
    assert float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                 - jnp.tanh(x.astype(jnp.float32))))) < 0.05


# ---- fastgrnn_cell ---------------------------------------------------------

@pytest.mark.parametrize("low_rank", [False, True])
@pytest.mark.parametrize("T,B", [(16, 1), (128, 5), (64, 8)])
def test_fastgrnn_kernel_vs_ref(low_rank, T, B):
    from repro.kernels.fastgrnn_cell.ops import fastgrnn_window_kernel
    from repro.kernels.fastgrnn_cell.ref import fastgrnn_window_ref
    cfg = fg.FastGRNNConfig(rank_w=2 if low_rank else None,
                            rank_u=8 if low_rank else None)
    params = fg.init_params(cfg, jax.random.PRNGKey(0))
    xs = jnp.asarray(np.random.default_rng(1).normal(size=(T, B, 3)),
                     jnp.float32)
    h_k, traj_k = fastgrnn_window_kernel(params, xs)
    h_r, traj_r = fastgrnn_window_ref(params, xs, lut=True, mode="nearest")
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=0, atol=2e-5)
    np.testing.assert_allclose(np.asarray(traj_k), np.asarray(traj_r),
                               rtol=0, atol=2e-5)


# ---- fastgrnn_cell: batched single step (streaming) ------------------------

@pytest.mark.parametrize("low_rank", [False, True])
@pytest.mark.parametrize("backend", ["exact", "jit", "pallas"])
def test_q15_step_batched_vs_scalar_oracle(low_rank, backend):
    from repro.core.quantization import quantize_params, QuantConfig
    from repro.kernels.fastgrnn_cell.ops import Q15StreamStep
    from repro.kernels.fastgrnn_cell.ref import q15_step_batched_ref
    cfg = fg.FastGRNNConfig(rank_w=2 if low_rank else None,
                            rank_u=8 if low_rank else None)
    qp = quantize_params(fg.init_params(cfg, jax.random.PRNGKey(0)),
                         QuantConfig())
    rng = np.random.default_rng(5)
    S = 24
    h = (rng.normal(size=(S, 16)) * 0.4).astype(np.float32)
    x = rng.normal(size=(S, 3)).astype(np.float32)
    active = np.ones(S, bool)
    k = Q15StreamStep(qp, backend=backend)
    h_new = k.step(h, x, active)
    logits = k.head_logits(h_new)
    h_ref, log_ref = q15_step_batched_ref(qp, h, x)
    if backend == "exact":  # bit-identical to the scalar C-equivalent path
        np.testing.assert_array_equal(h_new.view(np.int32),
                                      h_ref.view(np.int32))
        np.testing.assert_array_equal(logits.view(np.int32),
                                      log_ref.view(np.int32))
    else:  # XLA contracts mul+add into FMA: allclose, not bitwise
        np.testing.assert_allclose(h_new, h_ref, rtol=0, atol=1e-5)
        np.testing.assert_allclose(logits, log_ref, rtol=0, atol=1e-5)


def test_q15_step_inactive_slots_hold_state():
    from repro.core.quantization import quantize_params, QuantConfig
    from repro.kernels.fastgrnn_cell.ops import Q15StreamStep
    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    qp = quantize_params(fg.init_params(cfg, jax.random.PRNGKey(0)),
                         QuantConfig())
    rng = np.random.default_rng(6)
    h = (rng.normal(size=(8, 16)) * 0.4).astype(np.float32)
    x = rng.normal(size=(8, 3)).astype(np.float32)
    active = np.zeros(8, bool)
    active[::2] = True
    k = Q15StreamStep(qp)
    h_new = k.step(h, x, active)
    np.testing.assert_array_equal(h_new[1::2].view(np.int32),
                                  h[1::2].view(np.int32))
    assert not np.array_equal(h_new[::2], h[::2])


# ---- q15_matmul ------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int16])
@pytest.mark.parametrize("m,k,n", [(8, 32, 16), (64, 96, 130),
                                   (200, 256, 128), (1, 128, 256)])
def test_q15_matmul_kernel(dtype, m, k, n):
    from repro.kernels.q15_matmul.ops import q15_matmul
    from repro.kernels.q15_matmul.ref import q15_matmul_ref
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    hi = 120 if dtype == jnp.int8 else 30000
    wq = jnp.asarray(rng.integers(-hi, hi, (k, n)), dtype)
    s = 0.0021
    got = q15_matmul(x, wq, s)
    ref = q15_matmul_ref(x, wq, s)
    denom = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(got - ref))) / denom < 2e-2  # bf16 tiles


def test_q15_matmul_batched_lead_dims():
    from repro.kernels.q15_matmul.ops import q15_matmul
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 5, 64)), jnp.float32)
    wq = jnp.asarray(rng.integers(-100, 100, (64, 32)), jnp.int8)
    out = q15_matmul(x, wq, 0.01)
    assert out.shape == (2, 5, 32)


# ---- ssd_scan --------------------------------------------------------------

@pytest.mark.parametrize("b,S,H,P,G,N,chunk", [
    (1, 32, 2, 8, 1, 8, 8),
    (2, 80, 4, 8, 2, 16, 16),
    (2, 100, 4, 16, 4, 8, 32),   # S not a chunk multiple -> padding path
])
def test_ssd_scan_kernel(b, S, H, P, G, N, chunk):
    from repro.kernels.ssd_scan.ops import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_scan_ref
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, S, G, N))
    C = jax.random.normal(ks[4], (b, S, G, N))
    y_k, st_k = ssd_scan(x, dt, A, B, C, chunk=chunk)
    y_r, st_r = ssd_scan_ref(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                               rtol=1e-4, atol=1e-4)


def test_ssd_scan_bf16_inputs():
    from repro.kernels.ssd_scan.ops import ssd_scan
    from repro.kernels.ssd_scan.ref import ssd_scan_ref
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    b, S, H, P, G, N = 1, 32, 2, 8, 1, 8
    x = jax.random.normal(ks[0], (b, S, H, P)).astype(jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, S, G, N)).astype(jnp.bfloat16)
    C = jax.random.normal(ks[4], (b, S, G, N)).astype(jnp.bfloat16)
    y_k, _ = ssd_scan(x, dt, A, B, C, chunk=8)
    y_r, _ = ssd_scan_ref(x, dt, A, B, C, chunk=8)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=0.1, atol=0.1)
