"""Static/dynamic cross-check: runtime saturation witnesses vs the prover.

The qlint abstract interpreter (:mod:`repro.analysis.qlint`) classifies
every saturation site in the integer program as **reachable** (the
proven operand interval actually exceeds the clamp bounds) or **dead**
(the clamp is documentation — the interval already fits).  The runtime
:class:`repro.obs.numerics.NumericsMonitor` counts, per site, how often
a concrete execution actually hit each clamp.  This module closes the
loop between the two:

* a *dead* site with a nonzero runtime count is a soundness bug — the
  abstract interpreter under-approximated a range, exactly the class of
  error the analysis gate exists to rule out;
* a site the prover never modeled (present in the counter vocabulary
  but in neither classification list) firing at runtime means the
  instrumented program and the analyzed program have diverged;
* a *reachable* site with a zero count is fine — the prover
  over-approximates by design (``gate.hf_clip`` is the canonical
  example: statically reachable, dynamically never hit on the
  reference traces).  Callers that *expect* a witness (e.g. the
  stress-amplified golden segment driving ``h_next`` into saturation)
  pass ``expect_nonzero=`` to turn a missing witness into a violation
  too — that direction catches instrumentation rot, where counters
  silently stop counting.

The check is pure data -> data (no model builds, no RNG, no clock):
one qlint target dict from the ``analysis_report`` artifact on the
static side, one ``NumericsMonitor.snapshot()`` dict on the dynamic
side.  ``deploy/verify.py`` runs it as part of the parity protocol and
``python -m repro.analysis --crosscheck`` exposes it to CI.
"""
from __future__ import annotations

from typing import Any


def target_by_name(report: dict[str, Any], name: str) -> dict[str, Any]:
    """Pick one qlint target out of a full ``analysis_report`` dict."""
    for t in report["qlint"]["targets"]:
        if t["name"] == name:
            return t
    known = [t["name"] for t in report["qlint"]["targets"]]
    raise KeyError(f"no qlint target {name!r} in report (have {known})")


def crosscheck(target: dict[str, Any], snapshot: dict[str, Any],
               expect_nonzero: tuple[str, ...] = ()) -> dict[str, Any]:
    """Check one runtime counter snapshot against one qlint target.

    ``target`` is a qlint target dict (``analysis_report`` schema:
    must carry ``saturation.reachable`` / ``saturation.dead``);
    ``snapshot`` is a ``NumericsMonitor.snapshot()`` dict (or any dict
    with a ``"sites"`` name->count mapping, e.g. the C engine's
    counters zipped with ``site_order``).  Returns a verdict dict::

        {"ok": bool,
         "violations": [str, ...],       # empty iff ok
         "witnessed": [site, ...],       # nonzero-count sites
         "unwitnessed_reachable": [...]} # reachable, count == 0

    The containment law: dynamic witnesses must be a subset of the
    statically reachable sites.  ``expect_nonzero`` additionally
    requires a witness at the named sites.
    """
    sat = target["saturation"]
    reachable = set(sat["reachable"])
    dead = set(sat["dead"])
    counts: dict[str, int] = snapshot["sites"]

    violations: list[str] = []
    witnessed: list[str] = []
    for site in sorted(counts):
        n = int(counts[site])
        if n == 0:
            continue
        witnessed.append(site)
        if site in dead:
            violations.append(
                f"{site}: statically dead saturation fired {n} times — "
                f"the abstract interpreter under-approximated its range")
        elif site not in reachable:
            violations.append(
                f"{site}: fired {n} times but the prover never "
                f"classified it — instrumented and analyzed programs "
                f"have diverged")
    for site in expect_nonzero:
        if int(counts.get(site, 0)) == 0:
            violations.append(
                f"{site}: expected a runtime witness but the counter "
                f"is zero — saturation instrumentation is not counting")
    unwitnessed = sorted(reachable - set(witnessed))
    return {
        "ok": not violations,
        "violations": violations,
        "witnessed": witnessed,
        "unwitnessed_reachable": unwitnessed,
    }
