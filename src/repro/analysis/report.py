"""Machine-readable report for the static-analysis gate.

One ``analysis_report`` JSON artifact (the ``validate_bench``-style
schema; see ``benchmarks/validate_bench.py``) carries both pillars:

* ``qlint``  — per-target instruction table with the *proven* interval
  bound, minimum signed width, declared width, and saturation
  classification per site, plus any findings;
* ``detlint`` — per-file findings and the suppressions that were
  honored (an intentional exception is part of the record, not silence).

Determinism contract: the report contains no wall-clock, no host info,
and no floats — ints, strings and bools only, serialized as canonical
JSON (sorted keys, fixed separators).  Two runs over the same tree and
the same reference artifacts are byte-identical; CI regenerates the
committed ``ANALYSIS_report.json`` and ``cmp``s it, the same gate the
``.fgar`` artifact and the weight image already pass.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

#: Bumped when the report layout changes incompatibly.
SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verified rule violation (either pillar)."""
    check: str          # check id, e.g. "q-acc-width" / "det-donate-argnums"
    where: str          # qlint: site name; detlint: "path:line"
    message: str        # human-readable statement of the violation

    def to_dict(self) -> dict[str, str]:
        return {"check": self.check, "where": self.where,
                "message": self.message}


@dataclasses.dataclass(frozen=True)
class Suppression:
    """One honored inline suppression (``# detlint: ignore[check] reason``)."""
    check: str
    where: str
    reason: str

    def to_dict(self) -> dict[str, str]:
        return {"check": self.check, "where": self.where,
                "reason": self.reason}


def build_report(qlint_targets: list[dict[str, Any]],
                 detlint_result: dict[str, Any] | None) -> dict[str, Any]:
    """Assemble the full report dict from the two pillars' outputs."""
    findings = sum(len(t["findings"]) for t in qlint_targets)
    suppressed = 0
    det_block: dict[str, Any] = {"skipped": True}
    if detlint_result is not None:
        det_block = detlint_result
        findings += len(detlint_result["findings"])
        suppressed = len(detlint_result["suppressions"])
    return {
        "benchmark": "analysis_report",
        "schema_version": SCHEMA_VERSION,
        "qlint": {"targets": qlint_targets},
        "detlint": det_block,
        "summary": {
            "findings": findings,
            "suppressed": suppressed,
            "ok": findings == 0,
        },
    }


def dumps(report: dict[str, Any]) -> str:
    """Canonical JSON: sorted keys, fixed separators, trailing newline —
    the byte-stable form CI diffs against the committed artifact."""
    return json.dumps(report, sort_keys=True, indent=1,
                      separators=(",", ": ")) + "\n"


def write(report: dict[str, Any], path: str) -> None:
    with open(path, "w") as f:
        f.write(dumps(report))
