"""``qlint`` — integer-safety abstract interpreter for the Q15 programs.

The integer step program executed by :class:`repro.deploy.qvm.QVM` and
its bit-exact C twin (``repro.deploy.emit_c`` with ``engine="int"``) is
specified down to the bit: int16 state, int32 fine intermediates, int64
matvec accumulators, gemmlowp requantization, 256-entry LUT activations.
Until now those width claims were comments backed by hand audits (the
``bits30``/``bits44`` sizing in ``plan_from_image``, the "semantically
inert" clip notes).  ``qlint`` mechanizes them: it re-executes the whole
step + head program over the exact interval domain
(:mod:`repro.analysis.intervals`), seeded with the **actual** tensors of
a packed :class:`~repro.deploy.image.DeployImage` — real weight row
sums, real LUT table contents, real requant multipliers — and emits one
site record per instruction with the *proven* bound, the minimum signed
width that holds it, and the declared storage width of the concrete
program.  A declared width the proof does not cover is a finding; CI
fails on findings.

Checks (ids cited by findings and mutation fixtures):

* ``q-acc-width``      — every accumulator / intermediate / constant
  table fits its declared width (int64 matvec accs, int32 fine values
  and logits, int16 state).  The C engine has no saturating hardware:
  an unproved width is undefined behavior on the MCU, not a wrap.
* ``q-requant-range``  — every gemmlowp requant is well-formed:
  normalized mantissa ``m in [2^24, 2^25)`` (or the documented
  underflow-to-zero form ``m == 0``), round shift ``1 <= sh <= 62``,
  floor preshift ``pre >= 0``.
* ``q-requant-overflow`` — the requant's int64 internal product
  ``((acc >> pre) * m + 2^(sh-1))`` cannot overflow for the *proven*
  accumulator interval (the ``acc_bits`` contract of
  ``quantize_multiplier``, discharged against real ranges).
* ``q-lut-bounds``     — LUT index arithmetic fits int64 and the
  clamped index range lies inside the actual table (256 entries).
* ``q-int16-neg``      — no negation whose operand interval contains
  ``INT16_MIN`` lands in an int16 slot (``-(-32768)`` overflows).
* ``q-shift-neg``      — shift amounts are in ``[0, 63]``, and right
  shifts of possibly-negative operands occur only at the documented
  arithmetic-shift primitives (requant / LUT index / head shift — the
  qvm and the C twin pin those to arithmetic semantics; anywhere else a
  negative operand is a portability hazard).

Saturation sites are additionally classified **reachable or dead**: a
clamp whose operand interval already fits is dead (documentation), one
whose interval exceeds the clamp is load-bearing (the int16 store
saturation, by design).  The classification is recorded per site so a
calibration change that silently flips a "semantically inert" clip into
a load-bearing one shows up in the committed report diff.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.core.lut import LUT_SIZE
from repro.deploy.image import DeployImage
from repro.deploy.qvm import (FINE_CLIP, I16_MAX, I16_MIN, Q15_ONE, Requant,
                              _LUT_IDX0, plan_from_image)
from .intervals import Interval, WIDTH_RANGE
from .report import Finding

#: check id -> one-line statement (the docstring above carries the detail)
QLINT_CHECKS = {
    "q-acc-width": "value proven to fit its declared signed storage width",
    "q-requant-range": "requant m/sh/pre are well-formed gemmlowp constants",
    "q-requant-overflow": "requant internal product fits int64 for the "
                          "proven accumulator interval",
    "q-lut-bounds": "LUT index arithmetic in-range against the real table",
    "q-int16-neg": "no negation of an interval containing INT16_MIN into "
                   "an int16 slot",
    "q-shift-neg": "shift amounts in [0, 63]; negative operands only at "
                   "documented arithmetic-shift sites",
}

#: Declared storage widths of the concrete program (the qvm/emit_c
#: contract).  Mutation fixtures downgrade these to prove the gate bites.
DEFAULT_WIDTHS = {
    "acc": 64,       # matvec accumulators (CMSIS-NN q63_t convention)
    "fine": 32,      # fine-scale intermediates (pre, t1, t2)
    "requant": 64,   # requant internal product
    "wide": 64,      # gate-path int64 temporaries
    "logits": 32,    # head output (int32_t in C)
    "state": 16,     # persistent h
}

INT32 = WIDTH_RANGE[32]


@dataclasses.dataclass(frozen=True)
class Assumptions:
    """Analysis-time parameters.  The defaults are the real contract;
    the ``--selftest`` mutation fixtures perturb them (accumulator-width
    downgrade, truncated LUT) to prove every check can actually fire."""
    x: Interval = Interval(I16_MIN, I16_MAX)      # quantize_input saturates
    h: Interval = Interval(I16_MIN, I16_MAX)      # sat16-stored state
    widths: dict[str, int] = dataclasses.field(default_factory=dict)
    fine_clip: int = FINE_CLIP
    lut_size: int = LUT_SIZE

    def width(self, kind: str) -> int:
        return self.widths.get(kind, DEFAULT_WIDTHS[kind])


@dataclasses.dataclass
class Site:
    """One analyzed instruction: the report's unit of proof."""
    name: str
    op: str
    declared_bits: int
    iv: Interval
    sat: str | None = None      # "reachable" | "dead" | None

    def to_dict(self) -> dict[str, Any]:
        d: dict[str, Any] = {
            "site": self.name,
            "op": self.op,
            "declared_bits": self.declared_bits,
            "lo": self.iv.lo,
            "hi": self.iv.hi,
            "bits_needed": self.iv.bits_needed(),
            "margin_bits": self.declared_bits - self.iv.bits_needed(),
        }
        if self.sat is not None:
            d["saturation"] = self.sat
        return d


class Machine:
    """The abstract machine: each primitive mirrors one concrete
    operation of the qvm/C step program, records a :class:`Site` with
    the proven interval, and raises findings against the declared
    widths.  Public so the mutation fixtures can drive single
    primitives directly (e.g. an int16 negation site)."""

    def __init__(self, assume: Assumptions | None = None):
        self.assume = assume or Assumptions()
        self.sites: list[Site] = []
        self.findings: list[Finding] = []

    # -- recording -------------------------------------------------------
    def _site(self, name: str, op: str, iv: Interval, bits: int,
              sat: str | None = None) -> Interval:
        self.sites.append(Site(name, op, bits, iv, sat))
        if not iv.fits(bits):
            self._find("q-acc-width", name,
                       f"{op} value {iv} needs {iv.bits_needed()} bits but "
                       f"is stored in int{bits}")
        return iv

    def _find(self, check: str, site: str, message: str) -> None:
        self.findings.append(Finding(check=check, where=site, message=message))

    def _sat_class(self, iv: Interval, lo: int, hi: int) -> str:
        return "reachable" if iv.exceeds(lo, hi) else "dead"

    # -- primitives ------------------------------------------------------
    def const_table(self, name: str, values: np.ndarray, bits: int) -> Interval:
        """A baked constant array (biases, head bias) with its C storage
        width — ``plan_from_image`` range-checks ``headb_q`` but not the
        fine-scale biases; this closes that gap."""
        iv = Interval(int(np.min(values)), int(np.max(values)))
        return self._site(name, "const", iv, bits)

    def matvec(self, name: str, w_rows: np.ndarray, v: Interval) -> Interval:
        """Exact accumulator bound for ``acc_i = sum_j W[i, j] * v_j``
        with every ``v_j`` in ``v``: per-row positive/negative
        coefficient sums against the interval endpoints (the true
        reachable range, not the ``n * max|W|`` worst case)."""
        w = np.asarray(w_rows, np.int64)
        pos = np.where(w > 0, w, 0).sum(axis=1)
        neg = np.where(w < 0, w, 0).sum(axis=1)
        hi = max(int(p) * v.hi + int(n) * v.lo for p, n in zip(pos, neg))
        lo = min(int(p) * v.lo + int(n) * v.hi for p, n in zip(pos, neg))
        return self._site(f"{name}.acc", "matvec",
                          Interval(lo, hi), self.assume.width("acc"))

    def requant(self, name: str, rq: Requant, acc: Interval,
                out_clip: tuple[int, int] | None = INT32) -> Interval:
        """The gemmlowp rescale ``((acc >> pre) * m + 2^(sh-1)) >> sh``
        with the int32 output saturation both engines apply."""
        if not (rq.m == 0 or (1 << 24) <= rq.m < (1 << 25)):
            self._find("q-requant-range", name,
                       f"mantissa m={rq.m} outside [2^24, 2^25) "
                       f"(and not the underflow form m=0)")
        if not 1 <= rq.sh <= 62:
            self._find("q-requant-range", name,
                       f"round shift sh={rq.sh} outside [1, 62]")
        if rq.pre < 0:
            self._find("q-requant-range", name,
                       f"preshift pre={rq.pre} is negative")
        shifted = self.shr(f"{name}.pre", acc, max(rq.pre, 0),
                           self.assume.width("acc"), arith_ok=True,
                           record=False)
        sh = min(max(rq.sh, 1), 62)      # analyze on the clamped form
        half = 1 << (sh - 1)
        internal = shifted.mul(Interval.const(rq.m)).add(Interval.const(half))
        self.sites.append(Site(f"{name}.requant_acc", "requant",
                               self.assume.width("requant"), internal))
        if not internal.fits(self.assume.width("requant")):
            self._find("q-requant-overflow", f"{name}.requant_acc",
                       f"internal product {internal} needs "
                       f"{internal.bits_needed()} bits > "
                       f"int{self.assume.width('requant')} (acc_bits "
                       f"contract of quantize_multiplier violated)")
        out = internal.shr(sh)
        sat = None
        if out_clip is not None:
            sat = self._sat_class(out, *out_clip)
            out = out.clip(*out_clip)
        return self._site(f"{name}.out", "requant_out", out, 64, sat=sat)

    def fine(self, name: str, rq: Requant, acc: Interval) -> Interval:
        """Matvec epilogue: requant then the ±FINE_CLIP int32 clamp that
        keeps later sums of two fine values inside int32."""
        fc = self.assume.fine_clip
        out = self.requant(name, rq, acc)
        sat = self._sat_class(out, -fc - 1, fc)
        out = out.clip(-fc - 1, fc)
        return self._site(f"{name}.fine", "fine_clip", out,
                          self.assume.width("fine"), sat=sat)

    def lut(self, name: str, v: Interval, m: int, sh: int,
            table: np.ndarray) -> Interval:
        """Index ``(v * m + (idx0 << sh)) >> sh`` clamped into the real
        table; the returned interval is the exact min/max of the table
        slice the clamped index range can reach."""
        if len(table) != self.assume.lut_size:
            self._find("q-lut-bounds", name,
                       f"table has {len(table)} entries, expected "
                       f"{self.assume.lut_size}")
        idx_acc = v.mul(Interval.const(m)).add(
            Interval.const(_LUT_IDX0 << sh))
        self._site(f"{name}.idx_acc", "lut_index", idx_acc, 64)
        idx = self.shr(f"{name}.idx_shift", idx_acc, sh, 64,
                       arith_ok=True, record=False)
        sat = self._sat_class(idx, 0, self.assume.lut_size - 1)
        idx = idx.clip(0, self.assume.lut_size - 1)
        self.sites.append(Site(f"{name}.idx", "lut_clamp", 64, idx, sat=sat))
        if idx.hi > len(table) - 1 or idx.lo < 0:
            self._find("q-lut-bounds", f"{name}.idx",
                       f"clamped index range {idx} escapes the "
                       f"{len(table)}-entry table")
            idx = idx.clip(0, len(table) - 1)
        sl = np.asarray(table)[idx.lo:idx.hi + 1]
        return Interval(int(sl.min()), int(sl.max()))

    def add(self, name: str, a: Interval, b: Interval, bits: int) -> Interval:
        return self._site(name, "add", a.add(b), bits)

    def sub(self, name: str, a: Interval, b: Interval, bits: int) -> Interval:
        return self._site(name, "sub", a.sub(b), bits)

    def mul(self, name: str, a: Interval, b: Interval, bits: int) -> Interval:
        return self._site(name, "mul", a.mul(b), bits)

    def neg(self, name: str, v: Interval, bits: int) -> Interval:
        if bits == 16 and v.contains(I16_MIN):
            self._find("q-int16-neg", name,
                       f"negating {v} can produce {-I16_MIN}, which "
                       f"overflows int16 (INT16_MIN negation hazard)")
        return self._site(name, "neg", v.neg(), bits)

    def shr(self, name: str, v: Interval, n: int, bits: int,
            arith_ok: bool, record: bool = True) -> Interval:
        if not 0 <= n <= 63:
            self._find("q-shift-neg", name,
                       f"shift amount {n} outside [0, 63]")
            n = min(max(n, 0), 63)
        if v.lo < 0 and not arith_ok:
            self._find("q-shift-neg", name,
                       f"right shift of possibly-negative {v} outside the "
                       f"documented arithmetic-shift primitives")
        out = v.shr(n)
        if record:
            self._site(name, "asr", out, bits)
        return out

    def clip(self, name: str, v: Interval, lo: int, hi: int,
             bits: int) -> Interval:
        sat = self._sat_class(v, lo, hi)
        return self._site(name, "clip", v.clip(lo, hi), bits, sat=sat)

    def store16(self, name: str, v: Interval) -> Interval:
        """The single int16 store-rounding: sat16 then the state slot."""
        sat = self._sat_class(v, I16_MIN, I16_MAX)
        return self._site(name, "sat16_store", v.clip(I16_MIN, I16_MAX),
                          self.assume.width("state"), sat=sat)


def analyze_image(img: DeployImage, assume: Assumptions | None = None,
                  plan=None, name: str = "image") -> dict[str, Any]:
    """Abstractly execute one full step + head of the integer program
    packed in ``img`` and return the target record for the report.

    ``plan`` injection exists for the mutation fixtures (tampered
    requants); production callers let ``plan_from_image`` derive it,
    which is exactly what the qvm and ``emit_c`` execute.
    """
    assume = assume or Assumptions()
    p = plan if plan is not None else plan_from_image(img)
    m = Machine(assume)
    x = m._site("x", "input", assume.x, 16)
    h = m._site("h", "state", assume.h, assume.width("state"))
    wide = assume.width("wide")

    # -- recurrence: pre-activations ------------------------------------
    if p.low_rank:
        t1 = m.fine("w2", p.rq["w2"], m.matvec("w2", p.w["W2"].T, x))
        wx = m.fine("w1", p.rq["w1"], m.matvec("w1", p.w["W1"], t1))
        t2 = m.fine("u2", p.rq["u2"], m.matvec("u2", p.w["U2"].T, h))
        uh = m.fine("u1", p.rq["u1"], m.matvec("u1", p.w["U1"], t2))
    else:
        wx = m.fine("w", p.rq["w"], m.matvec("w", p.w["W"], x))
        uh = m.fine("u", p.rq["u"], m.matvec("u", p.w["U"], h))
    # C: `pre[i] = fg_fine(aw, ...) + fg_fine(au, ...)` — an int32 sum
    pre = m.add("pre", wx, uh, assume.width("fine"))

    # -- activations -----------------------------------------------------
    bz = m.const_table("const.bz_q", p.bz_q, 32)
    bh = m.const_table("const.bh_q", p.bh_q, 32)
    # C: `fg_lut(FG_SIG_LUT, pre[i] + FG_READ32(FG_BZ_Q, i))` — the sum
    # is computed in int before the call
    z_in = m.add("act.z_in", pre, bz, assume.width("fine"))
    h_in = m.add("act.ht_in", pre, bh, assume.width("fine"))
    z = m.lut("act.z", z_in, p.lut_m, p.lut_sh, p.sig_lut)
    ht = m.lut("act.ht", h_in, p.lut_m, p.lut_sh, p.tanh_lut)

    # -- gate combine, single int16 store-rounding -----------------------
    one_minus_z = m.sub("gate.one_minus_z", Interval.const(Q15_ONE), z, 32)
    g2 = m.add("gate.g2",
               m.mul("gate.zeta_term", Interval.const(p.zeta_q),
                     one_minus_z, wide),
               Interval.const(p.nu2_q), wide)
    g2ht = m.mul("gate.g2ht", g2, ht, wide)
    a_f = m.requant("gate", p.rq_gate, g2ht)
    zh = m.mul("gate.zh", z, h, wide)
    h_f = m.add("gate.hf", a_f, zh, wide)
    h_f = m.clip("gate.hf_clip", h_f, -(1 << 31), (1 << 31) - 1, wide)
    h_store = m.requant("hstore", p.rq_hstore, h_f)
    h_new = m.store16("h_next", h_store)

    # -- head -------------------------------------------------------------
    acc = m.matvec("head", p.w["head_w"].T, h)
    # C: `(int32_t)(acc >> FG_LOGIT_SH) + FG_READ32(FG_HEADB_Q, c)` —
    # the narrowing cast happens BEFORE the bias add, so the shifted
    # accumulator must itself fit int32
    shifted = m.shr("head.shift", acc, p.logit_sh,
                    assume.width("logits"), arith_ok=True)
    hb = m.const_table("const.headb_q", p.headb_q, 32)
    m.add("head.logits", shifted, hb, assume.width("logits"))

    # the int16 store saturation closes the h -> h' loop: the abstract
    # post-state re-establishes the assumed pre-state invariant
    state_closed = h_new.lo >= assume.h.lo and h_new.hi <= assume.h.hi
    if not state_closed:
        m._find("q-acc-width", "h_next",
                f"post-step state {h_new} escapes the assumed state "
                f"interval {assume.h} — loop invariant broken")

    sat_reach = sorted(s.name for s in m.sites if s.sat == "reachable")
    sat_dead = sorted(s.name for s in m.sites if s.sat == "dead")
    return {
        "name": name,
        "bits": int(img.bits),
        "low_rank": bool(p.low_rank),
        "arch": {"d": p.d, "H": p.H, "C": p.C,
                 "rank_w": p.rank_w, "rank_u": p.rank_u},
        "checks": sorted(QLINT_CHECKS),
        "n_sites": len(m.sites),
        "sites": [s.to_dict() for s in m.sites],
        "saturation": {"reachable": sat_reach, "dead": sat_dead},
        "state_closed": state_closed,
        "findings": [f.to_dict() for f in m.findings],
        "proved_overflow_free": not m.findings,
    }


def reference_targets(seeds: tuple[int, ...] = (0,)) -> list[dict[str, Any]]:
    """The CI gate's default subjects: the reference Q15 and Q7
    ``ModelArtifact``s (the same builds the deploy parity protocol and
    the golden fixtures pin), lowered to images and proven end-to-end."""
    from repro.deploy.goldens import build_reference_artifact
    from repro.deploy.image import build_image
    targets = []
    for seed in seeds:
        for bits, label in ((15, "q15"), (7, "q7")):
            art = build_reference_artifact(seed=seed, bits=bits)
            img = build_image(art)
            targets.append(analyze_image(
                img, name=f"reference-{label}-s{seed}"))
    return targets
