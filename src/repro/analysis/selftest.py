"""Mutation fixtures: prove every analyzer check can actually fire.

A static gate that never fires is indistinguishable from one that is
wired up wrong, so CI runs ``python -m repro.analysis --selftest``: each
fixture below plants one seeded defect — a tampered requant, an
accumulator-width downgrade, a reintroduced ``donate_argnums`` — and the
selftest PASSES only if the corresponding check catches it.  A fixture
whose defect sails through is a selftest failure (exit 1), i.e. the
mutation killed the gate and the gate must be fixed before it can gate
anything else.

qlint fixtures drive the abstract machine / ``analyze_image`` directly
(plan injection, width overrides); detlint fixtures lint small source
strings through the production ``lint_source`` path, including one that
proves the suppression syntax is honored (a suppressed defect must
produce a recorded suppression and *no* finding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.deploy.qvm import I16_MIN, Requant, plan_from_image
from .detlint import lint_source
from .intervals import Interval
from .qlint import Assumptions, Machine, analyze_image
from .report import Finding

_IMG_CACHE: dict[int, Any] = {}


def _reference_image(bits: int = 15):
    if bits not in _IMG_CACHE:
        from repro.deploy.goldens import build_reference_artifact
        from repro.deploy.image import build_image
        _IMG_CACHE[bits] = build_image(
            build_reference_artifact(seed=0, bits=bits))
    return _IMG_CACHE[bits]


# ---------------------------------------------------------------------------
# qlint fixtures — each returns the findings the seeded defect produced
# ---------------------------------------------------------------------------

def _fx_acc_width_downgrade() -> list[dict[str, Any]]:
    """Downgrade the matvec accumulator to int32: the proven Q15 row-sum
    ranges exceed 32 bits, so q-acc-width must fire."""
    rec = analyze_image(_reference_image(), Assumptions(widths={"acc": 32}))
    return rec["findings"]


def _fx_fine_width_downgrade() -> list[dict[str, Any]]:
    """Downgrade the fine intermediates to int16: the ±FINE_CLIP range
    needs 30 bits, so q-acc-width must fire at the .fine sites."""
    rec = analyze_image(_reference_image(), Assumptions(widths={"fine": 16}))
    return rec["findings"]


def _fx_requant_tamper() -> list[dict[str, Any]]:
    """Replace the gate requant with a denormalized m=3, sh=0 constant
    (the kind a hand-edited image could carry): q-requant-range fires."""
    img = _reference_image()
    plan = plan_from_image(img)
    plan = dataclasses.replace(plan, rq_gate=Requant(m=3, sh=0, pre=0))
    rec = analyze_image(img, plan=plan)
    return rec["findings"]


def _fx_requant_overflow() -> list[dict[str, Any]]:
    """Feed a requant an accumulator interval wide enough that
    ``(acc >> pre) * m`` escapes int64 — the acc_bits contract of
    quantize_multiplier, violated on purpose."""
    m = Machine()
    m.requant("fx", Requant(m=(1 << 24), sh=30, pre=0),
              Interval(-(1 << 45), (1 << 45) - 1))
    return [f.to_dict() for f in m.findings]


def _fx_lut_truncated() -> list[dict[str, Any]]:
    """Hand the LUT primitive a 128-entry table while the program still
    computes 256-entry indices: q-lut-bounds fires."""
    m = Machine()
    m.lut("fx", Interval(-(1 << 20), 1 << 20), m=1 << 10, sh=15,
          table=np.zeros(128, np.int64))
    return [f.to_dict() for f in m.findings]


def _fx_int16_neg() -> list[dict[str, Any]]:
    """Negate an interval containing INT16_MIN into an int16 slot:
    ``-(-32768)`` does not exist in int16, q-int16-neg fires."""
    m = Machine()
    m.neg("fx", Interval(I16_MIN, 0), bits=16)
    return [f.to_dict() for f in m.findings]


def _fx_shift_hazard() -> list[dict[str, Any]]:
    """A shift amount outside [0, 63] and a right shift of a negative
    operand outside the documented arithmetic sites: q-shift-neg."""
    m = Machine()
    m.shr("fx.amount", Interval(0, 100), 64, 64, arith_ok=True)
    m.shr("fx.negative", Interval(-5, 5), 1, 64, arith_ok=False)
    return [f.to_dict() for f in m.findings]


# ---------------------------------------------------------------------------
# detlint fixtures — seeded-defect sources through the production linter
# ---------------------------------------------------------------------------

_DET_SOURCES: dict[str, tuple[str, str]] = {
    "det-builtin-hash": ("data/fx.py", (
        "def seed_for(split):\n"
        "    return hash(split) % 2**32\n")),
    "det-wallclock": ("serve/fx.py", (
        "import time\n"
        "def snapshot(state):\n"
        "    state['saved_at'] = time.time()\n"
        "    return state\n")),
    "det-donate-argnums": ("serve/fx.py", (
        "import jax\n"
        "def build(step):\n"
        "    return jax.jit(step, donate_argnums=(0, 1))\n")),
    "det-jit-pallas": ("kernels/fx.py", (
        "import jax\n"
        "@jax.jit\n"
        "def fused(x):\n"
        "    return pl.pallas_call(kern, out_shape=x)(x)\n")),
    "det-set-iteration": ("serve/fx.py", (
        "def dispatch_order(shards):\n"
        "    return [s for s in set(shards)]\n")),
    "det-span-pairing": ("serve/fx.py", (
        "def tick(self, tr):\n"
        "    t0 = tr.t()\n"
        "    self.work()\n")),
    "det-span-registry": ("serve/fx.py", (
        "def tick(self, tr):\n"
        "    t0 = tr.t()\n"
        "    self.work()\n"
        "    tr.rec('fleet.dispach', t0)\n")),
    "det-conserved-counters": ("serve/fleet/engine.py", (
        "class FleetEngine:\n"
        "    def __init__(self):\n"
        "        self._retired = {'stream_steps': 0, 'completed': 0,\n"
        "                         'ring_spills': 0}\n")),
}


def _det_fixture(check: str) -> Callable[[], list[dict[str, Any]]]:
    def run() -> list[dict[str, Any]]:
        path, src = _DET_SOURCES[check]
        findings, _ = lint_source(src, path)
        return [f.to_dict() for f in findings]
    return run


def _fx_suppression_honored() -> list[dict[str, Any]]:
    """The inverse fixture: a defect carrying a well-formed suppression
    comment must yield zero findings and exactly one recorded
    suppression — silence without a record would hide exceptions from
    review."""
    src = ("import jax\n"
           "def build(step):\n"
           "    return jax.jit(step,\n"
           "                   donate_argnums=(0,))"
           "  # detlint: ignore[det-donate-argnums] training-only step\n")
    findings, suppressions = lint_source(src, "serve/fx.py")
    ok = (not findings and len(suppressions) == 1
          and suppressions[0].check == "det-donate-argnums"
          and suppressions[0].reason == "training-only step")
    if ok:
        # report the expected check as "caught" via a synthetic marker
        return [Finding("suppression-honored", "serve/fx.py:4",
                        "suppressed defect recorded, not silenced").to_dict()]
    return []


#: fixture name -> (check id that must appear in the findings, runner)
FIXTURES: dict[str, tuple[str, Callable[[], list[dict[str, Any]]]]] = {
    "acc-width-downgrade": ("q-acc-width", _fx_acc_width_downgrade),
    "fine-width-downgrade": ("q-acc-width", _fx_fine_width_downgrade),
    "requant-tamper": ("q-requant-range", _fx_requant_tamper),
    "requant-overflow": ("q-requant-overflow", _fx_requant_overflow),
    "lut-truncated": ("q-lut-bounds", _fx_lut_truncated),
    "int16-neg": ("q-int16-neg", _fx_int16_neg),
    "shift-hazard": ("q-shift-neg", _fx_shift_hazard),
    **{f"seeded-{c}": (c, _det_fixture(c)) for c in _DET_SOURCES},
    "suppression-honored": ("suppression-honored", _fx_suppression_honored),
}


def run_selftest() -> dict[str, Any]:
    """Run every fixture; ``ok`` is True only when every seeded defect
    was caught by exactly the check it targets."""
    results = {}
    for name, (expect, fn) in FIXTURES.items():
        findings = fn()
        caught = any(f["check"] == expect for f in findings)
        results[name] = {"expect": expect, "caught": caught,
                         "n_findings": len(findings)}
    return {"fixtures": results,
            "ok": all(r["caught"] for r in results.values())}
