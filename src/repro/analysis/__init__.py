"""Static analysis for the repro stack, CI-gated.

Two pillars (see ``docs/analysis.md`` for the full check catalog):

* :mod:`repro.analysis.qlint` — an interval / bit-width abstract
  interpreter over the integer step + head program shared by
  ``repro.deploy.qvm`` and the emitted C (``repro.deploy.emit_c``),
  seeded with the actual tensors of a packed :class:`DeployImage`.  It
  *proves* every accumulator fits its declared width, every requant is
  well-formed and overflow-free, every LUT index lands in the real
  table — and classifies each saturation site as reachable or dead.
* :mod:`repro.analysis.detlint` — an AST linter over ``src/repro``
  encoding the determinism / bit-exactness rules this repo learned the
  hard way (each check's docstring cites the motivating PR).

``python -m repro.analysis`` runs both and emits one canonical-JSON
``analysis_report`` artifact; ``--selftest`` runs the seeded-defect
mutation fixtures (:mod:`repro.analysis.selftest`) that prove every
check can fire.
"""
from .crosscheck import crosscheck, target_by_name
from .detlint import CHECK_IDS as DETLINT_CHECKS, lint_source, lint_tree
from .intervals import Interval, WIDTH_RANGE
from .qlint import (DEFAULT_WIDTHS, QLINT_CHECKS, Assumptions, Machine,
                    analyze_image, reference_targets)
from .report import (SCHEMA_VERSION, Finding, Suppression, build_report,
                     dumps, write)
from .selftest import FIXTURES, run_selftest

__all__ = [
    "Interval", "WIDTH_RANGE",
    "Machine", "Assumptions", "analyze_image", "reference_targets",
    "QLINT_CHECKS", "DEFAULT_WIDTHS",
    "lint_tree", "lint_source", "DETLINT_CHECKS",
    "Finding", "Suppression", "build_report", "dumps", "write",
    "SCHEMA_VERSION",
    "run_selftest", "FIXTURES",
    "crosscheck", "target_by_name",
]
