"""Exact integer interval domain for the Q15 abstract interpreter.

The analysis domain is closed integer intervals ``[lo, hi]`` over
arbitrary-precision Python ints: abstract values themselves can never
overflow, so the interpreter *computes* the true reachable range of every
accumulator and then *checks* it against the declared storage width of the
concrete program (int16 state, int32 fine intermediates, int64 matvec
accumulators — the contract shared by ``repro.deploy.qvm`` and the C twin
``repro.deploy.emit_c`` emits).

Every transfer function below is the exact image of the corresponding
concrete integer operation over a box:

* ``add``/``sub``/``neg``/``mul`` — standard interval arithmetic (the
  four-corner product for ``mul``);
* ``shr`` — *arithmetic* right shift (floor division by a power of two),
  the semantics both NumPy and the generated C implement; it is monotone,
  so the image of a box is the box of the images;
* ``clip`` — saturation, the image of ``np.clip`` / the C clamp idiom.

Monotone unary maps (requantization, LUT index affine) are applied at the
two endpoints by callers — exact for the same reason.  Nothing here
widens: the qvm step program is loop-free per tick and the single loop
(h -> h') is closed by the int16 store saturation, so a fixed point is
reached in one pass.
"""
from __future__ import annotations

import dataclasses

#: Named signed storage widths of the concrete program.
WIDTH_RANGE = {
    8: (-(1 << 7), (1 << 7) - 1),
    16: (-(1 << 15), (1 << 15) - 1),
    32: (-(1 << 31), (1 << 31) - 1),
    64: (-(1 << 63), (1 << 63) - 1),
}


@dataclasses.dataclass(frozen=True)
class Interval:
    """Closed integer interval ``[lo, hi]`` (exact Python ints)."""
    lo: int
    hi: int

    def __post_init__(self):
        if self.lo > self.hi:
            raise ValueError(f"empty interval [{self.lo}, {self.hi}]")

    # -- constructors ----------------------------------------------------
    @staticmethod
    def const(v: int) -> "Interval":
        return Interval(int(v), int(v))

    @staticmethod
    def of_width(bits: int) -> "Interval":
        """The full range of a signed ``bits``-wide integer."""
        lo, hi = WIDTH_RANGE[bits]
        return Interval(lo, hi)

    # -- arithmetic ------------------------------------------------------
    def add(self, other: "Interval") -> "Interval":
        return Interval(self.lo + other.lo, self.hi + other.hi)

    def sub(self, other: "Interval") -> "Interval":
        return Interval(self.lo - other.hi, self.hi - other.lo)

    def neg(self) -> "Interval":
        return Interval(-self.hi, -self.lo)

    def mul(self, other: "Interval") -> "Interval":
        c = (self.lo * other.lo, self.lo * other.hi,
             self.hi * other.lo, self.hi * other.hi)
        return Interval(min(c), max(c))

    def shr(self, n: int) -> "Interval":
        """Arithmetic right shift (floor; Python ``>>`` on negatives is
        already the arithmetic shift NumPy and the C engines use)."""
        if n < 0:
            raise ValueError(f"negative shift amount {n}")
        return Interval(self.lo >> n, self.hi >> n)

    def shl(self, n: int) -> "Interval":
        if n < 0:
            raise ValueError(f"negative shift amount {n}")
        return Interval(self.lo << n, self.hi << n)

    def clip(self, lo: int, hi: int) -> "Interval":
        """Saturating clamp — the image of ``np.clip(v, lo, hi)``."""
        return Interval(min(max(self.lo, lo), hi), min(max(self.hi, lo), hi))

    def union(self, other: "Interval") -> "Interval":
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi))

    # -- queries ---------------------------------------------------------
    def abs_max(self) -> int:
        return max(abs(self.lo), abs(self.hi))

    def contains(self, v: int) -> bool:
        return self.lo <= v <= self.hi

    def fits(self, bits: int) -> bool:
        """True iff every value in the interval is representable as a
        signed ``bits``-wide integer."""
        lo, hi = WIDTH_RANGE[bits]
        return lo <= self.lo and self.hi <= hi

    def bits_needed(self) -> int:
        """Minimum signed width (in bits) that holds the whole interval:
        the proven bound the report records per instruction."""
        b = 1
        while not (-(1 << (b - 1)) <= self.lo and self.hi <= (1 << (b - 1)) - 1):
            b += 1
        return b

    def exceeds(self, lo: int, hi: int) -> bool:
        """True iff some value in the interval lies outside ``[lo, hi]``
        (i.e. a clamp to that range is *reachable*, not dead)."""
        return self.lo < lo or self.hi > hi

    def __repr__(self) -> str:  # compact in reports/messages
        return f"[{self.lo}, {self.hi}]"
