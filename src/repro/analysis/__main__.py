"""CLI for the static-analysis gate.

CI usage (``.github/workflows/ci.yml``, static-analysis job)::

    python -m repro.analysis --fail-on-findings --report ANALYSIS_ci.json
    python -m repro.analysis --selftest

The report is canonical JSON with no wall-clock, host info, or floats —
two runs over the same tree are byte-identical, so CI ``cmp``s the fresh
report against the committed ``ANALYSIS_report.json``.
"""
from __future__ import annotations

import argparse
import sys

from . import build_report, dumps, lint_tree, reference_targets, write
from .selftest import run_selftest


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Q15 integer-safety prover + determinism linter")
    ap.add_argument("--report", metavar="PATH",
                    help="write the analysis_report JSON artifact here")
    ap.add_argument("--fail-on-findings", action="store_true",
                    help="exit 1 when any finding survives suppression")
    ap.add_argument("--qlint-only", action="store_true",
                    help="skip the AST determinism linter")
    ap.add_argument("--detlint-only", action="store_true",
                    help="skip the interval prover (no artifact builds)")
    ap.add_argument("--seeds", default="0",
                    help="comma-separated reference-artifact seeds "
                         "(default: 0)")
    ap.add_argument("--selftest", action="store_true",
                    help="run the seeded-defect mutation fixtures instead")
    args = ap.parse_args(argv)

    if args.selftest:
        result = run_selftest()
        for name, r in sorted(result["fixtures"].items()):
            mark = "caught" if r["caught"] else "MISSED"
            print(f"  {mark:>6}  {name}  [{r['expect']}]")
        n = len(result["fixtures"])
        ok = result["ok"]
        print(f"selftest: {n} fixtures, "
              f"{'all caught' if ok else 'DEFECTS MISSED'}")
        return 0 if ok else 1

    qlint_targets = []
    if not args.detlint_only:
        seeds = tuple(int(s) for s in args.seeds.split(",") if s.strip())
        qlint_targets = reference_targets(seeds=seeds)
    det = None if args.qlint_only else lint_tree()

    report = build_report(qlint_targets, det)
    if args.report:
        write(report, args.report)
    else:
        sys.stdout.write(dumps(report))

    s = report["summary"]
    for t in qlint_targets:
        status = "proved" if t["proved_overflow_free"] else "FAILED"
        print(f"qlint: {t['name']}: {status} ({t['n_sites']} sites, "
              f"{len(t['saturation']['reachable'])} reachable / "
              f"{len(t['saturation']['dead'])} dead saturations)",
              file=sys.stderr)
    if det is not None:
        print(f"detlint: {det['files']} files, "
              f"{len(det['findings'])} findings, "
              f"{len(det['suppressions'])} suppressions", file=sys.stderr)
        for f in det["findings"]:
            print(f"  {f['where']}: [{f['check']}] {f['message']}",
                  file=sys.stderr)
    for t in qlint_targets:
        for f in t["findings"]:
            print(f"  {t['name']}:{f['where']}: [{f['check']}] "
                  f"{f['message']}", file=sys.stderr)
    print(f"analysis: {s['findings']} findings, {s['suppressed']} "
          f"suppressed, ok={s['ok']}", file=sys.stderr)
    return 1 if (args.fail_on_findings and not s["ok"]) else 0


if __name__ == "__main__":
    sys.exit(main())
