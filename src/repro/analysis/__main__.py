"""CLI for the static-analysis gate.

CI usage (``.github/workflows/ci.yml``, static-analysis job)::

    python -m repro.analysis --fail-on-findings --report ANALYSIS_ci.json
    python -m repro.analysis --selftest
    python -m repro.analysis --crosscheck

``--crosscheck`` closes the static/dynamic loop: it rebuilds the
reference Q15 and Q7 images, runs the *monitored* qvm over the golden
windows (plus a x8 input-amplified stress segment that must witness
``h_next`` saturation), and checks every runtime counter against the
fresh qlint reachability classification via
:func:`repro.analysis.crosscheck.crosscheck`.

The report is canonical JSON with no wall-clock, host info, or floats —
two runs over the same tree are byte-identical, so CI ``cmp``s the fresh
report against the committed ``ANALYSIS_report.json``.
"""
from __future__ import annotations

import argparse
import sys

from . import build_report, crosscheck, dumps, lint_tree, reference_targets, \
    write
from .selftest import run_selftest

#: Input gain for the saturation-stress crosscheck segment: x8 drives the
#: reference model's post-gate state outside int16 (``h_next`` fires) while
#: every matvec/LUT site stays within its proven bounds.
STRESS_GAIN = 8


def run_crosscheck(seeds: tuple[int, ...] = (0,),
                   n_windows: int = 64) -> int:
    """Live static/dynamic cross-check over the reference images.

    For each (seed, bits) reference build: analyze the image (fresh
    qlint reachability), then run the monitored qvm over the golden
    test windows twice — unmodified, and input-amplified by
    :data:`STRESS_GAIN` with ``expect_nonzero=("h_next",)`` so a
    silently-dead counter pipeline fails the gate rather than passing
    vacuously.  Exit 0 iff every segment's witnesses are contained in
    the statically reachable site set."""
    import numpy as np

    from repro.data import hapt
    from repro.deploy.goldens import build_reference_artifact
    from repro.deploy.image import build_image
    from repro.deploy.qvm import QVM
    from repro.obs.numerics import NumericsMonitor
    from .qlint import analyze_image

    windows = hapt.load("test", n=n_windows).windows
    ok = True
    for seed in seeds:
        for bits, label in ((15, "q15"), (7, "q7")):
            art = build_reference_artifact(seed=seed, bits=bits)
            img = build_image(art)
            target = analyze_image(img, name=f"reference-{label}-s{seed}")
            for segment, gain, expect in (
                    ("golden", 1, ()),
                    ("stress", STRESS_GAIN, ("h_next",))):
                mon = NumericsMonitor()
                vm = QVM(img, monitor=mon)
                vm.run_windows(vm.quantize_input(
                    np.asarray(windows, np.float32) * gain))
                verdict = crosscheck(target, mon.snapshot(),
                                     expect_nonzero=expect)
                ok = ok and verdict["ok"]
                wit = ", ".join(verdict["witnessed"]) or "none"
                print(f"crosscheck: {target['name']} [{segment}]: "
                      f"{'ok' if verdict['ok'] else 'VIOLATION'} "
                      f"(witnessed: {wit}; unwitnessed reachable: "
                      f"{len(verdict['unwitnessed_reachable'])})",
                      file=sys.stderr)
                for v in verdict["violations"]:
                    print(f"  {v}", file=sys.stderr)
    print(f"crosscheck: {'ok' if ok else 'FAILED'}", file=sys.stderr)
    return 0 if ok else 1


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Q15 integer-safety prover + determinism linter")
    ap.add_argument("--report", metavar="PATH",
                    help="write the analysis_report JSON artifact here")
    ap.add_argument("--fail-on-findings", action="store_true",
                    help="exit 1 when any finding survives suppression")
    ap.add_argument("--qlint-only", action="store_true",
                    help="skip the AST determinism linter")
    ap.add_argument("--detlint-only", action="store_true",
                    help="skip the interval prover (no artifact builds)")
    ap.add_argument("--seeds", default="0",
                    help="comma-separated reference-artifact seeds "
                         "(default: 0)")
    ap.add_argument("--selftest", action="store_true",
                    help="run the seeded-defect mutation fixtures instead")
    ap.add_argument("--crosscheck", action="store_true",
                    help="run the static/dynamic saturation cross-check "
                         "on the reference images instead")
    ap.add_argument("--windows", type=int, default=64, metavar="N",
                    help="golden windows per crosscheck run (default: 64)")
    args = ap.parse_args(argv)

    if args.crosscheck:
        seeds = tuple(int(s) for s in args.seeds.split(",") if s.strip())
        return run_crosscheck(seeds=seeds, n_windows=args.windows)

    if args.selftest:
        result = run_selftest()
        for name, r in sorted(result["fixtures"].items()):
            mark = "caught" if r["caught"] else "MISSED"
            print(f"  {mark:>6}  {name}  [{r['expect']}]")
        n = len(result["fixtures"])
        ok = result["ok"]
        print(f"selftest: {n} fixtures, "
              f"{'all caught' if ok else 'DEFECTS MISSED'}")
        return 0 if ok else 1

    qlint_targets = []
    if not args.detlint_only:
        seeds = tuple(int(s) for s in args.seeds.split(",") if s.strip())
        qlint_targets = reference_targets(seeds=seeds)
    det = None if args.qlint_only else lint_tree()

    report = build_report(qlint_targets, det)
    if args.report:
        write(report, args.report)
    else:
        sys.stdout.write(dumps(report))

    s = report["summary"]
    for t in qlint_targets:
        status = "proved" if t["proved_overflow_free"] else "FAILED"
        print(f"qlint: {t['name']}: {status} ({t['n_sites']} sites, "
              f"{len(t['saturation']['reachable'])} reachable / "
              f"{len(t['saturation']['dead'])} dead saturations)",
              file=sys.stderr)
    if det is not None:
        print(f"detlint: {det['files']} files, "
              f"{len(det['findings'])} findings, "
              f"{len(det['suppressions'])} suppressions", file=sys.stderr)
        for f in det["findings"]:
            print(f"  {f['where']}: [{f['check']}] {f['message']}",
                  file=sys.stderr)
    for t in qlint_targets:
        for f in t["findings"]:
            print(f"  {t['name']}:{f['where']}: [{f['check']}] "
                  f"{f['message']}", file=sys.stderr)
    print(f"analysis: {s['findings']} findings, {s['suppressed']} "
          f"suppressed, ok={s['ok']}", file=sys.stderr)
    return 1 if (args.fail_on_findings and not s["ok"]) else 0


if __name__ == "__main__":
    sys.exit(main())
