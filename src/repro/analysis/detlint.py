"""``detlint`` — the determinism / bit-exactness linter for ``src/repro``.

Every check below encodes a trap this repo actually hit (see
``CHANGES.md``); each check's docstring cites the PR where the trap was
found by hand so the rule's provenance is reviewable.  The linter is
purely syntactic (one ``ast`` parse per file, no imports of the linted
code), deterministic, and fast enough to run as a hard CI gate.

Suppression syntax — intentional exceptions must be visible in review::

    jax.jit(step, donate_argnums=(0, 1))  # detlint: ignore[det-donate-argnums] training step; no serving state

A suppression comment applies to the findings on its own line, or — when
the comment stands alone on a line — to the next line.  Only
suppressions that actually silenced a finding are recorded in the
report; the reason text after the bracket is carried verbatim.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Any, Callable, Iterable

from repro.obs.invariants import CONSERVED_SCHED, CONSERVED_WORKLOAD
from repro.obs.phases import PHASES
from .report import Finding, Suppression

#: Path prefixes (relative to the linted root) where wall-clock reads
#: would contaminate state, snapshots, serialized artifacts, or numerics.
#: Training-side telemetry (train/, launch/) is out of scope by design.
STATE_PATHS = ("serve/", "deploy/", "compress/", "obs/", "core/", "data/",
               "kernels/")

#: Paths where iteration order feeds fused dispatch or stats output.
ORDERED_PATHS = ("serve/", "obs/")

#: Receiver names that identify tracer objects at span call sites.
_TRACER_NAMES = ("tr", "tracer", "_tracer")

_SUPPRESS_RE = re.compile(
    r"#\s*detlint:\s*ignore\[([a-z0-9\-, ]+)\]\s*(.*)$")

_WALLCLOCK_CALLS = {
    ("time", "time"), ("time", "time_ns"), ("time", "localtime"),
    ("time", "ctime"), ("time", "asctime"), ("time", "strftime"),
    ("datetime", "now"), ("datetime", "utcnow"), ("datetime", "today"),
    ("date", "today"),
}


def _dotted(node: ast.AST) -> tuple[str, ...]:
    """('a', 'b', 'c') for ``a.b.c``; () when not a plain dotted name."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def _recv_name(call_func: ast.Attribute) -> str:
    """Last component of the receiver of a method call (``self._tracer``
    -> '_tracer', ``tr`` -> 'tr')."""
    v = call_func.value
    if isinstance(v, ast.Attribute):
        return v.attr
    if isinstance(v, ast.Name):
        return v.id
    return ""


def _is_tracer_recv(call_func: ast.AST) -> bool:
    return (isinstance(call_func, ast.Attribute)
            and _recv_name(call_func) in _TRACER_NAMES)


def _mentions_jit(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id == "jit":
            return True
        if isinstance(sub, ast.Attribute) and sub.attr == "jit":
            return True
    return False


@dataclasses.dataclass(frozen=True)
class Check:
    name: str
    doc: str                                   # one line, cites the trap
    scope: Callable[[str], bool]               # relpath -> lint this file?
    run: Callable[[ast.AST, str], Iterable[tuple[int, str]]]


def _everywhere(path: str) -> bool:
    return True


def _state_paths(path: str) -> bool:
    return path.startswith(STATE_PATHS)


def _ordered_paths(path: str) -> bool:
    return path.startswith(ORDERED_PATHS)


def _span_paths(path: str) -> bool:
    # obs/trace.py implements the primitive (its _Span adapter forwards a
    # caller-supplied phase); consumers everywhere else are in scope.
    return path != "obs/trace.py"


# ---------------------------------------------------------------------------
# Check bodies
# ---------------------------------------------------------------------------

def _check_builtin_hash(tree: ast.AST, path: str):
    """PR 1: synthetic HAPT was seeded via ``hash(split)`` — randomized
    per process by PYTHONHASHSEED, so two runs produced different
    datasets.  Fixed to crc32; ``hash()`` stays banned in ``src/repro``."""
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "hash"):
            yield (node.lineno,
                   "builtin hash() is PYTHONHASHSEED-randomized; use "
                   "zlib.crc32 (see data/hapt.py) for stable seeding")


def _check_wallclock(tree: ast.AST, path: str):
    """PR 1 / PR 7: wall-clock reads in state, snapshot, or serialized
    paths break byte-identical replay (the metrics snapshot explicitly
    strips wallclock-tagged fields to stay byte-stable).  Monotonic
    ``perf_counter`` timing for telemetry is allowed."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        d = _dotted(node.func)
        if len(d) >= 2 and (d[-2], d[-1]) in _WALLCLOCK_CALLS:
            yield (node.lineno,
                   f"wall-clock call {'.'.join(d)}() in a state/snapshot "
                   f"path; deterministic outputs must not read the clock")


def _check_donate_argnums(tree: ast.AST, path: str):
    """PR 8: ``donate_argnums`` made the XLA CPU executable ~3x slower
    for the resident step AND shifted its fusion by ~1 ulp, breaking the
    host-vs-device bit-identity contract.  Donation anywhere near the
    serving path needs an explicit, visible exception."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg in ("donate_argnums", "donate_argnames"):
                    yield (kw.value.lineno,
                           f"{kw.arg} changes XLA fusion (~1 ulp) and was "
                           f"measured 3x slower on CPU (PR 8); donation "
                           f"must be an explicit suppressed exception")


def _check_jit_pallas(tree: ast.AST, path: str):
    """PR 8: wrapping an interpret-mode pallas call in ``jax.jit`` fuses
    the pad/slice into the trace and makes the result batch-shape
    unstable (~1 ulp between a 16-row dispatch and two 8-row ones) —
    the resident pallas wrapper runs its pads eagerly for exactly this
    reason (kernels/fastgrnn_cell/ops.py::_build_pallas_resident)."""
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        jit_decs = [d for d in node.decorator_list if _mentions_jit(d)]
        if not jit_decs:
            continue
        calls_pallas = any(
            isinstance(sub, ast.Call) and (
                (isinstance(sub.func, ast.Attribute)
                 and sub.func.attr == "pallas_call")
                or (isinstance(sub.func, ast.Name)
                    and sub.func.id == "pallas_call"))
            for sub in ast.walk(node))
        if calls_pallas:
            yield (jit_decs[0].lineno,
                   f"jax.jit wraps pallas_call in {node.name}(): "
                   f"interpret-mode pallas under jit is batch-shape "
                   f"unstable (~1 ulp, PR 8)")


def _check_set_iteration(tree: ast.AST, path: str):
    """PR 5/7 hygiene: fused-dispatch grouping and stats assembly must
    not iterate containers with unspecified order; a ``set`` iterated
    into a dispatch order or a stats list makes output
    machine-dependent.  Sort first (``sorted(set(...))`` is fine)."""
    def is_unordered(it: ast.AST) -> bool:
        if isinstance(it, (ast.Set, ast.SetComp)):
            return True
        return (isinstance(it, ast.Call) and isinstance(it.func, ast.Name)
                and it.func.id in ("set", "frozenset"))

    for node in ast.walk(tree):
        iters: list[ast.AST] = []
        if isinstance(node, (ast.For, ast.AsyncFor)):
            iters.append(node.iter)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            iters.extend(g.iter for g in node.generators)
        for it in iters:
            if is_unordered(it):
                yield (it.lineno,
                       "iteration over a set has unspecified order in a "
                       "dispatch/stats path; wrap in sorted(...)")


def _function_scopes(tree: ast.AST):
    """Yield (function node, direct statements excluding nested defs)."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _walk_own(fn: ast.AST):
    """Walk a function body without descending into nested functions."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _check_span_pairing(tree: ast.AST, path: str):
    """PR 7: spans are recorded as a ``t0 = tracer.t()`` /
    ``tracer.rec(phase, t0)`` pair.  A ``t()`` whose result is never
    passed to ``rec`` is a dropped span (latency silently missing from
    the phase breakdown), and a non-literal phase defeats the static
    registry check."""
    for fn in _function_scopes(tree):
        starts: dict[str, int] = {}
        consumed: set[str] = set()
        for node in _walk_own(fn):
            if (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)
                    and isinstance(node.value.func, ast.Attribute)
                    and node.value.func.attr == "t"
                    and not node.value.args
                    and _is_tracer_recv(node.value.func)):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        starts[tgt.id] = node.lineno
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("rec", "span")
                    and _is_tracer_recv(node.func)):
                args = node.args
                if not args or not (isinstance(args[0], ast.Constant)
                                    and isinstance(args[0].value, str)):
                    yield (node.lineno,
                           f"span phase passed to .{node.func.attr}() must "
                           f"be a string literal (registry-checkable)")
                if (node.func.attr == "rec" and len(args) >= 2
                        and isinstance(args[1], ast.Name)):
                    consumed.add(args[1].id)
        for name, line in sorted(starts.items()):
            if name not in consumed:
                yield (line,
                       f"span start {name} = tracer.t() is never passed to "
                       f"tracer.rec(...) in {fn.name}() — dropped span")


def _check_span_registry(tree: ast.AST, path: str):
    """PR 7: every recorded phase must be in
    ``repro.obs.phases.PHASES`` — a typo'd phase silently interns a new
    ring and splits the latency history for that phase."""
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("rec", "span")
                and _is_tracer_recv(node.func)
                and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            phase = node.args[0].value
            if phase not in PHASES:
                yield (node.lineno,
                       f"span phase {phase!r} is not registered in "
                       f"repro.obs.phases.PHASES")


def _dict_keys_of(node: ast.AST) -> set[str] | None:
    """String keys of a dict literal or a ``{k: 0 for k in (...)}``
    comprehension over a literal tuple/list; None when not static."""
    if isinstance(node, ast.Dict):
        keys = set()
        for k in node.keys:
            if not (isinstance(k, ast.Constant) and isinstance(k.value, str)):
                return None
            keys.add(k.value)
        return keys
    if isinstance(node, ast.DictComp):
        it = node.generators[0].iter if node.generators else None
        if isinstance(it, (ast.Tuple, ast.List)):
            keys = set()
            for e in it.elts:
                if not (isinstance(e, ast.Constant)
                        and isinstance(e.value, str)):
                    return None
                keys.add(e.value)
            return keys
    return None


def _check_conserved_counters(tree: ast.AST, path: str):
    """PR 6/7: fleet totals obey the conservation law live + retired ==
    total (``repro.obs.invariants``).  The retired accumulators in
    ``FleetEngine`` and the conservation sets must name the same
    counters, or a crash/rebuild silently loses (or double-counts) a
    counter the invariant no longer covers."""
    expected = {"_retired": set(CONSERVED_WORKLOAD),
                "_retired_sched": set(CONSERVED_SCHED)}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        for tgt in node.targets:
            if not (isinstance(tgt, ast.Attribute)
                    and tgt.attr in expected):
                continue
            keys = _dict_keys_of(node.value)
            if keys is None:
                continue
            want = expected[tgt.attr]
            missing, extra = sorted(want - keys), sorted(keys - want)
            if missing or extra:
                detail = []
                if missing:
                    detail.append(f"missing {missing}")
                if extra:
                    detail.append(f"unregistered {extra}")
                yield (node.lineno,
                       f"self.{tgt.attr} keys drift from the "
                       f"obs.invariants conservation sets: "
                       f"{'; '.join(detail)}")


def _engine_path(path: str) -> bool:
    return path.endswith("serve/fleet/engine.py")


#: The check registry.  Order is the report order.
CHECKS: tuple[Check, ...] = (
    Check("det-builtin-hash",
          "no PYTHONHASHSEED-randomized hash() (PR 1: hash-seeded HAPT)",
          _everywhere, _check_builtin_hash),
    Check("det-wallclock",
          "no wall-clock reads in state/snapshot paths (PR 1/7)",
          _state_paths, _check_wallclock),
    Check("det-donate-argnums",
          "no donate_argnums (PR 8: 3x slower + 1 ulp fusion shift)",
          _everywhere, _check_donate_argnums),
    Check("det-jit-pallas",
          "no jax.jit around interpret-mode pallas_call (PR 8: "
          "batch-shape unstable)",
          _everywhere, _check_jit_pallas),
    Check("det-set-iteration",
          "no unordered set iteration in dispatch/stats paths (PR 5/7)",
          _ordered_paths, _check_set_iteration),
    Check("det-span-pairing",
          "t()/rec() spans paired, phases literal (PR 7)",
          _span_paths, _check_span_pairing),
    Check("det-span-registry",
          "span phases drawn from repro.obs.phases.PHASES (PR 7)",
          _span_paths, _check_span_registry),
    Check("det-conserved-counters",
          "retired counters match obs.invariants conservation sets "
          "(PR 6/7)",
          _engine_path, _check_conserved_counters),
)

CHECK_IDS = tuple(c.name for c in CHECKS)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def _suppressions_by_line(src: str) -> dict[int, tuple[set[str], str]]:
    """line number -> (suppressed check ids, reason).  A comment-only
    line's suppression shifts to the following line."""
    out: dict[int, tuple[set[str], str]] = {}
    lines = src.splitlines()
    for i, line in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        checks = {c.strip() for c in m.group(1).split(",") if c.strip()}
        reason = m.group(2).strip()
        target = i + 1 if line.lstrip().startswith("#") else i
        if target in out:
            prev_checks, prev_reason = out[target]
            checks |= prev_checks
            reason = reason or prev_reason
        out[target] = (checks, reason)
    return out


def lint_source(src: str, relpath: str
                ) -> tuple[list[Finding], list[Suppression]]:
    """Lint one file's source.  ``relpath`` is the path relative to the
    linted root (posix separators) — it drives check scoping."""
    tree = ast.parse(src, filename=relpath)
    suppress = _suppressions_by_line(src)
    findings: list[Finding] = []
    suppressions: list[Suppression] = []
    for check in CHECKS:
        if not check.scope(relpath):
            continue
        for line, message in check.run(tree, relpath):
            where = f"{relpath}:{line}"
            sup = suppress.get(line)
            if sup and check.name in sup[0]:
                suppressions.append(Suppression(
                    check=check.name, where=where, reason=sup[1]))
            else:
                findings.append(Finding(
                    check=check.name, where=where, message=message))
    return findings, suppressions


def default_root() -> Path:
    """The ``src/repro`` tree this module itself lives in."""
    return Path(__file__).resolve().parent.parent


def lint_tree(root: Path | str | None = None) -> dict[str, Any]:
    """Lint every ``*.py`` under ``root`` (default: the live
    ``src/repro``).  Returns the detlint block of the analysis report."""
    root = Path(root) if root is not None else default_root()
    findings: list[Finding] = []
    suppressions: list[Suppression] = []
    files = sorted(p for p in root.rglob("*.py"))
    for p in files:
        rel = p.relative_to(root).as_posix()
        f, s = lint_source(p.read_text(), rel)
        findings.extend(f)
        suppressions.extend(s)
    return {
        "root": root.name,
        "files": len(files),
        "checks": list(CHECK_IDS),
        "check_docs": {c.name: c.doc for c in CHECKS},
        "findings": [f.to_dict() for f in findings],
        "suppressions": [s.to_dict() for s in suppressions],
    }
