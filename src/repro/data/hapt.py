"""HAPT (Human Activities and Postural Transitions) data pipeline.

The real HAPT dataset [Reyes-Ortiz et al. 2016] is not downloadable in this
offline container.  This module provides:

  1. ``load_real(path)`` — loader for the canonical HAPT raw layout
     (``Train/X_train.txt`` etc.), used automatically if files exist;
  2. ``generate_synthetic(...)`` — a structured synthetic generator with the
     paper's exact geometry: tri-axial 50 Hz acceleration, 128-sample
     windows, six basic classes, subject-disjoint train/val/test splits of
     7352 / 1515 / 3399 windows.

The synthetic signal model per class (units: g, +-2 g range as in the
paper's live-sensor config):

  * static classes (SITTING, STANDING, LAYING): a fixed gravity orientation
    per class with small per-subject orientation jitter + sensor noise;
  * dynamic classes (WALKING, UPSTAIRS, DOWNSTAIRS): gravity + gait
    fundamental (1.4-2.2 Hz, per-subject cadence) with class-specific
    harmonic mix, vertical-axis asymmetry for stairs (UP: stronger first
    harmonic; DOWN: impact spikes - the class the literature finds hardest);
  * all classes: AR(1) sensor noise + slow baseline drift.

Subject-disjointness: 30 synthetic subjects with per-subject cadence,
orientation offset and noise level; subjects 1-21 train, 22-25 val,
26-30 test (matching HAPT's protocol shape).
"""
from __future__ import annotations

import dataclasses
import os
import zlib

import numpy as np


CLASSES = ("WALKING", "UPSTAIRS", "DOWNSTAIRS", "SITTING", "STANDING", "LAYING")
N_CLASSES = 6
WINDOW = 128
RATE_HZ = 50.0
SPLIT_WINDOWS = {"train": 7352, "val": 1515, "test": 3399}
SPLIT_SUBJECTS = {"train": range(1, 22), "val": range(22, 26), "test": range(26, 31)}

_GRAVITY = {
    # unit gravity direction in device frame per class (waist-mounted phone)
    "WALKING": (0.05, -0.10, 1.00),
    "UPSTAIRS": (0.18, -0.05, 0.98),
    "DOWNSTAIRS": (-0.15, 0.08, 0.98),
    "SITTING": (0.55, 0.10, 0.82),
    "STANDING": (0.02, -0.02, 1.00),
    "LAYING": (0.98, 0.05, -0.12),
}
_DYNAMIC = {"WALKING": 0.24, "UPSTAIRS": 0.20, "DOWNSTAIRS": 0.30}


@dataclasses.dataclass
class HAPTSplit:
    windows: np.ndarray    # (N, 128, 3) float32
    labels: np.ndarray     # (N,) int32
    subjects: np.ndarray   # (N,) int32


def _subject_traits(subject: int) -> dict:
    rng = np.random.default_rng(10_000 + subject)
    return {
        "cadence_hz": float(rng.uniform(1.4, 2.2)),
        "orient_jitter": rng.normal(0, 0.06, size=3),
        "noise": float(rng.uniform(0.015, 0.04)),
        "amp": float(rng.uniform(0.8, 1.25)),
    }


def _window_for(cls: str, traits: dict, rng: np.random.Generator) -> np.ndarray:
    t = np.arange(WINDOW) / RATE_HZ
    g = np.asarray(_GRAVITY[cls]) + traits["orient_jitter"]
    g = g / np.linalg.norm(g)
    sig = np.tile(g, (WINDOW, 1)).astype(np.float64)

    if cls in _DYNAMIC:
        f = traits["cadence_hz"] * rng.uniform(0.92, 1.08)
        phase = rng.uniform(0, 2 * np.pi)
        amp = _DYNAMIC[cls] * traits["amp"]
        fund = np.sin(2 * np.pi * f * t + phase)
        h2 = np.sin(2 * np.pi * 2 * f * t + 2.1 * phase)
        if cls == "WALKING":
            mix = amp * (fund + 0.35 * h2)
            lateral = 0.4 * amp * np.sin(2 * np.pi * 0.5 * f * t + phase)
        elif cls == "UPSTAIRS":
            mix = amp * (0.8 * fund + 0.6 * h2)          # lift-dominated
            lateral = 0.25 * amp * np.sin(2 * np.pi * 0.5 * f * t)
        else:  # DOWNSTAIRS: impact spikes, broader band -> hardest class
            impact = np.clip(np.sin(2 * np.pi * f * t + phase), 0.55, None) - 0.55
            mix = amp * (0.6 * fund + 0.5 * h2 + 2.2 * impact)
            lateral = 0.35 * amp * np.sin(2 * np.pi * 0.5 * f * t + 0.7)
        sig[:, 2] += mix
        sig[:, 0] += 0.45 * mix + 0.3 * lateral
        sig[:, 1] += lateral
    elif cls == "SITTING":
        # slow postural sway distinguishes SITTING from STANDING
        sig += 0.02 * np.sin(2 * np.pi * 0.25 * t + rng.uniform(0, 6.28))[:, None]

    # AR(1) sensor noise + slow drift
    e = rng.normal(0, traits["noise"], size=(WINDOW, 3))
    for i in range(1, WINDOW):
        e[i] += 0.5 * e[i - 1]
    drift = rng.normal(0, 0.01, size=3) * (t / t[-1])[:, None]
    return (sig + e + drift).astype(np.float32)


def generate_synthetic(split: str, seed: int = 0, n: int | None = None) -> HAPTSplit:
    n = n if n is not None else SPLIT_WINDOWS[split]
    subjects = list(SPLIT_SUBJECTS[split])
    # crc32, not hash(): str hashing is randomized per process
    # (PYTHONHASHSEED), which made the "synthetic HAPT" a different dataset
    # on every run — every accuracy threshold downstream was a coin flip
    rng = np.random.default_rng(seed * 7919 + zlib.crc32(split.encode()) % 100_000)
    xs = np.empty((n, WINDOW, 3), np.float32)
    ys = np.empty((n,), np.int32)
    subj = np.empty((n,), np.int32)
    traits = {s: _subject_traits(s) for s in subjects}
    for i in range(n):
        s = subjects[i % len(subjects)]
        c = int(rng.integers(0, N_CLASSES))
        xs[i] = _window_for(CLASSES[c], traits[s], rng)
        ys[i] = c
        subj[i] = s
    return HAPTSplit(windows=xs, labels=ys, subjects=subj)


def load_real(root: str, split: str) -> HAPTSplit | None:
    """Load the canonical HAPT raw-data layout if present, else None."""
    sub = {"train": "Train", "val": "Train", "test": "Test"}[split]
    xp = os.path.join(root, sub, f"X_{sub.lower()}.txt")
    if not os.path.exists(xp):
        return None
    X = np.loadtxt(xp, dtype=np.float32)
    y = np.loadtxt(os.path.join(root, sub, f"y_{sub.lower()}.txt"), dtype=np.int32) - 1
    s = np.loadtxt(os.path.join(root, sub, f"subject_id_{sub.lower()}.txt"), dtype=np.int32)
    keep = y < N_CLASSES  # six basic activities only (paper Sec. VI-D)
    X, y, s = X[keep], y[keep], s[keep]
    # the canonical features file is 561-dim; raw windows live elsewhere —
    # reshape only if raw (N,384); otherwise refuse and fall back.
    if X.shape[1] == WINDOW * 3:
        X = X.reshape(-1, WINDOW, 3)
        return HAPTSplit(X, y, s)
    return None


def load(split: str, seed: int = 0, root: str | None = None, n: int | None = None) -> HAPTSplit:
    root = root or os.environ.get("HAPT_ROOT", "/data/hapt")
    real = load_real(root, split) if os.path.isdir(root) else None
    return real if real is not None else generate_synthetic(split, seed, n)


def batches(split: HAPTSplit, batch_size: int, seed: int, time_major: bool = True):
    """Shuffled epoch iterator -> (xs (T,B,3) or (B,T,3), labels (B,))."""
    idx = np.random.default_rng(seed).permutation(len(split.labels))
    for i in range(0, len(idx) - batch_size + 1, batch_size):
        j = idx[i:i + batch_size]
        xs = split.windows[j]
        if time_major:
            xs = np.transpose(xs, (1, 0, 2))
        yield xs, split.labels[j]
