"""Deterministic synthetic LM token pipeline.

Production shape: a stateless, seekable token source — batch ``i`` is a pure
function of (seed, step, shard) so that (a) restarts resume exactly
(fault tolerance: no data replay / loss), (b) each data-parallel shard
draws disjoint streams without coordination, (c) stragglers can be
re-assigned shards deterministically.

The stream is a mixture of Zipfian unigrams and short repeated motifs so
that a language model has actual structure to learn in the examples.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    motif_len: int = 8
    motif_prob: float = 0.3


def _rng_for(cfg: TokenStreamConfig, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard, 0xA11CE])
    )


def batch_at(cfg: TokenStreamConfig, step: int, shard: int = 0, num_shards: int = 1):
    """Tokens for one step/shard: (local_batch, seq_len+1) int32.

    Returns inputs/targets packed together; callers slice [:, :-1]/[:, 1:].
    """
    local = cfg.global_batch // num_shards
    rng = _rng_for(cfg, step, shard)
    v = cfg.vocab_size
    # Zipf over a shuffled alphabet (stable shuffle from the seed only)
    base = rng.zipf(cfg.zipf_a, size=(local, cfg.seq_len + 1)).astype(np.int64)
    toks = (base - 1) % v
    # overlay repeated motifs (structure for the model to learn)
    n_motifs = max(1, int(cfg.motif_prob * cfg.seq_len / cfg.motif_len))
    for b in range(local):
        motif = rng.integers(0, v, size=cfg.motif_len)
        for _ in range(n_motifs):
            p = int(rng.integers(0, cfg.seq_len - cfg.motif_len))
            toks[b, p:p + cfg.motif_len] = motif
    return toks.astype(np.int32)


def lm_batch(cfg: TokenStreamConfig, step: int, shard: int = 0, num_shards: int = 1):
    toks = batch_at(cfg, step, shard, num_shards)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
