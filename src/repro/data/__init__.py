from . import hapt, tokens  # noqa: F401
