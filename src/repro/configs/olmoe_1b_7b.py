"""OLMoE-1B-7B [arXiv:2409.02060; hf]: 64 experts top-8.
16L d_model=2048 16H (kv=16) expert d_ff=1024 vocab=50304."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50_304, head_dim=128, mlp_kind="swiglu",
    num_experts=64, top_k=8,
    param_dtype="bfloat16",
)
