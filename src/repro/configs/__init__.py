"""Architecture configs: the 10 assigned architectures + the paper's own
FastGRNN HAR deployment config.  ``get(name)`` returns a ModelConfig;
``ARCHS`` lists the assigned LM-family ids."""
from .base import ModelConfig, ShapeConfig, SHAPES, applicable  # noqa: F401

from . import (minitron_4b, qwen2_1_5b, deepseek_7b, nemotron_4_340b,
               olmoe_1b_7b, moonshot_v1_16b_a3b, internvl2_76b,
               zamba2_1_2b, hubert_xlarge, mamba2_780m, fastgrnn_har)  # noqa: F401

ARCHS = {
    "minitron-4b": minitron_4b.CONFIG,
    "qwen2-1.5b": qwen2_1_5b.CONFIG,
    "deepseek-7b": deepseek_7b.CONFIG,
    "nemotron-4-340b": nemotron_4_340b.CONFIG,
    "olmoe-1b-7b": olmoe_1b_7b.CONFIG,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b.CONFIG,
    "internvl2-76b": internvl2_76b.CONFIG,
    "zamba2-1.2b": zamba2_1_2b.CONFIG,
    "hubert-xlarge": hubert_xlarge.CONFIG,
    "mamba2-780m": mamba2_780m.CONFIG,
}


def get(name: str) -> ModelConfig:
    return ARCHS[name]


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (assignment: small
    layers/width, few experts, tiny vocab)."""
    import dataclasses as _dc
    small = dict(
        num_layers=2, d_model=64,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=128 if cfg.vocab_size else 0,
        head_dim=16 if cfg.num_heads else 0,
        num_experts=4 if cfg.num_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        mamba_headdim=16 if cfg.uses_mamba else 64,
        attn_every=2 if cfg.attn_every else 0,
        num_patches=8 if cfg.frontend == "vision" else cfg.num_patches,
        ssd_chunk=32,
        remat=False,
    )
    small.update(overrides)
    return _dc.replace(cfg, **small)
