"""Zamba2-1.2B [arXiv:2411.15242; hf]: Mamba2 backbone + ONE shared
attention+MLP block invoked periodically (weight sharing across depth —
the paper's shared-(W,U) idea at block scale).
38 mamba layers, d_model=2048, shared block: 32H (kv=32) d_ff=8192,
ssm_state=64.  long_500k uses sliding-window attention (w=4096) in the
shared block — the assignment's sub-quadratic requirement."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32_000, head_dim=64, mlp_kind="gelu",
    ssm_state=64, mamba_headdim=64, attn_every=6, sliding_window=4096,
    param_dtype="bfloat16",
)
