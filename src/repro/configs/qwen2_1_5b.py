"""Qwen2-1.5B [arXiv:2407.10671; hf]: GQA with QKV bias.
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936, SwiGLU, tied embeds."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151_936, head_dim=128, mlp_kind="swiglu",
    qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
    param_dtype="bfloat16",
)
