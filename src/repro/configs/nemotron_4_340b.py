"""Nemotron-4-340B [arXiv:2402.16819; unverified]: GQA + squared-ReLU.
96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.

Numerics: params/optimizer-moments in bf16 so the 340B deployment fits
16 GB/chip HBM at 512 chips (see DESIGN.md Sec. 5)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18_432, num_heads=96, num_kv_heads=8,
    d_ff=73_728, vocab_size=256_000, head_dim=192, mlp_kind="relu2",
    param_dtype="bfloat16", opt_state_dtype="bfloat16",
)
