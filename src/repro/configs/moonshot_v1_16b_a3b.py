"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B]: 64e top-6 MoE.
48L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=163840."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=163_840, head_dim=128, mlp_kind="swiglu",
    num_experts=64, top_k=6,
    param_dtype="bfloat16",
)
