"""HuBERT-XLarge [arXiv:2106.07447; unverified]: encoder-only audio.
48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (masked-unit targets).
The conv waveform frontend is a STUB: input_specs() supplies precomputed
frame embeddings (B, S, 1280).  Encoder-only -> no decode shapes."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504, head_dim=80, mlp_kind="gelu",
    causal=False, is_encoder=True, frontend="audio",
    param_dtype="bfloat16",
)
