"""Mamba2-780m [arXiv:2405.21060; unverified]: pure SSD, attention-free.
48L d_model=1536, ssm_state=128, vocab=50280, d_inner=2*d_model,
headdim=64 (48 ssm heads), no MLP (d_ff=0)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50_280, mlp_kind="swiglu",
    ssm_state=128, mamba_headdim=64,
    param_dtype="bfloat16",
)
