"""Config dataclasses: architectures, input shapes, applicability rules."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | vlm | hybrid | audio | ssm
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0               # 0 -> d_model // num_heads
    qkv_bias: bool = False
    mlp_kind: str = "swiglu"        # swiglu | geglu | gelu | relu2
    causal: bool = True
    is_encoder: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    mamba_headdim: int = 64
    mamba_groups: int = 1
    attn_every: int = 0             # hybrid: shared attn+mlp block period
    sliding_window: int | None = None  # used for hybrid long-context cells
    ssd_chunk: int = 256
    # modality frontend stubs (assignment: frontend is a STUB)
    frontend: str | None = None     # "vision" | "audio"
    num_patches: int = 256          # vision stub: patches per image
    # numerics / training
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    opt_state_dtype: str = "float32"
    z_loss: float = 1e-4
    aux_loss_weight: float = 0.01
    # L-S-Q compression hooks (the paper's technique at LM scale)
    lsq_rank: int | None = None     # low-rank factorized FFN dense layers
    lsq_sparsity: float = 0.0       # IHT target sparsity during training
    lsq_quant_bits: int = 0         # 0=off, 8/16 -> serving weight quant

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def uses_mamba(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return not self.is_encoder


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules: encoder-only archs skip decode shapes; long_500k
    runs only for sub-quadratic (ssm/hybrid) archs."""
    if shape.kind == "decode" and cfg.is_encoder:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "long_500k needs sub-quadratic attention (ssm/hybrid only)"
    return True, ""
