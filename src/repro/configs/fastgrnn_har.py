"""The paper's own deployment config (Table X): FastGRNN on HAPT.
H=16, d=3, T=128 @ 50 Hz, 6 classes, r_w=2, r_u=8, s=0.5, Q15+calibration,
256-entry LUT over [-8, 8]."""
from repro.core.fastgrnn import FastGRNNConfig
from repro.core.compression import IHTConfig
from repro.core.quantization import QuantConfig

CELL = FastGRNNConfig(input_dim=3, hidden_dim=16, num_classes=6,
                      rank_w=2, rank_u=8)
CELL_FULL_RANK = FastGRNNConfig(input_dim=3, hidden_dim=16, num_classes=6)
IHT = IHTConfig(target_sparsity=0.5, ramp_epochs=50, finetune_epochs=50)
QUANT = QuantConfig(bits=16, calibration_batches=5, headroom=0.10)

EPOCHS = 100
BATCH_SIZE = 64
LEARNING_RATE = 1e-3
SEEDS = (0, 1, 2, 3, 4)
WINDOW = 128
SAMPLE_RATE_HZ = 50.0
