"""InternVL2-76B backbone [arXiv:2404.16821; unverified]:
InternLM2-76B language tower: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256.  The InternViT frontend is a STUB per the assignment:
input_specs() supplies precomputed patch embeddings (B, 256, d_model)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28_672, vocab_size=128_256, head_dim=128, mlp_kind="swiglu",
    frontend="vision", num_patches=256,
    param_dtype="bfloat16", opt_state_dtype="bfloat16",
)
