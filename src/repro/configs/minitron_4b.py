"""Minitron-4B: depth/width-pruned Nemotron [arXiv:2407.14679; hf].
32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000, squared-ReLU FFN."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=9216, vocab_size=256_000, head_dim=128, mlp_kind="relu2",
    rope_theta=10_000.0,
    param_dtype="bfloat16",
)
