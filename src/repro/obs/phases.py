"""The span phase-name registry: one home for every tick-phase label.

Span phases used to be free-form string literals scattered across the
serving stack; a typo ("fleet.dispach") would silently intern a new
phase, splitting its latency history and breaking downstream dashboards
keyed on the documented names.  Every phase recorded through the
:class:`repro.obs.trace.Tracer` API (``rec`` / ``span``) must be listed
here; the ``det-span-registry`` check in :mod:`repro.analysis.detlint`
statically verifies every literal at every call site, and
``tests/test_obs.py`` asserts the registry covers the serving tree.

Grouped by the subsystem that records them (see
``docs/observability.md`` for the span model):
"""
from __future__ import annotations

#: Single-engine tick phases (serve/streaming.py).
ENGINE_PHASES = (
    "engine.tick", "engine.gather", "engine.kernel", "engine.device_wait",
    "engine.emit", "engine.finish",
)

#: Fleet front-door tick phases (serve/fleet/engine.py).
FLEET_PHASES = (
    "fleet.tick", "fleet.begin", "fleet.dispatch", "fleet.dispatch_issue",
    "fleet.device_wait", "fleet.snapshot", "fleet.flush_spill",
    "fleet.deliver", "fleet.finish",
)

#: Continuous-batching LM engine phases (serve/engine.py).
LM_PHASES = ("lm.tick", "lm.prefill", "lm.decode")

#: Slot-scheduler phases (serve/scheduler.py).
SCHED_PHASES = ("sched.admit", "sched.release")

#: Deploy parity-protocol sections (deploy/verify.py timings_s surface).
VERIFY_PHASES = (
    "verify.total", "verify.qvm", "verify.engine", "verify.qruntime_subset",
    "verify.fp32", "verify.cc_build", "verify.c_float", "verify.c_int",
    "verify.numerics",
)

#: Every registered span phase.
PHASES: frozenset[str] = frozenset(
    ENGINE_PHASES + FLEET_PHASES + LM_PHASES + SCHED_PHASES + VERIFY_PHASES)


def registered(phase: str) -> bool:
    return phase in PHASES


def assert_registered(phase: str) -> None:
    """Loud form for harnesses: raise on an unregistered phase name."""
    if phase not in PHASES:
        raise ValueError(
            f"span phase {phase!r} is not in repro.obs.phases.PHASES — "
            f"register it (and its docs) before recording it")
