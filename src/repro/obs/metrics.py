"""Metrics registry: counters, gauges and log2-bucket histograms behind
one schema, replacing the serving layers' ad-hoc ``stats()`` dicts for
SLO accounting.

Design
------
* **Fixed log2 buckets, µs -> s.**  Every histogram shares the bucket
  ladder ``1 µs, 2 µs, 4 µs, ..., 2^21 µs (~2.1 s), +inf`` — wide enough
  for a kernel dispatch and a full-fleet snapshot pass on the same axis,
  and *fixed*, so histograms from different runs/shards merge by adding
  count vectors.  Observation is one ``searchsorted`` (scalar or
  vectorized for columnar emission paths).
* **Wall-clock tagging.**  A metric created with ``wallclock=True``
  (latency histograms, deadline-miss counters) is intrinsically
  nondeterministic; ``snapshot(deterministic=True)`` drops those and
  keeps the deterministic skeleton — that is the byte-stable surface CI
  compares across identical runs.
* **Exporters.**  ``snapshot()`` emits one canonical JSON-able dict
  (``benchmark: "metrics_snapshot"`` so ``benchmarks/validate_bench.py``
  schema-gates it like every other artifact); ``prometheus()`` renders
  the standard text exposition format (counters, gauges, cumulative
  ``_bucket``/``_sum``/``_count`` histogram series).

The registry is plain Python + NumPy with no locks: the serving stack is
single-threaded per process, and the fleet engine owns exactly one
registry (shard-level series are name-prefixed, e.g.
``fleet.shard3.deadline_miss_stream_ticks``).
"""
from __future__ import annotations

import json
from typing import Any, Iterable

import numpy as np

#: Schema version of the snapshot artifact (bump on breaking change).
SNAPSHOT_SCHEMA_VERSION = 1

#: Shared histogram bucket upper edges in µs: 2^0 .. 2^21 (~2.1 s).
#: Observations above the last edge land in the +inf overflow bucket.
BUCKET_EDGES_US: tuple[int, ...] = tuple(2 ** k for k in range(22))


class Counter:
    """Monotonic counter."""
    __slots__ = ("name", "help", "wallclock", "value")

    def __init__(self, name: str, help: str = "", wallclock: bool = False):
        self.name = name
        self.help = help
        self.wallclock = wallclock
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""
    __slots__ = ("name", "help", "wallclock", "value")

    def __init__(self, name: str, help: str = "", wallclock: bool = False):
        self.name = name
        self.help = help
        self.wallclock = wallclock
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed log2-bucket histogram over µs (see :data:`BUCKET_EDGES_US`).

    ``counts[i]`` is the number of observations with
    ``value <= BUCKET_EDGES_US[i]`` (non-cumulative, per-bucket);
    ``counts[-1]`` is the +inf overflow.  ``sum_us`` accumulates exactly,
    so means are available alongside the bucketed percentiles."""
    __slots__ = ("name", "help", "wallclock", "counts", "sum_us", "count")

    def __init__(self, name: str, help: str = "", wallclock: bool = False):
        self.name = name
        self.help = help
        self.wallclock = wallclock
        self.counts = np.zeros(len(BUCKET_EDGES_US) + 1, np.int64)
        self.sum_us = 0.0
        self.count = 0

    def observe_us(self, us: float) -> None:
        i = int(np.searchsorted(_EDGES, us, side="left"))
        self.counts[i] += 1
        self.sum_us += us
        self.count += 1

    def observe_ns(self, ns: int) -> None:
        self.observe_us(ns / 1e3)

    def observe_many_us(self, us: np.ndarray) -> None:
        """Vectorized observation (columnar emission / warm-up sweeps)."""
        us = np.asarray(us, np.float64).ravel()
        if us.size == 0:
            return
        idx = np.searchsorted(_EDGES, us, side="left")
        np.add.at(self.counts, idx, 1)
        self.sum_us += float(us.sum())
        self.count += int(us.size)

    def quantile_us(self, q: float) -> float:
        """Bucket-resolution quantile estimate (upper edge of the bucket
        holding the q-th observation; +inf overflow reports the top edge
        doubled so it stays finite and obviously saturated)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, target, side="left"))
        if i >= len(BUCKET_EDGES_US):
            return float(BUCKET_EDGES_US[-1] * 2)
        return float(BUCKET_EDGES_US[i])


_EDGES = np.asarray(BUCKET_EDGES_US, np.float64)


class MetricsRegistry:
    """Name -> metric registry with get-or-create accessors and the two
    exporters.  Metric kinds are namespaced separately is an error —
    re-registering a name as a different kind raises."""

    def __init__(self):
        self._metrics: dict[str, Any] = {}

    # -- get-or-create -------------------------------------------------
    def counter(self, name: str, help: str = "",
                wallclock: bool = False) -> Counter:
        return self._get(name, Counter, help, wallclock)

    def gauge(self, name: str, help: str = "",
              wallclock: bool = False) -> Gauge:
        return self._get(name, Gauge, help, wallclock)

    def histogram(self, name: str, help: str = "",
                  wallclock: bool = False) -> Histogram:
        return self._get(name, Histogram, help, wallclock)

    def _get(self, name, cls, help, wallclock):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help, wallclock)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> list[str]:
        return sorted(self._metrics)

    # -- exporters -----------------------------------------------------
    def snapshot(self, deterministic: bool = False) -> dict[str, Any]:
        """One canonical dict of every metric, names sorted.
        ``deterministic=True`` drops wall-clock-tagged metrics so two
        identical runs serialize byte-identically (CI's determinism
        gate); the default keeps everything."""
        counters: dict[str, int] = {}
        gauges: dict[str, float] = {}
        hists: dict[str, Any] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if deterministic and m.wallclock:
                continue
            if isinstance(m, Counter):
                counters[name] = int(m.value)
            elif isinstance(m, Gauge):
                gauges[name] = float(m.value)
            else:
                hists[name] = {
                    "buckets_us": list(BUCKET_EDGES_US),
                    "counts": [int(c) for c in m.counts],
                    "count": int(m.count),
                    "sum_us": round(float(m.sum_us), 3),
                    "p50_us": m.quantile_us(0.50),
                    "p99_us": m.quantile_us(0.99),
                }
        return {
            "benchmark": "metrics_snapshot",
            "schema_version": SNAPSHOT_SCHEMA_VERSION,
            "deterministic": bool(deterministic),
            "counters": counters,
            "gauges": gauges,
            "histograms": hists,
        }

    def dumps(self, deterministic: bool = False) -> str:
        """Canonical JSON encoding of :meth:`snapshot` (sorted keys, no
        whitespace drift) — the byte-comparison surface."""
        return json.dumps(self.snapshot(deterministic=deterministic),
                          sort_keys=True, separators=(",", ":"))

    def prometheus(self) -> str:
        """Prometheus text exposition format.  Dots in metric names map
        to underscores (Prometheus name charset); histograms render the
        standard cumulative ``_bucket{le=...}`` series plus ``_sum`` and
        ``_count``."""
        lines: list[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            pname = _prom_name(name)
            if m.help:
                lines.append(f"# HELP {pname} {_prom_help(m.help)}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {m.value}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {_prom_num(m.value)}")
            else:
                lines.append(f"# TYPE {pname} histogram")
                cum = 0
                for edge, c in zip(BUCKET_EDGES_US, m.counts):
                    cum += int(c)
                    lines.append(f'{pname}_bucket{{le="{edge}"}} {cum}')
                cum += int(m.counts[-1])
                lines.append(f'{pname}_bucket{{le="+Inf"}} {cum}')
                lines.append(f"{pname}_sum {_prom_num(m.sum_us)}")
                lines.append(f"{pname}_count {m.count}")
        return "\n".join(lines) + "\n"


def _prom_name(name: str) -> str:
    """Sanitize into the Prometheus metric-name charset
    ``[a-zA-Z_:][a-zA-Z0-9_:]*``: every other character maps to ``_``,
    and a leading digit gets an underscore prefix (``isalnum`` admits
    digits everywhere *but* position 0)."""
    out = "".join(c if (c.isalnum() and c.isascii()) or c in "_:" else "_"
                  for c in name)
    return "_" + out if out[:1].isdigit() else out


def _prom_help(text: str) -> str:
    """Escape a HELP string per the text exposition format: backslash
    and newline are the only characters escaped on HELP lines."""
    return text.replace("\\", "\\\\").replace("\n", "\\n")


def _prom_num(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


def validate_snapshot(record: dict) -> list[str]:
    """Schema-gate one metrics snapshot (the ``validate_bench`` hook):
    required keys, bucket-ladder shape, count conservation, finiteness.
    Returns a list of errors; empty = valid."""
    errors: list[str] = []
    for key in ("benchmark", "schema_version", "deterministic",
                "counters", "gauges", "histograms"):
        if key not in record:
            errors.append(f"missing top-level key {key!r}")
    if errors:
        return errors
    if record["benchmark"] != "metrics_snapshot":
        errors.append(f"benchmark must be 'metrics_snapshot', "
                      f"got {record['benchmark']!r}")
    if record["schema_version"] != SNAPSHOT_SCHEMA_VERSION:
        errors.append(f"schema_version {record['schema_version']!r} != "
                      f"{SNAPSHOT_SCHEMA_VERSION}")
    for name, v in record["counters"].items():
        if not isinstance(v, int) or isinstance(v, bool) or v < 0:
            errors.append(f"counter {name!r}: must be a non-negative int, "
                          f"got {v!r}")
    for name, v in record["gauges"].items():
        if not isinstance(v, (int, float)) or isinstance(v, bool) \
                or not np.isfinite(v):
            errors.append(f"gauge {name!r}: must be a finite number")
    for name, h in record["histograms"].items():
        for key in ("buckets_us", "counts", "count", "sum_us",
                    "p50_us", "p99_us"):
            if key not in h:
                errors.append(f"histogram {name!r}: missing {key!r}")
        if sorted(h) != sorted(("buckets_us", "counts", "count", "sum_us",
                                "p50_us", "p99_us")):
            continue
        if list(h["buckets_us"]) != list(BUCKET_EDGES_US):
            errors.append(f"histogram {name!r}: bucket ladder differs from "
                          f"the canonical log2 edges")
        if len(h["counts"]) != len(BUCKET_EDGES_US) + 1:
            errors.append(f"histogram {name!r}: counts length "
                          f"{len(h['counts'])} != {len(BUCKET_EDGES_US) + 1}")
        elif sum(h["counts"]) != h["count"]:
            errors.append(f"histogram {name!r}: bucket counts sum "
                          f"{sum(h['counts'])} != count {h['count']}")
        if any((not isinstance(c, int)) or isinstance(c, bool) or c < 0
               for c in h["counts"]):
            errors.append(f"histogram {name!r}: counts must be "
                          f"non-negative ints")
    return errors


def merge_histogram_counts(counts: Iterable[Iterable[int]]) -> list[int]:
    """Merge per-shard histograms sharing the fixed bucket ladder by
    summing count vectors (the property the fixed edges exist for)."""
    out = np.zeros(len(BUCKET_EDGES_US) + 1, np.int64)
    for c in counts:
        c = np.asarray(list(c), np.int64)
        if c.shape != out.shape:
            raise ValueError(f"histogram counts length {c.shape[0]} != "
                             f"{out.shape[0]}")
        out += c
    return [int(v) for v in out]
