"""Numeric-health telemetry: runtime saturation counters + calibration drift.

PR 9 made the Q15 integer contracts *statically* provable: ``repro.analysis``
re-executes the deployed step/head program over exact integer intervals and
classifies every saturation site reachable or dead (``ANALYSIS_report.json``).
This module is the dynamic half of that loop — at serving time it counts how
often each named site actually fires and how far live activations drift from
the ranges the artifact was calibrated on:

* **Per-site saturation counters.**  One monotonic counter per named clamp
  site, reusing the analyzer's site IDs verbatim (``gate.hf_clip``,
  ``h_next``, ``w2.out`` / ``w2.fine`` requant + fine clips, ``act.z.idx`` /
  ``act.ht.idx`` LUT index clamps, ``head.logits`` narrowing cast) so a
  runtime snapshot and the static report key the same vocabulary.  Counted
  in the qvm (``deploy/qvm.py``), the emitted C (``FG_NUMERIC_COUNTERS``
  block, parity-gated against the qvm), and the batched float kernels
  (where the integer sites collapse to the LUT domain saturations).
* **Per-tensor activation ranges.**  min / max / |v|-ratio histogram per
  named tensor against the artifact's calibration limit, folded into a
  deterministic ``calibration_drift`` score (range-overflow fraction plus
  p99 quantile shift) — the early-warning signal that a tenant's sensor
  left the calibrated envelope *before* argmax agreement degrades.
* **Static cross-check.**  :func:`repro.analysis.crosscheck` asserts every
  runtime witness is a statically-reachable site (a dead site firing is a
  hard invariant violation); ``deploy/verify.py`` runs it as part of the
  parity protocol.

Determinism contract: monitors hang off the ``Observability`` bundle and
default to ``None`` (every hook skipped — the bit-exact fast path is
untouched); a *monitored* run only ever reads intermediate values, so it is
byte-identical to an unmonitored run on every backend (test-gated like the
tracer).  Snapshots contain no wall-clock fields and round floats, so two
identical runs serialize byte-identically.
"""
from __future__ import annotations

from typing import Any, Iterable

import numpy as np

#: Q15 code range — calibration scales are value-per-LSB, so the calibrated
#: amplitude limit of a tensor with scale ``s`` is ``s * Q15_LIMIT``.
Q15_LIMIT = 32767

#: Saturation sites of the integer cell shared by every image geometry, in
#: program order (the matvec sites come first, per-image — see
#: :func:`site_order`).
CELL_SITES = (
    "act.z.idx",    # sigmoid LUT index clamp (qlint: act.z.idx)
    "act.ht.idx",   # tanh LUT index clamp
    "gate.out",     # gate requant int32 saturation
    "gate.hf_clip", # gate-combine accumulator ±2^31 clip
    "hstore.out",   # h-store requant int32 saturation
    "h_next",       # h-store int16 saturation (load-bearing, reachable)
    "head.logits",  # head narrowing cast int64 -> int32
)

#: Per-matvec sites: requant int32 saturation then the ±(2^29-1) fine clip.
MATVEC_SITES = ("out", "fine")


def site_order(low_rank: bool = True) -> tuple[str, ...]:
    """The canonical ordered site vocabulary of one deployed image — the
    contract between the qvm monitor, the emitted C counter block
    (``FG_SITE_*`` indices are positions in this tuple) and the analyzer's
    report.  Matvec sites appear in the qvm's execution order."""
    names = ("w2", "w1", "u2", "u1") if low_rank else ("w", "u")
    mv = tuple(f"{n}.{k}" for n in names for k in MATVEC_SITES)
    return mv + CELL_SITES


def site_index(site: str, low_rank: bool = True) -> int:
    return site_order(low_rank).index(site)


def limits_from_scales(act_scales: dict[str, float] | None,
                       q_max: int = Q15_LIMIT) -> dict[str, float]:
    """Calibrated per-tensor amplitude limits from deploy calibration
    scales (value-per-LSB): ``limit = scale * 32767`` — the largest
    magnitude representable without saturating at that scale."""
    if not act_scales:
        return {}
    return {k: float(act_scales[k]) * q_max
            for k in sorted(act_scales) if float(act_scales[k]) > 0.0}


# ---------------------------------------------------------------------------
# Per-tensor range statistics
# ---------------------------------------------------------------------------

#: |v|/limit ratio histogram: 16 buckets of width 1/8 over [0, 2) plus one
#: overflow bucket (ratio >= 2).  Fixed edges so shard histograms merge by
#: adding count vectors, same property as the metrics bucket ladder.
RATIO_BUCKETS = 17

#: Registry-publish cadence (engine ticks).  ``publish`` walks every
#: site and tensor and recomputes drift — per-tick export dominates the
#: monitor's cost on small models, and since counters are delta-tracked
#: a throttled publish drops nothing.
PUBLISH_EVERY = 32


class RangeStats:
    """Running min/max + |v|/limit histogram for one named tensor.

    Two observation paths: :meth:`observe` (full — histogram + extrema,
    used on the rare emission/trace paths) and :meth:`note` (light —
    pre-reduced extrema and overflow count from the hot tick loop, no
    histogram).  Both feed the same drift score."""

    __slots__ = ("limit", "n", "n_over", "vmin", "vmax", "hist")

    def __init__(self, limit: float | None = None):
        self.limit = None if limit is None else float(limit)
        self.n = 0
        self.n_over = 0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self.hist = np.zeros(RATIO_BUCKETS, np.int64)

    def observe(self, values) -> None:
        v = np.asarray(values, np.float64).ravel()
        if v.size == 0:
            return
        self.n += int(v.size)
        self.vmin = min(self.vmin, float(v.min()))
        self.vmax = max(self.vmax, float(v.max()))
        if self.limit is not None:
            a = np.abs(v)
            self.n_over += int(np.count_nonzero(a > self.limit))
            idx = np.minimum((a * (8.0 / self.limit)).astype(np.int64),
                             RATIO_BUCKETS - 1)
            np.add.at(self.hist, idx, 1)

    def note(self, vmin: float, vmax: float, n: int, n_over: int = 0) -> None:
        """Fold pre-reduced extrema (hot-path form: the caller already has
        the reduction, no histogram pass)."""
        if n <= 0:
            return
        self.n += int(n)
        self.n_over += int(n_over)
        self.vmin = min(self.vmin, float(vmin))
        self.vmax = max(self.vmax, float(vmax))

    def merge(self, other: "RangeStats") -> None:
        if other.n == 0:
            return
        if self.limit is None:
            self.limit = other.limit
        self.n += other.n
        self.n_over += other.n_over
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        self.hist += other.hist

    # -- drift ----------------------------------------------------------
    def p99_ratio(self) -> float:
        """Bucket-resolution 99th-percentile |v|/limit ratio (upper edge
        of the bucket holding the p99 observation; histogram-less stats
        fall back to the max-ratio, the only quantile they know)."""
        if self.limit is None or self.n == 0:
            return 0.0
        total = int(self.hist.sum())
        if total == 0:
            m = max(abs(self.vmin), abs(self.vmax))
            return m / self.limit
        cum = np.cumsum(self.hist)
        i = int(np.searchsorted(cum, 0.99 * total, side="left"))
        return (i + 1) / 8.0

    def drift(self) -> float:
        """Deterministic calibration-drift score: the fraction of
        observations outside the calibrated limit plus the p99 quantile
        shift beyond it.  0.0 = fully inside calibration; ~0 < drift <= 1
        = tail excursions; > 1 = bulk shift."""
        if self.limit is None or self.n == 0:
            return 0.0
        over = self.n_over / self.n
        return over + max(0.0, self.p99_ratio() - 1.0)

    def snapshot(self) -> dict[str, Any]:
        out: dict[str, Any] = {
            "n": int(self.n),
            "n_over": int(self.n_over),
            "min": 0.0 if self.n == 0 else round(self.vmin, 6),
            "max": 0.0 if self.n == 0 else round(self.vmax, 6),
            "limit": None if self.limit is None else round(self.limit, 6),
            "drift": round(self.drift(), 6),
        }
        if self.hist.any():
            out["hist"] = [int(c) for c in self.hist]
        return out


# ---------------------------------------------------------------------------
# The monitor
# ---------------------------------------------------------------------------

class NumericsMonitor:
    """Per-site saturation counters + per-tensor range stats, with
    per-shard children for fleet aggregation.

    The parent monitor rides ``Observability.numerics``; each serving
    engine counts into its own :meth:`shard` child (shard index, or -1
    for a standalone engine), and :meth:`snapshot` aggregates parent +
    children deterministically.  All hooks are pure reads of intermediate
    values — attaching a monitor never changes a computed result."""

    def __init__(self, limits: dict[str, float] | None = None):
        self._limits: dict[str, float] = dict(limits or {})
        self._sites: dict[str, int] = {}
        self._tensors: dict[str, RangeStats] = {}
        self._children: dict[int, "NumericsMonitor"] = {}
        self._published: dict[str, int] = {}

    @classmethod
    def from_scales(cls, act_scales: dict[str, float] | None
                    ) -> "NumericsMonitor":
        return cls(limits_from_scales(act_scales))

    # -- configuration --------------------------------------------------
    def set_default_limits(self, limits: dict[str, float]) -> None:
        """Install calibration limits for tensors that do not have one yet
        (late binding: the artifact is often only known to the engine)."""
        for k in sorted(limits):
            if k not in self._limits:
                self._limits[k] = float(limits[k])
                st = self._tensors.get(k)
                if st is not None and st.limit is None:
                    st.limit = float(limits[k])

    def limit(self, tensor: str) -> float | None:
        return self._limits.get(tensor)

    def declare(self, sites: Iterable[str]) -> None:
        """Pre-register sites at zero so an un-fired site still appears in
        the snapshot (the cross-check needs zero counts to be visible)."""
        for s in sites:
            self._sites.setdefault(s, 0)

    # -- observation ----------------------------------------------------
    def count(self, site: str, n: int) -> None:
        if n:
            self._sites[site] = self._sites.get(site, 0) + int(n)
        else:
            self._sites.setdefault(site, 0)

    def count_events(self, events: dict[str, int]) -> None:
        for site in sorted(events):
            self.count(site, events[site])

    def observe(self, tensor: str, values) -> None:
        st = self._tensors.get(tensor)
        if st is None:
            st = self._tensors[tensor] = RangeStats(self._limits.get(tensor))
        st.observe(values)

    def note_range(self, tensor: str, vmin: float, vmax: float, n: int,
                   n_over: int = 0) -> None:
        st = self._tensors.get(tensor)
        if st is None:
            st = self._tensors[tensor] = RangeStats(self._limits.get(tensor))
        st.note(vmin, vmax, n, n_over)

    # -- fleet sharding -------------------------------------------------
    def shard(self, index: int) -> "NumericsMonitor":
        """Get-or-create the child monitor for one shard (index -1 = a
        standalone engine).  Children share the parent's limit table."""
        child = self._children.get(index)
        if child is None:
            child = self._children[index] = NumericsMonitor()
            child._limits = self._limits   # shared (not copied): limits
            # late-bound on the parent reach already-created children
        return child

    def shard_indices(self) -> list[int]:
        return sorted(self._children)

    def reset(self) -> None:
        """Zero this monitor's own counters/stats (crash-retirement path:
        the fleet folds the child's snapshot into its retired accumulator
        first, so nothing is lost).  Children are left alone."""
        self._sites = {}
        self._tensors = {}
        self._published = {}

    # -- aggregation / export -------------------------------------------
    def _aggregate(self) -> tuple[dict[str, int], dict[str, RangeStats]]:
        sites = dict(self._sites)
        tensors = {k: v for k, v in self._tensors.items()}
        agg_tensors: dict[str, RangeStats] = {}
        for name in sorted(tensors):
            st = RangeStats(tensors[name].limit)
            st.merge(tensors[name])
            agg_tensors[name] = st
        for idx in sorted(self._children):
            csites, ctensors = self._children[idx]._aggregate()
            for s in sorted(csites):
                sites[s] = sites.get(s, 0) + csites[s]
            for name in sorted(ctensors):
                st = agg_tensors.get(name)
                if st is None:
                    st = agg_tensors[name] = RangeStats(ctensors[name].limit)
                st.merge(ctensors[name])
        return sites, agg_tensors

    def site_counts(self) -> dict[str, int]:
        """Aggregated per-site counters (self + shard children)."""
        return self._aggregate()[0]

    def drift(self) -> float:
        """The worst per-tensor drift score (the fleet's one-number
        health gauge)."""
        _, tensors = self._aggregate()
        return max((t.drift() for t in tensors.values()), default=0.0)

    def snapshot(self, per_shard: bool = False) -> dict[str, Any]:
        """One deterministic dict: aggregated site counters, per-tensor
        range stats + drift, worst drift.  ``per_shard=True`` adds each
        child's own snapshot keyed by shard index."""
        sites, tensors = self._aggregate()
        out: dict[str, Any] = {
            "schema": "numerics_snapshot",
            "sites": {k: int(sites[k]) for k in sorted(sites)},
            "tensors": {k: tensors[k].snapshot() for k in sorted(tensors)},
            "drift": round(max((t.drift() for t in tensors.values()),
                               default=0.0), 6),
        }
        if per_shard:
            out["per_shard"] = {
                str(i): self._children[i].snapshot()
                for i in sorted(self._children)}
        return out

    def publish(self, reg) -> None:
        """Export into a :class:`repro.obs.metrics.MetricsRegistry`:
        monotone per-site counters (delta-tracked so repeated publishes
        never double-count) and per-tensor / overall drift gauges."""
        sites, tensors = self._aggregate()
        for site in sorted(sites):
            prev = self._published.get(site, 0)
            delta = sites[site] - prev
            c = reg.counter(f"numerics.sat.{site}",
                            "saturation/clamp events at this site")
            if delta > 0:
                c.inc(delta)
                self._published[site] = sites[site]
        worst = 0.0
        for name in sorted(tensors):
            d = tensors[name].drift()
            worst = max(worst, d)
            reg.gauge(f"numerics.drift.{name}",
                      "calibration-drift score for this tensor").set(d)
        reg.gauge("numerics.drift",
                  "worst per-tensor calibration-drift score").set(worst)


def merge_site_counts(into: dict[str, int],
                      counts: dict[str, int]) -> dict[str, int]:
    """Fold one site-counter dict into an accumulator (the fleet's
    crash-retirement helper; conservation is checked by
    ``obs.invariants.check_numerics_conservation``)."""
    for site in sorted(counts):
        into[site] = into.get(site, 0) + int(counts[site])
    return into
