"""Reusable serving-stack invariants.

The counter-conservation law the failover tests enforce — every
monotonic fleet total equals the sum over live shards plus the retired
accumulator of crashed shards; nothing is lost or double-counted by a
crash/rebuild — used to live as an assert helper inside
``tests/faultharness.py``.  It is a *production* invariant, not a test
detail: a debug-mode fleet (``Observability(debug=True)``) checks it on
every ``FleetEngine.stats()`` call, and the test harness delegates here,
so the two cannot drift.
"""
from __future__ import annotations

#: Workload counters conserved across shard crash/rebuild.
CONSERVED_WORKLOAD = ("completed", "stream_steps", "ring_spills",
                      "replay_suppressed")
#: Scheduler counters conserved across shard crash/rebuild.
CONSERVED_SCHED = ("admissions", "recycles", "spills", "completed",
                   "cancelled", "evictions", "ticks")
#: Gauges that must stay live-only (never folded into retired).
LIVE_GAUGES = ("active", "pending")


def check_conservation(stats: dict) -> list[str]:
    """Check the counter-conservation invariant over one
    ``FleetEngine.stats()`` dict.  Returns a list of violation
    descriptions; empty = conserved."""
    errors: list[str] = []
    per = stats["per_shard"]
    retired = stats["retired"]
    for key in CONSERVED_WORKLOAD:
        live = sum(p[key] for p in per)
        if stats[key] != live + retired[key]:
            errors.append(f"{key}: fleet total {stats[key]} != live {live} "
                          f"+ retired {retired[key]}")
    rsched = retired["scheduler"]
    for key in CONSERVED_SCHED:
        live = sum(p["scheduler"][key] for p in per)
        if stats["scheduler"][key] != live + rsched[key]:
            errors.append(f"scheduler.{key}: fleet total "
                          f"{stats['scheduler'][key]} != live {live} "
                          f"+ retired {rsched[key]}")
    for key in LIVE_GAUGES:
        live = sum(p[key] for p in per)
        if stats[key] != live:
            errors.append(f"{key}: gauge {stats[key]} != live sum {live} "
                          f"(gauges must not include retired shards)")
    errors += check_numerics_conservation(stats)
    return errors


def check_numerics_conservation(stats: dict) -> list[str]:
    """Numeric-health counter conservation: every per-site saturation
    total in ``stats()["numerics"]["sites"]`` equals the sum over the
    live shards' monitor children plus ``retired_sites`` (counts folded
    in by ``crash_shard`` before it reset the dead shard's child).
    No-op (empty list) when the fleet runs unmonitored."""
    errors: list[str] = []
    num = stats.get("numerics")
    if num is None:
        return errors
    retired = num.get("retired_sites", {})
    live: dict[str, int] = {}
    for p in stats["per_shard"]:
        psnap = p.get("numerics")
        if not psnap:
            continue
        for k in sorted(psnap["sites"]):
            live[k] = live.get(k, 0) + psnap["sites"][k]
    for k in sorted(set(num["sites"]) | set(live) | set(retired)):
        total = num["sites"].get(k, 0)
        if total != live.get(k, 0) + retired.get(k, 0):
            errors.append(
                f"numerics.{k}: fleet total {total} != live "
                f"{live.get(k, 0)} + retired {retired.get(k, 0)}")
    return errors


def assert_conservation(stats: dict) -> None:
    """Raise ``AssertionError`` with every violation if the conservation
    invariant does not hold (the test-harness / debug-mode entry point)."""
    errors = check_conservation(stats)
    assert not errors, "counter conservation violated:\n  " + \
        "\n  ".join(errors)
