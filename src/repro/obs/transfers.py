"""Host<->device transfer accounting for the device-resident serving path.

The fleet's "zero steady-state copies of h" claim (device-resident async
ticks) must be a *measured invariant*, not a comment: every
``Q15StreamStep`` owns a :class:`TransferLedger` and books the bytes it
moves across the host/device boundary — per-tick ``x``/mask staging
(``h2d``), hidden-state uploads/downloads (``h2d``/``d2h`` with
``state=True``), and emission/tap/snapshot row pulls.  The ledger is a
handful of plain int adds, cheap enough to stay always-on (no
Observability bundle required), and tests/benchmarks read it through
``stats()["transfers"]``:

* a steady-state fused tick on the device-resident jit/pallas path books
  **zero** ``h_h2d_bytes``/``h_d2h_bytes`` (the regression gate in
  ``tests/test_device_fleet.py``);
* the legacy host-staged path books a full ``h`` round-trip per tick —
  the contrast ``benchmarks/fleet_bench.py`` publishes per results row.

Byte counts are *logical* transfer volume (what would cross PCIe/ICI on
a real accelerator); on CPU jax may alias instead of copying, but the
invariant "no h crosses the boundary per steady tick" is the same.
"""
from __future__ import annotations

#: Ledger/snapshot keys, in canonical order: total staged bytes each way,
#: plus the hidden-state-only sub-accounts the zero-copy gate reads.
TRANSFER_KEYS = ("h2d_bytes", "d2h_bytes", "h_h2d_bytes", "h_d2h_bytes")


class TransferLedger:
    """Monotonic host<->device byte counters (one per kernel instance)."""

    __slots__ = TRANSFER_KEYS

    def __init__(self) -> None:
        self.h2d_bytes = 0
        self.d2h_bytes = 0
        self.h_h2d_bytes = 0
        self.h_d2h_bytes = 0

    def h2d(self, nbytes: int, *, state: bool = False) -> None:
        """Book a host->device transfer; ``state=True`` marks hidden-state
        bytes (the zero-copy invariant's sub-account)."""
        self.h2d_bytes += nbytes
        if state:
            self.h_h2d_bytes += nbytes

    def d2h(self, nbytes: int, *, state: bool = False) -> None:
        self.d2h_bytes += nbytes
        if state:
            self.h_d2h_bytes += nbytes

    def snapshot(self) -> dict[str, int]:
        return {k: getattr(self, k) for k in TRANSFER_KEYS}


def sum_transfers(snapshots) -> dict[str, int]:
    """Fold ledger snapshots (dicts) into one total — the fleet's
    ``stats()["transfers"]`` roll-up across shard + group kernels."""
    tot = dict.fromkeys(TRANSFER_KEYS, 0)
    for snap in snapshots:
        for k in TRANSFER_KEYS:
            tot[k] += snap[k]
    return tot
