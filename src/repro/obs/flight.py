"""Flight recorder: what happened in the seconds before a shard crash.

A crash report that only says "shard 3 died at tick 812" is useless for
diagnosing *why*; the flight recorder pairs the tracer's chronological
span ring (the exact pre-crash tick phases, in order) with the last N
stream-event summaries per shard, and dumps both as one typed artifact
the moment ``FleetEngine.crash_shard`` runs.

Determinism contract: ``dumps(deterministic=True)`` strips wall-clock
span fields and serializes with sorted keys, so two identical runs under
the same :class:`~repro.serve.fleet.faults.ScheduledFaults` schedule
produce **byte-identical** crash dumps — asserted across the full
phase x shard crash matrix in ``tests/test_obs.py`` and recorded in
``BENCH_obs.json``.

Event summaries are deliberately compact (tick, shard, event count, the
tail of (stream_id, kind, step) triples): at fleet scale a lockstep
window boundary emits 100k+ events in one tick, and the recorder must
not turn delivery into an O(events) copy — it keeps the count and the
last few, bounded by ``events_per_shard``.
"""
from __future__ import annotations

import json
from collections import deque
from typing import Any

from .trace import NullTracer, Tracer

#: Per-shard cap on retained (stream_id, kind, step) event triples.
DEFAULT_EVENTS_PER_SHARD = 64


class FlightRecorder:
    """Crash-dump assembler over a :class:`~repro.obs.trace.Tracer`."""

    def __init__(self, tracer: Tracer | NullTracer, *,
                 events_per_shard: int = DEFAULT_EVENTS_PER_SHARD,
                 max_crashes: int = 16):
        self.tracer = tracer
        self.events_per_shard = events_per_shard
        self._events: dict[int, deque] = {}
        self._event_counts: dict[int, int] = {}
        self._crashes: deque = deque(maxlen=max_crashes)

    # ------------------------------------------------------------------
    # Live feed (called by the fleet during delivery)
    # ------------------------------------------------------------------
    def note_events(self, shard: int, tick: int, summaries: list,
                    total: int | None = None) -> None:
        """Record one shard's tick emission: ``summaries`` is a short
        list of (stream_id, kind, step) triples (the caller truncates to
        ``events_per_shard``; columnar batches summarize, they do not
        expand).  ``total`` is the true emission count when the
        summaries are a truncation of a larger batch."""
        q = self._events.get(shard)
        if q is None:
            q = self._events[shard] = deque(maxlen=self.events_per_shard)
            self._event_counts[shard] = 0
        self._event_counts[shard] += (len(summaries) if total is None
                                      else total)
        for sid, kind, step in summaries[-self.events_per_shard:]:
            q.append((tick, sid, kind, int(step)))

    # ------------------------------------------------------------------
    # Crash capture
    # ------------------------------------------------------------------
    def record_crash(self, report: dict, *, tick: int,
                     counters: dict | None = None) -> dict[str, Any]:
        """Assemble and retain one crash dump from a
        ``FleetEngine.crash_shard`` recovery report.  Returns the dump."""
        shard = report.get("shard")
        dump: dict[str, Any] = {
            "artifact": "flight_record",
            "version": 1,
            "tick": int(tick),
            "shard": shard,
            "phase": report.get("phase"),
            "recovery": {k: report[k] for k in sorted(report)},
            "trace": self.tracer.flight(),
            "recent_events": {
                str(s): {
                    "total_events": self._event_counts.get(s, 0),
                    "tail": [{"tick": t, "stream": sid, "kind": kind,
                              "step": step}
                             for t, sid, kind, step in self._events.get(
                                 s, ())],
                } for s in sorted(self._events)},
            "counters": counters or {},
        }
        self._crashes.append(dump)
        return dump

    # ------------------------------------------------------------------
    # Introspection / export
    # ------------------------------------------------------------------
    @property
    def n_crashes(self) -> int:
        return len(self._crashes)

    def last(self) -> dict[str, Any] | None:
        """The most recent crash dump (None if no crash was recorded)."""
        return self._crashes[-1] if self._crashes else None

    def crashes(self) -> list[dict[str, Any]]:
        return list(self._crashes)

    def dumps(self, deterministic: bool = False) -> str:
        """Canonical JSON of every retained crash dump.  With
        ``deterministic=True`` wall-clock span fields are stripped from
        the embedded traces, making the bytes stable across identical
        runs (the crash-matrix byte-stability gate)."""
        crashes = [self._strip(c) if deterministic else c
                   for c in self._crashes]
        return json.dumps({"artifact": "flight_record_log",
                           "deterministic": bool(deterministic),
                           "crashes": crashes},
                          sort_keys=True, separators=(",", ":"))

    @staticmethod
    def _strip(dump: dict) -> dict:
        out = dict(dump)
        out["trace"] = [{k: v for k, v in rec.items()
                         if k not in ("t0_us", "dur_us")}
                        for rec in dump["trace"]]
        return out
