"""Tick-phase tracer: fixed-size span rings for the serving hot path.

The paper's headline is a *latency* claim — 9.21 ms/sample against a
20 ms 50 Hz tick budget — so the serving stack needs to answer "where
does a tick spend its time?" without perturbing the thing it measures.
This tracer is built around two constraints:

* **No allocation on the hot path.**  A span is recorded with two calls
  — ``t0 = tracer.t()`` before the work and ``tracer.rec(phase, t0)``
  after — that write into preallocated NumPy rings through an integer
  cursor.  Phase names are interned to integer ids on first use; the
  steady state is one dict hit plus a handful of array stores.
* **Zero cost when disabled.**  :data:`NULL_TRACER` (the engines'
  default) implements the same surface as no-ops: ``t()`` returns the
  cached small int ``0`` and ``rec`` returns immediately, so the
  bit-exact fast path stays untouched (gated by the zero-allocation
  test in ``tests/test_obs.py`` and the <2 % overhead budget in
  ``benchmarks/obs_bench.py``).

Two views of the recorded spans:

* **Per-phase duration rings** — ``phase_stats()`` folds the last
  ``capacity`` durations of every phase into count / total / p50 / p99 /
  max (the latency-breakdown surface ``BENCH_obs.json`` publishes).
* **The flight ring** — one chronological ring over *all* spans
  (sequence number, fleet tick, phase, shard, start, duration).
  ``flight()`` returns its tail: the exact pre-crash phase history the
  :class:`repro.obs.flight.FlightRecorder` dumps on ``crash_shard``.

Wall-clock fields (``t0_us`` / ``dur_us``) are intrinsically
nondeterministic; every exporter that promises byte-stable output
(``flight(deterministic=True)``, the metrics snapshot) strips them and
keeps the deterministic skeleton (seq, tick, phase, shard).
"""
from __future__ import annotations

import time
from typing import Any

import numpy as np


class _NullSpan:
    """Reusable no-op context manager (one shared instance, no alloc)."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: the engines' default.  Every method is a no-op
    cheap enough for the fused-tick hot path (no timestamps taken, no
    objects allocated)."""
    enabled = False
    __slots__ = ()

    def t(self) -> int:
        return 0

    def rec(self, phase: str, t0: int, shard: int = -1) -> int:
        return 0

    def set_tick(self, tick: int) -> None:
        pass

    def span(self, phase: str, shard: int = -1):
        return _NULL_SPAN

    def phase_stats(self) -> dict:
        return {}

    def flight(self, last: int | None = None,
               deterministic: bool = False) -> list:
        return []

    def totals_s(self) -> dict:
        return {}


NULL_TRACER = NullTracer()


class _Span:
    """Context-manager adapter over the ``t()``/``rec()`` pair, for call
    sites that are not allocation-sensitive (harnesses, ``deploy.verify``).
    Exposes the recorded duration as ``.dur_ns`` after exit."""
    __slots__ = ("_tracer", "_phase", "_shard", "_t0", "dur_ns")

    def __init__(self, tracer: "Tracer", phase: str, shard: int):
        self._tracer = tracer
        self._phase = phase
        self._shard = shard
        self.dur_ns = 0

    def __enter__(self):
        self._t0 = self._tracer.t()
        return self

    def __exit__(self, *exc):
        self.dur_ns = self._tracer.rec(self._phase, self._t0, self._shard)
        return False


class Tracer:
    """Span recorder with fixed-size rings (see module docstring).

    ``capacity`` bounds both the chronological flight ring and each
    phase's duration ring; recording wraps, it never grows."""
    enabled = True

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._epoch = time.perf_counter_ns()
        self._tick = 0
        # phase interning
        self._phase_ids: dict[str, int] = {}
        self._phase_names: list[str] = []
        # per-phase duration rings + monotonic totals
        self._durs: list[np.ndarray] = []
        self._cursors: list[int] = []
        self._counts: list[int] = []
        self._total_ns: list[int] = []
        # chronological flight ring
        self._seq = 0
        self._fl_seq = np.full(capacity, -1, np.int64)
        self._fl_tick = np.zeros(capacity, np.int64)
        self._fl_phase = np.full(capacity, -1, np.int32)
        self._fl_shard = np.full(capacity, -1, np.int32)
        self._fl_t0 = np.zeros(capacity, np.int64)     # ns since epoch
        self._fl_dur = np.zeros(capacity, np.int64)    # ns

    # ------------------------------------------------------------------
    # Hot-path surface
    # ------------------------------------------------------------------
    def t(self) -> int:
        """Span start: a raw ``perf_counter_ns`` timestamp."""
        return time.perf_counter_ns()

    def set_tick(self, tick: int) -> None:
        """Tag subsequent spans with the current fleet tick (flight-ring
        context; called once per tick, not per span)."""
        self._tick = tick

    def rec(self, phase: str, t0: int, shard: int = -1) -> int:
        """Record a span that started at ``t0`` and ends now.  Returns
        the span duration in ns (callers layer deadline accounting on
        top without a second clock read)."""
        t1 = time.perf_counter_ns()
        dur = t1 - t0
        pid = self._phase_ids.get(phase)
        if pid is None:
            pid = self._intern(phase)
        # per-phase duration ring
        cur = self._cursors[pid]
        self._durs[pid][cur] = dur
        self._cursors[pid] = (cur + 1) % self.capacity
        self._counts[pid] += 1
        self._total_ns[pid] += dur
        # chronological flight ring
        i = self._seq % self.capacity
        self._fl_seq[i] = self._seq
        self._fl_tick[i] = self._tick
        self._fl_phase[i] = pid
        self._fl_shard[i] = shard
        self._fl_t0[i] = t0 - self._epoch
        self._fl_dur[i] = dur
        self._seq += 1
        return dur

    def span(self, phase: str, shard: int = -1) -> _Span:
        """Context-manager convenience for cold call sites."""
        return _Span(self, phase, shard)

    # ------------------------------------------------------------------
    # Views
    # ------------------------------------------------------------------
    def phase_stats(self) -> dict[str, dict[str, Any]]:
        """Per-phase latency breakdown over each phase's retained ring:
        ``{phase: {count, total_us, p50_us, p99_us, max_us}}`` (count and
        total are monotonic over the tracer's whole lifetime; the
        percentiles cover the last ``capacity`` spans).  Phases sort by
        name so the snapshot is structurally deterministic."""
        out: dict[str, dict[str, Any]] = {}
        for name in sorted(self._phase_ids):
            pid = self._phase_ids[name]
            n = min(self._counts[pid], self.capacity)
            durs = self._durs[pid][:n]
            us = durs / 1e3
            out[name] = {
                "count": int(self._counts[pid]),
                "total_us": round(self._total_ns[pid] / 1e3, 3),
                "p50_us": round(float(np.percentile(us, 50)), 3),
                "p99_us": round(float(np.percentile(us, 99)), 3),
                "max_us": round(float(us.max()), 3),
            }
        return out

    def totals_s(self) -> dict[str, float]:
        """Total recorded seconds per phase (the ``deploy.verify`` timing
        surface: one span per protocol section, summed)."""
        return {name: self._total_ns[self._phase_ids[name]] / 1e9
                for name in sorted(self._phase_ids)}

    def flight(self, last: int | None = None,
               deterministic: bool = False) -> list[dict[str, Any]]:
        """Chronological tail of the flight ring (oldest first), each
        span as a dict.  ``deterministic=True`` strips the wall-clock
        fields (``t0_us`` / ``dur_us``) so two identical runs produce
        byte-identical dumps — the flight-recorder stability contract."""
        n = min(self._seq, self.capacity)
        if last is not None:
            n = min(n, last)
        out = []
        for k in range(self._seq - n, self._seq):
            i = k % self.capacity
            rec: dict[str, Any] = {
                "seq": int(self._fl_seq[i]),
                "tick": int(self._fl_tick[i]),
                "phase": self._phase_names[int(self._fl_phase[i])],
                "shard": int(self._fl_shard[i]),
            }
            if not deterministic:
                rec["t0_us"] = round(int(self._fl_t0[i]) / 1e3, 3)
                rec["dur_us"] = round(int(self._fl_dur[i]) / 1e3, 3)
            out.append(rec)
        return out

    # ------------------------------------------------------------------
    def _intern(self, phase: str) -> int:
        pid = len(self._phase_names)
        self._phase_ids[phase] = pid
        self._phase_names.append(phase)
        self._durs.append(np.zeros(self.capacity, np.int64))
        self._cursors.append(0)
        self._counts.append(0)
        self._total_ns.append(0)
        return pid
