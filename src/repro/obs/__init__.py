"""Deterministic, low-overhead observability for the serving stack.

Three cooperating pieces (see ``docs/observability.md``):

* :mod:`repro.obs.trace` — tick-phase tracer: fixed-size span rings,
  no allocation on the hot path, a :data:`~repro.obs.trace.NULL_TRACER`
  default that keeps the bit-exact fast path untouched.
* :mod:`repro.obs.metrics` — counters / gauges / fixed log2-bucket
  histograms behind one schema, with canonical-JSON and Prometheus
  exporters and a ``validate_bench``-style schema gate.
* :mod:`repro.obs.flight` — flight recorder: the tracer ring plus the
  last N stream events per shard, dumped as a typed artifact on
  ``FleetEngine.crash_shard``.

:class:`Observability` bundles them with the SLO deadline config; every
serving layer (``FleetEngine``, ``StreamingEngine``, ``SlotScheduler``,
the LM ``Engine``) accepts one via ``obs=`` and defaults to
:data:`NULL_OBS` (all hooks no-ops).
"""
from __future__ import annotations

import dataclasses

from .flight import DEFAULT_EVENTS_PER_SHARD, FlightRecorder
from .invariants import (CONSERVED_SCHED, CONSERVED_WORKLOAD,
                         assert_conservation, check_conservation,
                         check_numerics_conservation)
from .metrics import (BUCKET_EDGES_US, SNAPSHOT_SCHEMA_VERSION, Counter,
                      Gauge, Histogram, MetricsRegistry,
                      merge_histogram_counts, validate_snapshot)
from .numerics import (CELL_SITES, NumericsMonitor, RangeStats,
                       limits_from_scales, merge_site_counts, site_order)
from .phases import PHASES, assert_registered, registered
from .trace import NULL_TRACER, NullTracer, Tracer
from .transfers import TRANSFER_KEYS, TransferLedger, sum_transfers


@dataclasses.dataclass
class Observability:
    """The bundle a serving layer consumes.

    ``deadline_ms`` is the per-tick SLO budget for deadline-miss
    accounting; ``None`` derives it from the engine's sample rate
    (50 Hz -> 20 ms, the paper's real-time bar).  ``debug=True`` turns
    on invariant checking in ``FleetEngine.stats()``
    (:func:`repro.obs.invariants.assert_conservation`)."""
    tracer: Tracer | NullTracer = NULL_TRACER
    metrics: MetricsRegistry | None = None
    recorder: FlightRecorder | None = None
    deadline_ms: float | None = None
    debug: bool = False
    numerics: NumericsMonitor | None = None

    @property
    def enabled(self) -> bool:
        """True when any instrumentation is active (tracing or metrics);
        engines use this to skip obs-only branches entirely."""
        return self.tracer.enabled or self.metrics is not None

    @classmethod
    def null(cls) -> "Observability":
        """The shared all-off bundle (module-level :data:`NULL_OBS`)."""
        return NULL_OBS

    @classmethod
    def full(cls, *, capacity: int = 4096, deadline_ms: float | None = None,
             events_per_shard: int = DEFAULT_EVENTS_PER_SHARD,
             debug: bool = False, numerics: bool = False) -> "Observability":
        """Everything on: tracer + metrics registry + flight recorder.
        ``numerics=True`` additionally attaches a bare
        :class:`~repro.obs.numerics.NumericsMonitor` (no calibration
        limits — engines late-bind those from the artifact; build the
        monitor via ``NumericsMonitor.from_scales`` to set them up
        front)."""
        tracer = Tracer(capacity=capacity)
        return cls(tracer=tracer, metrics=MetricsRegistry(),
                   recorder=FlightRecorder(
                       tracer, events_per_shard=events_per_shard),
                   deadline_ms=deadline_ms, debug=debug,
                   numerics=NumericsMonitor() if numerics else None)


#: The default bundle: all hooks no-ops, zero hot-path cost.
NULL_OBS = Observability()

__all__ = [
    "Observability", "NULL_OBS",
    "Tracer", "NullTracer", "NULL_TRACER",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "BUCKET_EDGES_US", "SNAPSHOT_SCHEMA_VERSION",
    "validate_snapshot", "merge_histogram_counts",
    "FlightRecorder", "DEFAULT_EVENTS_PER_SHARD",
    "TransferLedger", "TRANSFER_KEYS", "sum_transfers",
    "check_conservation", "assert_conservation",
    "check_numerics_conservation",
    "CONSERVED_WORKLOAD", "CONSERVED_SCHED",
    "PHASES", "registered", "assert_registered",
    "NumericsMonitor", "RangeStats", "CELL_SITES", "site_order",
    "limits_from_scales", "merge_site_counts",
]
