"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs_per_device   / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device   / HBM_bw_per_chip
  collective = collective_bytes_per_device / ICI_link_bw

``cost_analysis()`` on an SPMD-partitioned executable reports per-device
FLOPs/bytes (verified empirically), so the per-chip division is already
done.  Collective bytes are NOT in cost_analysis: we parse the compiled
HLO and sum the operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction (two-pass
parse: instruction-name -> shape table, then operand lookup).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# "%name = bf16[8,128]{1,0} op-name(operands...)" or tuple types
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)\)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples by summing)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict
    unknown_trip_whiles: int = 0

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


# NB: computation headers may contain "/*index=5*/" comments inside the
# parameter tuple — the param group must tolerate '='.
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*\{")
_ATTR_RE = re.compile(r"(\w+)=%?([\w.\-]+)")


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    entry_alias = None
    for line in hlo_text.splitlines():
        if "->" in line and line.rstrip().endswith("{"):
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    entry_alias = cur
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    if entry_alias:
        comps["__entry__"] = comps[entry_alias]
    return comps


def _trip_count(cond_lines: list[str]) -> int | None:
    """jax scans lower to while(cond: ind_var < constant)."""
    consts: dict[str, int] = {}
    for line in cond_lines:
        m = re.match(r"\s*%?([\w.\-]+)\s*=\s*\w+\[\]\s*constant\((\d+)\)", line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for line in cond_lines:
        if "compare(" in line and ("direction=LT" in line or "direction=GT" in line):
            for ref in re.findall(r"%([\w.\-]+)", line.split("compare(", 1)[1]):
                if ref in consts:
                    return consts[ref]
    if len(consts) == 1:
        return next(iter(consts.values()))
    return None


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective op, multiplying ops inside
    while-loop bodies by the loop trip count (recursively).  This is what
    makes scanned-layer HLO collective accounting correct — XLA's own
    cost_analysis does NOT do this."""
    comps = _split_computations(hlo_text)
    # global shape table (instruction names are unique enough across comps)
    shapes: dict[str, str] = {}
    for lines in comps.values():
        for line in lines:
            m = _INSTR_RE.match(line)
            if m:
                shapes[m.group(1)] = m.group(2)

    counts: dict[str, int] = {}
    byts: dict[str, int] = {}
    unknown = [0]

    def visit(comp_name: str, mult: float, seen: tuple = ()):
        if comp_name not in comps or comp_name in seen:
            return
        for line in comps[comp_name]:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            _, _, op, operands = m.groups()
            base = op.rstrip("0123456789.")
            attrs = dict(_ATTR_RE.findall(line))
            matched = None
            for coll in _COLLECTIVES:
                if base == coll or base == coll + "-start":
                    matched = coll
                    break
            if matched:
                b = 0
                for ref in re.findall(r"%([\w.\-]+)", operands):
                    if ref in shapes:
                        b += _shape_bytes(shapes[ref])
                if b == 0:
                    b = _shape_bytes(operands)
                counts[matched] = counts.get(matched, 0) + int(mult)
                byts[matched] = byts.get(matched, 0) + int(b * mult)
            elif base == "while":
                body = attrs.get("body")
                cond = attrs.get("condition")
                trip = _trip_count(comps.get(cond, [])) if cond else None
                if trip is None:
                    trip = 1
                    unknown[0] += 1
                visit(body, mult * trip, seen + (comp_name,))
            elif base in ("call", "fusion", "conditional", "custom-call"):
                for key in ("to_apply", "called_computations", "true_computation",
                            "false_computation", "branch_computations"):
                    if key in attrs:
                        visit(attrs[key], mult, seen + (comp_name,))
    visit("__entry__", 1.0)
    return CollectiveStats(counts=counts, bytes_by_kind=byts,
                           unknown_trip_whiles=unknown[0])


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_global: float
    chips: int

    @classmethod
    def from_cost(cls, cost, kind: str, *, pods: int, data: int, model: int,
                  collective_bytes_per_device: float,
                  model_flops_global: float,
                  weight_shards: int | None = None) -> "Roofline":
        """Build roofline terms from the analytic CellCost + parsed
        collectives, applying the sharding split factors:
          * compute: fully parallel over all chips;
          * weights: FSDP all-gathers mean each chip READS 1/tp of every
            weight per pass (passes = 3 for train: fwd/remat/bwd);
          * activations: sharded over the batch axes (pod x data);
          * decode caches + optimizer state: sharded over all chips
            (opt not sharded over pods -> data x model)."""
        chips = pods * data * model
        passes = 3.0 if kind == "train" else 1.0
        weight_dev = cost.weight_bytes_per_pass * passes / (weight_shards or model)
        # activations shard over the batch axes; under sequence parallelism
        # (weight_shards == 1) they shard over `model` too.  For TP runs
        # this is conservative (FFN/attn intermediates ARE model-sharded,
        # the residual stream is not).
        act_shards = pods * data * (model if weight_shards == 1 else 1)
        act_dev = cost.act_bytes / act_shards
        cache_dev = cost.cache_bytes / chips
        opt_dev = cost.opt_bytes / (data * model)
        return cls(
            flops_per_device=cost.flops_total / chips,
            bytes_per_device=weight_dev + act_dev + cache_dev + opt_dev,
            collective_bytes_per_device=collective_bytes_per_device,
            model_flops_global=model_flops_global,
            chips=chips,
        )

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs — catches remat/redundancy waste."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of the compute roofline at the bound:
        useful-FLOPs time / bound time."""
        t_useful = self.model_flops_global / (self.chips * PEAK_FLOPS)
        return t_useful / self.t_bound if self.t_bound else 0.0

    def row(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }
