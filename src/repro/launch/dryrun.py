import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes with 512 placeholder host devices.

  single-pod : 16 x 16           (data, model)        = 256 chips
  multi-pod  : 2 x 16 x 16       (pod, data, model)   = 512 chips

For each runnable cell this prints compiled.memory_analysis() (proves the
program fits per-chip HBM) and compiled.cost_analysis() (FLOPs/bytes for
the roofline), parses collective bytes out of the partitioned HLO, and
appends a JSON record consumed by EXPERIMENTS.md Sec. Dry-run/Roofline.

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out results/dryrun.jsonl
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.configs.base import SHAPES, applicable
from repro.launch.mesh import make_production_mesh
from repro.launch import sharding as sh
from repro.launch import roofline as rf
from repro.launch import analytic
from repro.models import registry
from repro.train.optimizer import AdamConfig


def build_cell(arch: str, shape_name: str, mesh):
    """-> (jitted fn, abstract args) for one cell."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    mode = sh.parallel_mode(cfg, shape, mesh)
    seqp = mode is not None
    batch_sds = registry.input_specs(cfg, shape)
    batch_specs = sh.batch_pspecs(cfg, shape, mesh, seq_parallel=seqp)
    aparams = registry.abstract_params(cfg)
    pspecs = sh.param_pspecs(aparams, mesh, mode=mode, cfg=cfg)
    n_params = sh.named(mesh, pspecs)
    n_batch = {k: jax.sharding.NamedSharding(mesh, batch_specs[k])
               for k in batch_sds}

    if shape.kind == "train":
        acfg = AdamConfig(state_dtype=cfg.opt_state_dtype)
        aopt = registry.abstract_opt(cfg, acfg)
        ospecs = sh.opt_pspecs(aopt, pspecs)
        n_opt = sh.named(mesh, ospecs)
        step = registry.make_train_step(cfg, acfg, mesh=mesh,
                                        seq_parallel=seqp)
        jf = jax.jit(step,
                     in_shardings=(n_params, n_opt, n_batch),
                     out_shardings=(n_params, n_opt, None),
                     # detlint: ignore[det-donate-argnums] training step: params/opt buffers are consumed, no bit-exactness contract
                     donate_argnums=(0, 1))
        return jf, (aparams, aopt, batch_sds)

    if shape.kind == "prefill":
        step = registry.make_prefill_step(cfg, shape, mesh=mesh,
                                          seq_parallel=seqp)
        jf = jax.jit(step, in_shardings=(n_params, n_batch))
        return jf, (aparams, batch_sds)

    # decode
    acache = registry.abstract_cache(cfg, shape)
    cspecs = sh.cache_pspecs(cfg, shape, mesh, acache)
    n_cache = sh.named(mesh, cspecs)
    splitkv = sh.use_splitkv(cfg, shape, mesh)
    quant_bits = int(os.environ.get("REPRO_SERVE_QUANT", "0"))
    if quant_bits:
        qp, scales = registry.abstract_quantized_params(cfg, quant_bits)
        n_scales = jax.tree.map(
            lambda _: jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()), scales)
        step = registry.make_decode_step_quantized(cfg, shape, quant_bits,
                                                   mesh=mesh, splitkv=splitkv)
        jf = jax.jit(step,
                     in_shardings=(n_params, n_scales, n_cache,
                                   n_batch["tokens"]),
                     out_shardings=(None, n_cache),
                     # detlint: ignore[det-donate-argnums] LM decode cache donation: compile-shape dryrun, not the FastGRNN serving path
                     donate_argnums=(2,))
        return jf, (qp, scales, acache, batch_sds["tokens"])
    step = registry.make_decode_step(cfg, shape, mesh=mesh, splitkv=splitkv)
    jf = jax.jit(step,
                 in_shardings=(n_params, n_cache, n_batch["tokens"]),
                 out_shardings=(None, n_cache),
                 # detlint: ignore[det-donate-argnums] LM decode cache donation: compile-shape dryrun, not the FastGRNN serving path
                 donate_argnums=(1,))
    return jf, (aparams, acache, batch_sds["tokens"])


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             keep_hlo: bool = False) -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    ok, reason = applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.devices.size
        jf, aargs = build_cell(arch, shape_name, mesh)
        t0 = time.time()
        lowered = jf.lower(*aargs)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        hlo = compiled.as_text()
        colls = rf.parse_collectives(hlo)
        pods = int(mesh.shape.get("pod", 1))
        data = int(mesh.shape["data"])
        model = int(mesh.shape["model"])
        n_params = registry.param_count(cfg)
        qbits = int(os.environ.get("REPRO_SERVE_QUANT", "0")) \
            if shape.kind == "decode" else 0
        cost = analytic.cell_cost(cfg, shape, n_params=n_params,
                                  batch_shards=pods * data,
                                  weight_quant_bits=qbits)
        mode = sh.parallel_mode(cfg, shape, mesh)
        seqp = mode == "ssm_seq"  # weights replicated only in ssm mode
        roof = rf.Roofline.from_cost(
            cost, shape.kind, pods=pods, data=data, model=model,
            collective_bytes_per_device=float(colls.total_bytes),
            model_flops_global=registry.step_flops_model(cfg, shape),
            weight_shards=1 if seqp else None)
        rec["parallel_mode"] = mode
        rec.update(
            status="ok",
            chips=chips,
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            n_params=n_params,
            analytic={
                "flops_fwd_global": cost.flops_fwd,
                "flops_total_global": cost.flops_total,
                "weight_bytes_per_pass": cost.weight_bytes_per_pass,
                "act_bytes": cost.act_bytes,
                "cache_bytes": cost.cache_bytes,
                "opt_bytes": cost.opt_bytes,
                "notes": cost.notes,
            },
            hlo_raw={  # XLA cost_analysis — loop bodies counted ONCE (caveat)
                "flops_per_device": float(ca.get("flops", 0.0)),
                "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
            },
            flops_per_device=roof.flops_per_device,
            bytes_per_device=roof.bytes_per_device,
            collective_bytes_per_device=roof.collective_bytes_per_device,
            collective_counts=colls.counts,
            collective_bytes_by_kind=colls.bytes_by_kind,
            unknown_trip_whiles=colls.unknown_trip_whiles,
            model_flops_global=roof.model_flops_global,
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
            },
            roofline=roof.row(),
        )
        if keep_hlo:
            rec["hlo_path"] = f"/tmp/hlo_{arch}_{shape_name}_{mesh_name}.txt"
            with open(rec["hlo_path"], "w") as f:
                f.write(hlo)
    except Exception as e:  # a failure here is a bug in our sharding
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--keep-hlo", action="store_true")
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    archs = list(configs.ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    out_f = open(args.out, "a") if args.out else None
    for a, s, m in cells:
        rec = run_cell(a, s, m, keep_hlo=args.keep_hlo)
        line = json.dumps(rec)
        print(line, flush=True)
        if out_f:
            out_f.write(line + "\n")
            out_f.flush()
    if out_f:
        out_f.close()


if __name__ == "__main__":
    main()
