"""Analytic per-cell FLOPs / HBM-bytes model for the roofline.

WHY THIS EXISTS: XLA's ``compiled.cost_analysis()`` counts a while-loop
body ONCE — no trip-count multiplication (verified empirically; see
EXPERIMENTS.md Sec. Roofline/Methodology).  With layers driven by
jax.lax.scan (required to keep 96-layer HLO compilable), raw HLO FLOPs
undercount by ~num_layers.  We therefore compute the roofline terms from
closed-form per-architecture formulas, VALIDATED against an unrolled
(scan_layers-off) HLO compile of a mid-size arch where cost_analysis is
exact (tests/test_roofline.py + EXPERIMENTS.md).

All counts are GLOBAL (whole step, all chips); launch/roofline.py divides
by the mesh factors.  FLOPs = 2 * MACs everywhere.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.moe import capacity


@dataclasses.dataclass(frozen=True)
class CellCost:
    flops_fwd: float            # one forward pass, global
    flops_total: float          # step total (train: fwd + remat + bwd)
    weight_bytes_per_pass: float  # weight HBM reads, one pass, global
    act_bytes: float            # activation HBM traffic, whole step, global
    cache_bytes: float          # decode: KV/SSM cache traffic per step
    opt_bytes: float            # optimizer state + master param RW (train)
    param_count: float
    notes: str = ""

    @property
    def hbm_bytes_total(self) -> float:
        passes = 3.0 if self.flops_total > 1.5 * self.flops_fwd else 1.0
        return (self.weight_bytes_per_pass * passes + self.act_bytes
                + self.cache_bytes + self.opt_bytes)


def _attn_flops_per_token(cfg: ModelConfig, s_ctx: float) -> float:
    """QK^T + PV flops per token at average context s_ctx."""
    A = cfg.num_heads * cfg.head_dim
    return 4.0 * s_ctx * A


def _proj_flops_per_token(cfg: ModelConfig) -> float:
    d = cfg.d_model
    A = cfg.num_heads * cfg.head_dim
    Kv = cfg.num_kv_heads * cfg.head_dim
    return 2.0 * d * (A + 2 * Kv) + 2.0 * A * d


def _mlp_flops_per_token(cfg: ModelConfig, d_ff: int) -> float:
    mults = 3 if cfg.mlp_kind in ("swiglu", "geglu") else 2
    return 2.0 * mults * cfg.d_model * d_ff


def _moe_flops_per_token(cfg: ModelConfig, n_tokens_per_shard: float) -> tuple[float, float]:
    """(ideal, with capacity padding) flops per token."""
    router = 2.0 * cfg.d_model * cfg.num_experts
    ideal = cfg.top_k * _mlp_flops_per_token(cfg, cfg.d_ff)
    cap = capacity(int(n_tokens_per_shard), cfg.top_k, cfg.num_experts,
                   cfg.capacity_factor)
    pad_factor = cap * cfg.num_experts / max(n_tokens_per_shard * cfg.top_k, 1)
    return router + ideal, router + ideal * pad_factor


def _mamba_flops_per_token(cfg: ModelConfig, decode: bool) -> float:
    d = cfg.d_model
    di = 2 * d
    P = cfg.mamba_headdim
    H = di // P
    g, n = cfg.mamba_groups, cfg.ssm_state
    proj = 2.0 * d * (2 * di + 2 * g * n + H) + 2.0 * di * d
    conv = 2.0 * 4 * (di + 2 * g * n)
    if decode:
        ssd = 2.0 * H * (3 * n * P)                       # state update + y
    else:
        Q = cfg.ssd_chunk
        # intra: scores Q*N + y_diag Q*P per (token, head); states/off 2*N*P
        ssd = 2.0 * H * (Q * n + Q * P + 2 * n * P)
    return proj + conv + ssd


def _layer_flops_per_token(cfg: ModelConfig, s_ctx: float, decode: bool,
                           tokens_per_shard: float) -> tuple[float, float]:
    """(ideal, padded) — identical unless MoE capacity padding applies."""
    if cfg.family == "ssm":
        f = _mamba_flops_per_token(cfg, decode)
        return f, f
    if cfg.family == "hybrid":
        f = _mamba_flops_per_token(cfg, decode)
        # shared attn+mlp block amortized over attn_every mamba layers
        shared = (_proj_flops_per_token(cfg) + _attn_flops_per_token(cfg, s_ctx)
                  + _mlp_flops_per_token(cfg, cfg.d_ff)) / cfg.attn_every
        return f + shared, f + shared
    base = _proj_flops_per_token(cfg) + _attn_flops_per_token(cfg, s_ctx)
    if cfg.family == "moe":
        ideal, padded = _moe_flops_per_token(cfg, tokens_per_shard)
        return base + ideal, base + padded
    f = base + _mlp_flops_per_token(cfg, cfg.d_ff)
    return f, f


def _param_bytes(cfg: ModelConfig, n_params: float) -> float:
    return n_params * (2 if cfg.param_dtype == "bfloat16" else 4)


def cell_cost(cfg: ModelConfig, shape: ShapeConfig, *, n_params: float,
              batch_shards: int = 32, act_itemsize: int = 2,
              weight_quant_bits: int = 0) -> CellCost:
    B, S = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    n_tokens = B * (1 if decode else S)
    if decode:
        s_ctx = S
    else:
        s_ctx = (S + 1) / 2 if cfg.causal else S
        if cfg.sliding_window and shape.name == "long_500k":
            s_ctx = min(s_ctx, cfg.sliding_window)
    tokens_per_shard = n_tokens / batch_shards

    ideal_tok, padded_tok = _layer_flops_per_token(cfg, s_ctx, decode,
                                                   tokens_per_shard)
    fwd = n_tokens * padded_tok * cfg.num_layers
    # unembed (+ vlm patch positions add tokens for every layer: approximate
    # by inflating token count for vlm)
    if cfg.family == "vlm" and not decode:
        fwd *= (S + cfg.num_patches) / S
    fwd += n_tokens * 2.0 * cfg.d_model * cfg.vocab_size
    if shape.kind == "train":
        remat = 1.0 if cfg.remat else 0.0
        total = fwd * (3.0 + remat)
    else:
        total = fwd

    wb = _param_bytes(cfg, n_params)
    if weight_quant_bits:
        wb = n_params * weight_quant_bits / 8.0   # L-S-Q serving weights
    act = n_tokens * cfg.d_model * cfg.num_layers * act_itemsize * 8.0
    if shape.kind == "train":
        act *= 3.0
    cache = 0.0
    if decode:
        if cfg.uses_attention:
            n_attn = (cfg.num_layers if cfg.family != "hybrid"
                      else cfg.num_layers // max(cfg.attn_every, 1))
            ctx = min(S, cfg.sliding_window) if (cfg.sliding_window and
                                                 shape.name == "long_500k") else S
            cache += n_attn * B * ctx * cfg.num_kv_heads * cfg.head_dim * 2 * 2
        if cfg.uses_mamba:
            di = 2 * cfg.d_model
            H = di // cfg.mamba_headdim
            cache += (cfg.num_layers * B * H * cfg.ssm_state *
                      cfg.mamba_headdim * 4 * 2)   # f32 read+write
    opt = 0.0
    if shape.kind == "train":
        os_bytes = 2 if cfg.opt_state_dtype == "bfloat16" else 4
        opt = n_params * (2 * os_bytes * 2 + 2 * _param_bytes(cfg, 1))  # m,v RW + p RW
    notes = ""
    if cfg.family == "moe":
        notes = f"moe capacity padding x{padded_tok / ideal_tok:.2f}"
    return CellCost(flops_fwd=fwd, flops_total=total,
                    weight_bytes_per_pass=wb, act_bytes=act,
                    cache_bytes=cache, opt_bytes=opt,
                    param_count=n_params, notes=notes)
