"""Serving launcher: batched generation with optional Q7/Q15 weights.

    python -m repro.launch.serve --arch mamba2-780m --reduced \
        --quant-bits 8 --new-tokens 32
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--quant-bits", type=int, default=0, choices=[0, 8, 16])
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import numpy as np
    import repro.configs as C
    from repro.models import registry
    from repro.serve.engine import Engine, ServeConfig

    cfg = C.get(args.arch)
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only")
    if args.reduced:
        cfg = C.reduced(cfg)
    params = registry.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params,
                 ServeConfig(max_len=args.prompt_len + args.new_tokens + 1,
                             quant_bits=args.quant_bits,
                             temperature=args.temperature))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len))
    out = eng.generate(prompts, max_new=args.new_tokens)
    print(f"generated {out.shape} tokens "
          f"(quant_bits={args.quant_bits or 'off'})")
    print(out[:, :16])


if __name__ == "__main__":
    main()
