"""Production training launcher.

    python -m repro.launch.train --arch qwen2-1.5b --steps 100 \
        [--reduced] [--mesh host|16x16|2x16x16] [--grad-compress 8]

On real hardware ``--mesh 16x16``/``2x16x16`` selects the production mesh
(jax.distributed.initialize is called when JAX_COORDINATOR is set); in
this CPU container use --reduced --mesh host.  Restart-safe: checkpoints
+ the seekable token stream resume exactly (see train/trainer.py).
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="host", choices=["host", "16x16", "2x16x16"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--iht-sparsity", type=float, default=0.0)
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        import jax
        jax.distributed.initialize()           # multi-host entry point

    import jax
    import jax.numpy as jnp
    import repro.configs as C
    from repro.data import tokens
    from repro.models import registry
    from repro.launch import sharding as sh
    from repro.launch.mesh import make_production_mesh, make_host_mesh
    from repro.train.optimizer import AdamConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = C.get(args.arch)
    if args.reduced:
        cfg = C.reduced(cfg)
    seq = args.seq or (64 if args.reduced else 4096)
    gbatch = args.global_batch or (8 if args.reduced else 256)

    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "2x16x16"))

    acfg = AdamConfig(lr=args.lr, state_dtype=cfg.opt_state_dtype)
    tcfg = tokens.TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                    global_batch=gbatch)
    step_fn = registry.make_train_step(cfg, acfg,
                                       mesh=mesh if mesh.devices.size > 1 else None)
    if mesh.devices.size > 1:
        aparams = registry.abstract_params(cfg)
        pspecs = sh.param_pspecs(aparams, mesh)
        n_p = sh.named(mesh, pspecs)
        aopt = registry.abstract_opt(cfg, acfg)
        n_o = sh.named(mesh, sh.opt_pspecs(aopt, pspecs))
        from jax.sharding import NamedSharding, PartitionSpec as P
        bspec = {k: NamedSharding(mesh, P(tuple(a for a in ("pod", "data")
                                                if a in mesh.axis_names), None))
                 for k in ("tokens", "labels")}
        step = jax.jit(step_fn, in_shardings=(n_p, n_o, bspec),
                       # detlint: ignore[det-donate-argnums] training step: params/opt buffers are consumed, no bit-exactness contract
                       out_shardings=(n_p, n_o, None), donate_argnums=(0, 1))
    else:
        # detlint: ignore[det-donate-argnums] training step: params/opt buffers are consumed, no bit-exactness contract
        step = jax.jit(step_fn, donate_argnums=(0, 1))

    def batch_fn(s):
        return {k: jnp.asarray(v) for k, v in tokens.lm_batch(tcfg, s).items()}

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps,
                      checkpoint_every=args.checkpoint_every,
                      checkpoint_dir=args.ckpt_dir or f"/tmp/repro_{args.arch}",
                      adam=acfg),
        init_params_fn=lambda: registry.init(cfg, jax.random.PRNGKey(0)),
        step_fn=step, batch_fn=batch_fn,
        on_straggler=lambda s, dt, v: print(f"[straggler] step {s}: {dt:.2f}s"))
    hist = trainer.run()
    losses = [h["loss"] for h in hist if "loss" in h]
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({len(losses)} steps, {trainer.restarts} restarts)")


if __name__ == "__main__":
    main()
