"""Production mesh factories.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.  Axes:

  * ``pod``   — crosses DCN; pure data parallelism (gradient all-reduce
                only, compressible via train/grad_compression.py);
  * ``data``  — within-pod FSDP axis (params/optimizer sharded, per-layer
                all-gather);
  * ``model`` — within-pod tensor/expert parallel axis.

Elastic scaling: the pod axis count is a constructor argument; checkpoints
store full logical arrays so a job can restart on a different pod count
(see train/checkpoint.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, num_pods: int = 2):
    shape = (num_pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / single host)."""
    n = jax.device_count()
    data = data if data is not None else max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def batch_axes(mesh) -> tuple[str, ...]:
    """The mesh axes the global batch is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_shards(mesh) -> int:
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in batch_axes(mesh)]))


def tp_size(mesh) -> int:
    return int(mesh.shape["model"]) if "model" in mesh.axis_names else 1
