"""Sharding rules: parameter / batch / cache PartitionSpecs per architecture.

Strategy (DESIGN.md Sec. 5): pod = DP (DCN), data = FSDP, model = TP/EP.

Param rules are name-based over the pytree paths produced by models/*.
Every rule checks divisibility against the mesh — a dim that does not
divide falls back to replication on that axis (GSPMD would pad; we prefer
explicit, documented fallbacks).  The roofline analysis (launch/roofline)
surfaces what those fallbacks cost.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import batch_axes, tp_size


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _axis(mesh, name: str, dim: int):
    """Axis name if it divides dim, else None (replicate)."""
    if name not in mesh.axis_names:
        return None
    return name if _div(dim, int(mesh.shape[name])) else None


def _baxis(mesh, dim: int):
    """Batch axes (pod,data) combined — falls back progressively."""
    axes = batch_axes(mesh)
    if not axes:
        return None
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if _div(dim, size):
        return axes if len(axes) > 1 else axes[0]
    if "data" in axes and _div(dim, int(mesh.shape["data"])):
        return "data"
    return None


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _leaf_rule(tokens: list[str], shape: tuple[int, ...], mesh) -> P:
    """Spec for an UNSTACKED leaf (no leading layer dim)."""
    t = tokens
    name = t[-1]
    mod = t[-2] if len(t) >= 2 else ""
    ax = lambda a, i: _axis(mesh, a, shape[i])

    if "embed" in t and name == "table":            # (V, D)
        # vocab on model ONLY: sharding D on `data` makes the unembed
        # contraction dim conflict with the batch's data-sharding and GSPMD
        # resolves it by REPLICATING the batch (observed: 40 GB f32 logits
        # buffers + f32 logits all-reduce).  See EXPERIMENTS.md Sec. Perf.
        return P(ax("model", 0), None)
    if "lm_head" in t:
        if name == "w":                             # (D, V)
            return P(None, ax("model", 1))
        if name in ("w1",):                         # low-rank (D, r)
            return P(None, None)
        if name in ("w2",):                         # (r, V)
            return P(None, ax("model", 1))
        return P(None)                              # bias (V,)
    # attention projections: params['attn'][{'q','k','v','o'}][{'w','b'}]
    if "attn" in t:
        proj = t[t.index("attn") + 1] if t.index("attn") + 1 < len(t) else ""
        if proj in ("q", "k", "v"):
            if name == "w":                         # (D, N*hd)
                return P(ax("data", 0), ax("model", 1))
            return P(ax("model", 0))                # bias (N*hd,)
        if proj == "o":
            if name == "w":                         # (N*hd, D)
                return P(ax("model", 0), ax("data", 1))
            return P(None)

    # MoE: router (D,E); experts (E, d_in, d_out)
    if "moe" in t:
        if "router" in t:
            return P(ax("data", 0), None) if name == "w" else P(None)
        if name in ("w_in", "w_gate"):              # (E, D, F)
            return P(ax("model", 0), ax("data", 1), None)
        if name == "w_out":                         # (E, F, D)
            return P(ax("model", 0), None, ax("data", 2))

    # dense MLP: params['mlp'][{'w_gate','w_in','w_out'}][{'w','b',...}]
    if "mlp" in t:
        proj = t[t.index("mlp") + 1] if t.index("mlp") + 1 < len(t) else ""
        if proj in ("w_gate", "w_in"):
            if name == "w":                         # (D, F)
                return P(ax("data", 0), ax("model", 1))
            if name == "w1":                        # low-rank (D, r)
                return P(ax("data", 0), None)
            if name == "w2":                        # (r, F)
                return P(None, ax("model", 1))
            return P(ax("model", 0))                # bias (F,)
        if proj == "w_out":
            if name == "w":                         # (F, D)
                return P(ax("model", 0), ax("data", 1))
            if name == "w1":                        # (F, r)
                return P(ax("model", 0), None)
            if name == "w2":                        # (r, D)
                return P(None, ax("data", 1))
            return P(None)                          # bias (D,)

    # Mamba2.  Projection weights deliberately do NOT shard their
    # contracting (d_model) dim on `data`: that conflicts with the batch's
    # data-sharding and GSPMD resolves it by REPLICATING the batch through
    # the whole mamba stack + all-reducing full-batch f32 projection
    # outputs (measured: 211 GB/device on mamba2-780m prefill_32k; see
    # EXPERIMENTS.md Sec. Perf iteration A1).  At <=1.2B params the FSDP
    # saving these weights would buy is irrelevant.
    if "mamba" in t:
        if mod in ("z_proj", "x_proj", "dt_proj") and name == "w":
            return P(None, ax("model", 1))
        if mod in ("B_proj", "C_proj") and name == "w":
            return P(None, None)
        if name == "conv_x":                        # (W, d_inner)
            return P(None, ax("model", 1))
        if name == "conv_x_b":
            return P(ax("model", 0))
        if name in ("conv_B", "conv_C"):
            return P(None, None)
        if name in ("conv_B_b", "conv_C_b"):
            return P(None)
        if name in ("A_log", "dt_bias", "D"):       # (H,)
            return P(ax("model", 0))
        if "gn" in t and name == "scale":           # (d_inner,)
            return P(ax("model", 0))
        if mod == "out_proj" and name == "w":       # (d_inner, D)
            return P(ax("model", 0), None)
    # norms & scalars & leftover biases: replicate
    return P(*([None] * len(shape)))


_TOKEN_RE = re.compile(r"\['([^']+)'\]")


def _sp_dense_leaf_rule(tokens, shape, mesh, kv_shardable: bool) -> P:
    """Megatron-SP + explicit-ZeRO layout (models/_seq_scan_dense)."""
    name = tokens[-1]
    d_ax = "data" if "data" in mesh.axis_names else None
    if "attn" in tokens:
        proj = tokens[tokens.index("attn") + 1]
        if proj == "q" and name == "w":
            return P(d_ax, "model")
        if proj in ("k", "v") and name == "w":
            return P(d_ax, "model" if kv_shardable else None)
        if proj == "o" and name == "w":
            return P("model", d_ax)
    if "mlp" in tokens:
        proj = tokens[tokens.index("mlp") + 1]
        if proj in ("w_in", "w_gate") and name == "w":
            return P(d_ax, "model")
        if proj == "w_out" and name == "w":
            return P("model", d_ax)
    if "embed" in tokens and name == "table":
        return P(_axis(mesh, "model", shape[0]), None)
    if "lm_head" in tokens and name == "w":
        return P(None, _axis(mesh, "model", shape[1]))
    return P(*([None] * len(shape)))


def param_pspecs(abstract_params, mesh, *, seq_parallel: bool = False,
                 mode: str | None = None, cfg=None) -> Any:
    """PartitionSpec pytree matching ``abstract_params``.

    Modes:
      * None          — FSDP x TP rules (_leaf_rule);
      * "ssm_seq"     — mamba-family sequence parallelism: ALL weights
        replicated, the sequence dim carries `model` (context-parallel SSD
        — EXPERIMENTS.md Sec. Perf A2; <=1.2B params so replication costs
        ~2.3 GB/chip and removes every per-layer TP all-reduce);
      * "sp_dense"    — Megatron-SP + explicit ZeRO for dense/vlm/audio
        (EXPERIMENTS.md Sec. Perf D).
    ``seq_parallel=True`` is shorthand for "ssm_seq" (back-compat)."""
    if seq_parallel and mode is None:
        mode = "ssm_seq"
    if mode == "ssm_seq":
        return jax.tree.map(lambda leaf: P(*([None] * leaf.ndim)),
                            abstract_params)
    kv_shardable = bool(cfg and cfg.num_kv_heads
                        and cfg.num_kv_heads % tp_size(mesh) == 0)
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    specs = []
    for path, leaf in flat:
        tokens = _TOKEN_RE.findall(jax.tree_util.keystr(path))
        stacked = tokens and tokens[0] == "blocks"
        shape = tuple(leaf.shape)
        rule = (lambda t, s: _sp_dense_leaf_rule(t, s, mesh, kv_shardable)) \
            if mode == "sp_dense" else (lambda t, s: _leaf_rule(t, s, mesh))
        if stacked:
            spec = P(None, *rule(tokens, shape[1:]))
        else:
            spec = rule(tokens, shape)
        assert len(spec) == len(shape) or spec == P(), (tokens, shape, spec)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_pspecs(abstract_opt, pspecs) -> Any:
    """Optimizer state: moments shard like params; step is replicated."""
    return {"m": pspecs, "v": pspecs, "step": P()}


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

def batch_pspecs(cfg, shape, mesh, *, seq_parallel: bool = False) -> dict[str, P]:
    b = _baxis(mesh, shape.global_batch)
    s = _axis(mesh, "model", shape.seq_len) if seq_parallel else None
    out: dict[str, P] = {}
    if cfg.family == "audio":
        out["frames"] = P(b, s, None)
    else:
        out["tokens"] = P(b, s)
    if shape.kind == "train":
        out["labels"] = P(b, s)
    if cfg.family == "vlm" and shape.kind != "decode":
        out["patch_embeds"] = P(b, None, None)
    return out


def use_splitkv(cfg, shape, mesh) -> bool:
    """Flash-decoding when KV heads do not divide tp (the cache then
    shards its sequence dim on `model`; see cache_pspecs)."""
    import os
    if os.environ.get("REPRO_NO_SPLITKV") == "1":
        return False
    tp = tp_size(mesh)
    return (shape.kind == "decode" and cfg.uses_attention
            and cfg.num_kv_heads % tp != 0 and tp > 1)


def use_seq_parallel(cfg, shape, mesh) -> bool:
    import os
    if os.environ.get("REPRO_NO_SEQP") == "1":   # A/B measurement switch
        return False
    # Sequence parallelism wins for BOTH ssm and hybrid: zamba2 train_4k
    # measures 47 GB/dev resharding traffic under seqp vs 104 GB/dev TP
    # all-reduces without it (2.2x; the shared-attention backward is the
    # remaining cost — ring attention is the next lever).  See
    # EXPERIMENTS.md Sec. Perf B1/B2.
    return (cfg.uses_mamba and shape.kind in ("train", "prefill")
            and "model" in mesh.axis_names
            and shape.seq_len % int(mesh.shape["model"]) == 0)


def parallel_mode(cfg, shape, mesh) -> str | None:
    """Select the sharding mode for one cell (None = FSDP x TP)."""
    import os
    if use_seq_parallel(cfg, shape, mesh):
        return "ssm_seq"
    if os.environ.get("REPRO_NO_SP_DENSE") == "1":
        return None
    tp = tp_size(mesh)
    s_total = shape.seq_len + (cfg.num_patches if cfg.family == "vlm" else 0)
    if (cfg.family in ("dense", "vlm", "audio") and shape.kind == "train"
            and tp > 1 and cfg.num_heads % tp == 0 and s_total % tp == 0):
        return "sp_dense"
    return None


def cache_pspecs(cfg, shape, mesh, abstract_cache) -> Any:
    """Specs for the decode cache pytree.

    KV heads shard on ``model`` when divisible; otherwise the cache
    SEQUENCE dim shards on ``model`` (flash-decoding-style split-KV: XLA
    turns the softmax reductions into small cross-shard collectives, and
    cache memory stays balanced with zero padding)."""
    b = _baxis(mesh, shape.global_batch)
    tp = tp_size(mesh)
    specs: dict[str, Any] = {}
    for key, leaf in abstract_cache.items():
        if key == "len":
            specs[key] = P()
        elif key in ("k", "v"):
            L_, B_, S_, KV_, hd_ = leaf.shape
            if _div(KV_, tp):
                specs[key] = P(None, b, None, "model", None)
            else:
                specs[key] = P(None, b, _axis(mesh, "model", S_), None, None)
        elif key == "ssm":
            specs[key] = P(None, b, _axis(mesh, "model", leaf.shape[2]), None, None)
        elif key == "conv":
            specs[key] = {
                "x": P(None, b, None, _axis(mesh, "model", leaf["x"].shape[3])),
                "B": P(None, b, None, None),
                "C": P(None, b, None, None),
            }
        else:
            raise KeyError(key)
    return specs


def logits_pspec(cfg, shape, mesh) -> P:
    b = _baxis(mesh, shape.global_batch)
    v_ax = _axis(mesh, "model", cfg.vocab_size)
    return P(b, None, v_ax)


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))
