"""Pytree PTQ for LM-scale serving — the single home of the quantization
math that used to live (duplicated) in ``serve/engine.quantize_for_serving``.

Same per-tensor symmetric recipe as the MCU path
(``core.quantization.quantize_tensor``), applied to an arbitrary nested
parameter pytree: every floating leaf with ``ndim >= 2`` is quantized to
int8 (Q7) or int16 (Q15); biases, norms and scalars pass through in float.
``serve/engine.Engine`` consumes these directly; the old
``quantize_for_serving`` / ``dequantize_params`` shim names served their
one deprecation release and are gone.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import quantization as q
from .passes import BITS_ALIASES


def quantize_tree(params, bits: int = 8):
    """Per-tensor symmetric PTQ of every >=2D floating weight leaf;
    biases/norms/scalars stay fp.  ``bits`` accepts Q-format (7/15) or
    storage-width (8/16) names.  Returns a 2-tuple ``(qtree, scales)``:
    ``qtree`` mirrors ``params`` with int8/int16 weight leaves, ``scales``
    mirrors it with the per-tensor dequant scale (a 0-d zero for leaves
    that were left untouched)."""
    bits = BITS_ALIASES.get(bits, bits)
    if bits not in (8, 16):
        raise ValueError(f"bits must be Q7/int8 or Q15/int16: {bits}")
    qmax = (1 << (bits - 1)) - 1
    dtype = jnp.int8 if bits == 8 else jnp.int16
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    qt, scales = [], []
    for path, leaf in flat:
        if leaf.ndim >= 2 and jnp.issubdtype(leaf.dtype, jnp.floating):
            qi, s = q.quantize_tensor(leaf.astype(jnp.float32), qmax)
            qt.append(qi.astype(dtype))
            scales.append(s)
        else:
            qt.append(leaf)
            scales.append(None)
    return (jax.tree_util.tree_unflatten(treedef, qt),
            jax.tree_util.tree_unflatten(
                treedef, [s if s is not None else jnp.zeros(())
                          for s in scales]))


def dequantize_tree(qtree, scales):
    """Inverse of :func:`quantize_tree` into bf16 (the serving compute
    dtype): integer >=2D leaves dequantize by their scale, everything else
    passes through."""
    def deq(ql, s):
        if jnp.issubdtype(ql.dtype, jnp.integer) and ql.ndim >= 2:
            return ql.astype(jnp.bfloat16) * s.astype(jnp.bfloat16)
        return ql
    return jax.tree.map(deq, qtree, scales)


def tree_size_report(qtree, bits: int = 8) -> dict[str, Any]:
    """Weight-byte accounting of a quantized pytree vs its bf16 baseline —
    the serving analogue of ``ModelArtifact.size_report`` (decode is
    HBM-bound, so quantized bytes are the roofline term that halves)."""
    bits = BITS_ALIASES.get(bits, bits)
    itemsize = bits // 8
    n_q = n_fp = q_bytes = fp_bytes = 0
    for leaf in jax.tree_util.tree_leaves(qtree):
        if jnp.issubdtype(leaf.dtype, jnp.integer) and leaf.ndim >= 2:
            n_q += int(leaf.size)
            q_bytes += int(leaf.size) * itemsize
        else:
            n_fp += int(leaf.size)
            fp_bytes += int(leaf.size) * 2          # bf16 passthrough
    dense = (n_q + n_fp) * 2
    return {
        "bits": bits,
        "quantized_params": n_q,
        "float_params": n_fp,
        "weight_bytes_quantized": q_bytes + fp_bytes,
        "weight_bytes_bf16": dense,
        "bytes_saved": dense - (q_bytes + fp_bytes),
        "compression_ratio": dense / max(q_bytes + fp_bytes, 1),
    }
