"""Composable compression passes over :class:`~repro.compress.artifact.ModelArtifact`.

Each pass implements the :class:`Pass` protocol — ``name``, pure and
deterministic ``apply(artifact) -> artifact`` — and appends one provenance
record (its config plus the metrics it achieved) to the artifact.  The
paper's L-S-Q recipe (Kusupati et al. 2018's FastGRNN pipeline, and the
Cortex-M deep-compression sequencing of Deutel et al. 2022) maps onto:

    LowRankFactor -> IHTSparsify -> QuantizePTQ -> CalibrateActivations
                  -> PackLUT

Purity rules every pass follows (they are what make the CI determinism
gate — double-run => byte-identical artifact — possible):

  * no wall-clock, RNG, or host state in the output or the provenance;
  * calibration data is part of the pass *config* (an explicit array or a
    deterministic ``"hapt:<split>:<n>"`` spec), never ambient state;
  * all math routes through the SAME functions the legacy entry points
    used (``core.quantization.quantize_params``, ``core.qruntime.calibrate``)
    so the Q15 artifact path stays bit-identical to the historical
    ``(QuantizedParams, act_scales)`` handoff and its golden traces.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import numpy as np

from repro.core import compression as comp
from repro.core import quantization as q
from repro.core.lut import make_lut, make_lut_q15
from .artifact import ModelArtifact, jsonify, tensor_digest

# Weight-width aliases: the paper speaks in fixed-point formats (Q15/Q7),
# the storage speaks in integer widths (int16/int8).  Accept both.
BITS_ALIASES = {15: 16, 16: 16, 7: 8, 8: 8}


@runtime_checkable
class Pass(Protocol):
    """One compression stage: pure, deterministic artifact -> artifact."""
    name: str

    def apply(self, artifact: ModelArtifact) -> ModelArtifact: ...

    def config(self) -> dict[str, Any]: ...


@dataclasses.dataclass(frozen=True)
class _ConfigPass:
    """Shared ``config()``: every dataclass field, with arrays collapsed
    to a content digest so provenance stays JSON-small yet still pins the
    exact inputs a pass saw."""

    def config(self) -> dict[str, Any]:
        out: dict[str, Any] = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, np.ndarray):
                out[f.name] = {"ndarray_sha": tensor_digest(v),
                               "shape": list(v.shape)}
            elif callable(v) and not isinstance(v, type):
                out[f.name] = getattr(v, "__name__", "callable")
            else:
                out[f.name] = jsonify(v)
        return out

    def _record(self, art: ModelArtifact,
                metrics: dict[str, Any]) -> ModelArtifact:
        return art.with_record({"pass": self.name, "config": self.config(),
                                "metrics": metrics})


# ---------------------------------------------------------------------------
# L: low-rank factorization
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LowRankFactor(_ConfigPass):
    """Paper Sec. III-B: factor dense W (H, d) / U (H, H) into thin pairs
    ``W1 @ W2^T`` / ``U1 @ U2^T`` by truncated SVD, matching the factored
    evaluation order of every runtime (``W1 (W2^T x)``).  A checkpoint
    that trained factored from the start passes through untouched (the
    usual FastGRNN recipe — this pass exists for dense checkpoints and
    for re-ranking experiments)."""
    rank_w: int = 2
    rank_u: int = 8
    name: str = dataclasses.field(default="low_rank", init=False)

    @staticmethod
    def _factor(w: np.ndarray, rank: int) -> tuple[np.ndarray, np.ndarray, float]:
        u, s, vt = np.linalg.svd(np.asarray(w, np.float64),
                                 full_matrices=False)
        r = min(rank, s.shape[0])
        a = (u[:, :r] * s[:r]).astype(np.float32)       # (H, r)
        b = vt[:r].T.astype(np.float32)                 # (d, r)
        err = float(np.linalg.norm(w - a @ b.T) / max(np.linalg.norm(w), 1e-30))
        return a, b, err

    def apply(self, art: ModelArtifact) -> ModelArtifact:
        p = dict(art.params)
        metrics: dict[str, Any] = {}
        if "W1" in p:
            return self._record(art, {"skipped": "already factored"})
        before = int(sum(v.size for v in p.values()))
        w1, w2, err_w = self._factor(p.pop("W"), self.rank_w)
        u1, u2, err_u = self._factor(p.pop("U"), self.rank_u)
        p.update(W1=w1, W2=w2, U1=u1, U2=u2)
        after = int(sum(v.size for v in p.values()))
        metrics = {"rank_w": int(w1.shape[1]), "rank_u": int(u1.shape[1]),
                   "rel_err_W": err_w, "rel_err_U": err_u,
                   "param_count": {"before": before, "after": after}}
        meta = {**art.meta, "low_rank": True,
                "rank_w": int(w1.shape[1]), "rank_u": int(u1.shape[1])}
        return self._record(art.replace(params=p, meta=meta), metrics)


# ---------------------------------------------------------------------------
# S: IHT sparsification (one-shot top-k projection of a trained checkpoint)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IHTSparsify(_ConfigPass):
    """Paper Sec. III-C's hard-thresholding projection, applied post-hoc:
    keep the top-k magnitude entries of every sparsifiable tensor, zero
    the rest, and record the masks + achieved per-tensor sparsity.  (The
    in-training cubic ramp stays in ``core/pipeline.train_fastgrnn``; a
    trained-with-IHT checkpoint flows through this pass as the final
    frozen-mask projection, which is idempotent on it.)"""
    sparsity: float = 0.5
    leaves: tuple[str, ...] = ("W", "U", "W1", "W2", "U1", "U2")
    name: str = dataclasses.field(default="iht_sparsify", init=False)

    def apply(self, art: ModelArtifact) -> ModelArtifact:
        if not 0.0 <= self.sparsity < 1.0:
            raise ValueError(f"sparsity must be in [0, 1): {self.sparsity}")
        cfg = comp.IHTConfig(target_sparsity=self.sparsity,
                             leaf_filter=lambda n: n in self.leaves)
        masks = comp.compute_masks(art.params, cfg, self.sparsity)
        params = {k: np.asarray(v, np.float32)
                  for k, v in comp.apply_masks(art.params, masks).items()}
        np_masks = {k: np.asarray(m, bool) for k, m in masks.items()
                    if hasattr(m, "shape") and k in self.leaves}
        achieved = {k: 1.0 - float(np.count_nonzero(params[k]))
                    / max(int(params[k].size), 1) for k in sorted(np_masks)}
        overall = comp.sparsity_of(params, leaf_filter=lambda n: n in self.leaves)
        return self._record(
            art.replace(params=params, masks={**art.masks, **np_masks}),
            {"target_sparsity": self.sparsity,
             "achieved_sparsity": float(overall),
             "per_tensor_sparsity": achieved})


# ---------------------------------------------------------------------------
# Q: per-tensor symmetric PTQ (Q15 / Q7)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantizePTQ(_ConfigPass):
    """Paper Sec. III-D / Appendix B: per-tensor symmetric post-training
    quantization.  ``bits`` accepts the fixed-point name (15 -> Q15 int16,
    7 -> Q7 int8) or the storage width (16/8).  Routes through
    ``core.quantization.quantize_params`` — the Q15 output is bit-identical
    to the historical direct call, which is what keeps the golden deploy
    images byte-stable across the API migration."""
    bits: int = 15
    float_leaves: tuple[str, ...] = q.QuantConfig.float_leaves
    name: str = dataclasses.field(default="quantize_ptq", init=False)

    @classmethod
    def from_config(cls, cfg: q.QuantConfig) -> "QuantizePTQ":
        return cls(bits=cfg.bits, float_leaves=cfg.float_leaves)

    def storage_bits(self) -> int:
        if self.bits not in BITS_ALIASES:
            raise ValueError(f"bits must be one of {sorted(BITS_ALIASES)} "
                             f"(Q15/int16 or Q7/int8): {self.bits}")
        return BITS_ALIASES[self.bits]

    def apply(self, art: ModelArtifact) -> ModelArtifact:
        if not art.params:
            raise ValueError("QuantizePTQ needs float params on the artifact")
        bits = self.storage_bits()
        cfg = q.QuantConfig(bits=bits, float_leaves=tuple(self.float_leaves))
        qp = q.quantize_params(art.params, cfg)
        metrics = {
            "bits": bits, "q_format": "Q15" if bits == 16 else "Q7",
            "scales": {k: float(np.float32(v))
                       for k, v in sorted(qp.scales.items())},
            "weight_bytes": qp.nbytes(),
            "float_leaves": sorted(qp.fp),
        }
        meta = {**art.meta, "bits": bits}
        return self._record(art.replace(qp=qp, meta=meta), metrics)


# ---------------------------------------------------------------------------
# Activation calibration (deploy scales and/or Table V storage scales)
# ---------------------------------------------------------------------------

def resolve_windows(windows: Any) -> np.ndarray:
    """Calibration data as an explicit (N, T, d) array or a deterministic
    ``"hapt:<split>:<n>"`` spec (the synthetic HAPT loader is crc32-seeded,
    so a spec is as reproducible as an inline array)."""
    if isinstance(windows, str):
        parts = windows.split(":")
        if len(parts) != 3 or parts[0] != "hapt":
            raise ValueError(
                f"windows spec must be 'hapt:<split>:<n>': {windows!r}")
        from repro.data import hapt
        return hapt.load(parts[1], n=int(parts[2])).windows
    return np.asarray(windows, np.float32)


@dataclasses.dataclass(frozen=True)
class CalibrateActivations(_ConfigPass):
    """Paper Sec. III-D: max-abs calibration with headroom over N windows.

    ``scope="deploy"`` records every scale the fixed-point export needs
    (x, low-rank intermediates, bias-inclusive pre, h, logits) into
    ``artifact.act_scales`` — what ``deploy/image.build_image`` packs.
    ``scope="storage"`` records the Table V activation-storage scales into
    ``artifact.storage_scales`` — what the calibrated-Q15-acts QRuntime
    mode consumes.  Both route through the single parameterized
    ``core.qruntime.calibrate`` implementation."""
    windows: Any = "hapt:train:5"
    headroom: float = 0.10
    scope: str = "deploy"                   # "deploy" | "storage"
    name: str = dataclasses.field(default="calibrate_activations", init=False)

    def apply(self, art: ModelArtifact) -> ModelArtifact:
        if self.scope not in ("deploy", "storage"):
            raise ValueError(f"scope must be deploy|storage: {self.scope}")
        if art.qp is None:
            raise ValueError("CalibrateActivations runs after QuantizePTQ "
                             "(it calibrates the quantized model's runtime)")
        from repro.core.qruntime import QRuntime, calibrate
        w = resolve_windows(self.windows)
        scales = calibrate(QRuntime(art.qp), w, headroom=self.headroom,
                           deploy=(self.scope == "deploy"))
        scales = {k: float(v) for k, v in scales.items()}
        field = "act_scales" if self.scope == "deploy" else "storage_scales"
        metrics = {"scope": self.scope, "n_windows": int(w.shape[0]),
                   "headroom": self.headroom,
                   "scales": dict(sorted(scales.items()))}
        return self._record(art.replace(**{field: scales}), metrics)


# ---------------------------------------------------------------------------
# LUT packing (Appendix C)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PackLUT(_ConfigPass):
    """Attach the 256-entry activation LUTs to the artifact: the f32 pair
    (the paper's 2 KB flash cost, float engine) and the int16 Q15 pair
    (1 KB, integer engine).  Purely derived — packed here so the artifact
    is self-contained for consumers that never import ``core.lut``."""
    kinds: tuple[str, ...] = ("sigmoid", "tanh")
    name: str = dataclasses.field(default="pack_lut", init=False)

    def apply(self, art: ModelArtifact) -> ModelArtifact:
        luts = dict(art.luts)
        for kind in self.kinds:
            luts[f"{kind}_f32"] = make_lut(kind)
            luts[f"{kind}_q15"] = make_lut_q15(kind)
        nbytes = int(sum(v.nbytes for v in luts.values()))
        return self._record(art.replace(luts=luts),
                            {"entries_per_table": int(luts[
                                f"{self.kinds[0]}_f32"].shape[0]),
                             "lut_bytes": nbytes})
