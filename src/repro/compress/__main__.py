"""CLI: compose compression passes from a config and emit one artifact.

    PYTHONPATH=src python -m repro.compress --preset q15-deploy \
        --out model.fgar --report report.json

    PYTHONPATH=src python -m repro.compress --config recipe.json \
        --params checkpoint.npz --out model.fgar

Config file shape (see docs/compression.md)::

    {"name": "deploy-q15",
     "passes": [
        {"pass": "iht_sparsify", "sparsity": 0.5},
        {"pass": "quantize_ptq", "bits": 15},
        {"pass": "calibrate_activations",
         "windows": "hapt:train:5", "scope": "deploy"},
        {"pass": "pack_lut"}]}

The emitted ``--report`` JSON carries ``"benchmark": "compress_artifact"``
and validates under ``benchmarks/validate_bench.py``; CI's determinism
gate runs this CLI twice and requires byte-identical ``--out`` files.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import sys

import numpy as np

from .artifact import ModelArtifact
from .pipeline import default_deploy_pipeline, pipeline_from_config

PRESETS = {
    "q15-deploy": lambda: default_deploy_pipeline(bits=15),
    "q7-deploy": lambda: default_deploy_pipeline(bits=7),
    "q15-sparse-deploy": lambda: default_deploy_pipeline(bits=15,
                                                         sparsity=0.5),
}


def _load_params(args) -> dict:
    if args.params:
        with np.load(args.params, allow_pickle=False) as z:
            return {k: z[k] for k in z.files}
    from repro.core import fastgrnn as fg
    import jax
    cfg = fg.FastGRNNConfig(rank_w=args.rank_w or None,
                            rank_u=args.rank_u or None)
    return fg.init_params(cfg, jax.random.PRNGKey(args.seed))


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.compress", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    src = ap.add_argument_group("model source")
    src.add_argument("--params", default=None,
                     help="float checkpoint .npz (name -> array); default: "
                          "deterministic random init")
    src.add_argument("--seed", type=int, default=0)
    src.add_argument("--rank-w", type=int, default=2)
    src.add_argument("--rank-u", type=int, default=8)
    rec = ap.add_argument_group("recipe")
    rec.add_argument("--config", default=None,
                     help="JSON pipeline config (list of pass specs)")
    rec.add_argument("--preset", default=None, choices=sorted(PRESETS),
                     help="built-in recipe instead of --config")
    out = ap.add_argument_group("outputs")
    out.add_argument("--out", default=None,
                     help="artifact path (.fgar); omit for a dry run")
    out.add_argument("--report", default=None,
                     help="size-report JSON path, or - for stdout")
    out.add_argument("--emit-image", default=None,
                     help="also lower to a packed deploy image (.fgrn)")
    args = ap.parse_args(argv)

    if args.config and args.preset:
        ap.error("--config and --preset are mutually exclusive")
    if args.config:
        with open(args.config) as f:
            pipe = pipeline_from_config(json.load(f))
    else:
        pipe = PRESETS[args.preset or "q15-deploy"]()

    art = pipe.run(ModelArtifact.from_params(_load_params(args)))
    blob = art.to_bytes()
    sha = hashlib.sha256(blob).hexdigest()
    print(art.summary())
    for r in art.provenance:
        print(f"  pass {r['pass']}")

    if args.out:
        with open(args.out, "wb") as f:
            f.write(blob)
        print(f"wrote {args.out} ({len(blob)} bytes, sha256 {sha[:16]}...)")
    if args.emit_image:
        from repro.deploy.image import build_image
        img = build_image(art)
        with open(args.emit_image, "wb") as f:
            f.write(img.to_bytes())
        print(f"wrote {args.emit_image} ({img.nbytes()} bytes)")
    if args.report:
        report = {"benchmark": "compress_artifact",
                  "pipeline": pipe.name,
                  "sha256": sha,
                  "artifact_bytes": len(blob),
                  "size": art.size_report(),
                  "provenance": art.provenance}
        blob = json.dumps(report, indent=2)
        if args.report == "-":
            print(blob)
        else:
            with open(args.report, "w") as f:
                f.write(blob + "\n")
            print(f"wrote {args.report}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
