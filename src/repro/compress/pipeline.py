"""`Pipeline` — deterministic composition of compression passes.

A pipeline is just an ordered tuple of :class:`~repro.compress.passes.Pass`
objects run left to right over one
:class:`~repro.compress.artifact.ModelArtifact`; every pass appends its own
provenance record, so the finished artifact carries the full recipe that
produced it.  ``pipeline_from_config`` builds one from a JSON-able config
(the ``python -m repro.compress`` CLI input), and
``default_deploy_pipeline`` is the paper's PTQ -> deploy-calibration ->
LUT recipe used by ``deploy/goldens.build_reference_artifact``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable

from .artifact import ModelArtifact
from .passes import (CalibrateActivations, IHTSparsify, LowRankFactor,
                     PackLUT, Pass, QuantizePTQ)

PASS_REGISTRY: dict[str, type] = {
    "low_rank": LowRankFactor,
    "iht_sparsify": IHTSparsify,
    "quantize_ptq": QuantizePTQ,
    "calibrate_activations": CalibrateActivations,
    "pack_lut": PackLUT,
}


@dataclasses.dataclass(frozen=True)
class Pipeline:
    """Ordered, pure composition of passes (one artifact in, one out)."""
    passes: tuple[Pass, ...]
    name: str = "compress"

    def __post_init__(self):
        object.__setattr__(self, "passes", tuple(self.passes))

    def run(self, artifact: ModelArtifact) -> ModelArtifact:
        for p in self.passes:
            artifact = p.apply(artifact)
        return artifact

    def describe(self) -> list[dict[str, Any]]:
        return [{"pass": p.name, "config": p.config()} for p in self.passes]


def pipeline_from_config(cfg: Iterable[dict[str, Any]] | dict[str, Any],
                         name: str = "compress") -> Pipeline:
    """Build a pipeline from a JSON config: either a list of pass specs
    ``[{"pass": "quantize_ptq", "bits": 15}, ...]`` or a dict with a
    ``"passes"`` key holding that list.  Unknown pass names or kwargs fail
    loudly (determinism gate: a config typo must not silently change the
    recipe)."""
    if isinstance(cfg, dict):
        name = cfg.get("name", name)
        cfg = cfg["passes"]
    passes = []
    for spec in cfg:
        spec = dict(spec)
        kind = spec.pop("pass")
        cls = PASS_REGISTRY.get(kind)
        if cls is None:
            raise ValueError(f"unknown pass {kind!r} "
                             f"(known: {sorted(PASS_REGISTRY)})")
        for k in ("leaves", "float_leaves", "kinds"):
            if k in spec and isinstance(spec[k], list):
                spec[k] = tuple(spec[k])
        passes.append(cls(**spec))
    return Pipeline(passes=tuple(passes), name=name)


def default_deploy_pipeline(bits: int = 15,
                            calib: Any = "hapt:train:5",
                            headroom: float = 0.10,
                            sparsity: float | None = None) -> Pipeline:
    """The paper's deployment recipe: [IHT ->] PTQ -> deploy calibration ->
    LUT pack.  ``bits=15`` reproduces the historical Q15 export exactly;
    ``bits=7`` is the Q7 path (same image format, int8-range weights)."""
    passes: list[Pass] = []
    if sparsity:
        passes.append(IHTSparsify(sparsity=sparsity))
    passes += [
        QuantizePTQ(bits=bits),
        CalibrateActivations(windows=calib, headroom=headroom,
                             scope="deploy"),
        PackLUT(),
    ]
    return Pipeline(passes=tuple(passes),
                    name=f"deploy-q{'15' if bits in (15, 16) else '7'}")


def compress(params: dict[str, Any], pipeline: Pipeline) -> ModelArtifact:
    """One-call convenience: wrap a float checkpoint and run a pipeline."""
    return pipeline.run(ModelArtifact.from_params(params))
