"""`ModelArtifact` — the one handoff object of the compression pipeline.

The paper's pipeline (low-rank -> IHT sparsity -> per-tensor Q15 PTQ ->
activation calibration -> deploy) used to exist in this repo as four
disconnected handoffs: `(params)`, `(params, masks)`, `(QuantizedParams,
act_scales)` and the packed `DeployImage`.  A :class:`ModelArtifact`
carries *all* of that state plus per-pass provenance, so every consumer
(`core/qruntime.QRuntime.from_artifact`, `serve/streaming.StreamingEngine`,
`deploy/image.build_image`, the benchmarks and examples) takes one object
and never re-assembles tuples.

Serialization is a deterministic, versioned binary format (``.fgar``):

  +--------+-----------------------------------------------------------+
  | magic  | ``FGAR``, u16 artifact version, u32 header length         |
  | header | canonical JSON (sorted keys, compact separators): meta,   |
  |        | per-tensor manifest, quantizer scales, activation scales, |
  |        | full per-pass provenance                                  |
  | payload| raw little-endian tensor bytes, manifest order            |
  +--------+-----------------------------------------------------------+

Determinism contract (gated in CI and ``tests/test_compress.py``):

  * save -> load -> save is byte-identical;
  * running the same :class:`~repro.compress.pipeline.Pipeline` twice over
    the same checkpoint produces byte-identical artifacts (passes are pure
    and record no wall-clock state in provenance).

``size_report()`` is the deployed-footprint audit: per-tensor dense bytes
at the artifact's weight width (2 B/entry at Q15, 1 B/entry at Q7) plus a
CSR-style packed estimate for sparsified tensors (values + column indices
+ row pointers), the accounting behind the paper's 566-byte figure.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import struct
from typing import Any

import numpy as np

from repro.core.quantization import QuantizedParams

MAGIC = b"FGAR"
ARTIFACT_VERSION = 1

_PREAMBLE = struct.Struct("<4sHI")      # magic, version, header length

# Tensor groups, serialized in this fixed order (names sorted inside each):
_GROUPS = ("params", "masks", "q", "fp", "luts")

# Canonical on-disk dtypes per group (params/fp are f32 by construction;
# masks keep their bool-ness through a round-trip via the |b1 tag; q keeps
# its quantized width; luts are i2 or f4).
_DTYPE_TAGS = {"<f4": np.dtype("<f4"), "<i2": np.dtype("<i2"),
               "<i1": np.dtype("<i1"), "|u1": np.dtype("u1"),
               "|b1": np.dtype(bool)}


def jsonify(obj: Any) -> Any:
    """Recursively convert numpy scalars/arrays into JSON-safe values.

    Floats go through ``float(np.float32(...))`` ONLY at the caller's
    discretion — here we preserve the exact binary64 value so provenance
    round-trips bit-for-bit through ``json.dumps``/``loads``.
    """
    if isinstance(obj, dict):
        return {str(k): jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonify(v) for v in obj]
    if isinstance(obj, (np.bool_, bool)):
        return bool(obj)
    if isinstance(obj, (np.integer, int)):
        return int(obj)
    if isinstance(obj, (np.floating, float)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return jsonify(obj.tolist())
    return obj


def _canonical_json(obj: Any) -> bytes:
    return json.dumps(jsonify(obj), sort_keys=True,
                      separators=(",", ":")).encode("utf-8")


def _dtype_tag(a: np.ndarray) -> str:
    kind = np.asarray(a).dtype
    if kind == np.bool_:
        return "|b1"
    if kind == np.uint8:
        return "|u1"
    if kind == np.int8:
        return "<i1"
    if kind == np.int16:
        return "<i2"
    return "<f4"


def tensor_digest(a: np.ndarray) -> str:
    """Short content digest for provenance records (never for security)."""
    t = np.ascontiguousarray(np.asarray(a))
    return hashlib.sha256(t.tobytes()).hexdigest()[:16]


@dataclasses.dataclass
class ModelArtifact:
    """Versioned, serializable carrier of one model through the pipeline.

    Fields fill in as passes run: ``params``/``masks`` after the float
    stages, ``qp`` after PTQ, ``act_scales`` (deploy calibration) and/or
    ``storage_scales`` (Table V activation-storage calibration) after
    :class:`~repro.compress.passes.CalibrateActivations`, ``luts`` after
    :class:`~repro.compress.passes.PackLUT`.  ``provenance`` appends one
    record per pass: ``{"pass", "config", "metrics"}``.
    """
    version: int = ARTIFACT_VERSION
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)
    params: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    masks: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    qp: QuantizedParams | None = None
    act_scales: dict[str, float] = dataclasses.field(default_factory=dict)
    storage_scales: dict[str, float] = dataclasses.field(default_factory=dict)
    luts: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    provenance: list[dict[str, Any]] = dataclasses.field(default_factory=list)

    # -- construction -----------------------------------------------------
    @classmethod
    def from_params(cls, params: dict[str, Any],
                    meta: dict[str, Any] | None = None) -> "ModelArtifact":
        """Wrap a float parameter pytree (jax or numpy leaves) as the
        pipeline's input artifact.  Leaves are canonicalized to float32
        numpy; scalars become 0-d arrays.  Architecture metadata (d, H, C,
        ranks) is inferred from the FastGRNN tensor names when present."""
        p = {k: np.asarray(v, np.float32) for k, v in params.items()}
        m = dict(meta or {})
        m.setdefault("format", "fastgrnn" if "b_z" in p else "generic")
        if "b_z" in p:
            low_rank = "W1" in p
            m.setdefault("low_rank", low_rank)
            m.setdefault("H", int(p["b_z"].shape[0]))
            m.setdefault("d", int(p["W2"].shape[0] if low_rank
                                  else p["W"].shape[1]))
            m.setdefault("C", int(p["head_b"].shape[0]))
            m.setdefault("rank_w", int(p["W1"].shape[1]) if low_rank else 0)
            m.setdefault("rank_u", int(p["U1"].shape[1]) if "U1" in p else 0)
        art = cls(meta=m, params=p)
        return art.with_record({
            "pass": "source",
            "config": {},
            "metrics": {"param_count": int(sum(v.size for v in p.values())),
                        "params_sha": {k: tensor_digest(v)
                                       for k, v in sorted(p.items())}},
        })

    # -- functional updates ----------------------------------------------
    def replace(self, **kw: Any) -> "ModelArtifact":
        return dataclasses.replace(self, **kw)

    def with_record(self, record: dict[str, Any]) -> "ModelArtifact":
        return self.replace(provenance=[*self.provenance, jsonify(record)])

    # -- introspection ----------------------------------------------------
    @property
    def bits(self) -> int | None:
        """Quantized weight width (16 -> Q15 int16, 8 -> Q7 int8)."""
        if self.qp is not None:
            return self.qp.bits
        return self.meta.get("bits")

    @property
    def low_rank(self) -> bool:
        if self.qp is not None:
            return "W1" in self.qp.q or "W1" in self.qp.fp
        return "W1" in self.params

    def passes_applied(self) -> list[str]:
        return [r["pass"] for r in self.provenance]

    # -- runtime consumption (one gate shared by every consumer) ----------
    def require_qp(self) -> QuantizedParams:
        if self.qp is None:
            raise ValueError("artifact carries no quantized params — run a "
                             "QuantizePTQ pass first")
        return self.qp

    def runtime_scales(self, quantized_acts: bool = False
                       ) -> dict[str, float] | None:
        """Activation-storage scales for a runtime consumer.  The deployed
        configuration (paper Table V winning row) keeps activations in
        FP32 through the LUTs, so the *deploy* calibration scales
        (``act_scales`` — export-compiler scales for x/pre/h/logits) are
        deliberately never returned here.  ``quantized_acts=True`` selects
        the calibrated-Q15-activation counterfactual, which requires a
        ``CalibrateActivations(scope="storage")`` pass."""
        if not quantized_acts:
            return None
        if not self.storage_scales:
            raise ValueError(
                "quantized_acts=True needs artifact.storage_scales "
                "(CalibrateActivations(scope='storage'))")
        return dict(self.storage_scales)

    def sha256(self) -> str:
        return hashlib.sha256(self.to_bytes()).hexdigest()

    # -- serialization ----------------------------------------------------
    def _tensor_groups(self) -> dict[str, dict[str, np.ndarray]]:
        g: dict[str, dict[str, np.ndarray]] = {
            "params": self.params, "masks": self.masks, "luts": self.luts,
            "q": {}, "fp": {}}
        if self.qp is not None:
            g["q"] = {k: np.asarray(v) for k, v in self.qp.q.items()}
            g["fp"] = {k: np.asarray(v, np.float32)
                       for k, v in self.qp.fp.items()}
        return g

    def to_bytes(self) -> bytes:
        groups = self._tensor_groups()
        manifest, payload = [], []
        for group in _GROUPS:
            for name in sorted(groups[group]):
                a = np.asarray(groups[group][name])
                tag = _dtype_tag(a)
                t = np.ascontiguousarray(a.astype(_DTYPE_TAGS[tag],
                                                  copy=False))
                manifest.append({"group": group, "name": name, "dtype": tag,
                                 "shape": [int(s) for s in a.shape]})
                payload.append(t.tobytes())
        header = {
            "artifact_version": self.version,
            "meta": self.meta,
            "act_scales": {k: float(v) for k, v in self.act_scales.items()},
            "storage_scales": {k: float(v)
                               for k, v in self.storage_scales.items()},
            "q_bits": None if self.qp is None else int(self.qp.bits),
            "q_scales": (None if self.qp is None else
                         {k: float(np.float32(v))
                          for k, v in self.qp.scales.items()}),
            "provenance": self.provenance,
            "tensors": manifest,
        }
        hj = _canonical_json(header)
        return (_PREAMBLE.pack(MAGIC, self.version, len(hj)) + hj
                + b"".join(payload))

    @classmethod
    def from_bytes(cls, blob: bytes) -> "ModelArtifact":
        magic, ver, hlen = _PREAMBLE.unpack_from(blob, 0)
        if magic != MAGIC:
            raise ValueError(f"bad artifact magic {magic!r}")
        if ver != ARTIFACT_VERSION:
            raise ValueError(f"unsupported artifact version {ver}")
        off = _PREAMBLE.size
        header = json.loads(blob[off:off + hlen].decode("utf-8"))
        off += hlen
        groups: dict[str, dict[str, np.ndarray]] = {g: {} for g in _GROUPS}
        for ent in header["tensors"]:
            dt = _DTYPE_TAGS[ent["dtype"]]
            n = int(np.prod(ent["shape"])) if ent["shape"] else 1
            a = np.frombuffer(blob, dt, count=n, offset=off)
            off += a.nbytes
            groups[ent["group"]][ent["name"]] = \
                a.reshape(ent["shape"]).copy()
        if off != len(blob):
            raise ValueError(f"trailing artifact bytes: {len(blob) - off}")
        qp = None
        if header["q_bits"] is not None:
            qp = QuantizedParams(q=groups["q"],
                                 scales=dict(header["q_scales"]),
                                 fp=groups["fp"], bits=int(header["q_bits"]))
        return cls(version=ver, meta=header["meta"], params=groups["params"],
                   masks=groups["masks"], qp=qp,
                   act_scales=dict(header["act_scales"]),
                   storage_scales=dict(header["storage_scales"]),
                   luts=groups["luts"], provenance=header["provenance"])

    def save(self, path: str) -> bytes:
        blob = self.to_bytes()
        with open(path, "wb") as f:
            f.write(blob)
        return blob

    @classmethod
    def load(cls, path: str) -> "ModelArtifact":
        with open(path, "rb") as f:
            return cls.from_bytes(f.read())

    # -- deployed-footprint audit ----------------------------------------
    def size_report(self) -> dict[str, Any]:
        """Deployed weight-footprint accounting at the artifact's true
        weight width (Q7 counts 1 B/entry even though the wire image cells
        stay int16) with a CSR-style packed alternative for sparse tensors:
        ``nnz * itemsize`` values + ``nnz`` column indices (u8 when the row
        width allows, else u16) + ``rows + 1`` u16 row pointers.  The
        per-tensor ``packing`` picks whichever is smaller — the honest
        version of the paper's 566-byte claim under IHT sparsity."""
        report: dict[str, Any] = {
            "artifact_version": self.version,
            "passes": self.passes_applied(),
            "meta": jsonify(self.meta),
        }
        if self.qp is None:
            total = int(sum(v.size for v in self.params.values())) * 4
            report["bits"] = 32
            report["weight_bytes_dense"] = total
            report["weight_bytes_packed"] = total
            report["tensors"] = [
                {"name": k, "shape": list(v.shape), "bytes": int(v.size) * 4}
                for k, v in sorted(self.params.items())]
            return report
        bits = self.qp.bits
        itemsize = 2 if bits == 16 else 1
        tensors = []
        dense_total = packed_total = nnz_total = n_total = 0
        for name in self.qp.tensor_order():
            t = np.asarray(self.qp.q[name])
            rows, cols = (t.shape if t.ndim == 2 else (1, t.size))
            n, nnz = int(t.size), int(np.count_nonzero(t))
            dense = n * itemsize
            idx_b = 1 if cols <= 256 else 2
            csr = nnz * itemsize + nnz * idx_b + (rows + 1) * 2
            packing = "csr" if csr < dense else "dense"
            packed = min(csr, dense)
            tensors.append({
                "name": name, "shape": [int(s) for s in t.shape],
                "dtype": f"int{8 * itemsize}",
                "scale": float(np.float32(self.qp.scales[name])),
                "nnz": nnz, "sparsity": 1.0 - nnz / max(n, 1),
                "dense_bytes": dense, "csr_bytes": csr,
                "packing": packing, "packed_bytes": packed,
            })
            dense_total += dense
            packed_total += packed
            nnz_total += nnz
            n_total += n
        fp_bytes = int(sum(np.asarray(v).size
                           for v in self.qp.fp.values())) * 4
        scale_bytes = 4 * len(self.qp.scales)
        act_bytes = 4 * (len(self.act_scales) + len(self.storage_scales))
        lut_bytes = int(sum(np.asarray(v).nbytes
                            for v in self.luts.values()))
        report.update({
            "bits": bits,
            "q_format": "Q15" if bits == 16 else "Q7",
            "tensors": tensors,
            "weight_bytes_dense": dense_total,
            "weight_bytes_packed": packed_total,
            "weight_sparsity": 1.0 - nnz_total / max(n_total, 1),
            "const_bytes": fp_bytes + scale_bytes + act_bytes,
            "lut_bytes": lut_bytes,
            "total_bytes_packed": packed_total + fp_bytes + scale_bytes
                                  + act_bytes + lut_bytes,
            "paper_weight_budget_bytes": 566,
            "within_paper_weight_budget": packed_total <= 566,
        })
        return report

    def summary(self) -> str:
        bits = self.bits
        stages = " -> ".join(self.passes_applied()) or "(empty)"
        size = (f"{self.size_report()['weight_bytes_packed']} B packed"
                if self.qp is not None else
                f"{sum(v.size for v in self.params.values())} f32 params")
        return (f"ModelArtifact v{self.version} [{stages}] "
                f"bits={bits} {size}")
