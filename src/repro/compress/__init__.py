"""repro.compress — composable compression passes + the `ModelArtifact`.

The paper's spine (low-rank -> IHT sparsity -> per-tensor PTQ ->
activation calibration -> deploy) as a sequence of pure, deterministic
passes over one versioned, serializable artifact:

  * :mod:`.artifact` — :class:`ModelArtifact`: the single handoff object
    every runtime consumes (``core/qruntime``, ``serve/streaming``,
    ``deploy/image``), with per-pass provenance, a deterministic ``.fgar``
    binary format, and a CSR-aware ``size_report``;
  * :mod:`.passes`   — the :class:`Pass` protocol and the concrete stages
    ``LowRankFactor``, ``IHTSparsify``, ``QuantizePTQ`` (Q15 *and* Q7),
    ``CalibrateActivations`` (deploy / storage scopes), ``PackLUT``;
  * :mod:`.pipeline` — :class:`Pipeline` composition, a JSON config
    loader, and the paper's ``default_deploy_pipeline``;
  * :mod:`.tree`     — pytree PTQ for LM serving (the single home of the
    math formerly duplicated in ``serve/engine.quantize_for_serving``).

CLI: ``python -m repro.compress --preset q15-deploy --out model.fgar``
(see ``python -m repro.compress --help``).
"""
from .artifact import ARTIFACT_VERSION, ModelArtifact
from .passes import (BITS_ALIASES, CalibrateActivations, IHTSparsify,
                     LowRankFactor, PackLUT, Pass, QuantizePTQ,
                     resolve_windows)
from .pipeline import (PASS_REGISTRY, Pipeline, compress,
                       default_deploy_pipeline, pipeline_from_config)
from .tree import dequantize_tree, quantize_tree, tree_size_report

__all__ = [
    "ARTIFACT_VERSION", "ModelArtifact",
    "BITS_ALIASES", "Pass", "LowRankFactor", "IHTSparsify", "QuantizePTQ",
    "CalibrateActivations", "PackLUT", "resolve_windows",
    "PASS_REGISTRY", "Pipeline", "compress", "default_deploy_pipeline",
    "pipeline_from_config",
    "quantize_tree", "dequantize_tree", "tree_size_report",
]
