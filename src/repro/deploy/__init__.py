"""repro.deploy — MCU export compiler + pure-integer Q15 emulator.

The paper's headline contribution is the *deployment* half: a 566-byte
weight image running bit-equivalently on an 8-bit AVR and a multiplier-less
MSP430.  This package ships that artifact:

  * :mod:`.image`   — pack a calibrated ``QuantizedParams`` model +
    activation scales + both 256-entry LUTs into a deterministic,
    versioned, size-audited weight image (``.fgrn``);
  * :mod:`.qvm`     — a pure-integer Q15 virtual machine (int16 storage,
    wide accumulators, explicit shifts/saturation, zero float ops in the
    hot loop) executing the packed image on host — the repo's stand-in
    for the multiplier-less MSP430 path;
  * :mod:`.emit_c`  — a C code generator lowering the image into a
    self-contained ``fastgrnn_model.h`` / ``fastgrnn_cell.c`` translation
    unit for ``avr`` / ``msp430`` / ``host`` targets (no libm in the LUT
    path), plus a host build-and-drive harness;
  * :mod:`.goldens` — golden-trace generation (per-step hidden states +
    final argmax) with a checked-in-fixture regeneration CLI;
  * :mod:`.verify`  — the parity harness reproducing the paper's
    3,399-window 100%-agreement protocol across FP32 / QRuntime /
    StreamingEngine / qvm / compiled C.
"""
from .image import (DeployImage, build_image, export_model, size_report,
                    audit_platforms, ACT_KEYS, IMAGE_VERSION)
from .qvm import QVM, QuantPlan, Requant, quantize_multiplier
from .emit_c import generate_sources, write_sources, compile_host, CHostModel
from .goldens import (build_reference_artifact, build_reference_model,
                      generate_goldens, save_goldens, load_goldens)
from .verify import run_parity

__all__ = [
    "DeployImage", "build_image", "export_model", "size_report",
    "audit_platforms", "ACT_KEYS", "IMAGE_VERSION",
    "QVM", "QuantPlan", "Requant", "quantize_multiplier",
    "generate_sources", "write_sources", "compile_host", "CHostModel",
    "build_reference_artifact", "build_reference_model", "generate_goldens",
    "save_goldens", "load_goldens",
    "run_parity",
]
