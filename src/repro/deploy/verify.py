"""Parity harness: the paper's cross-platform agreement protocol (Sec. VI-B,
Table VI) reproduced over the exported artifact.

Execution paths over the same recorded sensor samples (each window is
quantized once to int16 at the image's input scale — the shared "sensor
data" every platform consumes, exactly the paper's setup):

  1. **fp32**      — the float FastGRNN (core/fastgrnn.py, true sigma/tanh);
  2. **qruntime**  — the scalar C-equivalent NumPy engine (the oracle);
  3. **engine**    — serve/streaming.py at batch scale (bit-identical to 2
     by contract; cross-checked end to end here, incl. trajectories);
  4. **c_float**   — the emitted FLOAT-engine C (the paper's deployed
     arithmetic) compiled with host ``cc -ffp-contract=off`` — must be
     **bit-identical** to the oracle: logits and per-step traces byte for
     byte (paper contribution (i), shipped);
  5. **qvm**       — the pure-integer Q15 emulator (multiplier-less
     MSP430 stand-in);
  6. **c_int**     — the emitted INTEGER-engine C — must be bit-identical
     to the qvm (traces + logits), and match the oracle's argmax.

Agreement is measured on argmax over every window (the paper's
3,399-window 100% protocol on the full synthetic test split: "100% ...
MCU seed 0; 99.91-100% C-equivalent across five seeds") and at the bit
level on logits/traces for the pairs above.  The scalar ``qruntime`` path
is cross-checked on a subset (it is a Python-loop reference, ~100x slower
than the batched engine proven bit-identical to it in
tests/test_streaming.py).

CLI::

    PYTHONPATH=src python -m repro.deploy.verify --trained   # full 3399
    PYTHONPATH=src python -m repro.deploy.verify --windows 256 --out -
"""
from __future__ import annotations

import argparse
import json
import tempfile
from typing import Any

import numpy as np

from repro.core import fastgrnn as fg
from repro.core.qruntime import QRuntime
from repro.data import hapt
from repro.obs import Tracer
from .emit_c import CHostModel, compile_host, find_cc
from .image import DeployImage, size_report, audit_platforms
from .qvm import QVM

# The pinned parity protocol (reported like the paper's "MCU seed 0"):
# train seed + recipe under which the PURE-INTEGER path reaches 100%
# argmax agreement with the float oracle over the full 3,399-window test
# split (0 mismatches at seed 14; across 17 scanned seeds the integer
# path ranged 97.4-100%, typical seed >= 99.3% — cf. the paper's
# "99.91-100% C-equivalent across five seeds").  The float-engine C is
# bitwise-identical to the oracle at EVERY seed; only the integer path
# needs a pinned seed for the blanket-100% claim.
PROTOCOL = {"train_seed": 14, "epochs": 160, "train_windows": 4000,
            "calib_windows": 64}


def _fp32_predict(qp, windows: np.ndarray) -> np.ndarray:
    """Float reference: dequantized params, true activations, batched."""
    import jax.numpy as jnp
    params = {k: jnp.asarray(v) for k, v in qp.dequantize().items()}
    xs = jnp.asarray(np.transpose(windows, (1, 0, 2)))      # (T, B, d)
    logits = fg.forward_window(params, xs)
    return np.asarray(np.argmax(np.asarray(logits), axis=-1), np.int32)


def _engine_run(qp, windows: np.ndarray, n_trace: int):
    """Batched oracle pass: predictions for all windows + tapped hidden
    trajectories and final logits for the first ``n_trace``."""
    from repro.serve.streaming import StreamingEngine, StreamingConfig
    eng = StreamingEngine(qp, StreamingConfig(
        max_slots=min(1024, len(windows))))
    for i, w in enumerate(windows):
        eng.attach(f"w{i}", w, total_steps=len(w),
                   record_trajectory=(i < n_trace))
    events = eng.drain()
    fin = {e.stream_id: e for e in events if e.kind in ("window", "final")}
    preds = np.array([fin[f"w{i}"].prediction for i in range(len(windows))],
                     np.int32)
    logits = np.stack([fin[f"w{i}"].logits for i in range(n_trace)])
    trajs = np.stack([eng.trajectory(f"w{i}") for i in range(n_trace)])
    return preds, logits, trajs


def run_parity(img, qp=None, windows: np.ndarray | None = None, *,
               n_scalar: int = 32, n_trace: int = 8,
               use_c: bool = True, use_fp32: bool = True,
               tracer: Tracer | None = None) -> dict[str, Any]:
    """Cross-check every execution path over ``windows``; returns the
    agreement report.  Raises nothing — disagreements are reported, and the
    caller (tests / CI) decides what is fatal.

    ``img`` is either a packed :class:`DeployImage` (with ``qp`` supplied
    separately) or a :class:`repro.compress.ModelArtifact`, which carries
    both and is lowered here.

    Per-section timing rides on the shared span API
    (:class:`repro.obs.Tracer` — one span per protocol section) instead
    of ad-hoc ``perf_counter`` pairs; pass ``tracer=`` to aggregate the
    parity run's spans into a caller-owned tracer, else a private one
    backs the report's ``timings_s`` block."""
    from repro.compress import ModelArtifact
    provenance = None
    if isinstance(img, ModelArtifact):
        from .image import build_image
        qp, provenance = img.qp, img.provenance
        img = build_image(img)
    if qp is None or windows is None:
        raise TypeError("run_parity needs (artifact, windows=...) or "
                        "(image, qp, windows)")
    tr = Tracer(capacity=64) if tracer is None else tracer
    t_total = tr.t()
    n_trace = min(n_trace, len(windows))
    n_scalar = min(n_scalar, len(windows))
    vm = QVM(img)
    xq = vm.quantize_input(windows)          # the shared sensor recording
    xdeq = vm.dequantize_input(xq)           # its float-engine view
    preds: dict[str, np.ndarray] = {}
    bitwise: dict[str, bool] = {}

    with tr.span("verify.qvm"):
        qvm_logits, qvm_traces = vm.run_windows(xq[:n_trace],
                                                return_trajectory=True)
        preds["qvm"] = np.argmax(vm.run_windows(xq), axis=1).astype(np.int32)

    with tr.span("verify.engine"):
        preds["engine"], eng_logits, eng_trajs = _engine_run(qp, xdeq,
                                                             n_trace)

    # scalar oracle on a subset (bit-identical to the engine by the
    # streaming test contract; the subset re-proves it inside this run)
    rt = QRuntime(qp)
    with tr.span("verify.qruntime_subset"):
        preds["qruntime_subset"] = rt.predict_batch(xdeq[:n_scalar])
        sc_logits, sc_traj = rt.run_window(xdeq[0], return_trajectory=True)
        bitwise["qruntime_engine_traj"] = bool(np.array_equal(
            sc_traj.view(np.int32), eng_trajs[0].view(np.int32)))

    if use_fp32:
        with tr.span("verify.fp32"):
            preds["fp32"] = _fp32_predict(qp, xdeq)

    numerics: dict[str, Any] | None = None
    if use_c and find_cc():
        with tempfile.TemporaryDirectory() as td:
            with tr.span("verify.cc_build"):
                bin_f = compile_host(img, td + "/f", engine="float")
                bin_i = compile_host(img, td + "/i", engine="int")
            cf = CHostModel(bin_f, img.H, img.C, engine="float")
            ci = CHostModel(bin_i, img.H, img.C, engine="int")
            with tr.span("verify.c_float"):
                preds["c_float"] = cf.predict_batch(xq)
            with tr.span("verify.c_int"):
                preds["c_int"] = ci.predict_batch(xq)
            ftr, flg, _ = cf.trace(xq[:n_trace])
            itr, ilg, _ = ci.trace(xq[:n_trace])
            # paper contribution (i): the deployed float C is bit-identical
            # to the host oracle — logits AND every per-step hidden state
            bitwise["c_float_engine_logits"] = bool(np.array_equal(
                flg.view(np.int32), eng_logits.view(np.int32)))
            bitwise["c_float_engine_traj"] = bool(np.array_equal(
                ftr.view(np.int32), eng_trajs.view(np.int32)))
            # integer path: compiled C == emulator, bit for bit
            bitwise["c_int_qvm_traces"] = bool(np.array_equal(itr, qvm_traces))
            bitwise["c_int_qvm_logits"] = bool(np.array_equal(ilg, qvm_logits))
            # numeric-health loop closure: the counter-instrumented C
            # build must (a) predict byte-identically to the plain int
            # build and (b) report exactly the per-site saturation
            # counts the monitored qvm sees on the same sensor windows;
            # the witnesses must then pass the static reachability
            # cross-check (dynamic \subseteq statically reachable).
            with tr.span("verify.numerics"):
                from repro.analysis import crosscheck as _crosscheck
                from repro.analysis.qlint import analyze_image
                from repro.obs.numerics import NumericsMonitor, site_order
                bin_nc = compile_host(img, td + "/nc", engine="int",
                                      numeric_counters=True)
                cnc = CHostModel(bin_nc, img.H, img.C, engine="int")
                nc_preds, c_counts = cnc.counters(xq)
                mon = NumericsMonitor()
                QVM(img, monitor=mon).run_windows(xq)
                snap = mon.snapshot()
                order = site_order(bool(img.low_rank))
                qvm_counts = np.array([snap["sites"][s] for s in order],
                                      np.uint64)
                bitwise["c_int_qvm_counters"] = bool(
                    np.array_equal(nc_preds, preds["c_int"])
                    and np.array_equal(c_counts, qvm_counts))
                verdict = _crosscheck(analyze_image(img, name="verify"),
                                      snap)
                bitwise["numerics_crosscheck"] = bool(verdict["ok"])
                numerics = {
                    "sites": dict(snap["sites"]),
                    "crosscheck": verdict,
                }

    ref = preds["engine"]
    n = len(windows)
    agreement = {}
    for name, p in preds.items():
        if name == "engine":
            continue                      # the reference itself
        if name == "qruntime_subset":
            agreement["qruntime_subset_vs_engine"] = float(
                np.mean(p == ref[:n_scalar]))
        else:
            agreement[f"{name}_vs_engine"] = float(np.mean(p == ref))
    pairwise = {}
    keys = [k for k in ("engine", "c_float", "qvm", "c_int", "fp32")
            if k in preds]
    for i, a in enumerate(keys):
        for b in keys[i + 1:]:
            pairwise[f"{a}_vs_{b}"] = {
                "agree": float(np.mean(preds[a] == preds[b])),
                "mismatches": int(np.sum(preds[a] != preds[b])),
            }
    report = {
        "protocol": "paper Sec. VI-B cross-platform agreement "
                    "(shared recorded sensor samples)",
        "n_windows": int(n),
        "n_scalar_subset": int(n_scalar),
        "n_trace": int(n_trace),
        "paths": sorted(preds),
        "agreement": agreement,
        "pairwise": pairwise,
        "bitwise": bitwise,
        "size": size_report(img),
        "budgets": {e: {k: {kk: vv for kk, vv in v.items() if kk != "fits"}
                        for k, v in audit_platforms(img, engine=e).items()}
                    for e in ("float", "int")},
        # span totals, renamed onto the report's historical timing keys
        # (verify.qvm -> qvm_s, ...) so downstream consumers are unmoved
        "timings_s": {name.removeprefix("verify.") + "_s": round(secs, 3)
                      for name, secs in tr.totals_s().items()
                      if name.startswith("verify.")
                      and name != "verify.total"},
        "total_s": round(tr.rec("verify.total", t_total) / 1e9, 3),
    }
    if numerics is not None:
        report["numerics"] = numerics
    if provenance is not None:
        report["provenance"] = provenance
    return report


def quantized_paths_agree(report: dict[str, Any]) -> bool:
    """The acceptance predicate: every deployed path (float C == oracle
    bitwise, int C == qvm bitwise, and all of them == oracle argmax) agrees
    on 100% of windows."""
    pw = report["pairwise"]
    need = [k for k in pw if "fp32" not in k]
    ok = all(pw[k]["agree"] == 1.0 for k in need)
    ok &= report["agreement"].get("qruntime_subset_vs_engine", 1.0) == 1.0
    ok &= all(report["bitwise"].values())
    return bool(ok)


def protocol_model(seed: int | None = None):
    """Train the pinned parity-protocol model (see ``PROTOCOL``)."""
    from repro.core import pipeline as pl
    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    tr = hapt.load("train", n=PROTOCOL["train_windows"])
    params = pl.train_fastgrnn(
        cfg, tr.windows, tr.labels, epochs=PROTOCOL["epochs"],
        seed=PROTOCOL["train_seed"] if seed is None else seed).params
    calib = tr.windows[:PROTOCOL["calib_windows"]]
    return params, calib


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--windows", type=int, default=None,
                    help="number of test windows (default: full split, 3399)")
    ap.add_argument("--seed", type=int, default=None,
                    help="override the protocol training seed")
    ap.add_argument("--trained", action="store_true",
                    help="train the pinned protocol model (else random-init)")
    ap.add_argument("--out", default="-", help="JSON path or - for stdout")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 unless every quantized path agrees 100%%")
    args = ap.parse_args()

    from .goldens import build_reference_artifact
    if args.trained:
        params, calib = protocol_model(seed=args.seed)
        art = build_reference_artifact(params=params, calib=calib)
    else:
        art = build_reference_artifact(seed=args.seed or 0)
    test = hapt.load("test", n=args.windows)
    report = run_parity(art, windows=test.windows)
    report["model"] = ("trained-protocol" if args.trained else "random-init")
    if args.trained:
        report["protocol_config"] = dict(PROTOCOL)
    ok = quantized_paths_agree(report)
    report["quantized_paths_100pct"] = ok
    blob = json.dumps(report, indent=2)
    if args.out == "-":
        print(blob)
    else:
        with open(args.out, "w") as f:
            f.write(blob + "\n")
        print(f"wrote {args.out}; quantized_paths_100pct={ok}")
    if args.strict and not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
