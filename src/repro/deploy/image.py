"""Packed weight image: the deployable artifact (paper Sec. V-B, Table I).

``build_image`` lowers a calibrated :class:`repro.compress.ModelArtifact`
(or, via a deprecation shim, the legacy ``(QuantizedParams, act_scales)``
pair) into a :class:`DeployImage`; ``DeployImage.to_bytes`` serializes it into a
deterministic, versioned byte image mirroring what gets flashed next to the
paper's ~200-line C translation unit:

  +--------+----------------------------------------------------------+
  | header | magic "FGRN", version, bits, flags, dims (d,H,C,rw,ru)   |
  | q      | int16 Q15 weight tensors, canonical order, row-major LE  |
  | scales | one f32 per weight tensor, same order                    |
  | consts | b_z, b_h (H f32 each), head_b (C f32), zeta, nu (raw f32)|
  | acts   | 6 f32 activation scales (x, wx1, uh1, pre, h, logits)    |
  | luts   | sigmoid + tanh as 256 x int16 Q15 (integer engine, 1 KB) |
  |        | then as 256 x f32 (float engine — the paper's 2 KB pair) |
  +--------+----------------------------------------------------------+

Determinism contract: two exports of the same checkpoint are byte-identical
(fixed tensor order via ``QuantizedParams.tensor_order``, fixed activation-
scale order, little-endian, no timestamps).  The CI export-determinism gate
and ``tests/test_deploy.py`` enforce this.

Size audit: ``audit_platforms`` checks the image + the integer engine's
SRAM working set against ``core/mcu.PLATFORMS`` flash/SRAM budgets for the
paper's two targets (AVR ATmega328P, MSP430G2553) — export fails loudly
rather than shipping an unflashable image.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Any

import numpy as np

from repro.core import mcu
from repro.core.lut import LUT_SIZE, make_lut, make_lut_q15
from repro.core.quantization import QuantizedParams

MAGIC = b"FGRN"
IMAGE_VERSION = 1
# Activation-scale slots, fixed order.  wx1/uh1 are the low-rank
# intermediates (W2^T x, U2^T h); zero for full-rank models.
ACT_KEYS = ("x", "wx1", "uh1", "pre", "h", "logits")

_HEADER = struct.Struct("<4sHBBHHHHHH")   # magic, ver, bits, flags, d,H,C,rw,ru,ntens


@dataclasses.dataclass
class DeployImage:
    """In-memory form of the packed weight image."""
    version: int
    bits: int
    low_rank: bool
    d: int
    H: int
    C: int
    rank_w: int                     # 0 = full rank
    rank_u: int
    q: dict[str, np.ndarray]        # name -> int16, canonical order
    scales: dict[str, float]        # name -> f32 dequant scale
    b_z: np.ndarray                 # (H,) f32
    b_h: np.ndarray                 # (H,) f32
    head_b: np.ndarray              # (C,) f32
    zeta_raw: float                 # pre-sigmoid scalars, as checkpointed
    nu_raw: float
    act_scales: dict[str, float]    # ACT_KEYS -> f32
    sig_lut: np.ndarray             # (256,) int16 Q15 (integer engine)
    tanh_lut: np.ndarray            # (256,) int16 Q15
    sig_lut_f32: np.ndarray         # (256,) f32 (float engine, paper's 2 KB)
    tanh_lut_f32: np.ndarray        # (256,) f32

    # -- canonical tensor geometry --------------------------------------
    def tensor_order(self) -> tuple[str, ...]:
        if self.low_rank:
            return ("W1", "W2", "U1", "U2", "head_w")
        return ("W", "U", "head_w")

    def tensor_shape(self, name: str) -> tuple[int, int]:
        d, H, C = self.d, self.H, self.C
        return {
            "W": (H, d), "U": (H, H),
            "W1": (H, self.rank_w), "W2": (d, self.rank_w),
            "U1": (H, self.rank_u), "U2": (H, self.rank_u),
            "head_w": (H, C),
        }[name]

    # -- serialization ---------------------------------------------------
    def to_bytes(self) -> bytes:
        order = self.tensor_order()
        out = [_HEADER.pack(MAGIC, self.version, self.bits,
                            1 if self.low_rank else 0,
                            self.d, self.H, self.C,
                            self.rank_w, self.rank_u, len(order))]
        for name in order:
            t = np.ascontiguousarray(self.q[name], dtype="<i2")
            if t.shape != self.tensor_shape(name):
                raise ValueError(f"{name}: shape {t.shape} != "
                                 f"{self.tensor_shape(name)}")
            out.append(t.tobytes())
        out.append(np.asarray([self.scales[n] for n in order],
                              "<f4").tobytes())
        out.append(np.asarray(self.b_z, "<f4").tobytes())
        out.append(np.asarray(self.b_h, "<f4").tobytes())
        out.append(np.asarray(self.head_b, "<f4").tobytes())
        out.append(np.asarray([self.zeta_raw, self.nu_raw], "<f4").tobytes())
        out.append(np.asarray([self.act_scales.get(k, 0.0) for k in ACT_KEYS],
                              "<f4").tobytes())
        out.append(np.ascontiguousarray(self.sig_lut, "<i2").tobytes())
        out.append(np.ascontiguousarray(self.tanh_lut, "<i2").tobytes())
        out.append(np.ascontiguousarray(self.sig_lut_f32, "<f4").tobytes())
        out.append(np.ascontiguousarray(self.tanh_lut_f32, "<f4").tobytes())
        return b"".join(out)

    @classmethod
    def from_bytes(cls, blob: bytes) -> "DeployImage":
        magic, ver, bits, flags, d, H, C, rw, ru, ntens = _HEADER.unpack_from(blob, 0)
        if magic != MAGIC:
            raise ValueError(f"bad magic {magic!r}")
        if ver != IMAGE_VERSION:
            raise ValueError(f"unsupported image version {ver}")
        img = cls(version=ver, bits=bits, low_rank=bool(flags & 1),
                  d=d, H=H, C=C, rank_w=rw, rank_u=ru,
                  q={}, scales={}, b_z=None, b_h=None, head_b=None,
                  zeta_raw=0.0, nu_raw=0.0, act_scales={},
                  sig_lut=None, tanh_lut=None,
                  sig_lut_f32=None, tanh_lut_f32=None)
        order = img.tensor_order()
        if len(order) != ntens:
            raise ValueError(f"tensor count {ntens} != expected {len(order)}")
        off = _HEADER.size

        def take(dtype, n):
            nonlocal off
            a = np.frombuffer(blob, dtype, count=n, offset=off)
            off += a.nbytes
            return a

        for name in order:
            shape = img.tensor_shape(name)
            img.q[name] = take("<i2", int(np.prod(shape))).reshape(shape).copy()
        sc = take("<f4", len(order))
        img.scales = {n: float(s) for n, s in zip(order, sc)}
        img.b_z = take("<f4", H).astype(np.float32)
        img.b_h = take("<f4", H).astype(np.float32)
        img.head_b = take("<f4", C).astype(np.float32)
        zn = take("<f4", 2)
        img.zeta_raw, img.nu_raw = float(zn[0]), float(zn[1])
        ac = take("<f4", len(ACT_KEYS))
        img.act_scales = {k: float(v) for k, v in zip(ACT_KEYS, ac)}
        img.sig_lut = take("<i2", LUT_SIZE).copy()
        img.tanh_lut = take("<i2", LUT_SIZE).copy()
        img.sig_lut_f32 = take("<f4", LUT_SIZE).astype(np.float32)
        img.tanh_lut_f32 = take("<f4", LUT_SIZE).astype(np.float32)
        if off != len(blob):
            raise ValueError(f"trailing bytes: {len(blob) - off}")
        return img

    # -- memory accounting ----------------------------------------------
    def weight_bytes(self) -> int:
        """Q15 weight payload — the paper's '566-byte' figure analog."""
        return sum(int(np.prod(self.tensor_shape(n))) * 2
                   for n in self.tensor_order())

    def lut_bytes(self, engine: str = "both") -> int:
        """LUT flash: the float engine carries the paper's 2 KB f32 pair,
        the integer engine a 1 KB int16 pair; the image packs both."""
        f32, i16 = 2 * LUT_SIZE * 4, 2 * LUT_SIZE * 2
        return {"float": f32, "int": i16, "both": f32 + i16}[engine]

    def const_bytes(self) -> int:
        """Scales, biases, scalars, activation scales (f32 each)."""
        return 4 * (len(self.tensor_order()) + 2 * self.H + self.C + 2
                    + len(ACT_KEYS))

    def nbytes(self) -> int:
        return _HEADER.size + self.weight_bytes() + self.lut_bytes() \
            + self.const_bytes()

    def engine_flash_bytes(self, engine: str) -> int:
        """Flash footprint of ONE deployed engine's data (what a single
        target actually carries: weights + its LUT format + constants)."""
        return self.weight_bytes() + self.lut_bytes(engine) + self.const_bytes()

    def sram_needed(self, engine: str = "float") -> int:
        """Runtime working set.  ``float``: the paper's engine (~300 B of
        f32 h/pre/z/h~/logits + scratch).  ``int``: int16 state + int32
        fine intermediates — leaner despite the wider scratch."""
        r = max(self.rank_w, self.rank_u, 1)
        if engine == "float":
            f32s = 4 * self.H + self.C + max(r, self.d)  # h,pre,z,h~,logits,t
            return f32s * 4 + 48
        int16s = self.H + self.d                  # h, x
        int32s = self.H + r + self.C              # pre, t-scratch, logits
        return int16s * 2 + int32s * 4 + 64


def build_image(artifact) -> DeployImage:
    """Lower a calibrated model into the packed image form.

    ``artifact`` is a :class:`repro.compress.ModelArtifact` carrying
    quantized params + deploy calibration scales (a ``QuantizePTQ`` pass
    followed by ``CalibrateActivations(scope="deploy")``).  The legacy
    ``build_image(qp, act_scales)`` 2-argument form was a one-release
    deprecation shim and is gone; wrap the pair as
    ``ModelArtifact(qp=qp, act_scales=act_scales)`` instead.

    Q15 (bits=16) reproduces the historical image byte-for-byte.  Q7
    (bits=8) packs the int8-range weights into the same int16 cell layout
    with ``bits=8`` in the header, so the qvm / emitted C consume both
    widths through one quantization plan (scales absorb the width).
    """
    if isinstance(artifact, QuantizedParams):
        raise TypeError(
            "build_image(qp, act_scales) was removed; pass a "
            "repro.compress.ModelArtifact (QuantizePTQ -> "
            "CalibrateActivations(scope='deploy')), or wrap the pair as "
            "ModelArtifact(qp=qp, act_scales=act_scales)")
    if getattr(artifact, "qp", None) is None:
        raise ValueError("build_image needs a ModelArtifact with "
                         "quantized params (run QuantizePTQ first)")
    act_scales = artifact.act_scales
    qp = artifact.qp
    if qp.bits not in (16, 8):
        raise ValueError(f"export supports Q15 (bits=16) and Q7 (bits=8) "
                         f"weights, got bits={qp.bits}")
    low_rank = "W1" in qp.q
    need = {"x", "pre", "h", "logits"} | ({"wx1", "uh1"} if low_rank else set())
    missing = need - set(act_scales or {})
    if missing:
        raise ValueError(f"act_scales missing {sorted(missing)} — use "
                         "core.qruntime.calibrate_deploy (the "
                         "CalibrateActivations(scope='deploy') pass), "
                         "not calibrate")
    names = ("W1", "W2", "U1", "U2", "head_w") if low_rank else ("W", "U", "head_w")
    q = {n: np.asarray(qp.q[n], np.int16) for n in names}
    # round every scalar constant to f32 AT BUILD TIME: the serialized
    # image stores f32, and the quantization plan (requant multipliers)
    # must be identical whether derived from a live or a reloaded image
    f32 = lambda v: float(np.float32(v))
    scales = {n: f32(qp.scales[n]) for n in names}
    H = q["head_w"].shape[0]
    d = q["W2"].shape[0] if low_rank else q["W"].shape[1]
    C = q["head_w"].shape[1]
    return DeployImage(
        version=IMAGE_VERSION, bits=qp.bits, low_rank=low_rank,
        d=d, H=H, C=C,
        rank_w=q["W1"].shape[1] if low_rank else 0,
        rank_u=q["U1"].shape[1] if low_rank else 0,
        q=q, scales=scales,
        b_z=np.asarray(qp.fp["b_z"], np.float32),
        b_h=np.asarray(qp.fp["b_h"], np.float32),
        head_b=np.asarray(qp.fp["head_b"], np.float32),
        zeta_raw=f32(qp.fp["zeta"]), nu_raw=f32(qp.fp["nu"]),
        act_scales={k: f32(act_scales.get(k, 0.0)) for k in ACT_KEYS},
        sig_lut=make_lut_q15("sigmoid"), tanh_lut=make_lut_q15("tanh"),
        sig_lut_f32=make_lut("sigmoid"), tanh_lut_f32=make_lut("tanh"))


def export_model(artifact,
                 path: str | None = None) -> tuple[DeployImage, bytes]:
    """One-call export: build, serialize, optionally write ``path``.
    ``artifact`` is a calibrated :class:`repro.compress.ModelArtifact`."""
    img = build_image(artifact)
    blob = img.to_bytes()
    if path is not None:
        with open(path, "wb") as f:
            f.write(blob)
    return img, blob


def size_report(img: DeployImage) -> dict[str, Any]:
    return {
        "image_version": img.version,
        "bits": img.bits,
        "arch": {"d": img.d, "H": img.H, "C": img.C,
                 "rank_w": img.rank_w, "rank_u": img.rank_u,
                 "low_rank": img.low_rank},
        "header_bytes": _HEADER.size,
        "weight_bytes": img.weight_bytes(),
        "lut_bytes": {"float_engine": img.lut_bytes("float"),
                      "int_engine": img.lut_bytes("int")},
        "const_bytes": img.const_bytes(),
        "total_bytes": img.nbytes(),
        "engine_flash_bytes": {e: img.engine_flash_bytes(e)
                               for e in ("float", "int")},
        "sram_needed": {e: img.sram_needed(e) for e in ("float", "int")},
        "tensors": [{"name": n, "shape": img.tensor_shape(n),
                     "scale": img.scales[n]} for n in img.tensor_order()],
    }


def audit_platforms(img: DeployImage,
                    platforms: tuple[str, ...] = ("avr", "msp430"),
                    engine: str = "float") -> dict[str, Any]:
    """Assert one deployed engine's flash/SRAM needs fit every requested
    platform budget.  Defaults to the paper's float engine (the larger of
    the two working sets); the integer engine is strictly leaner on SRAM."""
    return {key: mcu.audit_budget(img.engine_flash_bytes(engine),
                                  img.sram_needed(engine), mcu.platform(key))
            for key in platforms}
