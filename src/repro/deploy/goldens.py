"""Golden-trace generation for the deployment parity protocol.

A *golden* pins down the full deployed behavior of one exported model on a
fixed batch of HAPT windows:

  * per-step int16 hidden-state trajectories for the first ``n_trace``
    windows (the cross-platform bit-equivalence witness — paper
    contribution (i)),
  * final int32 logits + argmax for every window,
  * the image byte digest, so a golden can only be replayed against the
    exact export that produced it.

Goldens are deterministic end to end: synthetic HAPT is crc32-seeded,
model init is a threefry PRNGKey, PTQ/calibration are round-to-nearest,
and the qvm is integer-only — two independent export runs must produce
byte-identical goldens (asserted in tests and gated in CI).

Checked-in fixtures live in ``tests/goldens/``; regenerate with::

    PYTHONPATH=src python -m repro.deploy.goldens --out tests/goldens
"""
from __future__ import annotations

import argparse
import hashlib
import os
from typing import Any

import numpy as np

from repro.compress import ModelArtifact, default_deploy_pipeline
from repro.core import fastgrnn as fg
from repro.core.quantization import QuantizedParams
from repro.data import hapt
from .image import DeployImage, build_image
from .qvm import QVM

# Fixture geometry: small enough to check in, big enough to exercise the
# recurrence (8 full 128-step trajectories + 256 window predictions).
N_TRACE = 8
N_WINDOWS = 256
CALIB_WINDOWS = 5


def build_reference_artifact(seed: int = 0, low_rank: bool = True,
                             params: dict | None = None,
                             calib: np.ndarray | None = None,
                             bits: int = 15) -> ModelArtifact:
    """Deterministic calibrated model -> compression artifact.

    By default: the paper's low-rank H=16 r_w=2 r_u=8 FastGRNN at random
    init (threefry seed — bit-stable across platforms) through the
    ``default_deploy_pipeline`` (PTQ at ``bits`` -> Sec. III-D 5-window
    deploy calibration on synthetic HAPT train data -> LUT pack).  The
    Q15 artifact is bit-identical to the historical direct
    ``quantize_params`` + ``calibrate_deploy`` handoff.  Pass ``params``
    (e.g. trained weights) to export a real checkpoint; ``bits=7`` builds
    the Q7 artifact.
    """
    if params is None:
        cfg = fg.FastGRNNConfig(rank_w=2 if low_rank else None,
                                rank_u=8 if low_rank else None)
        params = fg.init_params(cfg, __import__("jax").random.PRNGKey(seed))
    if calib is None:
        calib = f"hapt:train:{CALIB_WINDOWS}"
    pipe = default_deploy_pipeline(bits=bits, calib=calib)
    return pipe.run(ModelArtifact.from_params(params))


def build_reference_model(seed: int = 0, low_rank: bool = True,
                          params: dict | None = None,
                          calib: np.ndarray | None = None,
                          ) -> tuple[QuantizedParams, dict[str, float], DeployImage]:
    """Legacy-shaped convenience: the reference artifact unpacked into the
    historical ``(qp, act_scales, image)`` triple (tests and benches that
    predate the artifact API)."""
    art = build_reference_artifact(seed=seed, low_rank=low_rank,
                                   params=params, calib=calib)
    return art.qp, dict(art.act_scales), build_image(art)


def generate_goldens(img: DeployImage, windows: np.ndarray,
                     n_trace: int = N_TRACE) -> dict[str, Any]:
    """Run the qvm over ``windows`` and freeze its observable behavior."""
    vm = QVM(img)
    xq = vm.quantize_input(windows)
    logits, traces = vm.run_windows(xq[:n_trace], return_trajectory=True)
    all_logits = vm.run_windows(xq)
    blob = img.to_bytes()
    return {
        "image_sha256": hashlib.sha256(blob).hexdigest(),
        "image_bytes": np.frombuffer(blob, np.uint8),
        "xq": xq,
        "traces": traces,                       # (n_trace, T, H) int16
        "trace_logits": logits,                 # (n_trace, C) int32
        "logits": all_logits,                   # (N, C) int32
        "preds": np.argmax(all_logits, axis=1).astype(np.int32),
    }


def save_goldens(goldens: dict[str, Any], path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez_compressed(path, **goldens)


def load_goldens(path: str) -> dict[str, Any]:
    with np.load(path, allow_pickle=False) as z:
        return {k: (z[k] if z[k].ndim else z[k].item()) for k in z.files}


def default_fixture(seed: int = 0) -> dict[str, Any]:
    """The checked-in fixture: reference model + deterministic test windows."""
    _, _, img = build_reference_model(seed=seed)
    windows = hapt.load("test", n=N_WINDOWS).windows
    return generate_goldens(img, windows)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="tests/goldens",
                    help="directory for the .npz fixtures")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="verify existing fixtures instead of writing")
    args = ap.parse_args()
    g = default_fixture(seed=args.seed)
    path = os.path.join(args.out, f"qvm_reference_s{args.seed}.npz")
    if args.check:
        old = load_goldens(path)
        for k in ("image_bytes", "xq", "traces", "trace_logits", "logits", "preds"):
            np.testing.assert_array_equal(old[k], g[k], err_msg=k)
        assert old["image_sha256"] == g["image_sha256"]
        print(f"OK: {path} reproduces bit-for-bit")
    else:
        save_goldens(g, path)
        print(f"wrote {path} (image sha256 {g['image_sha256'][:16]}..., "
              f"{g['preds'].shape[0]} windows, {g['traces'].shape[0]} traces)")


if __name__ == "__main__":
    main()
