"""Pure-integer Q15 FastGRNN virtual machine (paper Sec. V-G).

Executes a packed :class:`~repro.deploy.image.DeployImage` with **zero
float operations in the hot loop** — the repo's stand-in for the
multiplier-less MSP430 path, and the bit-exact twin of the generated
integer C translation unit (``emit_c`` with ``engine="int"``): every
operation below is specified at the bit level and the C engine implements
the identical sequence, so per-step hidden-state traces are byte-identical
between the two.

Numeric conventions (mirrored one-for-one in the C):

  * persistent state (h) and all tensors are int16; saturation (not
    wraparound) at [-32768, 32767] wherever a value is stored to int16;
  * transient within-step intermediates (pre-activations, low-rank
    intermediates) are int32 at *fine* scales — 8 extra fractional bits
    below their calibrated Q15 scale (``FINE_SHIFT``) — the TFLite int16
    convention of int32 intermediate precision.  This keeps the engine's
    rounding noise well under the float reference's LUT bucket width,
    which is what makes the paper's 100%-agreement protocol reachable;
  * matvec accumulators are 64-bit, the CMSIS-NN q15 convention
    (``arm_fully_connected_q15`` accumulates in ``q63_t``): two int16
    operands already produce 2^30-scale products, so 16-term rows
    overflow int32 in the worst case;
  * rescaling between fixed-point formats is ``requant``:
    ``(acc * M + (1 << (SH-1))) >> SH`` with a precomputed integer
    multiplier ``M in [2^24, 2^25)`` — round-half-up, arithmetic shift
    (the TFLite/gemmlowp scheme, normalized mantissa form);
  * activations go through the 256-entry int16 Q15 LUTs; the bucket index
    is one integer multiply+shift (floor — no libm, no float compare);
  * the gate combine is evaluated at product scale and rounded **once**
    into the stored int16 h — matching the float engine's single
    store-rounding, which keeps the two paths' trajectories locked to the
    same Q15 grid outside genuine rounding-boundary ties;
  * z, h~, zeta, nu live at the *unit* Q15 scale (value = q/32767 — they
    are bounded by 1); x and h live at the calibrated scales packed in
    the image.

The only float touchpoint is :meth:`QVM.quantize_input`, the sensor
boundary (the MCU's ADC equivalent) — it runs once per sample *outside*
the recurrence and is excluded from the zero-float contract, which
``tests/test_deploy.py`` enforces by checking dtypes through the hot path.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import numpy as np

from repro.core.lut import LUT_SIZE, INPUT_MIN, INPUT_MAX
from .image import DeployImage

I16_MIN, I16_MAX = -32768, 32767
Q15_ONE = 32767
# Extra fractional bits of the int32 within-step intermediates below their
# calibrated Q15 scale: value = q * (s / 2^FINE_SHIFT), |q| <~ 2^23.
FINE_SHIFT = 8
# Fine int32 intermediates saturate at ±2^29 so that sums of two matvec
# outputs plus a bias always stay inside int32 in the C engine, even for
# pathological inputs 2^6 beyond the calibrated range (the LUT saturates
# far earlier, so the clip is semantically inert on real data).
FINE_CLIP = (1 << 29) - 1
# LUT index = (v * M + (idx0 << SH)) >> SH with v at the fine pre scale;
# idx0 = -INPUT_MIN / bucket_width = 128 for the [-8, 8) x 256 domain.
_LUT_IDX0 = int(-INPUT_MIN * LUT_SIZE / (INPUT_MAX - INPUT_MIN))
_LUT_GAIN = LUT_SIZE / (INPUT_MAX - INPUT_MIN)     # buckets per unit (16.0)


@dataclasses.dataclass(frozen=True)
class Requant:
    """Integer rescale ``s_in -> s_out``: floor-preshift by ``pre`` (folds
    into the factor), multiply by ``m``, round-shift by ``sh``."""
    m: int
    sh: int
    pre: int = 0

    def raw(self, acc: np.ndarray) -> np.ndarray:
        """The rescaled int64 value BEFORE the int32 saturation — the
        monitored path reads this to count ``*.out`` saturation events
        without changing the applied result."""
        acc = np.asarray(acc).astype(np.int64) >> self.pre
        return (acc * self.m + (1 << (self.sh - 1))) >> self.sh

    def apply(self, acc: np.ndarray) -> np.ndarray:
        """((acc >> pre) * m + half) >> sh on int64, round-half-up,
        arithmetic shifts (numpy and C agree on negative operands).
        The result saturates to int32 range — the C twin returns int32_t,
        and without the clip a pathological gate product (tiny calibrated
        h scale + saturating inputs) would wrap there but not here,
        breaking the bit-exact C/qvm contract."""
        return np.clip(self.raw(acc), -(1 << 31), (1 << 31) - 1)


def quantize_multiplier(factor: float, acc_bits: int = 37) -> Requant:
    """Normalized-mantissa fixed-point representation of a positive real
    rescale factor: ``factor ~= m * 2^(pre - sh)`` with ``m in [2^24, 2^25)``.

    25-bit mantissas keep the worst relative representation error below
    2^-24.  ``acc_bits`` is the caller's bound on the accumulator
    magnitude (bits); when it exceeds 37 the accumulator is floor-shifted
    right first so the ``m`` product can never overflow int64
    (2^37 * 2^25 < 2^63).  The preshift's floor loss is ~2^-37 relative —
    far below the mantissa error."""
    if not (factor > 0.0 and math.isfinite(factor)):
        raise ValueError(f"requant factor must be positive finite: {factor}")
    pre = max(0, acc_bits - 37)
    factor = factor * (1 << pre)            # folded into the mantissa
    mant, exp = math.frexp(factor)          # factor = mant * 2^exp, mant in [0.5,1)
    m = round(mant * (1 << 25))             # in [2^24, 2^25]
    sh = 25 - exp
    if m == (1 << 25):                      # rounding pushed mantissa to 1.0
        m >>= 1
        sh -= 1
    if sh < 1:
        raise ValueError(f"requant factor too large: {factor}")
    if sh > 62:                             # factor ~ 0: underflow to zero
        m, sh = 0, 62
    return Requant(m=m, sh=sh, pre=pre)


def sat16(v: np.ndarray) -> np.ndarray:
    """Saturate int values to int16 range (the paper's clip(round(.)))."""
    return np.clip(v, I16_MIN, I16_MAX)


@dataclasses.dataclass(frozen=True)
class QuantPlan:
    """Every integer constant the step loop needs, derived deterministically
    from the image.  ``emit_c`` bakes this same plan into the C header, so
    the emulator and the compiled C share one source of truth."""
    low_rank: bool
    d: int
    H: int
    C: int
    rank_w: int
    rank_u: int
    # weights as int64 numpy (exact integer matmuls)
    w: dict[str, np.ndarray]
    # matvec requants into fine int32 scales (names match the C macros)
    rq: dict[str, Requant]
    # biases at the fine pre scale, int32 range
    bz_q: np.ndarray
    bh_q: np.ndarray
    # gate constants: g2 = zeta_q*(Q15-z) + nu2_q at unit^2 scale
    zeta_q: int
    nu2_q: int
    rq_gate: Requant        # unit^2 / s_h: g2*h~ product -> F = s_h/Q15
    rq_hstore: Requant      # F -> s_h (the single h store-rounding, 1/Q15)
    # LUT index mapping for fine-pre-scale inputs
    lut_m: int
    lut_sh: int
    sig_lut: np.ndarray     # int64 view for exact gathers
    tanh_lut: np.ndarray
    # head
    headb_q: np.ndarray     # int32, at scale s_headw * s_h * 2^logit_sh
    logit_sh: int
    # boundary scales (float, used OUTSIDE the hot loop only)
    s_x: float
    s_h: float
    s_logits_q: float       # scale of the int32 logits the vm emits


def plan_from_image(img: DeployImage) -> QuantPlan:
    a = img.act_scales
    s_x, s_h = a["x"], a["h"]
    fine = 1 << FINE_SHIFT
    s_pref = a["pre"] / fine                # fine pre scale (int32 domain)
    w = {n: np.asarray(img.q[n], np.int64) for n in img.tensor_order()}
    # accumulator magnitude bounds (bits) for int64-overflow-safe requants:
    # int16*int16 products are 2^30; second-stage products are
    # 2^15 * FINE_CLIP = 2^44; each sum adds log2(n_terms).
    bits30 = lambda n: 30 + max(1, n).bit_length()
    bits44 = lambda n: 45 + max(1, n).bit_length()
    rq: dict[str, Requant] = {}
    if img.low_rank:
        s_t1 = a["wx1"] / fine              # fine low-rank intermediates
        s_t2 = a["uh1"] / fine
        rq["w2"] = quantize_multiplier(img.scales["W2"] * s_x / s_t1,
                                       bits30(img.d))
        rq["w1"] = quantize_multiplier(img.scales["W1"] * s_t1 / s_pref,
                                       bits44(img.rank_w))
        rq["u2"] = quantize_multiplier(img.scales["U2"] * s_h / s_t2,
                                       bits30(img.H))
        rq["u1"] = quantize_multiplier(img.scales["U1"] * s_t2 / s_pref,
                                       bits44(img.rank_u))
    else:
        rq["w"] = quantize_multiplier(img.scales["W"] * s_x / s_pref,
                                      bits30(img.d))
        rq["u"] = quantize_multiplier(img.scales["U"] * s_h / s_pref,
                                      bits30(img.H))
    bz_q = np.round(np.asarray(img.b_z, np.float64) / s_pref).astype(np.int64)
    bh_q = np.round(np.asarray(img.b_h, np.float64) / s_pref).astype(np.int64)
    zeta = 1.0 / (1.0 + math.exp(-img.zeta_raw))
    nu = 1.0 / (1.0 + math.exp(-img.nu_raw))
    # LUT index: float semantics are idx = clip(int((v_real + 8) * 16));
    # v_real = v_q * s_pref.  Floor, no rounding half — mirrors the float
    # engine's astype(int32) truncation.
    rq_lut = quantize_multiplier(s_pref * _LUT_GAIN, 31)   # |v| <= 2^31
    # head: logits = (acc >> logit_sh) + headb_q, argmax-invariant common
    # shift sized so the worst-case |acc| lands in int32.
    s_headw = img.scales["head_w"]
    acc_max = img.H * (Q15_ONE ** 2)
    logit_sh = max(0, int(acc_max).bit_length() - 30)
    headb_q = np.round(np.asarray(img.head_b, np.float64)
                       / (s_headw * s_h * (1 << logit_sh))).astype(np.int64)
    if np.any(np.abs(headb_q) > (1 << 31) - 1):
        raise ValueError("head bias overflows the shifted logit scale")
    unit = 1.0 / Q15_ONE
    return QuantPlan(
        low_rank=img.low_rank, d=img.d, H=img.H, C=img.C,
        rank_w=img.rank_w, rank_u=img.rank_u, w=w, rq=rq,
        bz_q=bz_q, bh_q=bh_q,
        zeta_q=round(zeta * Q15_ONE),
        nu2_q=round(nu * Q15_ONE * Q15_ONE),
        # gate product g2*h~ is bounded by 2^31 * 2^15 = 2^46; the
        # F-scale sum hf is clipped to ±2^31 before the store requant.
        rq_gate=quantize_multiplier(unit * unit / s_h, 47),
        rq_hstore=quantize_multiplier(unit, 32),
        lut_m=rq_lut.m, lut_sh=rq_lut.sh,
        sig_lut=np.asarray(img.sig_lut, np.int64),
        tanh_lut=np.asarray(img.tanh_lut, np.int64),
        headb_q=headb_q, logit_sh=logit_sh,
        s_x=float(s_x), s_h=float(s_h),
        s_logits_q=float(s_headw * s_h * (1 << logit_sh)))


def _count_outside(v: np.ndarray, lo: int, hi: int) -> int:
    """Number of elements strictly outside [lo, hi] — the shared counting
    semantic of every saturation site (qvm, C and kernel monitors must
    agree on this definition for the parity gates to hold)."""
    return int(np.count_nonzero((v < lo) | (v > hi)))


class QVM:
    """Batched pure-integer executor.  State is (B, H) int16; every public
    method except :meth:`quantize_input` is integer-only.

    ``monitor``: optional :class:`repro.obs.numerics.NumericsMonitor` —
    counts saturation/clamp events per analyzer site ID and observes
    pre-activation / hidden ranges at their real (dequantized) scales.
    The monitored path only *reads* intermediates; outputs are
    byte-identical with and without a monitor (test-gated)."""

    def __init__(self, img: DeployImage, monitor=None):
        self.img = img
        self.plan = plan_from_image(img)
        self.monitor = monitor
        if monitor is not None:
            from repro.obs.numerics import site_order
            monitor.declare(site_order(self.plan.low_rank))
            monitor.set_default_limits({
                "x": self.plan.s_x * Q15_ONE,
                "pre": float(img.act_scales["pre"]) * Q15_ONE,
                "h": self.plan.s_h * Q15_ONE,
            })

    # -- boundary (the ADC): float -> Q15, OUTSIDE the hot loop ----------
    def quantize_input(self, x: np.ndarray) -> np.ndarray:
        """(..., d) float samples -> int16 at the calibrated input scale."""
        x = np.asarray(x, np.float64)
        if self.monitor is not None:
            self.monitor.observe("x", x)
        q = np.round(x / self.plan.s_x)
        return sat16(q).astype(np.int16)

    def dequantize_input(self, xq: np.ndarray) -> np.ndarray:
        """The float engines' view of the same recorded sensor samples."""
        return (np.asarray(xq, np.float32)
                * np.float32(self.plan.s_x)).astype(np.float32)

    def init_state(self, batch: int) -> np.ndarray:
        return np.zeros((batch, self.plan.H), np.int16)

    # -- integer hot loop -------------------------------------------------
    def _matvec(self, name: str, wq: np.ndarray, vq: np.ndarray) -> np.ndarray:
        """(B, n) int @ (m, n)^T -> requant -> (B, m) int64 fine scale
        (exact: integer addition is associative, so numpy's sum order is
        irrelevant)."""
        acc = vq.astype(np.int64) @ wq.T        # (B, m)
        rq = self.plan.rq[name]
        if self.monitor is None:
            return np.clip(rq.apply(acc), -FINE_CLIP - 1, FINE_CLIP)
        raw = rq.raw(acc)
        self.monitor.count(f"{name}.out",
                           _count_outside(raw, -(1 << 31), (1 << 31) - 1))
        v32 = np.clip(raw, -(1 << 31), (1 << 31) - 1)
        self.monitor.count(f"{name}.fine",
                           _count_outside(v32, -FINE_CLIP - 1, FINE_CLIP))
        return np.clip(v32, -FINE_CLIP - 1, FINE_CLIP)

    def _lut(self, table: np.ndarray, vq: np.ndarray,
             site: str | None = None) -> np.ndarray:
        """Nearest-bucket lookup from a fine-pre-scale int value: one
        integer multiply+shift, then clip to the table (saturating the ±8
        tails — identical to the float engine's boundary handling)."""
        p = self.plan
        idx = (vq.astype(np.int64) * p.lut_m
               + (_LUT_IDX0 << p.lut_sh)) >> p.lut_sh
        if self.monitor is not None and site is not None:
            self.monitor.count(site, _count_outside(idx, 0, LUT_SIZE - 1))
        return table[np.clip(idx, 0, LUT_SIZE - 1)]

    def step(self, hq: np.ndarray, xq: np.ndarray) -> np.ndarray:
        """One integer FastGRNN step.  hq: (B, H) int16 at s_h; xq: (B, d)
        int16 at s_x -> new (B, H) int16 at s_h."""
        p = self.plan
        hq64 = hq.astype(np.int64)
        if p.low_rank:
            t1 = self._matvec("w2", p.w["W2"].T, xq)          # (B,rw) fine
            wx = self._matvec("w1", p.w["W1"], t1)            # (B,H) fine pre
            t2 = self._matvec("u2", p.w["U2"].T, hq64)        # (B,ru) fine
            uh = self._matvec("u1", p.w["U1"], t2)            # (B,H) fine pre
        else:
            wx = self._matvec("w", p.w["W"], xq)
            uh = self._matvec("u", p.w["U"], hq64)
        pre = wx + uh                                         # int32, fine
        zq = self._lut(p.sig_lut, pre + p.bz_q, "act.z.idx")  # (B,H) unit Q15
        htq = self._lut(p.tanh_lut, pre + p.bh_q, "act.ht.idx")
        # gate combine at product scale, ONE store-rounding into int16 h:
        #   h' = (zeta*(1-z) + nu) * h~ + z*h
        g2 = p.zeta_q * (Q15_ONE - zq) + p.nu2_q              # unit^2
        gate_acc = g2 * htq
        if self.monitor is None:
            a_f = p.rq_gate.apply(gate_acc)                   # F = s_h/Q15
        else:
            raw = p.rq_gate.raw(gate_acc)
            self.monitor.count(
                "gate.out", _count_outside(raw, -(1 << 31), (1 << 31) - 1))
            a_f = np.clip(raw, -(1 << 31), (1 << 31) - 1)
        h_f = a_f + zq * hq64                                 # F (z*h exact)
        # clip at ±2^31: beyond the int16 saturation threshold in F units
        # (2^30), so semantically inert — it only bounds the requant input
        if self.monitor is not None:
            self.monitor.count(
                "gate.hf_clip",
                _count_outside(h_f, -(1 << 31), (1 << 31) - 1))
        h_f = np.clip(h_f, -(1 << 31), (1 << 31) - 1)
        if self.monitor is None:
            h_new = sat16(p.rq_hstore.apply(h_f))             # s_h, int16
        else:
            raw = p.rq_hstore.raw(h_f)
            self.monitor.count(
                "hstore.out", _count_outside(raw, -(1 << 31), (1 << 31) - 1))
            v32 = np.clip(raw, -(1 << 31), (1 << 31) - 1)
            self.monitor.count("h_next", _count_outside(v32, I16_MIN, I16_MAX))
            h_new = sat16(v32)
            # activation-range telemetry at real (dequantized) scales
            s_pref = self.img.act_scales["pre"] / (1 << FINE_SHIFT)
            pre_real = pre.astype(np.float64) * s_pref
            self.monitor.observe("pre", pre_real)
            self.monitor.observe("h", h_new.astype(np.float64) * p.s_h)
        return h_new.astype(np.int16)

    def logits(self, hq: np.ndarray) -> np.ndarray:
        """(B, H) int16 -> (B, C) int32 logits at ``plan.s_logits_q``."""
        p = self.plan
        acc = hq.astype(np.int64) @ p.w["head_w"]             # (B, C)
        out = (acc >> p.logit_sh) + p.headb_q
        if self.monitor is not None:
            self.monitor.count(
                "head.logits", _count_outside(out, -(1 << 31), (1 << 31) - 1))
        return out.astype(np.int32)

    # -- window/batch drivers ---------------------------------------------
    def run_window(self, xq: np.ndarray, return_trajectory: bool = False):
        """xq: (T, d) int16 -> (C,) int32 logits [+ (T, H) int16 trace]."""
        lg, traj = self.run_windows(xq[None], return_trajectory=True)
        return (lg[0], traj[0]) if return_trajectory else lg[0]

    def run_windows(self, xq: np.ndarray, return_trajectory: bool = False):
        """xq: (B, T, d) int16 -> (B, C) int32 [+ (B, T, H) int16 traces].
        Steps all windows in lockstep — the vectorized image of the scalar
        MCU loop (identical per-row integer ops)."""
        B, T, _ = xq.shape
        hq = self.init_state(B)
        traj = (np.zeros((B, T, self.plan.H), np.int16)
                if return_trajectory else None)
        for t in range(T):
            hq = self.step(hq, xq[:, t])
            if return_trajectory:
                traj[:, t] = hq
        lg = self.logits(hq)
        return (lg, traj) if return_trajectory else lg

    def predict_batch(self, windows: np.ndarray) -> np.ndarray:
        """(B, T, d) float windows -> (B,) argmax predictions (input
        quantization at the boundary, then integer-only)."""
        xq = self.quantize_input(windows)
        return np.argmax(self.run_windows(xq), axis=1).astype(np.int32)

    def stats(self) -> dict[str, Any]:
        p = self.plan
        return {"low_rank": p.low_rank, "d": p.d, "H": p.H, "C": p.C,
                "rank_w": p.rank_w, "rank_u": p.rank_u,
                "fine_shift": FINE_SHIFT, "logit_shift": p.logit_sh,
                "requants": {k: (v.m, v.sh) for k, v in p.rq.items()}}
