"""Straggler detection and mitigation hooks.

In a synchronous SPMD job a single slow host gates every step.  The
monitor tracks per-step wall time as an EWMA + variance; a step slower
than ``ewma + k * sigma`` (and over an absolute floor) is flagged.
Mitigations wired into the trainer:

  * ``on_straggler`` callback — production deployments map this to host
    cordoning / pod eviction / re-slicing;
  * deadline-based step skip: if a step exceeds ``hard_deadline_s`` the
    trainer treats it as a fault -> checkpoint-restart path (the same
    machinery that covers node failure, so one tested path covers both);
  * the data pipeline is stateless/seekable (data/tokens.py), so a
    restarted or re-sliced job resumes from (step, shard) with no replay.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.1          # EWMA coefficient
    k_sigma: float = 4.0        # flag threshold in std devs
    min_samples: int = 8
    abs_floor_s: float = 0.05   # ignore jitter below this
    hard_deadline_factor: float = 10.0

    _ewma: float = 0.0
    _var: float = 0.0
    _n: int = 0
    flagged: int = 0

    def observe(self, dt_s: float) -> dict:
        """Record one step time.  Returns {straggler, hard_fault, ewma}."""
        self._n += 1
        if self._n == 1:
            self._ewma, self._var = dt_s, 0.0
            return {"straggler": False, "hard_fault": False, "ewma": dt_s}
        # judge against PRE-update stats — otherwise an outlier inflates
        # its own threshold and never gets flagged
        sigma = self._var ** 0.5
        slow = (self._n > self.min_samples
                and dt_s > self._ewma + self.k_sigma * sigma
                and dt_s > self._ewma + self.abs_floor_s)
        hard = (self._n > self.min_samples
                and dt_s > self.hard_deadline_factor * max(self._ewma, 1e-6))
        delta = dt_s - self._ewma
        self._ewma += self.alpha * delta
        self._var = (1 - self.alpha) * (self._var + self.alpha * delta * delta)
        if slow:
            self.flagged += 1
        return {"straggler": slow, "hard_fault": hard, "ewma": self._ewma}
