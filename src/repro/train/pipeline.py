"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

At the assigned 512-chip scale, FSDP x TP is the better fit (DESIGN.md
Sec. 5); this module exists for the beyond-512 growth path and is tested
on a small host mesh.  Schedule: forward microbatch pipeline with
``collective_permute`` hops between stages; jax AD transposes the permute
for the backward, giving the classic GPipe fwd-then-bwd schedule with
bubble fraction (P-1)/(M+P-1).

Layout: every stage runs the SAME callable over its own layer slice
(stacked stage-major params).  Inputs are microbatched (M, b, ...);
stage s works on microbatch (t - s) at tick t — implemented with a
rolled loop of M + P - 1 ticks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_fn, stage_params, x_micro, *, mesh, axis: str = "stage"):
    """stage_fn(params_slice, x) -> y, applied by each of P stages in turn.

    stage_params: pytree with leading dim P (stage-major layer slices),
    sharded P(axis) on that dim.  x_micro: (M, b, ...) microbatches.
    Returns (M, b, ...) outputs having passed through all P stages.
    """
    n_stage = int(mesh.shape[axis])
    m = x_micro.shape[0]
    ticks = m + n_stage - 1
    perm = [(i, i + 1) for i in range(n_stage - 1)]

    def local(params_loc, xm):
        # params_loc: stage slice (leading dim 1); xm: (M, b, ...) full copy
        params_loc = jax.tree.map(lambda a: a[0], params_loc)
        sid = jax.lax.axis_index(axis)
        b_shape = xm.shape[1:]
        carry = jnp.zeros(b_shape, xm.dtype)     # current in-flight microbatch
        outs = jnp.zeros_like(xm)

        def tick(t, state):
            carry, outs = state
            # stage 0 ingests microbatch t (if valid); others take the wire
            mb_idx = jnp.clip(t, 0, m - 1)
            fresh = jax.lax.dynamic_index_in_dim(xm, mb_idx, 0, keepdims=False)
            inp = jnp.where(sid == 0, fresh, carry)
            out = stage_fn(params_loc, inp)
            # ship to next stage
            shipped = jax.lax.ppermute(out, axis, perm)
            # last stage records its finished microbatch (t - P + 1)
            done_idx = jnp.clip(t - n_stage + 1, 0, m - 1)
            valid = (t - n_stage + 1 >= 0) & (sid == n_stage - 1)
            cur = jax.lax.dynamic_index_in_dim(outs, done_idx, 0, keepdims=False)
            upd = jnp.where(valid, out, cur)
            outs = jax.lax.dynamic_update_index_in_dim(outs, upd, done_idx, 0)
            return shipped, outs

        carry, outs = jax.lax.fori_loop(0, ticks, tick, (carry, outs))
        # broadcast results from the last stage to all (psum of masked)
        outs = jnp.where(sid == n_stage - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    fn = jax.shard_map(local, mesh=mesh,
                       in_specs=(P(axis), P()), out_specs=P(),
                       check_vma=False)
    return fn(stage_params, x_micro)


def bubble_fraction(n_stage: int, n_micro: int) -> float:
    return (n_stage - 1) / (n_micro + n_stage - 1)
