"""Gradient compression for cross-pod all-reduce, with error feedback.

The pod axis crosses DCN (slow links), so gradient all-reduce bytes are the
multi-pod bottleneck.  Two compressors:

  * ``bf16``: cast -> psum -> cast back (2x fewer bytes, no state);
  * ``int8``: per-leaf symmetric quantization with a globally agreed scale
    (one tiny psum of per-leaf maxima), int32-accumulated psum (exact), and
    ERROR FEEDBACK: the quantization residual is carried into the next
    step's gradient, so the compression bias vanishes over time
    (Karimireddy et al.-style EF-SGD; here EF-Adam).

Intended use: inside shard_map over the reduction axes, on per-shard
gradients, e.g.:

    def sharded_grads(params, batch):
        g = jax.grad(loss)(params, batch)          # per-shard gradient
        g, err = compressed_psum_tree(g, ("pod",), bits=8, error=err)
        ...

The q15_matmul kernel is the serving-side sibling of this trick (the
paper's Q15 insight applied to comm instead of weights).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _psum(x, axes):
    for ax in (axes if isinstance(axes, (tuple, list)) else (axes,)):
        x = jax.lax.psum(x, ax)
    return x


def _axis_size(axes):
    n = 1
    for ax in (axes if isinstance(axes, (tuple, list)) else (axes,)):
        n *= jax.lax.axis_size(ax)
    return n


def compressed_psum(g, axes, *, bits: int = 8, error=None):
    """All-reduce one gradient leaf in low precision.  Returns the MEAN
    over the axes and the new error-feedback residual."""
    gf = g.astype(jnp.float32)
    if error is not None:
        gf = gf + error
    n = _axis_size(axes)
    if bits == 16:
        red = _psum(gf.astype(jnp.bfloat16), axes).astype(jnp.float32) / n
        new_err = gf - _round_bf16(gf)   # local rounding residual (EF)
        return red, new_err
    qmax = (1 << (bits - 1)) - 1
    # agree on a global per-leaf scale (tiny collective).  MAX over shards,
    # not mean — a mean-of-maxima scale clips outlier shards and the error
    # bound no longer holds.
    amax = jnp.max(jnp.abs(gf))
    for ax in (axes if isinstance(axes, (tuple, list)) else (axes,)):
        amax = jax.lax.pmax(amax, ax)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(gf / scale), -qmax - 1, qmax).astype(jnp.int32)
    total = _psum(q, axes)
    red = total.astype(jnp.float32) * scale / n
    new_err = gf - q.astype(jnp.float32) * scale   # local residual
    return red, new_err


def _round_bf16(x):
    return x.astype(jnp.bfloat16).astype(jnp.float32)


def compressed_psum_tree(grads, axes, *, bits: int = 8, error=None):
    """Tree version.  ``error`` is a matching pytree (or None -> zeros)."""
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [compressed_psum(g, axes, bits=bits, error=e)
           for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def compression_ratio(bits: int) -> float:
    return 32.0 / bits
