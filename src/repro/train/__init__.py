from . import optimizer, grad_compression, checkpoint, straggler  # noqa: F401
