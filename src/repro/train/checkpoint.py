"""Checkpointing with elastic resharding — the fault-tolerance substrate.

Format: one directory per step containing
  * ``manifest.json`` — step, leaf paths, shapes, dtypes, user metadata;
  * ``arrays.npz``    — full LOGICAL arrays (gathered), keyed by leaf path.

Writing full logical arrays makes restore mesh-agnostic: a checkpoint
saved from a 2x16x16 mesh restores onto 16x16 (pod lost), 4x16x16 (pods
added), or a single CPU device — ``restore(..., shardings=...)`` simply
``device_put``s each leaf with the new sharding.  That is the elastic-
scaling story: resize at checkpoint boundaries (Sec. 5 of DESIGN.md).
At true multi-host scale the same layout is written per-host with a
host-0 gather barrier; this single-process harness exercises the
resharding logic, which is the part that breaks in practice.

Durability: write to ``<dir>.tmp`` then atomic rename; ``keep_last`` old
steps are garbage-collected only after a successful rename.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(directory: str, step: int, tree, metadata: dict | None = None,
         keep_last: int = 3) -> str:
    """Atomically persist ``tree`` at ``directory/step_<n>``."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **leaves)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in leaves.items()},
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep_last)
    return final


def _gc(directory: str, keep_last: int):
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, d))


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; if ``shardings`` (a
    matching pytree of jax.sharding.Sharding) is given, each leaf is placed
    with that sharding — this is the elastic-resharding path."""
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        data = {k: z[k] for k in z.files}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    flat_sh = (jax.tree_util.tree_flatten_with_path(shardings)[0]
               if shardings is not None else None)
    leaves = []
    for i, (p, leaf) in enumerate(flat):
        key = jax.tree_util.keystr(p)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"checkpoint leaf {key} shape {arr.shape} != "
                             f"expected {leaf.shape}")
        if flat_sh is not None:
            leaves.append(jax.device_put(arr, flat_sh[i][1]))
        else:
            leaves.append(jax.device_put(arr.astype(leaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def read_metadata(directory: str, step: int) -> dict:
    path = os.path.join(directory, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        return json.load(f)["metadata"]
