"""Fault-tolerant training loop.

Production posture (DESIGN.md Sec. 5):
  * deterministic, seekable data (repro/data/tokens.py) — restart resumes
    at (step, shard) with zero replay;
  * checkpoint every N steps (atomic, mesh-agnostic — see checkpoint.py);
  * crash / hard-straggler handling: the step loop runs under a retry
    guard; on failure the trainer restores the last checkpoint and
    continues (``max_restarts`` bounds runaway loops);
  * straggler EWMA monitor with an ``on_straggler`` callback;
  * optional IHT sparsification (the paper's S stage) and low-bit gradient
    all-reduce (grad_compression.py) wired in as config flags.

This same Trainer drives the MCU-scale FastGRNN example and the LM-scale
demo; tests inject faults to exercise restart/resume.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable

import jax
import numpy as np

from . import checkpoint as ckpt
from . import optimizer as opt_mod
from .straggler import StragglerMonitor


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    max_restarts: int = 3
    log_every: int = 10
    iht_sparsity: float = 0.0        # paper stage S at LM scale
    iht_ramp_steps: int = 0
    adam: opt_mod.AdamConfig = dataclasses.field(default_factory=opt_mod.AdamConfig)


class Trainer:
    def __init__(self, cfg: TrainerConfig, *, init_params_fn: Callable,
                 step_fn: Callable, batch_fn: Callable[[int], Any],
                 on_straggler: Callable | None = None,
                 fault_hook: Callable[[int], None] | None = None):
        """step_fn(params, opt_state, batch) -> (params, opt_state, metrics).
        batch_fn(step) -> batch (deterministic!).  fault_hook is a test
        seam: raise inside to simulate a node failure at a given step."""
        self.cfg = cfg
        self.init_params_fn = init_params_fn
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.on_straggler = on_straggler
        self.fault_hook = fault_hook
        self.monitor = StragglerMonitor()
        self.restarts = 0
        self.history: list[dict] = []

    # -- state ------------------------------------------------------------
    def _fresh_state(self):
        params = self.init_params_fn()
        opt_state = opt_mod.init(params, self.cfg.adam)
        return {"params": params, "opt": opt_state}

    def _restore_or_init(self):
        last = ckpt.latest_step(self.cfg.checkpoint_dir)
        state = self._fresh_state()
        if last is None:
            return state, 0
        state = ckpt.restore(self.cfg.checkpoint_dir, last, state)
        return state, int(ckpt.read_metadata(self.cfg.checkpoint_dir, last)
                          .get("next_step", last))

    def _save(self, state, step: int):
        ckpt.save(self.cfg.checkpoint_dir, step, state,
                  metadata={"next_step": step}, keep_last=self.cfg.keep_last)

    # -- loop ---------------------------------------------------------------
    def run(self) -> list[dict]:
        os.makedirs(self.cfg.checkpoint_dir, exist_ok=True)
        while True:
            try:
                state, start = self._restore_or_init()
                self._run_from(state, start)
                return self.history
            except KeyboardInterrupt:
                raise
            except Exception as e:  # node failure / hard straggler path
                self.restarts += 1
                self.history.append({"event": "restart", "error": str(e),
                                     "restarts": self.restarts})
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.cfg.max_restarts}") from e

    def _run_from(self, state, start: int):
        for step in range(start, self.cfg.total_steps):
            if self.fault_hook is not None:
                self.fault_hook(step)
            batch = self.batch_fn(step)
            t0 = time.time()
            params, opt, metrics = self.step_fn(state["params"], state["opt"], batch)
            jax.block_until_ready(params)
            dt = time.time() - t0
            state = {"params": params, "opt": opt}
            verdict = self.monitor.observe(dt)
            if verdict["straggler"] and self.on_straggler:
                self.on_straggler(step, dt, verdict)
            rec = {"step": step, "time_s": dt,
                   **{k: float(np.asarray(v)) for k, v in metrics.items()}}
            self.history.append(rec)
            if (step + 1) % self.cfg.checkpoint_every == 0 \
                    or step + 1 == self.cfg.total_steps:
                self._save(state, step + 1)
        return state
