"""Adam/AdamW with dtype-configurable moment states.

At 340B params, fp32 (m, v) costs 8 bytes/param — more than the bf16 params
themselves.  ``state_dtype="bfloat16"`` halves that (a distributed-
optimization trick the nemotron config uses to fit 16 GB/chip HBM); the
update math always runs in fp32 regardless of storage dtype.

Also provides the IHT optimizer wrapper — the paper's sparsification stage
as a first-class training feature (mask recomputed on the cubic schedule,
then frozen; works under pjit because masks are plain sharded jnp ops).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100


def schedule(cfg: AdamConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    return cfg.lr * warm


def init(params, cfg: AdamConfig):
    dt = jnp.dtype(cfg.state_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(params, grads, state, cfg: AdamConfig):
    """-> (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    lr = schedule(cfg, state["step"])
    sf = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** sf
    c2 = 1.0 - cfg.b2 ** sf
    dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        u = (mf / c1) / (jnp.sqrt(vf / c2) + cfg.eps)
        if cfg.weight_decay:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * u
        return newp.astype(p.dtype), mf.astype(dt), vf.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# IHT wrapper (paper Sec. III-C at LM scale)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IHTState:
    masks: Any
    frozen: bool


def iht_epoch_masks(params, epoch: int, target_sparsity: float,
                    ramp_epochs: int, prev: IHTState | None, path_filter=None):
    """Recompute masks on the cubic ramp; freeze after ramp_epochs."""
    from repro.core.compression import compute_masks_tree, sparsity_at_epoch
    from repro.core.compression import IHTConfig
    icfg = IHTConfig(target_sparsity=target_sparsity, ramp_epochs=ramp_epochs)
    if epoch >= ramp_epochs and prev is not None and prev.frozen:
        return prev
    s_e = sparsity_at_epoch(icfg, epoch)
    masks = compute_masks_tree(params, s_e, path_filter)
    return IHTState(masks=masks, frozen=epoch >= ramp_epochs)


def apply_iht(params, iht_state: IHTState | None):
    if iht_state is None:
        return params
    return jax.tree.map(lambda w, m: jnp.where(m, w, jnp.zeros_like(w)),
                        params, iht_state.masks)
