"""Engine-agnostic slot scheduler: the one continuous-batching core behind
both serving workloads.

The paper's deployment unit is one stateful FastGRNN per sensor; PR 1/PR 2
scaled that to a fleet with a slot-table streaming engine, and the LM
engine ran its own ad-hoc loop.  Both are the *same* scheduling problem —
N stateful sessions multiplexed over S resident compute slots — so the
battle-tested slot machinery (slot table, pending queue, FIFO admission,
slot recycling, per-slot counters, event plumbing) now lives here, once.

Division of labour
------------------
:class:`SlotScheduler` owns *placement*: which request occupies which slot,
who is waiting, when a freed slot is recycled, and the telemetry counters
(admissions / recycles / spills / occupancy) the sharded-streaming work
needs.  It never touches workload state.

A workload implements the :class:`SlotProgram` protocol and owns *compute*:
per-slot model state (hidden vectors, KV caches, sample rings, output
buffers) laid out as arrays indexed by slot.  The contract is small:

* ``admit(slot, request_id, payload, reset)`` — place a request into a
  slot.  ``reset=True`` means the slot was previously owned (recycled) and
  the program must clear any residual state before use.
* ``step(resident)`` — advance every resident slot by one unit of work and
  return a :class:`TickReport` (events to surface, slots that finished,
  how many slots actually advanced).
* ``release(slot, request_id, reason)`` — the slot is being vacated
  (``reason`` is ``"finished"`` or ``"cancelled"``); clean per-slot state
  and optionally return a final event (e.g. a partial-window prediction on
  detach).

Consumers:

* ``serve/streaming.py`` — Q15 sensor streams; one work unit = one 50 Hz
  sample through the batched FastGRNN step kernel.
* ``serve/engine.py`` — continuous-batching LM engine; one work unit = one
  decode token across all resident sequences, with a finished sequence's
  KV-cache slot re-prefilled from the pending queue on the next tick.
* ``serve/fleet`` — N schedulers composed behind one front door; the
  fleet drives the tick's two halves separately (``tick_begin`` /
  ``tick_finish``) to fuse every shard's program step into one batched
  kernel dispatch, and uses ``evict`` for live stream migration.

Admission policy
----------------
``admit_policy="any_free"`` (default) is true continuous batching: the
FIFO head is admitted the moment any slot frees.  ``"all_free"`` only
admits when *no* slot is resident — the window-boundary baseline the old
LM engine implemented, kept as a measurable reference point for
``benchmarks/serve_bench.py``.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Protocol, Sequence

import numpy as np

from repro.obs.trace import NULL_TRACER


@dataclasses.dataclass
class TickReport:
    """What a :class:`SlotProgram` did in one ``step`` call."""
    events: list = dataclasses.field(default_factory=list)
    finished: Sequence[int] = ()   # slots whose request completed this tick
    advanced: int = 0              # work units performed (telemetry)


class SlotProgram(Protocol):
    """Workload half of the scheduler/program split (see module docstring)."""

    def admit(self, slot: int, request_id: str, payload: Any,
              reset: bool) -> None: ...

    def step(self, resident: np.ndarray) -> TickReport: ...

    def release(self, slot: int, request_id: str, reason: str): ...


class HostProgram:
    """SlotProgram adapter binding the protocol hooks to privately-named
    methods on a host engine (``_admit_slot`` / ``_advance`` /
    ``_release_slot``), so an engine with its own public ``step()`` API
    can implement the protocol without a name collision.  Shared by both
    serving engines."""

    def __init__(self, host):
        self._host = host

    def admit(self, slot, request_id, payload, reset):
        self._host._admit_slot(slot, request_id, payload, reset)

    def step(self, resident) -> TickReport:
        return self._host._advance(resident)

    def release(self, slot, request_id, reason):
        return self._host._release_slot(slot, request_id, reason)


class SlotScheduler:
    """Slot table + pending queue + admission/recycling for a SlotProgram."""

    ADMIT_POLICIES = ("any_free", "all_free")

    def __init__(self, max_slots: int, program: SlotProgram, *,
                 admit_policy: str = "any_free", tracer=None):
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        if admit_policy not in self.ADMIT_POLICIES:
            raise ValueError(f"admit_policy must be one of {self.ADMIT_POLICIES}")
        self.max_slots = max_slots
        self.program = program
        self.admit_policy = admit_policy
        # tick-phase tracing seam (repro.obs): admission work is spanned
        # as "sched.admit" only when something is actually admissible, so
        # the idle-queue fast path never takes a timestamp
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.shard = -1     # fleet shard index tag for spans (set by owner)
        self.resident = np.zeros(max_slots, bool)
        self._slot_request: list[str | None] = [None] * max_slots
        self._free: list[int] = list(range(max_slots - 1, -1, -1))
        self._dirty = np.zeros(max_slots, bool)   # freed slots hold stale state
        self._pending: collections.deque[str] = collections.deque()
        self._payloads: dict[str, Any] = {}       # request -> payload (pending)
        self._slot_of: dict[str, int] = {}        # request -> slot (resident)
        # --- counters (the observability hook for sharded streaming) ----
        self._admissions = 0      # total placements into a slot
        self._recycles = 0        # placements that reused a previously-owned slot
        self._spills = 0          # submissions that had to wait in the queue
        self._completed = 0       # finished releases
        self._cancelled = 0       # cancelled releases (resident or pending)
        self._evictions = 0       # migration releases (live stream moved away)
        self._ticks = 0           # productive ticks (advanced > 0)
        self._peak_active = 0

    # ------------------------------------------------------------------
    # Request lifecycle
    # ------------------------------------------------------------------
    def submit(self, request_id: str, payload: Any = None) -> str:
        """Queue a request.  Returns ``"active"`` if it was placed into a
        slot immediately, ``"pending"`` if it joined the FIFO queue.
        Under ``admit_policy="all_free"`` admission happens only at tick
        start, so a wave fills all at once instead of the first request
        racing into an empty slot table alone."""
        if request_id in self._slot_of or request_id in self._payloads:
            raise ValueError(f"request {request_id!r} already submitted")
        self._payloads[request_id] = payload
        self._pending.append(request_id)
        if self.admit_policy == "any_free":
            self._try_admit()
        if request_id in self._slot_of:
            return "active"
        self._spills += 1
        return "pending"

    def cancel(self, request_id: str):
        """Withdraw a request.  Resident: the program's ``release`` hook runs
        with reason ``"cancelled"`` and its return value (e.g. a final
        partial event) is passed through.  Pending: silently dequeued."""
        if request_id in self._slot_of:
            ev = self._release(self._slot_of[request_id], reason="cancelled")
            self._cancelled += 1
            return ev
        if request_id in self._payloads:
            self._pending.remove(request_id)
            del self._payloads[request_id]
            self._cancelled += 1
            return None
        raise KeyError(f"request {request_id!r} is not scheduled")

    def evict(self, request_id: str) -> None:
        """Withdraw a request for live migration.  Unlike :meth:`cancel`,
        the release hook runs with reason ``"migrated"`` — no completion
        semantics, no final event — and the departure is counted in
        ``evictions``, not ``cancelled``.  The caller (the fleet front
        door) is responsible for having snapshotted the per-slot state it
        wants to carry to the destination shard *before* evicting."""
        if request_id in self._slot_of:
            self._release(self._slot_of[request_id], reason="migrated")
            self._evictions += 1
            return
        if request_id in self._payloads:
            self._pending.remove(request_id)
            del self._payloads[request_id]
            self._evictions += 1
            return
        raise KeyError(f"request {request_id!r} is not scheduled")

    # ------------------------------------------------------------------
    # Ticking
    # ------------------------------------------------------------------
    def tick(self) -> list:
        """One scheduling round: admit from the pending queue into free
        slots, step the program over the resident set, release finished
        slots (recycled next tick).  Returns the program's events."""
        resident = self.tick_begin()
        if resident is None:
            return []
        return self.tick_finish(self.program.step(resident))

    def tick_begin(self) -> np.ndarray | None:
        """First half of :meth:`tick`: run admission and return a copy of
        the resident mask the program should step, or ``None`` when no slot
        is resident.  Split out so a fleet front door can run admission on
        every shard, batch all shards' program steps into one fused kernel
        dispatch, and only then complete each shard with
        :meth:`tick_finish` — without this scheduler knowing about shards."""
        self._try_admit()
        if not self._slot_of:        # O(1): no resident request anywhere
            return None
        return self.resident.copy()

    def tick_finish(self, report: TickReport) -> list:
        """Second half of :meth:`tick`: account the program's
        :class:`TickReport` (productive-tick counter, finished-slot
        releases) and return its events."""
        if report.advanced:
            self._ticks += 1
        if len(report.finished):
            t0 = self.tracer.t()
            for slot in report.finished:
                self._release(int(slot), reason="finished")
                self._completed += 1
            self.tracer.rec("sched.release", t0, self.shard)
        return report.events

    def has_work(self) -> bool:
        return bool(self.resident.any()) or bool(self._pending)

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    @property
    def n_active(self) -> int:
        return self.max_slots - len(self._free)

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    def slot_of(self, request_id: str) -> int:
        """Resident slot of a request, or -1 while pending."""
        return self._slot_of.get(request_id, -1)

    def request_at(self, slot: int) -> str | None:
        return self._slot_request[slot]

    def stats(self) -> dict[str, Any]:
        return {
            "max_slots": self.max_slots,
            "active": self.n_active,
            "pending": self.n_pending,
            "occupancy": self.n_active / self.max_slots,
            "peak_active": self._peak_active,
            "admissions": self._admissions,
            "recycles": self._recycles,
            "spills": self._spills,
            "completed": self._completed,
            "cancelled": self._cancelled,
            "evictions": self._evictions,
            "ticks": self._ticks,
            "admit_policy": self.admit_policy,
        }

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _try_admit(self) -> None:
        if not (self._free and self._pending):
            return
        if self.admit_policy == "all_free" and self.resident.any():
            return
        t0 = self.tracer.t()
        while self._free and self._pending:
            rid = self._pending.popleft()
            self._place(rid, self._free.pop())
        self.tracer.rec("sched.admit", t0, self.shard)

    def _place(self, request_id: str, slot: int) -> None:
        payload = self._payloads.pop(request_id)
        reset = bool(self._dirty[slot])
        self._slot_request[slot] = request_id
        self._slot_of[request_id] = slot
        self.resident[slot] = True
        self._admissions += 1
        if reset:
            self._recycles += 1
        self._peak_active = max(self._peak_active, self.n_active)
        self.program.admit(slot, request_id, payload, reset)
        self._dirty[slot] = False

    def _release(self, slot: int, *, reason: str):
        request_id = self._slot_request[slot]
        ev = self.program.release(slot, request_id, reason)
        self._slot_request[slot] = None
        del self._slot_of[request_id]
        self.resident[slot] = False
        self._dirty[slot] = True
        self._free.append(slot)
        return ev
