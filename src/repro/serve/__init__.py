from . import engine, fleet, scheduler, streaming  # noqa: F401
