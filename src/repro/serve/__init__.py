from . import engine, scheduler, streaming  # noqa: F401
