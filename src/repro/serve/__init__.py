from . import engine, streaming  # noqa: F401
