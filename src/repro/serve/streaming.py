"""Multi-stream streaming inference engine for the deployed Q15 FastGRNN.

The paper deploys one 566-byte FastGRNN per microcontroller, classifying a
live 50 Hz tri-axial accelerometer stream in real time.  This module is the
server-side analogue of a *fleet* of such sensors: thousands of concurrent
stateful sessions (one hidden state + warm-up counter each) stepped in
lockstep by the batched Q15 single-step kernel
(``kernels/fastgrnn_cell.ops.Q15StreamStep``).

Placement — which stream occupies which resident slot, FIFO admission from
the pending queue, slot recycling when a stream finishes or detaches — is
delegated to the engine-agnostic :class:`repro.serve.scheduler.SlotScheduler`;
this module implements the workload half of that split (the
:class:`~repro.serve.scheduler.SlotProgram` protocol): per-slot FastGRNN
state, sample rings, window counters, and event emission.  The LM engine
(``serve/engine.py``) rides the identical scheduler.

Workload state is a **NumPy slot table**, not per-session Python objects:
per-slot step counters, window positions, stream lengths and sample
cursors are columns of (S,)-shaped arrays, and buffered samples live in
one offset-major (cap, S, d) ring buffer — a lockstep fleet's per-tick
gather is then one contiguous (S, d) slab read — so a tick costs a
handful of vectorized ops instead of a Python loop over every resident
stream.  Python loops remain only on the rare paths: admission,
completion, and event emission.

Determinism contract: with the default ``backend="exact"`` every stream's
hidden trajectory, logits and predictions are **bit-identical** to running
the scalar ``core/qruntime.QRuntime`` over the same samples (paper
contribution (i) — cross-platform agreement — preserved at batch scale).
The ``"jit"`` / ``"pallas"`` backends trade that for throughput (XLA
contracts mul+add into FMA, ~1e-9/step drift; argmax predictions agree in
practice).

Lifecycle::

    engine = StreamingEngine(qp)                     # or float params
    engine.attach("sensor-7", samples, total_steps=128)
    events = engine.step()        # one synchronous tick over all slots
    events += engine.drain()      # tick until no stream can advance
    engine.detach("sensor-7")     # early termination -> final event

Each emitted :class:`StreamEvent` carries the per-stream warm-up counter
state: predictions before ``warmup_samples`` total steps (paper Sec. VI-A:
median stabilization 74 samples = 1.48 s at 50 Hz) are flagged cold.

Trajectory taps (deployment parity): ``attach(..., record_trajectory=True)``
captures the stream's per-step hidden states; :meth:`StreamingEngine.trajectory`
returns them (bit-identical to ``QRuntime.run_window``'s trajectory under
the exact backend) — the cross-engine witness used by ``repro.deploy.verify``.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Iterable

import numpy as np

from repro.compress.artifact import ModelArtifact
from repro.core import quantization as q
from repro.kernels.fastgrnn_cell.ops import Q15StreamStep
from repro.obs import NULL_OBS, Observability
from repro.obs.numerics import PUBLISH_EVERY
from repro.serve.scheduler import HostProgram, SlotScheduler, TickReport


@dataclasses.dataclass(frozen=True)
class StreamingConfig:
    max_slots: int = 1024        # resident batch width (concurrent streams)
    window: int = 128            # samples per classification window (paper)
    warmup_samples: int = 74     # paper Sec. VI-A median t* at 50 Hz
    sample_rate_hz: float = 50.0
    reset_on_emit: bool = True   # tumbling windows (matches QRuntime.predict)
    backend: str = "exact"       # "exact" | "jit" | "pallas"
    interpret: bool = True       # pallas backend: interpret mode (CPU)
    mxu: bool = False            # pallas: 128-lane MXU matmul layout
    device: Any = None           # jax device for jit/pallas dispatch (fleet
    # shard placement); None = default device / process-local NumPy
    device_resident: Any = "auto"   # keep the hidden-state table on device
    # between ticks: steady-state ticks then move ZERO h bytes across the
    # host/device boundary (only x + active-mask stage h2d; emission/tap/
    # snapshot rows pull d2h on demand).  "auto" = yes for jit/pallas
    # when the topology has real device parallelism (an accelerator or
    # >1 device — see Q15StreamStep.device_state_profitable), never for
    # exact — the bit-exact backend stays host NumPy.  True forces
    # residency on any jit/pallas topology (the tests do, to pin the
    # zero-copy contract on CPU); False forces the host-staged path.
    batch_events: bool = False   # emit one columnar StreamEventBatch per
    # tick instead of per-stream StreamEvent objects (the fleet-scale path)
    ring_capacity: int = 256     # initial per-slot sample ring (grows 2x)
    max_ring_capacity: int = 1024  # growth cap: the ring is (cap, S, d)
    # shared, so one stream's deep backlog must not allocate O(S * backlog);
    # samples beyond the cap spill to a per-slot chunk queue and drain into
    # the ring as it frees


@dataclasses.dataclass
class StreamEvent:
    """One emitted prediction (window boundary, stream end, or detach)."""
    stream_id: str
    kind: str                    # "window" | "final"
    step: int                    # total per-stream samples consumed so far
    window_step: int             # samples in the window this was emitted from
    prediction: int
    logits: np.ndarray           # (C,) f32
    warm: bool                   # step >= warmup_samples (Sec. VI-A)


@dataclasses.dataclass
class StreamEventBatch:
    """Columnar emission record (``StreamingConfig.batch_events=True``):
    ONE object per tick carrying every stream that emitted, as arrays.
    At fleet scale a lockstep window boundary means 100k+ simultaneous
    emissions — building that many per-stream event objects costs more
    than the tick's model math, so the fleet path delivers predictions
    column-wise and lets the consumer fan out only where needed
    (:meth:`events` expands to per-stream :class:`StreamEvent`)."""
    stream_ids: list
    final: np.ndarray            # (k,) bool — True = "final", else "window"
    steps: np.ndarray            # (k,) int64
    window_steps: np.ndarray     # (k,) int64
    predictions: np.ndarray      # (k,) int32
    logits: np.ndarray           # (k, C) f32
    warm: np.ndarray             # (k,) bool

    def __len__(self) -> int:
        return len(self.stream_ids)

    def events(self) -> list[StreamEvent]:
        """Expand to per-stream events (convenience / compatibility)."""
        return [StreamEvent(stream_id=sid, kind="final" if f else "window",
                            step=int(st), window_step=int(ws),
                            prediction=int(p), logits=lg, warm=bool(w))
                for sid, f, st, ws, p, lg, w in zip(
                    self.stream_ids, self.final, self.steps,
                    self.window_steps, self.predictions, self.logits,
                    self.warm)]


@dataclasses.dataclass
class StreamState:
    """Portable bit-exact snapshot of one live stream — the unit of fleet
    migration.  :meth:`StreamingEngine.export_stream` detaches a stream
    into this form (hidden state, counters, every not-yet-consumed sample,
    trajectory tap) and :meth:`StreamingEngine.import_stream` re-attaches
    it on any engine built from the same weights; the continued trajectory
    is bit-identical to never having moved (exact backend)."""
    stream_id: str
    h: np.ndarray                        # (H,) f32 hidden state
    steps: int                           # total samples consumed so far
    wstep: int                           # position in the current window
    total: int | None                    # finite stream length; None = open
    samples: np.ndarray                  # (k, d) f32 buffered, unconsumed
    record_trajectory: bool = False
    trajectory: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Session:
    """Thin per-stream handle.  Counters/cursors live in the engine's slot
    table; this only tracks identity, placement, the not-yet-placed sample
    chunks of pending streams, the finite-length target, and the
    trajectory-tap flag."""
    stream_id: str
    slot: int = -1                       # -1 -> pending (no resident slot)
    total: int | None = None             # finite stream length; None = open
    chunks: collections.deque = dataclasses.field(
        default_factory=collections.deque)   # buffered while pending
    record_trajectory: bool = False
    restore: tuple | None = None         # (h, steps, wstep, suppress)
    # migrated-in state; ``suppress`` is the replay cursor — events up to
    # and including that step were already delivered upstream and are
    # swallowed when the stream re-runs them after a crash recovery


def coerce_samples(samples, input_dim: int, stream_id: str) -> np.ndarray:
    """Canonicalize fed samples to (k, input_dim) float32 — the one
    validation shared by the engine's ``feed`` and the fleet's spillover
    queue, so the two paths cannot drift."""
    samples = np.asarray(samples, np.float32)
    if samples.ndim == 1:
        samples = samples[None, :]
    if samples.ndim != 2 or samples.shape[1] != input_dim:
        raise ValueError(
            f"stream {stream_id!r}: samples must be (k, "
            f"{input_dim}), got {samples.shape}")
    return samples


def coerce_qp(params_or_qp, quant: q.QuantConfig | None = None
              ) -> q.QuantizedParams:
    """Normalize any accepted model form to :class:`QuantizedParams`:
    a :class:`ModelArtifact` yields its quantized params (deployed config:
    FP32 acts through the LUT — the artifact's deploy calibration scales
    are export-compiler scales, NOT activation-storage scales; opt into
    Table V storage quant via ``from_artifact(quantized_acts=True)``);
    a float param pytree gets per-tensor Q15 PTQ (Appendix B).  Shared by
    :class:`StreamingEngine` and the fleet front door."""
    if isinstance(params_or_qp, ModelArtifact):
        return params_or_qp.require_qp()
    if isinstance(params_or_qp, q.QuantizedParams):
        return params_or_qp
    return q.quantize_params(params_or_qp, quant or q.QuantConfig())


class StreamingEngine:
    """Slot-based continuous batching of stateful FastGRNN sessions."""

    def __init__(self, params_or_qp, config: StreamingConfig | None = None,
                 *, quant: q.QuantConfig | None = None,
                 act_scales: dict[str, float] | None = None,
                 naive_acts: bool = False,
                 obs: Observability | None = None):
        self.qp = coerce_qp(params_or_qp, quant)
        config = config or StreamingConfig()
        self.config = config
        # observability seam (repro.obs): NULL_OBS keeps every hook a
        # no-op so the bit-exact fast path is untouched by default
        self._obs = obs or NULL_OBS
        self._tracer = self._obs.tracer
        self._obs_shard = -1        # fleet shard index tag (set by owner)
        self._last_advanced = 0
        # numeric-health seam (repro.obs.numerics): resolved lazily via
        # _numerics() because the fleet tags _obs_shard after construction;
        # the kernel-side event dict is engine-owned and flushed per tick
        self._num_cache: tuple[int, Any] | None = None
        self._num_events: dict[str, Any] = {}
        self._num_tallied = False
        self._num_pub_tick = 0
        self.kernel = Q15StreamStep(self.qp, act_scales=act_scales,
                                    naive_acts=naive_acts,
                                    backend=config.backend,
                                    interpret=config.interpret,
                                    device=config.device,
                                    mxu=config.mxu)
        if config.device_resident == "auto":
            self._device_resident = self.kernel.device_state_profitable
        else:
            self._device_resident = bool(config.device_resident)
            if self._device_resident and not self.kernel.supports_device_state:
                raise ValueError("device_resident=True requires the jit or "
                                 "pallas backend (exact is host NumPy)")
        S, d = config.max_slots, self.kernel.input_dim
        self._h = (self.kernel.init_state_device(S) if self._device_resident
                   else self.kernel.init_state(S))
        self._h_inflight = False  # a step_resident dispatch is in flight:
        # _advance_begin must sync before overwriting the _x staging buffer
        # (jax.device_put may ALIAS host memory instead of copying, so
        # mutating staging while the dispatch reads it corrupts the
        # in-flight tick — measured, not hypothetical)
        self._h_pending = None    # fleet-installed lazy h view: a
        # (fused_h, lo, hi) provenance spec set by the fused device tick
        # instead of an eager per-shard device slice (one slice dispatch
        # per shard per tick ≈ 35% of a steady-state tick at 1024 slots).
        # _resolve_h materializes it on first row-level access; any
        # rebind of self._h to a fresh array must clear it (a stale spec
        # would let the fleet adopt pre-rebind state)
        self._h_prefetch = None   # identity-keyed (h, {slot: row}) one-shot
        # cache for batched snapshot pulls; any step/reset rebinds self._h
        # and invalidates it (device arrays are immutable)
        self._x = np.zeros((S, d), np.float32)
        # --- slot table (vectorized workload state) --------------------
        self._steps = np.zeros(S, np.int64)      # samples consumed
        self._wstep = np.zeros(S, np.int64)      # position in current window
        self._total = np.full(S, -1, np.int64)   # finite length; -1 = open
        self._head = np.zeros(S, np.int64)       # ring read cursor (absolute)
        self._tail = np.zeros(S, np.int64)       # ring write cursor (absolute)
        self._cap = max(8, min(config.ring_capacity, config.max_ring_capacity))
        # ring layout is (cap, S, d) — offset-major, not slot-major: a
        # fleet of 50 Hz sensors advances in lockstep, so the per-tick
        # gather usually reads ONE contiguous (S, d) slab instead of S
        # strided rows (measured ~50x cheaper at 16k slots; the slot-major
        # layout made the gather cost more than the step kernel)
        self._ring = np.zeros((self._cap, S, d), np.float32)
        self._spill: dict[int, collections.deque] = {}  # slot -> chunk queue
        self._tap = np.zeros(S, bool)            # trajectory-tap flag
        self._n_taps = 0                         # fast skip of the tap scan
        self._suppress = np.full(S, -1, np.int64)  # replay cursor: events at
        # steps <= this were already delivered before a crash; re-emissions
        # during replay are swallowed (state transitions still happen, so
        # the recovered trajectory stays bit-identical)
        self._warm_seen = np.zeros(S, bool)  # per-slot: this stream already
        # emitted a warm (post-warm-up) prediction — gates the once-per-
        # stream warm-up-samples metric (paper contribution ii, measured
        # continuously in serving)
        # --- placement: delegated to the shared slot scheduler ---------
        self._sched = SlotScheduler(S, HostProgram(self),
                                    tracer=self._tracer)
        self._sessions: dict[str, _Session] = {}
        self._trajectories: dict[str, list[np.ndarray]] = {}
        # telemetry (workload side; placement counters live in the scheduler)
        self._stream_steps = 0
        self._ring_spills = 0
        self._replay_suppressed = 0   # events swallowed by the replay cursor

    @classmethod
    def from_artifact(cls, artifact: ModelArtifact,
                      config: StreamingConfig | None = None, *,
                      quantized_acts: bool = False,
                      naive_acts: bool = False,
                      obs: Observability | None = None) -> "StreamingEngine":
        """Build the engine from a compression-pipeline artifact.  The
        default is the deployed configuration (FP32 acts, bit-identical to
        ``QRuntime.from_artifact``); ``quantized_acts=True`` selects the
        Table V calibrated-Q15-activation mode via
        ``ModelArtifact.runtime_scales`` (the gate shared with QRuntime).
        When the bundle carries a :class:`~repro.obs.numerics.NumericsMonitor`,
        the artifact's deploy calibration scales are late-bound into it as
        per-tensor drift limits."""
        eng = cls(artifact, config,
                  act_scales=artifact.runtime_scales(quantized_acts),
                  naive_acts=naive_acts, obs=obs)
        if obs is not None and obs.numerics is not None \
                and artifact.act_scales:
            from repro.obs.numerics import limits_from_scales
            obs.numerics.set_default_limits(
                limits_from_scales(artifact.act_scales))
        return eng

    # ------------------------------------------------------------------
    # Session lifecycle
    # ------------------------------------------------------------------
    def attach(self, stream_id: str, samples: np.ndarray | None = None, *,
               total_steps: int | None = None,
               record_trajectory: bool = False) -> str:
        """Register a stream.  Returns ``"active"`` if a slot was free,
        ``"pending"`` if the stream was queued for the next free slot.

        ``samples``: optional initial (k, d) buffer; more via :meth:`feed`.
        ``total_steps``: finite stream length — the session auto-finishes
        (emitting a final event and recycling its slot) after that many
        samples.  ``None`` keeps the stream open until :meth:`detach`.
        ``record_trajectory``: tap the per-step hidden states (exact
        backend: bit-identical to the scalar reference trajectory).
        """
        if stream_id in self._sessions:
            raise ValueError(f"stream {stream_id!r} already attached")
        s = _Session(stream_id=stream_id, total=total_steps,
                     record_trajectory=record_trajectory)
        self._sessions[stream_id] = s
        if record_trajectory:
            self._trajectories[stream_id] = []
        if samples is not None:
            self.feed(stream_id, samples)
        # the scheduler preserves FIFO fairness: a free slot goes to the
        # new stream only when no earlier stream is already waiting
        return self._sched.submit(stream_id, s)

    def feed(self, stream_id: str, samples: np.ndarray) -> None:
        """Append samples ((d,) or (k, d)) to a stream's input buffer."""
        s = self._sessions[stream_id]
        samples = coerce_samples(samples, self.kernel.input_dim, stream_id)
        if s.slot < 0:
            s.chunks.append(samples)
        else:
            self._ring_write(s.slot, samples)

    def detach(self, stream_id: str) -> StreamEvent | None:
        """Terminate a stream at a step boundary.  If it consumed samples
        since its last window emission, a ``"final"`` event for the partial
        window is returned; its slot is recycled to the pending queue."""
        if stream_id not in self._sessions:
            raise KeyError(f"stream {stream_id!r} is not attached")
        ev = self._sched.cancel(stream_id)
        self._sessions.pop(stream_id, None)   # pending path (resident path
        return ev                             # popped in _release_slot)

    # ------------------------------------------------------------------
    # Live migration (fleet rebalancing / shard drain)
    # ------------------------------------------------------------------
    def snapshot_stream(self, stream_id: str) -> StreamState:
        """Copy a live stream into a portable :class:`StreamState` —
        hidden state, step/window counters, every buffered-but-unconsumed
        sample (ring + spill backlog, FIFO order preserved), and a copy of
        the trajectory tap — *without* detaching it.  This is the fleet's
        periodic-checkpoint primitive: the stream keeps running, and the
        snapshot (wire-encoded via ``serve/fleet/wire.py``) plus the
        samples fed after it deterministically reproduce the stream's
        future on a replacement shard."""
        if stream_id not in self._sessions:
            raise KeyError(f"stream {stream_id!r} is not attached")
        s = self._sessions[stream_id]
        d = self.kernel.input_dim
        if s.slot >= 0:
            slot = s.slot
            n = int(self._tail[slot] - self._head[slot])
            idx = (self._head[slot] + np.arange(n)) % self._cap
            parts = [self._ring[idx, slot]] if n else []
            parts += list(self._spill.get(slot, ()))
            return StreamState(
                stream_id=stream_id,
                h=self._h_row(slot),
                steps=int(self._steps[slot]),
                wstep=int(self._wstep[slot]),
                total=None if self._total[slot] < 0 else int(self._total[slot]),
                samples=(np.concatenate(parts) if parts
                         else np.zeros((0, d), np.float32)),
                record_trajectory=s.record_trajectory,
                trajectory=list(self._trajectories.get(stream_id, ())))
        # pending: never stepped HERE — but a migrated-in stream that
        # is still waiting for a slot carries its restored hidden
        # state/counters on the session; those must travel onward, or
        # a second migration would silently rewind the stream to zero
        if s.restore is not None:
            h0, steps0, wstep0 = s.restore[:3]
            h0 = h0.copy()
        else:
            h0 = np.zeros(self.kernel.hidden_dim, np.float32)
            steps0 = wstep0 = 0
        parts = list(s.chunks)
        return StreamState(
            stream_id=stream_id,
            h=h0, steps=steps0, wstep=wstep0, total=s.total,
            samples=(np.concatenate(parts) if parts
                     else np.zeros((0, d), np.float32)),
            record_trajectory=s.record_trajectory,
            trajectory=list(self._trajectories.get(stream_id, ())))

    def export_stream(self, stream_id: str) -> StreamState:
        """Detach a stream into a portable :class:`StreamState` snapshot
        (see :meth:`snapshot_stream` for what travels).  No event is
        emitted and the departure is counted as a scheduler *eviction*,
        not a cancellation.  Re-attaching the snapshot via
        :meth:`import_stream` on any engine built from the same weights
        continues the stream bit-identically (exact backend)."""
        state = self.snapshot_stream(stream_id)
        self._trajectories.pop(stream_id, None)
        self._sched.evict(stream_id)          # resident path pops session
        self._sessions.pop(stream_id, None)   # pending path
        return state

    def import_stream(self, state: StreamState, *,
                      suppress_steps_until: int | None = None) -> str:
        """Re-attach a migrated stream from a :class:`StreamState`.
        Returns ``"active"``/``"pending"`` like :meth:`attach`.  The
        snapshot's hidden state and counters are restored into the slot at
        admission time, so a stream that waits in the pending queue first
        still resumes exactly where it left off.

        ``suppress_steps_until``: replay cursor for crash failover — the
        consumer already saw this stream's events up to and including
        that step, so re-emissions at steps <= it are swallowed (counted
        in ``stats()["replay_suppressed"]``) while the state transitions
        they mark (window reset, completion) still run, keeping the
        recovered trajectory bit-identical to the uninterrupted one."""
        if state.stream_id in self._sessions:
            raise ValueError(f"stream {state.stream_id!r} already attached")
        s = _Session(stream_id=state.stream_id, total=state.total,
                     record_trajectory=state.record_trajectory,
                     restore=(np.asarray(state.h, np.float32).copy(),
                              int(state.steps), int(state.wstep),
                              -1 if suppress_steps_until is None
                              else int(suppress_steps_until)))
        self._sessions[state.stream_id] = s
        if state.record_trajectory:
            self._trajectories[state.stream_id] = list(state.trajectory)
        if len(state.samples):
            s.chunks.append(np.asarray(state.samples, np.float32))
        return self._sched.submit(state.stream_id, s)

    # ------------------------------------------------------------------
    # Stepping
    # ------------------------------------------------------------------
    def step(self) -> list[StreamEvent]:
        """One synchronous tick: the scheduler admits pending streams into
        free slots, the program advances every resident stream that has a
        buffered sample by exactly one step, and window/final events are
        emitted.  Streams without buffered samples idle (hidden state held
        bit-for-bit)."""
        if not self._obs.enabled:
            return self._sched.tick()
        tr = self._tracer
        self._last_advanced = 0
        t0 = tr.t()
        events = self._sched.tick()
        dur_ns = tr.rec("engine.tick", t0, self._obs_shard)
        if self._obs.metrics is not None:
            self._tick_metrics(dur_ns, self._last_advanced)
        return events

    def _tick_metrics(self, dur_ns: int, advanced: int) -> None:
        """Tick-latency SLO accounting for a standalone engine (a fleet
        shard's ticks are accounted by the fleet front door instead)."""
        reg = self._obs.metrics
        us = dur_ns / 1e3
        reg.histogram("engine.tick_us", "wall time of one engine tick",
                      wallclock=True).observe_us(us)
        deadline_ms = self._obs.deadline_ms
        if deadline_ms is None:
            deadline_ms = 1e3 / self.config.sample_rate_hz
        if us > deadline_ms * 1e3 and advanced:
            reg.counter("engine.deadline_miss_ticks",
                        "ticks over the per-sample deadline",
                        wallclock=True).inc()
            reg.counter("engine.deadline_miss_stream_ticks",
                        "stream-steps advanced in ticks that missed "
                        "the deadline", wallclock=True).inc(advanced)
        mon = self._numerics()
        if mon is not None and self._obs_shard < 0:
            # standalone engines publish their own (-1) child; a fleet
            # shard's counts are published by the fleet front door instead
            # (publishing both would double-count into the same registry).
            # Publish on a cadence, not per tick: the export walks every
            # site/tensor and recomputes drift, which dominates monitor
            # cost on small models; counters are delta-tracked so a
            # throttled publish loses nothing.
            self._num_pub_tick += 1
            if self._num_pub_tick >= PUBLISH_EVERY:
                self._num_pub_tick = 0
                mon.publish(reg)

    def drain(self) -> list[StreamEvent]:
        """Tick until no resident or pending stream can advance (buffers
        empty).  Open streams stay attached; feed more and step again."""
        events: list[StreamEvent] = []
        while self._any_buffered():
            out = self.step()
            if not out and not bool(np.any(
                    self._sched.resident & (self._tail > self._head))):
                break  # only pending streams hold samples and no slot frees
            events.extend(out)
        return events

    # ------------------------------------------------------------------
    # Trajectory taps (deployment parity harness)
    # ------------------------------------------------------------------
    def trajectory(self, stream_id: str) -> np.ndarray:
        """(steps, H) hidden trajectory of a tapped stream (attach with
        ``record_trajectory=True``).  Survives stream completion/detach."""
        if stream_id not in self._trajectories:
            raise KeyError(f"stream {stream_id!r} was not tapped")
        rows = self._trajectories[stream_id]
        H = self.kernel.hidden_dim
        return (np.stack(rows) if rows else np.zeros((0, H), np.float32))

    # ------------------------------------------------------------------
    # SlotProgram hooks (called by the scheduler via HostProgram)
    # ------------------------------------------------------------------
    def _admit_slot(self, slot: int, stream_id: str, s: _Session,
                    reset: bool) -> None:
        s.slot = slot
        if reset:  # recycled slot: zero the previous stream's hidden state
            mask = np.arange(self.config.max_slots) == slot
            if self._device_resident:
                self._h = self.kernel.reset_device(self._resolve_h(), mask)
                self._h_pending = None
            else:
                self._h = self.kernel.reset(self._h, mask)
        self._steps[slot] = 0
        self._wstep[slot] = 0
        self._total[slot] = -1 if s.total is None else int(s.total)
        self._head[slot] = 0
        self._tail[slot] = 0
        self._tap[slot] = s.record_trajectory
        self._n_taps += int(s.record_trajectory)
        self._suppress[slot] = -1
        self._warm_seen[slot] = False
        if s.restore is not None:     # migrated-in stream: resume, don't reset
            h0, steps0, wstep0, suppress0 = s.restore
            if self._device_resident:
                self._h = self.kernel.set_rows_device(
                    self._resolve_h(), np.array([slot]), h0[None])
                self._h_pending = None
            else:
                if not self._h.flags.writeable:   # jit/pallas outputs are
                    self._h = self._h.copy()      # read-only numpy views
                self._h[slot] = h0
            self._steps[slot] = steps0
            self._wstep[slot] = wstep0
            self._suppress[slot] = suppress0
            # a migrated-in stream past warm-up already reported its
            # warm-up sample count on its previous shard
            self._warm_seen[slot] = steps0 >= self.config.warmup_samples
            s.restore = None
        while s.chunks:
            self._ring_write(slot, s.chunks.popleft())

    def _numerics(self):
        """This engine's numeric-health monitor — the shard child of the
        bundle's :class:`~repro.obs.numerics.NumericsMonitor` (resolved
        lazily and cached: the fleet tags ``_obs_shard`` after
        construction; -1 = standalone).  None when monitoring is off,
        which keeps every numerics hook a dead branch."""
        mon = self._obs.numerics
        if mon is None:
            return None
        cache = self._num_cache
        if cache is not None and cache[0] == self._obs_shard:
            return cache[1]
        child = mon.shard(self._obs_shard)
        child.declare(("act.z.idx", "act.ht.idx"))
        self._num_cache = (self._obs_shard, child)
        return child

    def _flush_numeric_events(self, mon) -> None:
        """Fold the kernel-side event dict (filled by
        ``qstep.tally_step_events``) into the monitor, once per tick."""
        ev = self._num_events
        counts = {}
        for k in ("act.z.idx", "act.ht.idx"):
            n = ev.pop(k, 0)
            if n:
                counts[k] = n
        if counts:
            mon.count_events(counts)
        pr = ev.pop("pre_range", None)
        if pr is not None:
            mon.note_range("pre", pr[0], pr[1], pr[2], pr[3])

    def _advance(self, resident: np.ndarray) -> TickReport:
        handle = self._advance_begin(resident)
        if handle is None:
            return TickReport()
        avail, rows = handle
        mon = self._numerics()
        tr = self._tracer
        t0 = tr.t()
        if self._device_resident:
            # async dispatch; self._h is consumed by the step.  The
            # output is adopted immediately — emission/tap row pulls
            # (and the staging sync at the top of the NEXT
            # _advance_begin) are the only places the host waits on it.
            # Per-tick numeric tallies are skipped on the resident path
            # (a host recompute would defeat the zero-h-copy contract);
            # emission-row drift telemetry still applies.
            h_new = self.kernel.step_resident(self._resolve_h(), self._x,
                                              avail)
            self._h_inflight = True
        else:
            if mon is not None:
                self.kernel.numeric_events = self._num_events
                if self.config.backend != "exact":
                    # jit/pallas: the accelerated dispatch is never
                    # touched (byte-identity by construction) — recompute
                    # the advanced rows on the host NumPy path to observe
                    # their intermediates
                    self.kernel.tally_numeric_events(self._h, self._x, rows)
            h_new = self.kernel.step_rows(self._h, self._x, avail, rows)
            if mon is not None:
                self.kernel.numeric_events = None
                self._flush_numeric_events(mon)
                self._num_tallied = True
        tr.rec("engine.kernel", t0, self._obs_shard)
        return self._advance_finish(handle, h_new)

    def _advance_begin(self, resident: np.ndarray):
        """Phase one of a tick: compute the advancing-row set and gather one
        sample per advancing slot from the ring into ``self._x``.  Returns
        ``(avail, rows)`` for :meth:`_advance_finish`, or ``None`` when no
        resident stream has a buffered sample.  Split from the kernel call
        so the fleet front door can batch every shard's step into one fused
        kernel dispatch per tick (see ``serve/fleet``)."""
        if self._h_inflight:
            # previous tick's device step may still be reading the _x
            # staging buffer it aliased at device_put time — sync before
            # the gather below overwrites it (the double-buffer boundary:
            # everything since the last dispatch overlapped device compute)
            t0 = self._tracer.t()
            self._h.block_until_ready()
            self._tracer.rec("engine.device_wait", t0, self._obs_shard)
            self._h_inflight = False
        avail = resident & (self._tail > self._head)
        rows = np.nonzero(avail)[0]
        if rows.size == 0:
            return None
        t0 = self._tracer.t()
        # gather one sample per advancing slot from the ring (vectorized)
        x = self._x
        full = rows.size == x.shape[0]
        heads = self._head if full else self._head[rows]
        if np.all(heads == heads[0]):  # lockstep fleet: contiguous slab
            o = int(heads[0]) % self._cap
            if full:
                x[:] = self._ring[o]
            else:
                x[:] = 0.0
                x[rows] = self._ring[o, rows]
        else:                          # streams drifted apart: 2-d gather
            x[:] = 0.0
            x[rows] = self._ring[heads % self._cap, rows]
        self._tracer.rec("engine.gather", t0, self._obs_shard)
        mon = self._numerics()
        self._num_tallied = False
        if mon is not None:
            # input-range telemetry from the already-gathered staging slab
            # (runs on both the standalone _advance path and the fleet's
            # fused tick, which calls the begin/finish halves directly)
            xv = x[rows]
            xl = mon.limit("x")
            xmin, xmax = float(xv.min()), float(xv.max())
            # min/max bound the elementwise scan: only count when the
            # slab actually crosses the calibration amplitude
            n_over = int(np.count_nonzero(np.abs(xv) > xl)) \
                if xl and (xmax > xl or xmin < -xl) else 0
            mon.note_range("x", xmin, xmax, int(xv.size), n_over)
            lim = mon.limit("pre")
            if lim:
                self._num_events["pre_limit"] = lim
        return (avail, rows)

    def _advance_finish(self, handle, h_new: np.ndarray) -> TickReport:
        """Phase two of a tick: accept the stepped hidden states and do the
        bookkeeping — cursors, counters, trajectory taps, window/final
        emission, tumbling-window resets."""
        avail, rows = handle
        t_fin = self._tracer.t()
        self._last_advanced = int(rows.size)
        mon = self._numerics()
        if mon is not None and not self._num_tallied \
                and not self._device_resident:
            # fused fleet tick: the group kernel stepped a cross-shard
            # batch, so per-shard attribution needs a host recompute of
            # this shard's advanced rows from its pre-step state (self._h
            # is still pre-step here).  Monitoring a fused fleet pays
            # this recompute; it defaults off.
            self.kernel.numeric_events = self._num_events
            self.kernel.tally_numeric_events(self._h, self._x, rows)
            self.kernel.numeric_events = None
            self._flush_numeric_events(mon)
            self._num_tallied = True
        if h_new is not None:
            self._h = h_new
            self._h_pending = None
        # h_new None: the fused fleet tick already installed this tick's
        # output as a lazy view spec (see FleetEngine._dispatch_group)
        if rows.size == self._head.size:     # steady state: every slot moved
            self._head += 1
            self._steps += 1
            self._wstep += 1
        else:
            self._head[rows] += 1
            self._steps[rows] += 1
            self._wstep[rows] += 1
        self._stream_steps += int(rows.size)
        if self._spill:
            self._drain_spill()

        if self._n_taps and np.any(self._tap[rows]):
            tap_rows = np.nonzero(self._tap & avail)[0]
            vals = self._h_rows(tap_rows)
            for i, slot in enumerate(tap_rows):
                sid = self._sched.request_at(int(slot))
                self._trajectories[sid].append(vals[i].copy())

        # emission: window boundaries + finished streams (rare -> loops)
        window = self.config.window
        at_window = avail & (self._wstep == window)
        finished = avail & (self._total >= 0) & (self._steps >= self._total)
        emit_rows = np.nonzero(at_window | finished)[0]
        events: list[StreamEvent] = []
        finished_rows: list[int] = []
        if emit_rows.size:               # rare tick: something emits
            t_emit = self._tracer.t()
            # replay cursor: events the consumer already saw before a
            # crash are swallowed; window-reset/finish bookkeeping below
            # still uses the full emit set, so the recovered state
            # transitions are identical to the uninterrupted run
            deliver = emit_rows[
                self._steps[emit_rows] > self._suppress[emit_rows]]
            self._replay_suppressed += int(emit_rows.size - deliver.size)
            if deliver.size:
                h_emit = self._h_rows(deliver)
                logits = self.kernel.head_logits(h_emit)
                mon = self._numerics()
                if mon is not None:
                    # full-histogram drift stats on the rare emission path
                    mon.observe("h", h_emit)
                    mon.observe("logits", logits)
                if self.config.batch_events:
                    events.append(self._event_batch(deliver, at_window,
                                                    logits))
                else:
                    for i, slot in enumerate(deliver):
                        kind = "window" if at_window[slot] else "final"
                        events.append(self._event(
                            self._sched.request_at(int(slot)), int(slot),
                            kind, int(self._wstep[slot]), logits[i]))
                if self._obs.metrics is not None:
                    self._emit_metrics(deliver)
            finished_rows = np.nonzero(finished)[0].tolist()
            if np.any(at_window):
                self._wstep[at_window] = 0
                if self.config.reset_on_emit:
                    if self._device_resident:
                        self._h = self.kernel.reset_device(
                            self._resolve_h(), at_window)
                        self._h_pending = None
                    else:
                        self._h = self.kernel.reset(self._h, at_window)
            self._tracer.rec("engine.emit", t_emit, self._obs_shard)
        self._tracer.rec("engine.finish", t_fin, self._obs_shard)
        return TickReport(events=events, finished=finished_rows,
                          advanced=int(rows.size))

    def _emit_metrics(self, deliver: np.ndarray) -> None:
        """Per-emission SLO metrics (only when a registry is attached):
        warm/cold prediction counters, and the once-per-stream warm-up
        sample count — how many samples a stream consumed before its
        first confident (post-warm-up) prediction, the paper's Sec. VI-A
        stabilization latency measured continuously in serving."""
        reg = self._obs.metrics
        steps = self._steps[deliver]
        warm = steps >= self.config.warmup_samples
        n_warm = int(warm.sum())
        reg.counter("stream.warm_emissions",
                    "predictions at/after the warm-up threshold").inc(n_warm)
        reg.counter("stream.cold_emissions",
                    "predictions before the warm-up threshold").inc(
                        int(deliver.size) - n_warm)
        first = warm & ~self._warm_seen[deliver]
        if np.any(first):
            reg.histogram(
                "stream.warmup_samples",
                "samples consumed before a stream's first warm "
                "prediction (axis = samples, not us)").observe_many_us(
                    steps[first])
            self._warm_seen[deliver[first]] = True

    def _release_slot(self, slot: int, stream_id: str,
                      reason: str) -> StreamEvent | None:
        ev = None
        if reason == "cancelled" and self._wstep[slot] > 0:
            if self._steps[slot] > self._suppress[slot]:
                # detach mid-window: emit the partial-window prediction
                logits = self.kernel.head_logits(
                    self._h_rows(np.array([slot])))[0]
                ev = self._event(stream_id, slot, "final",
                                 int(self._wstep[slot]), logits)
            else:
                self._replay_suppressed += 1
        s = self._sessions.pop(stream_id, None)
        if s is not None:
            s.slot = -1
        self._n_taps -= int(self._tap[slot])
        self._tap[slot] = False
        self._head[slot] = 0
        self._tail[slot] = 0
        self._spill.pop(slot, None)
        return ev

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _resolve_h(self):
        """Materialize the fleet-installed lazy h view, if any.  Fused
        device ticks hand each shard a ``(fused_h, lo, hi)`` spec instead
        of dispatching a per-shard device slice every tick; the first
        row-level access (emission, tap, snapshot, reset) pays the one
        slice.  The spec survives materialization — it is the fleet's
        adoption token — and is cleared only when ``self._h`` is rebound
        to an array that is no longer a view of the fused output."""
        if self._h is None:
            big, lo, hi = self._h_pending
            self._h = big[lo:hi]
        return self._h

    def _h_rows(self, rows) -> np.ndarray:
        """Host values of the given hidden-state rows, backend-agnostic:
        a plain fancy-index copy on the host path, a booked (k, H) d2h
        pull on the device-resident path (only the rows the host actually
        needs — emission, taps — ever cross the boundary)."""
        if self._device_resident:
            return self.kernel.rows_to_host(self._resolve_h(), rows)
        return self._h[rows]

    def _h_row(self, slot: int) -> np.ndarray:
        """One hidden-state row as a fresh host copy (snapshot path).
        Consults the :meth:`prefetch_h` cache so fleet-wide periodic
        checkpoints cost one batched gather, not one device round-trip
        per checkpointed stream."""
        if self._device_resident:
            cache = self._h_prefetch
            if cache is not None and cache[0] is self._h and slot in cache[1]:
                return cache[1][slot].copy()
            return self.kernel.rows_to_host(self._resolve_h(),
                                            np.array([slot]))[0]
        return self._h[slot].copy()

    def prefetch_h(self, slots) -> None:
        """Batch-pull the given slots' hidden rows into a one-shot cache
        keyed on the current device array's *identity* — any subsequent
        step/reset rebinds ``self._h`` (device arrays are immutable) and
        invalidates it automatically.  No-op on the host path, where the
        rows are already resident."""
        if not self._device_resident or len(slots) == 0:
            return
        rows = np.asarray(slots)
        h = self._resolve_h()
        vals = self.kernel.rows_to_host(h, rows)
        self._h_prefetch = (h, {int(s): v for s, v in zip(rows, vals)})

    def _any_buffered(self) -> bool:
        if bool(np.any(self._sched.resident & (self._tail > self._head))):
            return True
        if self._spill:
            return True
        return any(s.chunks for s in self._sessions.values() if s.slot < 0)

    def _ring_write(self, slot: int, samples: np.ndarray) -> None:
        k = len(samples)
        if k == 0:
            return
        if slot in self._spill:          # keep FIFO order behind the spill
            self._spill[slot].append(samples)
            return
        needed = int(self._tail[slot] - self._head[slot]) + k
        if needed > self._cap and self._cap < self.config.max_ring_capacity:
            self._grow_ring(min(needed, self.config.max_ring_capacity))
        space = self._cap - int(self._tail[slot] - self._head[slot])
        take = min(space, k)
        if take:
            idx = (self._tail[slot] + np.arange(take)) % self._cap
            self._ring[idx, slot] = samples[:take]
            self._tail[slot] += take
        if take < k:                     # backlog beyond the shared ring
            self._spill[slot] = collections.deque([samples[take:]])
            self._ring_spills += 1

    def _drain_spill(self) -> None:
        """Refill rings from spilled backlogs as space frees (rare path —
        only slots that were ever fed past max_ring_capacity)."""
        for slot in list(self._spill):
            q = self._spill[slot]
            while q:
                space = self._cap - int(self._tail[slot] - self._head[slot])
                if space <= 0:
                    break
                chunk = q.popleft()
                take = min(space, len(chunk))
                idx = (self._tail[slot] + np.arange(take)) % self._cap
                self._ring[idx, slot] = chunk[:take]
                self._tail[slot] += take
                if take < len(chunk):
                    q.appendleft(chunk[take:])
                    break
            if not q:
                del self._spill[slot]

    def _grow_ring(self, needed: int) -> None:
        new_cap = self._cap
        while new_cap < needed:
            new_cap *= 2
        new_cap = min(new_cap, max(self.config.max_ring_capacity, self._cap))
        if new_cap == self._cap:
            return
        ring = np.zeros((new_cap, self._ring.shape[1], self._ring.shape[2]),
                        np.float32)
        navail = self._tail - self._head
        for slot in np.nonzero(navail > 0)[0]:
            n = int(navail[slot])
            idx = (self._head[slot] + np.arange(n)) % self._cap
            ring[:n, slot] = self._ring[idx, slot]
        self._head[:] = 0                 # re-base cursors onto the copy
        self._tail[:] = navail
        self._ring, self._cap = ring, new_cap

    def _event_batch(self, emit_rows: np.ndarray, at_window: np.ndarray,
                     logits: np.ndarray) -> StreamEventBatch:
        """Columnar emission: every per-stream field sliced as an array;
        the only per-row Python is the slot -> stream-id lookup."""
        req = self._sched._slot_request
        steps = self._steps[emit_rows]
        return StreamEventBatch(
            stream_ids=[req[i] for i in emit_rows.tolist()],
            final=~at_window[emit_rows],
            steps=steps,
            window_steps=self._wstep[emit_rows],
            predictions=np.argmax(logits, axis=1).astype(np.int32),
            logits=np.asarray(logits, np.float32),
            warm=steps >= self.config.warmup_samples)

    def _event(self, stream_id: str, slot: int, kind: str, window_step: int,
               logits: np.ndarray) -> StreamEvent:
        steps = int(self._steps[slot])
        return StreamEvent(
            stream_id=stream_id, kind=kind, step=steps,
            window_step=window_step or self.config.window,
            prediction=int(np.argmax(logits)),
            logits=np.asarray(logits, np.float32).copy(),
            warm=steps >= self.config.warmup_samples)

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    @property
    def n_active(self) -> int:
        return self._sched.n_active

    @property
    def n_pending(self) -> int:
        return self._sched.n_pending

    def stats(self) -> dict[str, Any]:
        sched = self._sched.stats()
        mon = self._numerics()
        extra = {} if mon is None else {"numerics": mon.snapshot()}
        return {
            **extra,
            "backend": self.config.backend,
            "device_resident": self._device_resident,
            "transfers": self.kernel.transfers.snapshot(),
            "max_slots": self.config.max_slots,
            "active": sched["active"],
            "pending": sched["pending"],
            "peak_active": sched["peak_active"],
            "ticks": sched["ticks"],
            "stream_steps": self._stream_steps,
            "completed": sched["completed"] + sched["cancelled"],
            "ring_capacity": self._cap,
            "ring_spills": self._ring_spills,
            "replay_suppressed": self._replay_suppressed,
            # scheduler counters (admissions/recycles/spills/occupancy):
            # the observability surface the sharded-streaming work needs
            "scheduler": sched,
        }


def classify_windows(engine: StreamingEngine, windows: np.ndarray,
                     ids: Iterable[str] | None = None) -> np.ndarray:
    """Convenience: replay (N, T, d) windows as N finite streams through the
    engine (continuous batching if N > max_slots) and return the (N,) final
    predictions — the streaming equivalent of ``QRuntime.predict_batch``."""
    windows = np.asarray(windows, np.float32)
    ids = list(ids) if ids is not None else [f"w{i}" for i in range(len(windows))]
    for sid, w in zip(ids, windows):
        engine.attach(sid, w, total_steps=len(w))
    events = engine.drain()
    final: dict[str, int] = {}
    for e in events:
        if isinstance(e, StreamEventBatch):
            final.update(zip(e.stream_ids, (int(p) for p in e.predictions)))
        elif e.kind in ("window", "final"):
            final[e.stream_id] = e.prediction
    return np.array([final[sid] for sid in ids], np.int32)
