"""Multi-stream streaming inference engine for the deployed Q15 FastGRNN.

The paper deploys one 566-byte FastGRNN per microcontroller, classifying a
live 50 Hz tri-axial accelerometer stream in real time.  This module is the
server-side analogue of a *fleet* of such sensors: thousands of concurrent
stateful sessions (one hidden state + warm-up counter each) stepped in
lockstep by the batched Q15 single-step kernel
(``kernels/fastgrnn_cell.ops.Q15StreamStep``), with slot-based continuous
batching modeled on ``serve/engine.py`` — streams attach and detach at step
boundaries, and finished or detached slots are recycled from a pending
queue.

Determinism contract: with the default ``backend="exact"`` every stream's
hidden trajectory, logits and predictions are **bit-identical** to running
the scalar ``core/qruntime.QRuntime`` over the same samples (paper
contribution (i) — cross-platform agreement — preserved at batch scale).
The ``"jit"`` / ``"pallas"`` backends trade that for throughput (XLA
contracts mul+add into FMA, ~1e-9/step drift; argmax predictions agree in
practice).

Lifecycle::

    engine = StreamingEngine(qp)                     # or float params
    engine.attach("sensor-7", samples, total_steps=128)
    events = engine.step()        # one synchronous tick over all slots
    events += engine.drain()      # tick until no stream can advance
    engine.detach("sensor-7")     # early termination -> final event

Each emitted :class:`StreamEvent` carries the per-stream warm-up counter
state: predictions before ``warmup_samples`` total steps (paper Sec. VI-A:
median stabilization 74 samples = 1.48 s at 50 Hz) are flagged cold.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Iterable, Mapping

import numpy as np

from repro.core import quantization as q
from repro.kernels.fastgrnn_cell.ops import Q15StreamStep


@dataclasses.dataclass(frozen=True)
class StreamingConfig:
    max_slots: int = 1024        # resident batch width (concurrent streams)
    window: int = 128            # samples per classification window (paper)
    warmup_samples: int = 74     # paper Sec. VI-A median t* at 50 Hz
    sample_rate_hz: float = 50.0
    reset_on_emit: bool = True   # tumbling windows (matches QRuntime.predict)
    backend: str = "exact"       # "exact" | "jit" | "pallas"
    interpret: bool = True       # pallas backend: interpret mode (CPU)


@dataclasses.dataclass
class StreamEvent:
    """One emitted prediction (window boundary, stream end, or detach)."""
    stream_id: str
    kind: str                    # "window" | "final"
    step: int                    # total per-stream samples consumed so far
    window_step: int             # samples in the window this was emitted from
    prediction: int
    logits: np.ndarray           # (C,) f32
    warm: bool                   # step >= warmup_samples (Sec. VI-A)


@dataclasses.dataclass
class _Session:
    stream_id: str
    slot: int = -1                       # -1 -> pending (no resident slot)
    steps: int = 0                       # warm-up counter (samples consumed)
    window_step: int = 0
    total_steps: int | None = None       # finite stream length; None = open
    buffer: collections.deque = dataclasses.field(
        default_factory=collections.deque)

    @property
    def finished(self) -> bool:
        return self.total_steps is not None and self.steps >= self.total_steps


class StreamingEngine:
    """Slot-based continuous batching of stateful FastGRNN sessions."""

    def __init__(self, params_or_qp, config: StreamingConfig = StreamingConfig(),
                 *, quant: q.QuantConfig = q.QuantConfig(),
                 act_scales: dict[str, float] | None = None,
                 naive_acts: bool = False):
        if isinstance(params_or_qp, q.QuantizedParams):
            self.qp = params_or_qp
        else:  # float param pytree -> per-tensor Q15 PTQ (Appendix B)
            self.qp = q.quantize_params(params_or_qp, quant)
        self.config = config
        self.kernel = Q15StreamStep(self.qp, act_scales=act_scales,
                                    naive_acts=naive_acts,
                                    backend=config.backend,
                                    interpret=config.interpret)
        S = config.max_slots
        self._h = self.kernel.init_state(S)
        self._x = np.zeros((S, self.kernel.input_dim), np.float32)
        self._active = np.zeros((S,), bool)
        self._sessions: dict[str, _Session] = {}
        self._slot_owner: list[str | None] = [None] * S
        self._free: list[int] = list(range(S - 1, -1, -1))
        self._dirty = np.zeros((S,), bool)   # freed slots with stale state
        self._pending: collections.deque[str] = collections.deque()
        # telemetry
        self._ticks = 0
        self._stream_steps = 0
        self._completed = 0
        self._peak_active = 0

    # ------------------------------------------------------------------
    # Session lifecycle
    # ------------------------------------------------------------------
    def attach(self, stream_id: str, samples: np.ndarray | None = None, *,
               total_steps: int | None = None) -> str:
        """Register a stream.  Returns ``"active"`` if a slot was free,
        ``"pending"`` if the stream was queued for the next free slot.

        ``samples``: optional initial (k, d) buffer; more via :meth:`feed`.
        ``total_steps``: finite stream length — the session auto-finishes
        (emitting a final event and recycling its slot) after that many
        samples.  ``None`` keeps the stream open until :meth:`detach`.
        """
        if stream_id in self._sessions:
            raise ValueError(f"stream {stream_id!r} already attached")
        s = _Session(stream_id=stream_id, total_steps=total_steps)
        self._sessions[stream_id] = s
        if samples is not None:
            self.feed(stream_id, samples)
        # FIFO fairness: a free slot goes to the new stream only when no
        # earlier stream is already waiting, else the queue would starve
        if self._free and not self._pending:
            self._place(s, self._free.pop())
            return "active"
        self._pending.append(stream_id)
        return "pending"

    def feed(self, stream_id: str, samples: np.ndarray) -> None:
        """Append samples ((d,) or (k, d)) to a stream's input buffer."""
        s = self._sessions[stream_id]
        samples = np.asarray(samples, np.float32)
        if samples.ndim == 1:
            samples = samples[None, :]
        if samples.ndim != 2 or samples.shape[1] != self.kernel.input_dim:
            raise ValueError(
                f"stream {stream_id!r}: samples must be (k, "
                f"{self.kernel.input_dim}), got {samples.shape}")
        s.buffer.extend(samples)

    def detach(self, stream_id: str) -> StreamEvent | None:
        """Terminate a stream at a step boundary.  If it consumed samples
        since its last window emission, a ``"final"`` event for the partial
        window is returned; its slot is recycled to the pending queue."""
        s = self._sessions.pop(stream_id)
        ev = None
        if s.slot >= 0:
            if s.window_step > 0:
                logits = self.kernel.head_logits(
                    self._h[s.slot:s.slot + 1])[0]
                ev = self._event(s, "final", logits)
            self._release(s.slot)
        else:
            self._pending.remove(stream_id)
        self._completed += 1
        return ev

    # ------------------------------------------------------------------
    # Stepping
    # ------------------------------------------------------------------
    def step(self) -> list[StreamEvent]:
        """One synchronous tick: admit pending streams into free slots,
        advance every resident stream that has a buffered sample by exactly
        one step, and emit window/final events.  Streams without buffered
        samples idle (hidden state held bit-for-bit)."""
        self._admit()
        x, active = self._x, self._active
        x[:] = 0.0
        active[:] = False
        stepped: list[_Session] = []
        for sid in list(self._slot_owner):
            if sid is None:
                continue
            s = self._sessions[sid]
            if s.buffer:
                x[s.slot] = s.buffer.popleft()
                active[s.slot] = True
                stepped.append(s)
        if not stepped:
            return []
        self._h = self.kernel.step(self._h, x, active)
        self._ticks += 1
        self._stream_steps += len(stepped)

        # logits are computed only for emitting slots — most ticks emit
        # nothing, so running the head over all slots every tick would
        # throw away ~(window-1)/window of the work
        emits: list[tuple[_Session, str]] = []
        for s in stepped:
            s.steps += 1
            s.window_step += 1
            if s.window_step == self.config.window:
                emits.append((s, "window"))
            elif s.finished:               # partial window at stream end
                emits.append((s, "final"))
        events: list[StreamEvent] = []
        if emits:
            rows = np.array([s.slot for s, _ in emits])
            logits = self.kernel.head_logits(self._h[rows])
            events = [self._event(s, kind, logits[i])
                      for i, (s, kind) in enumerate(emits)]

        reset = np.zeros((self.config.max_slots,), bool)
        for s in stepped:
            if s.window_step == self.config.window:
                s.window_step = 0
                if self.config.reset_on_emit:
                    reset[s.slot] = True
            if s.finished:
                del self._sessions[s.stream_id]
                self._release(s.slot)
                self._completed += 1
        if reset.any():
            self._h = self.kernel.reset(self._h, reset)
        return events

    def drain(self) -> list[StreamEvent]:
        """Tick until no resident or pending stream can advance (buffers
        empty).  Open streams stay attached; feed more and step again."""
        events: list[StreamEvent] = []
        while any(s.buffer for s in self._sessions.values()):
            out = self.step()
            if not out and not any(
                    s.buffer for s in self._sessions.values() if s.slot >= 0):
                break  # only pending streams hold samples and no slot frees
            events.extend(out)
        return events

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _place(self, s: _Session, slot: int) -> None:
        s.slot = slot
        self._slot_owner[slot] = s.stream_id
        if self._dirty[slot]:  # recycled slot: zero the previous state
            self._h = self.kernel.reset(
                self._h, np.arange(self.config.max_slots) == slot)
            self._dirty[slot] = False
        n_active = self.config.max_slots - len(self._free)
        self._peak_active = max(self._peak_active, n_active)

    def _release(self, slot: int) -> None:
        self._slot_owner[slot] = None
        self._dirty[slot] = True
        self._free.append(slot)

    def _admit(self) -> None:
        while self._free and self._pending:
            sid = self._pending.popleft()
            self._place(self._sessions[sid], self._free.pop())

    def _event(self, s: _Session, kind: str, logits: np.ndarray) -> StreamEvent:
        return StreamEvent(
            stream_id=s.stream_id, kind=kind, step=s.steps,
            window_step=s.window_step or self.config.window,
            prediction=int(np.argmax(logits)),
            logits=np.asarray(logits, np.float32).copy(),
            warm=s.steps >= self.config.warmup_samples)

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    @property
    def n_active(self) -> int:
        return self.config.max_slots - len(self._free)

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    def stats(self) -> dict[str, Any]:
        return {
            "backend": self.config.backend,
            "max_slots": self.config.max_slots,
            "active": self.n_active,
            "pending": self.n_pending,
            "peak_active": self._peak_active,
            "ticks": self._ticks,
            "stream_steps": self._stream_steps,
            "completed": self._completed,
        }


def classify_windows(engine: StreamingEngine, windows: np.ndarray,
                     ids: Iterable[str] | None = None) -> np.ndarray:
    """Convenience: replay (N, T, d) windows as N finite streams through the
    engine (continuous batching if N > max_slots) and return the (N,) final
    predictions — the streaming equivalent of ``QRuntime.predict_batch``."""
    windows = np.asarray(windows, np.float32)
    ids = list(ids) if ids is not None else [f"w{i}" for i in range(len(windows))]
    for sid, w in zip(ids, windows):
        engine.attach(sid, w, total_steps=len(w))
    events = engine.drain()
    final = {e.stream_id: e.prediction for e in events
             if e.kind in ("window", "final")}
    return np.array([final[sid] for sid in ids], np.int32)
