"""Multi-stream streaming inference engine for the deployed Q15 FastGRNN.

The paper deploys one 566-byte FastGRNN per microcontroller, classifying a
live 50 Hz tri-axial accelerometer stream in real time.  This module is the
server-side analogue of a *fleet* of such sensors: thousands of concurrent
stateful sessions (one hidden state + warm-up counter each) stepped in
lockstep by the batched Q15 single-step kernel
(``kernels/fastgrnn_cell.ops.Q15StreamStep``).

Placement — which stream occupies which resident slot, FIFO admission from
the pending queue, slot recycling when a stream finishes or detaches — is
delegated to the engine-agnostic :class:`repro.serve.scheduler.SlotScheduler`;
this module implements the workload half of that split (the
:class:`~repro.serve.scheduler.SlotProgram` protocol): per-slot FastGRNN
state, sample rings, window counters, and event emission.  The LM engine
(``serve/engine.py``) rides the identical scheduler.

Workload state is a **NumPy slot table**, not per-session Python objects:
per-slot step counters, window positions, stream lengths and sample
cursors are columns of (S,)-shaped arrays, and buffered samples live in
one (S, cap, d) ring buffer, so a tick costs a handful of vectorized ops +
one fancy-index gather instead of a Python loop over every resident
stream.  Python loops remain only on the rare paths: admission,
completion, and event emission.

Determinism contract: with the default ``backend="exact"`` every stream's
hidden trajectory, logits and predictions are **bit-identical** to running
the scalar ``core/qruntime.QRuntime`` over the same samples (paper
contribution (i) — cross-platform agreement — preserved at batch scale).
The ``"jit"`` / ``"pallas"`` backends trade that for throughput (XLA
contracts mul+add into FMA, ~1e-9/step drift; argmax predictions agree in
practice).

Lifecycle::

    engine = StreamingEngine(qp)                     # or float params
    engine.attach("sensor-7", samples, total_steps=128)
    events = engine.step()        # one synchronous tick over all slots
    events += engine.drain()      # tick until no stream can advance
    engine.detach("sensor-7")     # early termination -> final event

Each emitted :class:`StreamEvent` carries the per-stream warm-up counter
state: predictions before ``warmup_samples`` total steps (paper Sec. VI-A:
median stabilization 74 samples = 1.48 s at 50 Hz) are flagged cold.

Trajectory taps (deployment parity): ``attach(..., record_trajectory=True)``
captures the stream's per-step hidden states; :meth:`StreamingEngine.trajectory`
returns them (bit-identical to ``QRuntime.run_window``'s trajectory under
the exact backend) — the cross-engine witness used by ``repro.deploy.verify``.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Iterable

import numpy as np

from repro.compress.artifact import ModelArtifact
from repro.core import quantization as q
from repro.kernels.fastgrnn_cell.ops import Q15StreamStep
from repro.serve.scheduler import HostProgram, SlotScheduler, TickReport


@dataclasses.dataclass(frozen=True)
class StreamingConfig:
    max_slots: int = 1024        # resident batch width (concurrent streams)
    window: int = 128            # samples per classification window (paper)
    warmup_samples: int = 74     # paper Sec. VI-A median t* at 50 Hz
    sample_rate_hz: float = 50.0
    reset_on_emit: bool = True   # tumbling windows (matches QRuntime.predict)
    backend: str = "exact"       # "exact" | "jit" | "pallas"
    interpret: bool = True       # pallas backend: interpret mode (CPU)
    ring_capacity: int = 256     # initial per-slot sample ring (grows 2x)
    max_ring_capacity: int = 1024  # growth cap: the ring is (S, cap, d)
    # shared, so one stream's deep backlog must not allocate O(S * backlog);
    # samples beyond the cap spill to a per-slot chunk queue and drain into
    # the ring as it frees


@dataclasses.dataclass
class StreamEvent:
    """One emitted prediction (window boundary, stream end, or detach)."""
    stream_id: str
    kind: str                    # "window" | "final"
    step: int                    # total per-stream samples consumed so far
    window_step: int             # samples in the window this was emitted from
    prediction: int
    logits: np.ndarray           # (C,) f32
    warm: bool                   # step >= warmup_samples (Sec. VI-A)


@dataclasses.dataclass
class _Session:
    """Thin per-stream handle.  Counters/cursors live in the engine's slot
    table; this only tracks identity, placement, the not-yet-placed sample
    chunks of pending streams, the finite-length target, and the
    trajectory-tap flag."""
    stream_id: str
    slot: int = -1                       # -1 -> pending (no resident slot)
    total: int | None = None             # finite stream length; None = open
    chunks: collections.deque = dataclasses.field(
        default_factory=collections.deque)   # buffered while pending
    record_trajectory: bool = False


class StreamingEngine:
    """Slot-based continuous batching of stateful FastGRNN sessions."""

    def __init__(self, params_or_qp, config: StreamingConfig | None = None,
                 *, quant: q.QuantConfig | None = None,
                 act_scales: dict[str, float] | None = None,
                 naive_acts: bool = False):
        if isinstance(params_or_qp, ModelArtifact):
            # deployed config: FP32 acts through the LUT.  The artifact's
            # deploy calibration scales are export-compiler scales, NOT
            # activation-storage scales; opt into Table V storage quant
            # explicitly (from_artifact(quantized_acts=True)).
            self.qp = params_or_qp.require_qp()
        elif isinstance(params_or_qp, q.QuantizedParams):
            self.qp = params_or_qp
        else:  # float param pytree -> per-tensor Q15 PTQ (Appendix B)
            self.qp = q.quantize_params(params_or_qp, quant or q.QuantConfig())
        config = config or StreamingConfig()
        self.config = config
        self.kernel = Q15StreamStep(self.qp, act_scales=act_scales,
                                    naive_acts=naive_acts,
                                    backend=config.backend,
                                    interpret=config.interpret)
        S, d = config.max_slots, self.kernel.input_dim
        self._h = self.kernel.init_state(S)
        self._x = np.zeros((S, d), np.float32)
        # --- slot table (vectorized workload state) --------------------
        self._steps = np.zeros(S, np.int64)      # samples consumed
        self._wstep = np.zeros(S, np.int64)      # position in current window
        self._total = np.full(S, -1, np.int64)   # finite length; -1 = open
        self._head = np.zeros(S, np.int64)       # ring read cursor (absolute)
        self._tail = np.zeros(S, np.int64)       # ring write cursor (absolute)
        self._cap = max(8, min(config.ring_capacity, config.max_ring_capacity))
        self._ring = np.zeros((S, self._cap, d), np.float32)
        self._spill: dict[int, collections.deque] = {}  # slot -> chunk queue
        self._tap = np.zeros(S, bool)            # trajectory-tap flag
        # --- placement: delegated to the shared slot scheduler ---------
        self._sched = SlotScheduler(S, HostProgram(self))
        self._sessions: dict[str, _Session] = {}
        self._trajectories: dict[str, list[np.ndarray]] = {}
        # telemetry (workload side; placement counters live in the scheduler)
        self._stream_steps = 0
        self._ring_spills = 0

    @classmethod
    def from_artifact(cls, artifact: ModelArtifact,
                      config: StreamingConfig | None = None, *,
                      quantized_acts: bool = False,
                      naive_acts: bool = False) -> "StreamingEngine":
        """Build the engine from a compression-pipeline artifact.  The
        default is the deployed configuration (FP32 acts, bit-identical to
        ``QRuntime.from_artifact``); ``quantized_acts=True`` selects the
        Table V calibrated-Q15-activation mode via
        ``ModelArtifact.runtime_scales`` (the gate shared with QRuntime)."""
        return cls(artifact, config,
                   act_scales=artifact.runtime_scales(quantized_acts),
                   naive_acts=naive_acts)

    # ------------------------------------------------------------------
    # Session lifecycle
    # ------------------------------------------------------------------
    def attach(self, stream_id: str, samples: np.ndarray | None = None, *,
               total_steps: int | None = None,
               record_trajectory: bool = False) -> str:
        """Register a stream.  Returns ``"active"`` if a slot was free,
        ``"pending"`` if the stream was queued for the next free slot.

        ``samples``: optional initial (k, d) buffer; more via :meth:`feed`.
        ``total_steps``: finite stream length — the session auto-finishes
        (emitting a final event and recycling its slot) after that many
        samples.  ``None`` keeps the stream open until :meth:`detach`.
        ``record_trajectory``: tap the per-step hidden states (exact
        backend: bit-identical to the scalar reference trajectory).
        """
        if stream_id in self._sessions:
            raise ValueError(f"stream {stream_id!r} already attached")
        s = _Session(stream_id=stream_id, total=total_steps,
                     record_trajectory=record_trajectory)
        self._sessions[stream_id] = s
        if record_trajectory:
            self._trajectories[stream_id] = []
        if samples is not None:
            self.feed(stream_id, samples)
        # the scheduler preserves FIFO fairness: a free slot goes to the
        # new stream only when no earlier stream is already waiting
        return self._sched.submit(stream_id, s)

    def feed(self, stream_id: str, samples: np.ndarray) -> None:
        """Append samples ((d,) or (k, d)) to a stream's input buffer."""
        s = self._sessions[stream_id]
        samples = np.asarray(samples, np.float32)
        if samples.ndim == 1:
            samples = samples[None, :]
        if samples.ndim != 2 or samples.shape[1] != self.kernel.input_dim:
            raise ValueError(
                f"stream {stream_id!r}: samples must be (k, "
                f"{self.kernel.input_dim}), got {samples.shape}")
        if s.slot < 0:
            s.chunks.append(samples)
        else:
            self._ring_write(s.slot, samples)

    def detach(self, stream_id: str) -> StreamEvent | None:
        """Terminate a stream at a step boundary.  If it consumed samples
        since its last window emission, a ``"final"`` event for the partial
        window is returned; its slot is recycled to the pending queue."""
        if stream_id not in self._sessions:
            raise KeyError(f"stream {stream_id!r} is not attached")
        ev = self._sched.cancel(stream_id)
        self._sessions.pop(stream_id, None)   # pending path (resident path
        return ev                             # popped in _release_slot)

    # ------------------------------------------------------------------
    # Stepping
    # ------------------------------------------------------------------
    def step(self) -> list[StreamEvent]:
        """One synchronous tick: the scheduler admits pending streams into
        free slots, the program advances every resident stream that has a
        buffered sample by exactly one step, and window/final events are
        emitted.  Streams without buffered samples idle (hidden state held
        bit-for-bit)."""
        return self._sched.tick()

    def drain(self) -> list[StreamEvent]:
        """Tick until no resident or pending stream can advance (buffers
        empty).  Open streams stay attached; feed more and step again."""
        events: list[StreamEvent] = []
        while self._any_buffered():
            out = self.step()
            if not out and not bool(np.any(
                    self._sched.resident & (self._tail > self._head))):
                break  # only pending streams hold samples and no slot frees
            events.extend(out)
        return events

    # ------------------------------------------------------------------
    # Trajectory taps (deployment parity harness)
    # ------------------------------------------------------------------
    def trajectory(self, stream_id: str) -> np.ndarray:
        """(steps, H) hidden trajectory of a tapped stream (attach with
        ``record_trajectory=True``).  Survives stream completion/detach."""
        if stream_id not in self._trajectories:
            raise KeyError(f"stream {stream_id!r} was not tapped")
        rows = self._trajectories[stream_id]
        H = self.kernel.hidden_dim
        return (np.stack(rows) if rows else np.zeros((0, H), np.float32))

    # ------------------------------------------------------------------
    # SlotProgram hooks (called by the scheduler via HostProgram)
    # ------------------------------------------------------------------
    def _admit_slot(self, slot: int, stream_id: str, s: _Session,
                    reset: bool) -> None:
        s.slot = slot
        if reset:  # recycled slot: zero the previous stream's hidden state
            self._h = self.kernel.reset(
                self._h, np.arange(self.config.max_slots) == slot)
        self._steps[slot] = 0
        self._wstep[slot] = 0
        self._total[slot] = -1 if s.total is None else int(s.total)
        self._head[slot] = 0
        self._tail[slot] = 0
        self._tap[slot] = s.record_trajectory
        while s.chunks:
            self._ring_write(slot, s.chunks.popleft())

    def _advance(self, resident: np.ndarray) -> TickReport:
        avail = resident & (self._tail > self._head)
        rows = np.nonzero(avail)[0]
        if rows.size == 0:
            return TickReport()
        # gather one sample per advancing slot from the ring (vectorized)
        x = self._x
        x[:] = 0.0
        x[rows] = self._ring[rows, self._head[rows] % self._cap]
        self._h = self.kernel.step_rows(self._h, x, avail, rows)
        self._head[rows] += 1
        self._steps[rows] += 1
        self._wstep[rows] += 1
        self._stream_steps += int(rows.size)
        if self._spill:
            self._drain_spill()

        if np.any(self._tap[rows]):
            for i in np.nonzero(self._tap & avail)[0]:
                sid = self._sched.request_at(i)
                self._trajectories[sid].append(self._h[i].copy())

        # emission: window boundaries + finished streams (rare -> loops)
        window = self.config.window
        at_window = avail & (self._wstep == window)
        finished = avail & (self._total >= 0) & (self._steps >= self._total)
        emit_rows = np.nonzero(at_window | finished)[0]
        events: list[StreamEvent] = []
        if emit_rows.size:
            logits = self.kernel.head_logits(self._h[emit_rows])
            for i, slot in enumerate(emit_rows):
                kind = "window" if at_window[slot] else "final"
                events.append(self._event(
                    self._sched.request_at(int(slot)), int(slot), kind,
                    int(self._wstep[slot]), logits[i]))

        if np.any(at_window):
            self._wstep[at_window] = 0
            if self.config.reset_on_emit:
                self._h = self.kernel.reset(self._h, at_window)
        return TickReport(events=events,
                          finished=np.nonzero(finished)[0].tolist(),
                          advanced=int(rows.size))

    def _release_slot(self, slot: int, stream_id: str,
                      reason: str) -> StreamEvent | None:
        ev = None
        if reason == "cancelled" and self._wstep[slot] > 0:
            # detach mid-window: emit the partial-window prediction
            logits = self.kernel.head_logits(self._h[slot:slot + 1])[0]
            ev = self._event(stream_id, slot, "final",
                             int(self._wstep[slot]), logits)
        s = self._sessions.pop(stream_id, None)
        if s is not None:
            s.slot = -1
        self._tap[slot] = False
        self._head[slot] = 0
        self._tail[slot] = 0
        self._spill.pop(slot, None)
        return ev

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _any_buffered(self) -> bool:
        if bool(np.any(self._sched.resident & (self._tail > self._head))):
            return True
        if self._spill:
            return True
        return any(s.chunks for s in self._sessions.values() if s.slot < 0)

    def _ring_write(self, slot: int, samples: np.ndarray) -> None:
        k = len(samples)
        if k == 0:
            return
        if slot in self._spill:          # keep FIFO order behind the spill
            self._spill[slot].append(samples)
            return
        needed = int(self._tail[slot] - self._head[slot]) + k
        if needed > self._cap and self._cap < self.config.max_ring_capacity:
            self._grow_ring(min(needed, self.config.max_ring_capacity))
        space = self._cap - int(self._tail[slot] - self._head[slot])
        take = min(space, k)
        if take:
            idx = (self._tail[slot] + np.arange(take)) % self._cap
            self._ring[slot, idx] = samples[:take]
            self._tail[slot] += take
        if take < k:                     # backlog beyond the shared ring
            self._spill[slot] = collections.deque([samples[take:]])
            self._ring_spills += 1

    def _drain_spill(self) -> None:
        """Refill rings from spilled backlogs as space frees (rare path —
        only slots that were ever fed past max_ring_capacity)."""
        for slot in list(self._spill):
            q = self._spill[slot]
            while q:
                space = self._cap - int(self._tail[slot] - self._head[slot])
                if space <= 0:
                    break
                chunk = q.popleft()
                take = min(space, len(chunk))
                idx = (self._tail[slot] + np.arange(take)) % self._cap
                self._ring[slot, idx] = chunk[:take]
                self._tail[slot] += take
                if take < len(chunk):
                    q.appendleft(chunk[take:])
                    break
            if not q:
                del self._spill[slot]

    def _grow_ring(self, needed: int) -> None:
        new_cap = self._cap
        while new_cap < needed:
            new_cap *= 2
        new_cap = min(new_cap, max(self.config.max_ring_capacity, self._cap))
        if new_cap == self._cap:
            return
        ring = np.zeros((self._ring.shape[0], new_cap, self._ring.shape[2]),
                        np.float32)
        navail = self._tail - self._head
        for slot in np.nonzero(navail > 0)[0]:
            n = int(navail[slot])
            idx = (self._head[slot] + np.arange(n)) % self._cap
            ring[slot, :n] = self._ring[slot, idx]
        self._head[:] = 0                 # re-base cursors onto the copy
        self._tail[:] = navail
        self._ring, self._cap = ring, new_cap

    def _event(self, stream_id: str, slot: int, kind: str, window_step: int,
               logits: np.ndarray) -> StreamEvent:
        steps = int(self._steps[slot])
        return StreamEvent(
            stream_id=stream_id, kind=kind, step=steps,
            window_step=window_step or self.config.window,
            prediction=int(np.argmax(logits)),
            logits=np.asarray(logits, np.float32).copy(),
            warm=steps >= self.config.warmup_samples)

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    @property
    def n_active(self) -> int:
        return self._sched.n_active

    @property
    def n_pending(self) -> int:
        return self._sched.n_pending

    def stats(self) -> dict[str, Any]:
        sched = self._sched.stats()
        return {
            "backend": self.config.backend,
            "max_slots": self.config.max_slots,
            "active": sched["active"],
            "pending": sched["pending"],
            "peak_active": sched["peak_active"],
            "ticks": sched["ticks"],
            "stream_steps": self._stream_steps,
            "completed": sched["completed"] + sched["cancelled"],
            "ring_capacity": self._cap,
            "ring_spills": self._ring_spills,
            # scheduler counters (admissions/recycles/spills/occupancy):
            # the observability surface the sharded-streaming work needs
            "scheduler": sched,
        }


def classify_windows(engine: StreamingEngine, windows: np.ndarray,
                     ids: Iterable[str] | None = None) -> np.ndarray:
    """Convenience: replay (N, T, d) windows as N finite streams through the
    engine (continuous batching if N > max_slots) and return the (N,) final
    predictions — the streaming equivalent of ``QRuntime.predict_batch``."""
    windows = np.asarray(windows, np.float32)
    ids = list(ids) if ids is not None else [f"w{i}" for i in range(len(windows))]
    for sid, w in zip(ids, windows):
        engine.attach(sid, w, total_steps=len(w))
    events = engine.drain()
    final = {e.stream_id: e.prediction for e in events
             if e.kind in ("window", "final")}
    return np.array([final[sid] for sid in ids], np.int32)
