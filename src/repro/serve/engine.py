"""Continuous-batching LM serving engine on the shared slot scheduler.

The paper's systems thesis — a tiny stateful cell plus careful scheduler/
runtime work beats bigger budgets (Sec. VI; Saha et al. 2022 call the
runtime the dominant efficiency lever) — applied at LM scale.  This engine
is the LM half of the scheduler/program split (see ``serve/scheduler.py``):
the :class:`~repro.serve.scheduler.SlotScheduler` owns placement (slot
table, pending queue, FIFO admission, recycling, counters) and this module
implements the :class:`~repro.serve.scheduler.SlotProgram` — per-slot KV /
SSM cache rows, preallocated output buffers, and batched sampling.

Design notes
------------
* **True continuous batching**: a finished sequence's KV-cache slot is
  re-prefilled from the pending queue on the next tick — not at window
  boundaries.  The cache is a slot table (``models/transformer.
  init_slot_cache``) with a per-slot fill level ``pos`` (S,); admission
  writes one sequence's prefix into its slot (``prefill_into_slot``) while
  the neighbours keep decoding, and every tick is ONE fixed-shape jit call
  (``decode_step_slotted``) regardless of occupancy.
* **Preallocated output**: generated tokens land in a fixed (S, cap) int32
  buffer at a per-slot cursor — decode cost is O(T), not the O(T^2)
  ``np.concatenate``-per-token of the old loop.
* **Quantized serving**: ``repro.compress.quantize_tree`` (the pass-API
  home of the per-tensor PTQ recipe) produces a Q15/Q7 weight pytree +
  scales.  The
  backbone runs over
  dequantized weights (decode is HBM-bound; int8 weights halve the
  dominant roofline term on real hardware), and the sampling head — the
  one matmul the engine itself owns — runs the *actual* integer weights
  through ``kernels/q15_matmul`` (dequantize-inside-the-kernel), so the
  quantized pytree is load-bearing, not decoration.
* ``admit_policy="all_free"`` recovers the old window-boundary behaviour
  (admit only when every slot is free) — kept as the measurable baseline
  for ``benchmarks/serve_bench.py``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress.tree import dequantize_tree, quantize_tree
from repro.models import transformer as T
from repro.obs import NULL_OBS, Observability
from repro.serve.scheduler import HostProgram, SlotScheduler, TickReport


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 2048             # per-slot KV capacity (prompt + new)
    max_slots: int = 8              # resident batch width (decode batch)
    temperature: float = 0.0        # 0 -> greedy
    eos_id: int = -1                # -1 -> never stop early
    quant_bits: int = 0             # 0 off, 8, 16
    seed: int = 0
    admit_policy: str = "any_free"  # "all_free" = window-boundary baseline


@dataclasses.dataclass
class LMRequest:
    """One queued generation: a prompt and a token budget."""
    request_id: str
    tokens: np.ndarray              # (s,) int32 prompt
    max_new: int                    # total tokens to emit (incl. the first)
    extra: dict | None = None       # e.g. vlm patch_embeds, (1, ...) rows


@dataclasses.dataclass
class Completion:
    """Event surfaced by :meth:`Engine.tick` when a request leaves a slot."""
    request_id: str
    tokens: np.ndarray              # (n_emitted,) int32
    finished: bool                  # False -> cancelled with partial output


class Engine:
    """Continuous-batching LM engine (prefill-into-slot + slotted decode)."""

    def __init__(self, cfg, params, serve_cfg: ServeConfig | None = None,
                 *, obs: Observability | None = None):
        self.cfg = cfg
        self.scfg = scfg = serve_cfg or ServeConfig()
        # same observability seam as the streaming stack: spans for the
        # two jit'd sections (lm.prefill / lm.decode) plus a per-tick
        # latency histogram and token counter; NULL_OBS keeps every hook
        # a no-op on the default path
        self._obs = NULL_OBS if obs is None else obs
        self._tracer = self._obs.tracer
        if scfg.quant_bits:
            self.qparams, self.scales = quantize_tree(
                params, scfg.quant_bits)
            self.params = dequantize_tree(self.qparams, self.scales)
            # quantized head: logits come from the integer weights via the
            # q15_matmul kernel, so decode/prefill return hidden states.
            # The (K, V) integer head matrix is laid out once here (the
            # tied path would otherwise transpose the whole embed table
            # every tick) and the kernel call is jitted so the pad-to-tile
            # runs compiled.
            self._quant_head = True
            if not cfg.tie_embeddings and "lm_head" in self.qparams:
                head_wq = self.qparams["lm_head"]["w"]
                head_scale = self.scales["lm_head"]["w"]
            else:
                head_wq = jnp.asarray(self.qparams["embed"]["table"]).T
                head_scale = self.scales["embed"]["table"]
            from repro.kernels.q15_matmul.ops import q15_matmul
            self._head_fn = jax.jit(lambda x: q15_matmul(
                x, head_wq, head_scale, out_dtype=jnp.float32))
        else:
            self.params = params
            self.qparams = self.scales = None
            self._quant_head = False
            self._head_fn = None
        S = scfg.max_slots
        self.cache = T.init_slot_cache(cfg, S, scfg.max_len, dtype=cfg.cdtype)
        self._decode = jax.jit(lambda p, c, t, a: T.decode_step_slotted(
            cfg, p, c, t, a, return_hidden=self._quant_head))
        self._prefills: dict[Any, Any] = {}     # prompt shape -> jitted fn
        self._key = jax.random.PRNGKey(scfg.seed)
        # --- per-slot host state (preallocated; written in place) -------
        self._out = np.zeros((S, scfg.max_len), np.int32)   # token buffer
        self._emitted = np.zeros(S, np.int64)               # out-buffer cursor
        self._budget = np.zeros(S, np.int64)
        self._eos_done = np.zeros(S, bool)
        self._last = np.zeros((S, 1), np.int32)             # next decode input
        self._results: dict[str, np.ndarray] = {}
        self._rid_counter = itertools.count()
        # telemetry
        self._prefill_count = 0
        self._decode_ticks = 0
        self._tokens_generated = 0
        self.sched = SlotScheduler(S, HostProgram(self),
                                   admit_policy=scfg.admit_policy,
                                   tracer=self._tracer)

    # ------------------------------------------------------------------
    # Request API
    # ------------------------------------------------------------------
    def submit(self, tokens: np.ndarray, max_new: int, *,
               request_id: str | None = None,
               extra: dict | None = None) -> str:
        """Queue one prompt for ``max_new`` generated tokens (the first is
        sampled at prefill time, matching ``generate`` semantics).  Returns
        the request id; the sequence prefills into a slot as soon as the
        scheduler places it."""
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 1:
            raise ValueError(f"prompt must be 1-D, got {tokens.shape}")
        if not 1 <= max_new <= self.scfg.max_len:
            raise ValueError(f"max_new must be in [1, {self.scfg.max_len}]")
        n_extra = 0            # vlm patch embeddings occupy cache positions
        if extra and "patch_embeds" in extra:
            n_extra = int(np.asarray(extra["patch_embeds"]).shape[1])
        if tokens.shape[0] + n_extra + max_new - 1 > self.scfg.max_len:
            raise ValueError(
                f"prompt ({tokens.shape[0]} tokens + {n_extra} patch "
                f"positions) + max_new ({max_new}) exceeds "
                f"max_len={self.scfg.max_len}")
        rid = request_id if request_id is not None \
            else f"r{next(self._rid_counter)}"
        self.sched.submit(rid, LMRequest(rid, tokens, int(max_new), extra))
        return rid

    def tick(self) -> list[Completion]:
        """One scheduling round: admit+prefill into free slots, one batched
        decode step over all resident sequences, release finished slots."""
        if not self._obs.enabled:
            return self.sched.tick()
        t0 = self._tracer.t()
        events = self.sched.tick()
        dur_ns = self._tracer.rec("lm.tick", t0)
        if self._obs.metrics is not None:
            self._obs.metrics.histogram(
                "lm.tick_us", "LM engine tick latency",
                wallclock=True).observe_ns(dur_ns)
        return events

    def run(self) -> list[Completion]:
        """Tick until every submitted request has completed."""
        events: list[Completion] = []
        while self.sched.has_work():
            events.extend(self.tick())
        return events

    def cancel(self, request_id: str) -> Completion:
        """Withdraw a request.  Resident sequences yield their partial
        tokens; a request still in the pending queue yields an empty
        result — either way :meth:`result` works afterwards, so callers
        need not know whether admission had happened yet."""
        ev = self.sched.cancel(request_id)
        if ev is None:                    # pending: nothing was emitted
            self._results[request_id] = np.zeros((0,), np.int32)
            ev = Completion(request_id, self._results[request_id].copy(),
                            False)
        return ev

    def result(self, request_id: str) -> np.ndarray:
        """Generated tokens of a completed/cancelled request (consumes it)."""
        return self._results.pop(request_id)

    def generate(self, tokens: np.ndarray, max_new: int,
                 extra: dict | None = None) -> np.ndarray:
        """Batch convenience: run (B, s) prompts to completion and return
        (B, max_new) tokens (continuous batching when B > max_slots; rows
        that hit ``eos_id`` early are padded with it)."""
        tokens = np.asarray(tokens, np.int32)
        rids = []
        for i in range(tokens.shape[0]):
            row_extra = None
            if extra:
                row_extra = {k: np.asarray(v)[i:i + 1] for k, v in extra.items()}
            rids.append(self.submit(tokens[i], max_new, extra=row_extra))
        self.run()
        pad = self.scfg.eos_id if self.scfg.eos_id >= 0 else 0
        out = np.full((tokens.shape[0], max_new), pad, np.int32)
        for i, rid in enumerate(rids):
            row = self.result(rid)
            out[i, :row.shape[0]] = row
        return out

    def stats(self) -> dict[str, Any]:
        sched = self.sched.stats()
        return {
            "max_slots": self.scfg.max_slots,
            "active": sched["active"],
            "pending": sched["pending"],
            "occupancy": sched["occupancy"],
            "peak_active": sched["peak_active"],
            "prefills": self._prefill_count,
            "decode_ticks": self._decode_ticks,
            "tokens_generated": self._tokens_generated,
            "quant_bits": self.scfg.quant_bits,
            # scheduler counters (admissions/recycles/spills/occupancy):
            # shared observability surface with the streaming engine
            "scheduler": sched,
        }

    # ------------------------------------------------------------------
    # SlotProgram hooks (called by the scheduler via HostProgram)
    # ------------------------------------------------------------------
    def _admit_slot(self, slot: int, request_id: str, req: LMRequest,
                    reset: bool) -> None:
        # No reset_cache_slot here: prefill overwrites the SSM/conv rows
        # entirely and the KV rows up to the prompt length, and everything
        # past ``pos`` is masked out — a recycled slot cannot leak its
        # previous occupant.  (reset_cache_slot exists for callers that
        # want belt-and-braces hygiene; it copies the whole cache.)
        batch = {"tokens": jnp.asarray(req.tokens[None, :])}
        if req.extra:
            batch.update({k: jnp.asarray(v) for k, v in req.extra.items()})
        t0 = self._tracer.t()
        out, self.cache = self._prefill_fn(batch)(
            self.params, self.cache, batch, slot)
        self._tracer.rec("lm.prefill", t0)
        logits = self._head_logits(out[:, -1:]) if self._quant_head \
            else out[:, -1, :]
        first = self._sample(logits)[0]
        self._out[slot, 0] = first
        self._emitted[slot] = 1
        self._budget[slot] = req.max_new
        self._last[slot, 0] = first
        self._eos_done[slot] = (self.scfg.eos_id >= 0
                                and first == self.scfg.eos_id)
        self._prefill_count += 1
        self._tokens_generated += 1

    def _advance(self, resident: np.ndarray) -> TickReport:
        need = resident & ~self._eos_done & (self._emitted < self._budget)
        if need.any():
            t0 = self._tracer.t()
            out, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(self._last),
                jnp.asarray(need))
            self._tracer.rec("lm.decode", t0)
            logits = self._head_logits(out) if self._quant_head \
                else out[:, 0, :]
            nxt = self._sample(logits)                    # (S,) batched
            rows = np.nonzero(need)[0]
            self._out[rows, self._emitted[rows]] = nxt[rows]
            self._emitted[rows] += 1
            self._last[rows, 0] = nxt[rows]
            if self.scfg.eos_id >= 0:
                self._eos_done[rows] |= (nxt[rows] == self.scfg.eos_id)
            self._decode_ticks += 1
            self._tokens_generated += int(rows.size)
            if self._obs.metrics is not None:
                self._obs.metrics.counter(
                    "lm.tokens_generated",
                    "tokens emitted by decode ticks").inc(int(rows.size))
        finished = resident & (self._eos_done | (self._emitted >= self._budget))
        fin_rows = np.nonzero(finished)[0].tolist()
        events = [Completion(self.sched.request_at(s),
                             self._out[s, :self._emitted[s]].copy(), True)
                  for s in fin_rows]
        return TickReport(events=events, finished=fin_rows,
                          advanced=int(need.sum()))

    def _release_slot(self, slot: int, request_id: str,
                      reason: str) -> Completion | None:
        toks = self._out[slot, :self._emitted[slot]].copy()
        self._results[request_id] = toks
        self._emitted[slot] = 0
        self._budget[slot] = 0
        self._eos_done[slot] = False
        if reason == "cancelled":
            return Completion(request_id, toks, False)
        return None

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _prefill_fn(self, batch):
        """jit'd prefill-into-slot, cached per prompt geometry (the slot
        index is a traced argument, so admission never retraces)."""
        key = tuple(sorted((k, v.shape) for k, v in batch.items()))
        fn = self._prefills.get(key)
        if fn is None:
            fn = jax.jit(lambda p, c, b, s: T.prefill_into_slot(
                self.cfg, p, c, b, s, return_hidden=self._quant_head))
            self._prefills[key] = fn
        return fn

    def _head_logits(self, hidden) -> jax.Array:
        """Sampling head over the *integer* quantized weights via the
        q15_matmul kernel (dequantize-inside-the-kernel) — the previously
        dead ``qparams``/``scales`` doing real work.  hidden: (n, 1, D) or
        (n, s, D); uses the last position.  -> (n, V) f32."""
        return self._head_fn(hidden[:, -1, :].astype(jnp.float32))

    def _sample(self, logits) -> np.ndarray:
        """(n, V) -> (n,) int32, greedy or temperature (batched)."""
        if self.scfg.temperature <= 0:
            return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self._key, k = jax.random.split(self._key)
        return np.asarray(jax.random.categorical(
            k, logits / self.scfg.temperature), np.int32)
