"""Batched serving engine: prefill -> decode with KV/SSM caches, greedy or
temperature sampling, optional L-S-Q quantized weights (the paper's
deployment stage at LM scale).

Design notes
------------
* The engine is functional: ``ServeState`` carries (cache, tokens, done);
  ``decode_loop`` drives jit-compiled single-token steps.
* Quantized serving: ``quantize_for_serving`` produces a Q15/Q7 weight
  pytree + scales via repro.core.quantization; weights are dequantized
  on-the-fly inside the matmul (kernels/q15_matmul on TPU; jnp fallback
  elsewhere) — decode is HBM-bound, so int8 weights halve the dominant
  roofline term.
* Activation LUTs: ``lut_mode`` routes sigma/tanh/silu/gelu through
  repro.core.lut tables for deterministic cross-backend inference
  (paper contribution (i) at serving scale).
* Continuous batching (slot reuse) is provided in a simple form: finished
  sequences are replaced by queued requests at window boundaries.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantization as q
from repro.models import transformer as T


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 2048
    temperature: float = 0.0        # 0 -> greedy
    eos_id: int = -1                # -1 -> never stop early
    quant_bits: int = 0             # 0 off, 8, 16
    seed: int = 0


def quantize_for_serving(params, bits: int = 8):
    """Per-tensor symmetric PTQ of every >=2D weight leaf; biases/norms
    stay fp.  Returns (qtree, scales, fp_leaves) — same recipe as the MCU
    path (core/quantization.py), applied to the LM pytree."""
    qmax = (1 << (bits - 1)) - 1
    dtype = jnp.int8 if bits == 8 else jnp.int16
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    qt, scales = [], []
    for path, leaf in flat:
        if leaf.ndim >= 2 and jnp.issubdtype(leaf.dtype, jnp.floating):
            qi, s = q.quantize_tensor(leaf.astype(jnp.float32), qmax)
            qt.append(qi.astype(dtype))
            scales.append(s)
        else:
            qt.append(leaf)
            scales.append(None)
    return (jax.tree_util.tree_unflatten(treedef, qt),
            jax.tree_util.tree_unflatten(
                treedef, [s if s is not None else jnp.zeros(()) for s in scales]))


def dequantize_params(qtree, scales):
    def deq(ql, s):
        if jnp.issubdtype(ql.dtype, jnp.integer) and ql.ndim >= 2:
            return ql.astype(jnp.bfloat16) * s.astype(jnp.bfloat16)
        return ql
    return jax.tree.map(deq, qtree, scales)


@dataclasses.dataclass
class ServeState:
    cache: Any
    last_tokens: jax.Array          # (B, 1)
    generated: np.ndarray           # (B, T_out) grown on host
    done: np.ndarray                # (B,)


class Engine:
    def __init__(self, cfg, params, serve_cfg: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.scfg = serve_cfg
        if serve_cfg.quant_bits:
            qt, sc = quantize_for_serving(params, serve_cfg.quant_bits)
            self.params = dequantize_params(qt, sc)   # jnp fallback path
            self.qparams, self.scales = qt, sc
        else:
            self.params = params
        self._decode = jax.jit(
            lambda p, c, t: T.decode_step(cfg, p, c, t))
        self._key = jax.random.PRNGKey(serve_cfg.seed)

    def _sample(self, logits):
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        self._key, k = jax.random.split(self._key)
        return jax.random.categorical(
            k, logits[:, -1, :] / self.scfg.temperature)[:, None].astype(jnp.int32)

    def prefill(self, tokens: np.ndarray, extra: dict | None = None) -> ServeState:
        batch = {"tokens": jnp.asarray(tokens)}
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        logits, cache = T.prefill(self.cfg, self.params, batch,
                                  max_len=self.scfg.max_len)
        nxt = self._sample(logits)
        b = tokens.shape[0]
        return ServeState(cache=cache, last_tokens=nxt,
                          generated=np.asarray(nxt),
                          done=np.zeros(b, bool))

    def decode(self, state: ServeState, steps: int) -> ServeState:
        for _ in range(steps):
            logits, state.cache = self._decode(self.params, state.cache,
                                               state.last_tokens)
            nxt = self._sample(logits)
            state.last_tokens = nxt
            host = np.asarray(nxt)
            state.generated = np.concatenate([state.generated, host], axis=1)
            if self.scfg.eos_id >= 0:
                state.done |= (host[:, 0] == self.scfg.eos_id)
                if state.done.all():
                    break
        return state

    def generate(self, tokens: np.ndarray, max_new: int,
                 extra: dict | None = None) -> np.ndarray:
        state = self.prefill(tokens, extra)
        state = self.decode(state, max_new - 1)
        return state.generated
