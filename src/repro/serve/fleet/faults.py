"""Fault-injection hook points for the fleet's crash-failover machinery.

Failover that is only exercised by real crashes is untestable; the fleet
therefore exposes deterministic *injection seams* and this module defines
the injector protocol that drives them.  A :class:`FaultInjector` passed
to :class:`~repro.serve.fleet.engine.FleetEngine` can

* **kill shards at chosen tick phases** — the engine calls
  :meth:`FaultInjector.crashes` at each of the :data:`PHASES` of every
  tick and crash-fails (drop + rebuild + recover, see
  ``FleetEngine.crash_shard``) whichever shards it names.  The phases
  bracket the tick's interesting interleavings: before any work
  (``pre_tick``), between the fused kernel dispatch's two halves
  (``mid_dispatch`` — admission and sample-gather have run via
  ``tick_begin``/``_advance_begin`` but no bookkeeping has), and after
  events were handed to the consumer (``post_emit``).
* **drop / duplicate / corrupt in-flight snapshots** — every wire-encoded
  :class:`~repro.serve.streaming.StreamState` checkpoint passes through
  :meth:`FaultInjector.filter_snapshot` on its way to the snapshot store,
  modelling a lossy checkpoint transport.

The test harness (``tests/faultharness.py``) builds schedules on top of
:class:`ScheduledFaults`; Hypothesis drives randomized lifecycles through
the same seams (``tests/test_fleet_properties.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

#: Tick phases at which the engine polls for injected crashes, in the
#: order they occur inside :meth:`FleetEngine.step`.
PHASES = ("pre_tick", "mid_dispatch", "post_emit")


class FaultInjector:
    """Base injector: no faults.  Subclass and override the seams."""

    def crashes(self, fleet, phase: str, tick: int) -> Iterable[int]:
        """Shard indices to crash-fail at this (tick, phase).  Called once
        per phase per fleet tick; returning the same index twice is safe
        (a rebuilt shard is simply rebuilt again)."""
        return ()

    def filter_snapshot(self, shard: int, stream_id: str,
                        blob: bytes) -> tuple[bytes, ...]:
        """Transform one in-flight snapshot blob.  Return ``()`` to drop
        it (the stream keeps its previous checkpoint and a deeper replay
        journal), ``(blob,)`` to deliver it, or ``(blob, blob)`` to
        duplicate it (idempotent store: last write wins)."""
        return (blob,)


@dataclasses.dataclass
class ScheduledFaults(FaultInjector):
    """Deterministic fault schedule: crash shard ``s`` at tick ``t``
    phase ``p`` for every ``(t, p, s)`` in ``schedule``; persistently
    drop / duplicate / corrupt every snapshot of the named streams.
    Corruption flips one bit of the blob's last byte — enough for the
    wire format's crc32 to reject it at recovery time."""
    schedule: Sequence[tuple[int, str, int]] = ()
    drop_snapshots: frozenset | set = frozenset()
    dup_snapshots: frozenset | set = frozenset()
    corrupt_snapshots: frozenset | set = frozenset()

    def __post_init__(self):
        for _, phase, _ in self.schedule:
            if phase not in PHASES:
                raise ValueError(
                    f"unknown tick phase {phase!r}; expected one of {PHASES}")

    def crashes(self, fleet, phase: str, tick: int) -> Iterable[int]:
        return [s for t, p, s in self.schedule if t == tick and p == phase]

    def filter_snapshot(self, shard: int, stream_id: str,
                        blob: bytes) -> tuple[bytes, ...]:
        if stream_id in self.drop_snapshots:
            return ()
        if stream_id in self.corrupt_snapshots:
            return (blob[:-1] + bytes([blob[-1] ^ 1]),)
        if stream_id in self.dup_snapshots:
            return (blob, blob)
        return (blob,)


def crash_matrix(shards: int, *, start_tick: int = 10,
                 spacing: int = 7) -> ScheduledFaults:
    """The full phase x shard crash matrix as one deterministic schedule:
    every shard crashed once at every tick phase, spread ``spacing`` ticks
    apart so each recovery completes before the next fault lands.

    This is the canonical worst-case failover workload shared by the
    flight-recorder byte-stability gate (``tests/test_obs.py``,
    ``benchmarks/obs_bench.py``): identical runs under the same matrix
    must produce byte-identical deterministic crash dumps."""
    schedule = []
    t = start_tick
    for phase in PHASES:
        for s in range(shards):
            schedule.append((t, phase, s))
            t += spacing
    return ScheduledFaults(schedule=tuple(schedule))
