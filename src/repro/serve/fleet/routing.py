"""Deterministic stream->shard routing via rendezvous (HRW) hashing.

The fleet needs a routing function that (a) is deterministic across
processes and restarts — the same stream id must land on the same shard no
matter which frontend computes the route, so ``hash()`` (randomized per
process by PYTHONHASHSEED) is out; and (b) is *stable under shard-count
change*: draining shard k must remap only shard k's streams, not reshuffle
the whole fleet the way ``crc32(sid) % n`` does.

Highest-random-weight (rendezvous) hashing gives both: every (stream,
shard) pair gets a 64-bit weight from a keyed blake2b digest and the
stream lives on the highest-weight *eligible* shard.  Removing a shard
from the eligible set promotes each of its streams to their next-best
shard and touches nothing else — the property the drain/decommission path
and its tests rely on.
"""
from __future__ import annotations

import hashlib
import struct
from typing import Sequence


def hrw_weight(stream_id: str, shard_key: str) -> int:
    """64-bit rendezvous weight of a (stream, shard) pair — a keyed
    blake2b digest, deterministic across processes and platforms."""
    h = hashlib.blake2b(digest_size=8)
    h.update(stream_id.encode("utf-8"))
    h.update(b"\x00")
    h.update(shard_key.encode("utf-8"))
    return struct.unpack("<Q", h.digest())[0]


def rank_shards(stream_id: str, shard_keys: Sequence[str]) -> list[int]:
    """All shard indices ranked best-first by rendezvous weight.
    Index 0 is the stream's home shard; the rest is its failover order
    (ties broken by shard index, which blake2b makes vanishingly rare)."""
    return sorted(range(len(shard_keys)),
                  key=lambda i: (-hrw_weight(stream_id, shard_keys[i]), i))


def route(stream_id: str, shard_keys: Sequence[str],
          eligible: Sequence[bool] | None = None) -> int:
    """The stream's home shard: highest rendezvous weight among eligible
    shards.  ``eligible`` masks out drained/decommissioned shards; routing
    for every other stream is unchanged (the HRW stability property)."""
    best, best_w = -1, -1
    for i, key in enumerate(shard_keys):
        if eligible is not None and not eligible[i]:
            continue
        w = hrw_weight(stream_id, key)
        if w > best_w:
            best, best_w = i, w
    if best < 0:
        raise ValueError("no eligible shard to route to")
    return best
