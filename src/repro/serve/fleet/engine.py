"""FleetEngine: one front door over N independent StreamingEngine shards.

The paper deploys one FastGRNN per device at 50 Hz; the cloud-side
complement is a process that serves *fleets* of such sensors — more
concurrent streams than one slot table should hold.  This module shards
the slot axis: N :class:`~repro.serve.streaming.StreamingEngine` shards,
each with its own :class:`~repro.serve.scheduler.SlotScheduler` (slot
table, pending FIFO, counters), composed behind one engine-shaped API.

Design
------
* **Routing** — deterministic rendezvous (HRW) hashing
  (``fleet/routing.py``): a stream's home shard is a pure function of its
  id and the eligible-shard set, stable across processes and under shard
  drain (removing a shard remaps only that shard's streams).
* **Admission** — shard-local: the home shard's scheduler places or
  queues the stream.  With ``max_pending_per_shard`` set, a saturated
  shard overflows into the fleet-level FIFO *spillover queue*; every tick
  drains it into the home shard when room frees, or the least-loaded
  eligible shard (deterministic tie-break) when the home stays hot.
* **Migration** — live and bit-exact: ``migrate()`` snapshots a stream
  off its shard (:meth:`StreamingEngine.export_stream` — hidden state,
  counters, unconsumed samples, trajectory tap) and re-attaches it on the
  destination (:meth:`~StreamingEngine.import_stream`).  Under the exact
  backend the continued trajectory is bit-identical to never having
  moved; ``decommission()`` uses this to drain a shard onto each
  stream's next-best rendezvous shard.
* **Fused ticks** — "batch across shards in one tick": shards run
  admission and sample-gather independently (`SlotScheduler.tick_begin` +
  `StreamingEngine._advance_begin`), then the fleet concatenates every
  co-located shard's (h, x, active) and makes ONE batched
  ``Q15StreamStep`` dispatch per device group, then each shard finishes
  its own bookkeeping.  The per-row math is row-independent, so fusion
  preserves the bit-exactness contract while amortizing per-dispatch
  overhead across shards — the measured source of near-linear shard
  scaling on CPU (``benchmarks/fleet_bench.py``).
* **Placement** — shards are assigned distinct jax devices when the
  process has them (``fleet/placement.py``; CPU runners fake them via
  ``--xla_force_host_platform_device_count``) and fall back to
  process-local NumPy shards otherwise.  The exact backend is always the
  NumPy fallback — that is the bit-identity contract surface.
* **Counters compose** — ``stats()`` sums every scheduler/workload
  counter across shards (admissions, recycles, spills, occupancy,
  evictions, …) and preserves the per-shard breakdown, plus fleet-level
  counters (``global_spills``, ``migrations``, fleet ticks).

Every stream remains **bit-identical** to the single-engine
``StreamingEngine`` reference regardless of shard count, routing, or
mid-stream migration (exact backend; asserted in ``tests/test_fleet.py``).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Iterable

import numpy as np

from repro.core import quantization as q
from repro.kernels.fastgrnn_cell.ops import Q15StreamStep
from repro.obs import (NULL_OBS, Observability, assert_conservation,
                       merge_site_counts)
from repro.obs.numerics import PUBLISH_EVERY
from repro.serve.scheduler import TickReport
from repro.serve.streaming import (StreamEvent, StreamEventBatch, StreamState,
                                   StreamingConfig, StreamingEngine,
                                   coerce_qp, coerce_samples)
from . import placement, routing, wire
from .faults import PHASES, FaultInjector


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Fleet shape.  ``stream`` is the per-shard template —
    ``stream.max_slots`` is the *per-shard* resident width, so fleet
    capacity is ``shards * stream.max_slots`` resident streams."""
    shards: int = 4
    stream: StreamingConfig = dataclasses.field(
        default_factory=StreamingConfig)
    max_pending_per_shard: int | None = None  # None = shard FIFOs unbounded
    # (nothing ever reaches the fleet spillover queue)
    placement: str = "auto"      # "auto" | "devices" | "host"
    fuse_ticks: bool = True      # one kernel dispatch per device group/tick
    snapshot_every: int | None = None   # crash-failover checkpoint cadence
    # in fleet ticks (None = failover disabled: no snapshots, no sample
    # journal, ``crash_shard`` refuses).  Every ``snapshot_every`` ticks
    # each live stream is wire-encoded (``fleet/wire.py``) into the
    # snapshot store; samples fed since a stream's last stored snapshot
    # are journaled, so snapshot + journal replay reconstructs the stream
    # bit-exactly on a replacement shard


@dataclasses.dataclass
class _JournalEntry:
    """Replay journal of one failover-protected stream: every sample
    chunk fed since the stream's last *stored* snapshot (cleared only on
    a successful store, so a dropped/duplicated snapshot just deepens the
    replay), plus the attach-time facts a zero-state recovery needs when
    no snapshot was ever stored."""
    total: int | None
    record_trajectory: bool
    chunks: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _SpillEntry:
    """A stream waiting in the fleet-level spillover queue (every shard it
    may route to is saturated).  Buffers samples until placement."""
    chunks: list
    total: int | None
    record_trajectory: bool


@dataclasses.dataclass
class _DeviceGroup:
    """Fused-dispatch state of one device group (the co-located shards
    whose ticks batch into ONE kernel call).  Each shard's ``_x`` is a
    view of ``x_big``, so phase-1 ring gathers write the fused x operand
    in place; ``h_big`` is last tick's fused output with per-shard views
    handed back, adopted as this tick's h operand whenever every shard
    still holds its view (steady state: zero copies besides the kernel's
    own output — and on the device-resident path ``h_big`` is a jax
    device array consumed in place by the step, so steady-state ticks
    never move a single h byte across the host/device boundary)."""
    device: Any
    idxs: list                  # shard indices, fleet order
    kernel: Q15StreamStep
    offsets: np.ndarray         # (len(idxs)+1,) row offsets into the batch
    x_big: np.ndarray           # (total, d) fused x staging
    av_big: np.ndarray          # (total,) fused active-mask staging
    h_big: Any = None           # last fused output (numpy or device array)
    h_views: list = dataclasses.field(default_factory=list)


class FleetEngine:
    """Sharded multi-stream serving: StreamingEngine semantics at fleet
    scale.  The public surface mirrors :class:`StreamingEngine`
    (``attach / feed / step / drain / detach / trajectory / stats``) plus
    the fleet verbs (``migrate / decommission / recommission /
    shard_of``), so existing drivers — ``classify_windows``, the
    streaming benchmark — run unchanged against a fleet."""

    def __init__(self, params_or_qp, config: FleetConfig | None = None,
                 *, quant: q.QuantConfig | None = None,
                 act_scales: dict[str, float] | None = None,
                 naive_acts: bool = False,
                 faults: FaultInjector | None = None,
                 obs: Observability | None = None):
        config = config or FleetConfig()
        if config.shards < 1:
            raise ValueError("shards must be >= 1")
        if config.snapshot_every is not None and config.snapshot_every < 1:
            raise ValueError("snapshot_every must be >= 1 (or None)")
        self.config = config
        self._act_scales = act_scales     # kept to rebuild a crashed shard
        self._naive_acts = naive_acts
        self._faults = faults
        # observability seam (repro.obs): every shard shares the fleet's
        # tracer/registry (spans carry the shard index; fixed-bucket
        # histograms merge by construction); NULL_OBS = all hooks no-ops
        self.obs = obs or NULL_OBS
        self._tracer = self.obs.tracer
        self.qp = coerce_qp(params_or_qp, quant)
        devices = placement.shard_devices(
            config.shards, config.placement, config.stream.backend)
        self.shard_keys = [f"shard-{i}" for i in range(config.shards)]
        self.shards = [
            self._make_shard(devices[i], i)
            for i in range(config.shards)]
        self._routable = [True] * config.shards
        # device groups for fused dispatch: co-located shards batch into
        # one kernel call per tick (keyed by device identity; None = the
        # process-local / default-device group)
        groups = placement.device_groups(devices)
        self._group_kernels = {
            dev: Q15StreamStep(self.qp, act_scales=act_scales,
                               naive_acts=naive_acts,
                               backend=config.stream.backend,
                               interpret=config.stream.interpret,
                               device=dev, mxu=config.stream.mxu)
            for dev, _ in groups}
        self._devices = devices
        # device-resident fused ticks: h lives on device between ticks
        # and the fused step is an ASYNC dispatch (all shards' config is
        # the template, so the resolved residency is uniform)
        self._device_resident = self.shards[0]._device_resident
        self._owner: dict[str, int] = {}   # stream -> shard (incl. pending)
        self._spilled: "collections.OrderedDict[str, _SpillEntry]" = \
            collections.OrderedDict()      # fleet-level FIFO spillover
        self._ticks = 0
        self._global_spills = 0
        self._migrations = 0
        # --- crash failover (active when config.snapshot_every is set) --
        self._snapshots: dict[str, bytes] = {}   # stream -> last stored blob
        self._journal: dict[str, _JournalEntry] = {}   # live streams only
        self._cursor: dict[str, int] = {}  # stream -> last delivered step
        self._failovers = 0
        self._replayed_samples = 0
        self._snapshots_taken = 0
        self._snapshots_dropped = 0
        self._snapshots_duplicated = 0
        # monotonic counters of crashed shards, folded in so fleet totals
        # stay conserved across a shard rebuild (stats()["retired"])
        self._retired = {"stream_steps": 0, "completed": 0,
                         "ring_spills": 0, "replay_suppressed": 0}
        self._retired_sched = {k: 0 for k in (
            "admissions", "recycles", "spills", "completed", "cancelled",
            "evictions", "ticks")}
        from repro.obs import TRANSFER_KEYS
        self._retired_transfers = dict.fromkeys(TRANSFER_KEYS, 0)
        # numeric-health counters of crashed shards (site -> count): a
        # crash folds the dying shard's monitor child in here and resets
        # the child for the replacement engine, so live + retired stays
        # conserved (obs.invariants.check_numerics_conservation)
        self._retired_numerics: dict[str, int] = {}
        self._num_pub_tick = 0
        # --- fused-tick staging (one _DeviceGroup per device) ----------
        # One (sum S_i, ...) buffer per kernel operand per group, with
        # each shard's segment handed out as a view: phase-1 ring gathers
        # write the fused x operand in place (zero concat), and the fused
        # step's output h is adopted back as next tick's input when no
        # shard rebound its hidden state in between.
        d = self.shards[0].kernel.input_dim
        self._group_list: list[_DeviceGroup] = []
        self._group_of: dict[int, _DeviceGroup] = {}
        for dev, idxs in groups:
            widths = [self.shards[i].config.max_slots for i in idxs]
            offs = np.concatenate([[0], np.cumsum(widths)])
            g = _DeviceGroup(device=dev, idxs=list(idxs),
                             kernel=self._group_kernels[dev], offsets=offs,
                             x_big=np.zeros((int(offs[-1]), d), np.float32),
                             av_big=np.zeros(int(offs[-1]), bool),
                             h_views=[None] * len(idxs))
            self._group_list.append(g)
            for j, i in enumerate(idxs):
                self._group_of[i] = g
                if config.fuse_ticks:
                    self.shards[i]._x = g.x_big[offs[j]:offs[j + 1]]
        # device-resident fused outputs issued this tick and not yet
        # waited on: the next tick syncs them (fleet.device_wait) BEFORE
        # phase 1 overwrites the x/mask staging the dispatch aliased
        self._inflight: list = []
        # per-tick SLO deadline (ns): the paper's real-time bar is one
        # sample period (50 Hz -> 20 ms); overridable via obs.deadline_ms
        deadline_ms = self.obs.deadline_ms
        if deadline_ms is None:
            deadline_ms = 1e3 / config.stream.sample_rate_hz
        self._deadline_ns = deadline_ms * 1e6
        self._advanced_per_shard = [0] * config.shards
        if self.obs.metrics is not None:
            self._init_fleet_metrics()

    def _init_fleet_metrics(self) -> None:
        """Pre-register the fleet's SLO metric handles (no per-tick dict
        lookups on the instrumented path)."""
        reg = self.obs.metrics
        self._m_tick = reg.histogram(
            "fleet.tick_us", "wall time of one fleet tick", wallclock=True)
        self._m_ticks = reg.counter("fleet.ticks", "fleet ticks")
        self._m_events = reg.counter(
            "fleet.events_emitted", "stream events delivered to the consumer")
        self._m_miss_ticks = reg.counter(
            "fleet.deadline_miss_ticks",
            "ticks whose wall time exceeded the per-sample deadline",
            wallclock=True)
        self._m_miss_streams = reg.counter(
            "fleet.deadline_miss_stream_ticks",
            "stream-steps advanced in ticks that missed the deadline "
            "(each is one stream observing one late 50 Hz sample)",
            wallclock=True)
        self._m_shard_miss = [
            reg.counter(f"fleet.shard{i}.deadline_miss_stream_ticks",
                        "per-shard share of deadline-missed stream-steps",
                        wallclock=True)
            for i in range(self.config.shards)]
        self._m_active = reg.gauge("fleet.active", "resident streams")
        self._m_pending = reg.gauge("fleet.pending", "shard-queued streams")
        self._m_spilled = reg.gauge(
            "fleet.spilled", "streams in the fleet spillover queue")
        self._m_occupancy = reg.gauge(
            "fleet.occupancy", "resident streams / total slots")
        self._m_failovers = reg.counter(
            "fleet.failovers", "shard crash-failovers", wallclock=True)
        self._m_migrations = reg.counter(
            "fleet.migrations", "live stream migrations")
        # host<->device transfer bytes (logical volume; deterministic):
        # the steady-state fused tick on the device-resident path must
        # add ZERO to the h_* pair — the measured zero-copy invariant
        self._m_transfers = {
            "h2d_bytes": reg.counter(
                "fleet.h2d_bytes", "host->device bytes staged"),
            "d2h_bytes": reg.counter(
                "fleet.d2h_bytes", "device->host bytes pulled"),
            "h_h2d_bytes": reg.counter(
                "fleet.h_h2d_bytes", "hidden-state bytes uploaded"),
            "h_d2h_bytes": reg.counter(
                "fleet.h_d2h_bytes", "hidden-state bytes downloaded"),
        }
        self._last_transfers = self._transfer_totals()

    def _tick_metrics(self, dur_ns: int, events: list) -> None:
        """Per-tick SLO accounting: tick-latency histogram, 50 Hz
        deadline-miss counters (fleet and per-shard, in stream-ticks),
        occupancy/queue-depth gauges."""
        self._m_ticks.inc()
        self._m_tick.observe_us(dur_ns / 1e3)
        advanced = sum(self._advanced_per_shard)
        if advanced and dur_ns > self._deadline_ns:
            self._m_miss_ticks.inc()
            self._m_miss_streams.inc(advanced)
            for i, a in enumerate(self._advanced_per_shard):
                if a:
                    self._m_shard_miss[i].inc(a)
        n_ev = sum(len(e.stream_ids) if isinstance(e, StreamEventBatch)
                   else 1 for e in events)
        self._m_events.inc(n_ev)
        self._m_active.set(self.n_active)
        self._m_pending.set(self.n_pending)
        self._m_spilled.set(len(self._spilled))
        slots = self.max_streams
        self._m_occupancy.set(self.n_active / slots if slots else 0.0)
        cur = self._transfer_totals()
        for k, c in self._m_transfers.items():
            delta = cur[k] - self._last_transfers[k]
            if delta:
                c.inc(delta)
        self._last_transfers = cur
        mon = self.obs.numerics
        if mon is not None:
            # parent publish aggregates every shard child (delta-tracked);
            # shard engines skip their own publish when fleet-owned.
            # Throttled like the standalone engine: the export walk is
            # the expensive part, and deltas survive the wait.
            self._num_pub_tick += 1
            if self._num_pub_tick >= PUBLISH_EVERY:
                self._num_pub_tick = 0
                mon.publish(self.obs.metrics)

    def _note_shard_events(self, shard: int, evs: list) -> None:
        """Feed the flight recorder one shard's tick emission as compact
        (stream_id, kind, step) triples — columnar batches contribute
        their tail, never a full O(events) expansion."""
        rec = self.obs.recorder
        cap = rec.events_per_shard
        total = 0
        summ: list[tuple] = []
        for e in evs:
            if isinstance(e, StreamEventBatch):
                n = len(e.stream_ids)
                total += n
                take = min(cap, n)
                summ.extend(zip(
                    e.stream_ids[n - take:],
                    ("final" if f else "window" for f in e.final[n - take:]),
                    e.steps[n - take:].tolist()))
            else:
                total += 1
                summ.append((e.stream_id, e.kind, e.step))
        rec.note_events(shard, self._ticks, summ[-cap:], total=total)

    def _make_shard(self, device, index: int) -> StreamingEngine:
        """Construct one shard engine wired into the fleet's shared
        observability bundle (spans/metrics tagged with the shard index)."""
        sh = StreamingEngine(
            self.qp,
            dataclasses.replace(self.config.stream, device=device),
            act_scales=self._act_scales, naive_acts=self._naive_acts,
            obs=self.obs)
        sh._obs_shard = index
        sh._sched.shard = index
        return sh

    @classmethod
    def from_artifact(cls, artifact, config: FleetConfig | None = None, *,
                      quantized_acts: bool = False,
                      naive_acts: bool = False,
                      faults: FaultInjector | None = None,
                      obs: Observability | None = None) -> "FleetEngine":
        """Build the fleet from a compression-pipeline artifact — the same
        contract as :meth:`StreamingEngine.from_artifact`."""
        return cls(artifact, config,
                   act_scales=artifact.runtime_scales(quantized_acts),
                   naive_acts=naive_acts, faults=faults, obs=obs)

    # ------------------------------------------------------------------
    # Session lifecycle (StreamingEngine-shaped)
    # ------------------------------------------------------------------
    def attach(self, stream_id: str, samples: np.ndarray | None = None, *,
               total_steps: int | None = None,
               record_trajectory: bool = False) -> str:
        """Register a stream on its rendezvous home shard.  Returns
        ``"active"`` / ``"pending"`` (shard-local placement) or
        ``"spilled"`` when every admissible shard is saturated and the
        stream joined the fleet-level spillover queue."""
        self._reclaim(stream_id)
        if stream_id in self._owner or stream_id in self._spilled:
            raise ValueError(f"stream {stream_id!r} already attached")
        coerced = (None if samples is None
                   else self._check_samples(stream_id, samples))
        if self.config.snapshot_every is not None:
            self._drop_failover_state(stream_id)   # reused finished id
            self._journal[stream_id] = _JournalEntry(
                total=total_steps, record_trajectory=record_trajectory,
                chunks=[] if coerced is None else [coerced])
        dst = self._pick_shard(stream_id)
        if dst is None:
            entry = _SpillEntry(chunks=[], total=total_steps,
                                record_trajectory=record_trajectory)
            if coerced is not None:
                entry.chunks.append(coerced)
            self._spilled[stream_id] = entry
            self._global_spills += 1
            return "spilled"
        status = self.shards[dst].attach(
            stream_id, coerced, total_steps=total_steps,
            record_trajectory=record_trajectory)
        self._owner[stream_id] = dst
        return status

    def feed(self, stream_id: str, samples: np.ndarray) -> None:
        """Append samples to a stream, wherever it lives (shard-resident,
        shard-pending, or fleet-spilled)."""
        shard = self._owner.get(stream_id)
        if shard is not None and stream_id in self.shards[shard]._sessions:
            coerced = self._check_samples(stream_id, samples)
            self._journal_feed(stream_id, coerced)
            self.shards[shard].feed(stream_id, coerced)
            return
        if stream_id in self._spilled:
            coerced = self._check_samples(stream_id, samples)
            self._journal_feed(stream_id, coerced)
            self._spilled[stream_id].chunks.append(coerced)
            return
        raise KeyError(f"stream {stream_id!r} is not attached")

    def detach(self, stream_id: str) -> StreamEvent | None:
        """Terminate a stream (partial-window final event if it consumed
        samples since its last emission, exactly like the single engine)."""
        shard = self._owner.get(stream_id)
        if shard is not None and stream_id in self.shards[shard]._sessions:
            ev = self.shards[shard].detach(stream_id)
            del self._owner[stream_id]
            self._drop_failover_state(stream_id)
            return ev
        if stream_id in self._spilled:
            del self._spilled[stream_id]
            self._drop_failover_state(stream_id)
            return None
        self._owner.pop(stream_id, None)      # already finished: stale owner
        raise KeyError(f"stream {stream_id!r} is not attached")

    def trajectory(self, stream_id: str) -> np.ndarray:
        """(steps, H) hidden trajectory of a tapped stream — served by the
        shard that currently (or last) held it; migration carries the
        recorded prefix along, so the result spans shard moves."""
        shard = self._owner.get(stream_id)
        if shard is not None:
            return self.shards[shard].trajectory(stream_id)
        raise KeyError(f"stream {stream_id!r} was not tapped")

    # ------------------------------------------------------------------
    # Ticking
    # ------------------------------------------------------------------
    def step(self) -> list[StreamEvent]:
        """One fleet tick: drain the spillover queue into shards with
        room, then advance every shard — fused (one kernel dispatch per
        device group) or independently per shard.  Events are returned in
        shard order; per-stream ordering matches the single engine.

        With failover enabled (``snapshot_every``), the tick additionally
        checkpoints every live stream on cadence and polls the fault
        injector at each phase boundary (``faults.PHASES``): before any
        work, between the fused dispatch's two halves, and after events
        were handed to the consumer."""
        tr = self._tracer
        self._ticks += 1
        tr.set_tick(self._ticks)
        t_tick = tr.t()
        self._fire("pre_tick")
        se = self.config.snapshot_every
        if se is not None and self._ticks % se == 0:
            t0 = tr.t()
            self.snapshot_now()
            tr.rec("fleet.snapshot", t0)
        if self._spilled:
            t0 = tr.t()
            self._flush_spill()
            tr.rec("fleet.flush_spill", t0)
        live = self.n_active + self.n_pending
        if len(self._owner) > 2 * live + 1024:
            self._compact_owners()       # bound stale finished-id entries
        if not self.config.fuse_ticks:
            self._fire("mid_dispatch")
            events: list[StreamEvent] = []
            rec = self.obs.recorder
            for i, shard in enumerate(self.shards):
                out = shard.step()
                self._advanced_per_shard[i] = shard._last_advanced
                if rec is not None and out:
                    self._note_shard_events(i, out)
                events.extend(out)
        else:
            events = self._step_fused()
        t0 = tr.t()
        self._deliver(events)
        tr.rec("fleet.deliver", t0)
        self._fire("post_emit")
        dur_ns = tr.rec("fleet.tick", t_tick)
        if self.obs.metrics is not None:
            self._tick_metrics(dur_ns, events)
        return events

    def _step_fused(self) -> list[StreamEvent]:
        tr = self._tracer
        # phase 0 (device-resident only): sync last tick's dispatches.
        # Everything between last tick's issue and here — bookkeeping,
        # emission, delivery, the caller's own work — overlapped device
        # compute (the double-buffer window).  The sync MUST precede
        # phase 1: jax.device_put may alias the x/mask staging buffers
        # instead of copying, so overwriting them while a dispatch still
        # reads them corrupts the in-flight tick.
        if self._inflight:
            t0 = tr.t()
            for arr in self._inflight:
                arr.block_until_ready()
            self._inflight.clear()
            tr.rec("fleet.device_wait", t0)
        # phase 1: every shard runs admission + ring gather (no kernel)
        t0 = tr.t()
        begun: list[tuple] = []
        for shard in self.shards:
            resident = shard._sched.tick_begin()
            handle = (shard._advance_begin(resident)
                      if resident is not None else None)
            begun.append((resident, handle))
        tr.rec("fleet.begin", t0)
        # a shard crashed between the tick's two halves never reaches the
        # kernel: its gathered handle points at the dead engine's arrays
        for i in self._fire("mid_dispatch"):
            begun[i] = (None, None)
        # phase 2: one batched kernel dispatch per device group.  On the
        # device-resident path every group's dispatch is ISSUED before
        # any is waited on — co-located shards batch, distinct devices
        # compute concurrently.
        h_out: dict[int, np.ndarray] = {}
        t0 = tr.t()
        for g in self._group_list:
            self._dispatch_group(g, begun, h_out)
        tr.rec("fleet.dispatch", t0)
        # phase 3: per-shard bookkeeping + scheduler release accounting
        t0 = tr.t()
        events: list[StreamEvent] = []
        rec = self.obs.recorder
        for i, (resident, handle) in enumerate(begun):
            self._advanced_per_shard[i] = 0
            if resident is None:
                continue
            shard = self.shards[i]
            report = (shard._advance_finish(handle, h_out[i])
                      if handle is not None else TickReport())
            self._advanced_per_shard[i] = report.advanced
            out = shard._sched.tick_finish(report)
            if rec is not None and out:
                self._note_shard_events(i, out)
            events.extend(out)
        tr.rec("fleet.finish", t0)
        return events

    def _dispatch_group(self, g: _DeviceGroup, begun: list,
                        h_out: dict) -> None:
        """One group's fused dispatch.  Host path: synchronous
        ``step_rows`` over the fused operands (adopting last tick's
        output as this tick's h when every shard still holds its view;
        a shard rebinding ``_h`` — window reset, admission — falls back
        to one concatenate).  Device-resident path: ``step_resident``
        — an ASYNC dispatch that consumes the resident fused h, returns
        immediately, and is synced by the NEXT tick's ``device_wait``;
        per-shard h views are lazy device slices, so steady-state ticks
        move zero h bytes through the host."""
        idxs, off, tr = g.idxs, g.offsets, self._tracer
        live = [i for i in idxs if begun[i][1] is not None]
        if not live:
            return
        if not self._device_resident and len(live) == 1:
            # host fast path: a lone advancing shard steps its own arrays
            # (the exact backend computes only the active rows)
            i = live[0]
            sh, (avail, rows) = self.shards[i], begun[i][1]
            h_out[i] = g.kernel.step_rows(sh._h, sh._x, avail, rows)
            g.h_big = None
            return
        av = g.av_big
        if len(live) < len(idxs):
            av[:] = False
        for j, i in enumerate(idxs):
            if begun[i][1] is not None:
                av[off[j]:off[j + 1]] = begun[i][1][0]
        if self._device_resident:
            # adoption token: every shard's lazy view spec still points
            # at this group's last fused output (a shard that rebound
            # its h — reset, admission, migration restore — cleared it)
            adopted = (g.h_big is not None and
                       all((p := self.shards[i]._h_pending) is not None
                           and p[0] is g.h_big for i in idxs))
            t0 = tr.t()
            h_cat = (g.h_big if adopted
                     else g.kernel.concat_device(
                         [self.shards[i]._resolve_h() for i in idxs]))
            h_new = g.kernel.step_resident(h_cat, g.x_big, av)
            tr.rec("fleet.dispatch_issue", t0, idxs[0])
            self._inflight.append(h_new)
            g.h_big = h_new
            # per-shard views are LAZY: a real device slice here costs
            # one dispatch per shard per tick (~35% of a steady-state
            # 1024-slot tick); instead each shard gets a provenance spec
            # and materializes its slice only when it touches rows
            # (emission, taps, snapshots, resets).  Idle shards' rows
            # passed through the kernel masked (bit-preserved), so the
            # same spec keeps their state current with no host traffic.
            whole = h_new if len(idxs) == 1 else None
            for j, i in enumerate(idxs):
                sh = self.shards[i]
                sh._h = whole
                sh._h_pending = (h_new, off[j], off[j + 1])
                g.h_views[j] = None
                if i in live:
                    h_out[i] = None
            return
        adopted = (g.h_big is not None and
                   all(self.shards[i]._h is g.h_views[j]
                       for j, i in enumerate(idxs)))
        h_cat = (g.h_big if adopted    # steady state: no copy at all
                 else np.concatenate([self.shards[i]._h for i in idxs]))
        h_new = g.kernel.step_rows(h_cat, g.x_big, av, None)
        g.h_big = h_new
        for j, i in enumerate(idxs):
            view = h_new[off[j]:off[j + 1]]
            g.h_views[j] = view
            if i in live:
                h_out[i] = view

    def drain(self) -> list[StreamEvent]:
        """Tick until no stream anywhere in the fleet can advance.  Open
        streams stay attached, exactly like the single engine."""
        events: list[StreamEvent] = []
        while self._any_buffered():
            # a failover counts as progress: the crash tick itself advances
            # no stream, but recovery re-queued work that the next ticks
            # will replay — without this a crash mid-drain looks like a
            # stall and drain returns early
            before = (self._stream_steps(), self._failovers)
            out = self.step()
            events.extend(out)
            if not out and (self._stream_steps(), self._failovers) == before:
                break    # only unplaceable/pending streams hold samples
        return events

    # ------------------------------------------------------------------
    # Fleet verbs: migration, drain, decommission
    # ------------------------------------------------------------------
    def migrate(self, stream_id: str, dst: int | None = None) -> str:
        """Move a live stream to shard ``dst`` (default: its next-best
        rendezvous shard), bit-exactly: hidden state, counters, buffered
        samples and trajectory tap travel with it.  Returns the
        destination admission status (``"active"``/``"pending"``)."""
        src = self._owner.get(stream_id)
        if src is None or stream_id not in self.shards[src]._sessions:
            raise KeyError(f"stream {stream_id!r} is not on any shard")
        if dst is None:
            order = routing.rank_shards(stream_id, self.shard_keys)
            dst = next((i for i in order
                        if i != src and self._routable[i]), None)
            if dst is None:
                raise ValueError(
                    f"stream {stream_id!r}: no routable destination shard "
                    f"other than its current shard {src}")
        else:
            if not (0 <= dst < len(self.shards)):
                raise ValueError(f"no such shard: {dst}")
            if not self._routable[dst]:
                raise ValueError(
                    f"shard {dst} is decommissioned; recommission it "
                    "before migrating streams onto it")
        if dst == src:
            raise ValueError(f"stream {stream_id!r} is already on shard {src}")
        state = self.shards[src].export_stream(stream_id)
        self._owner[stream_id] = dst
        self._migrations += 1
        if self.obs.metrics is not None:
            self._m_migrations.inc()
        # carry the delivered-step watermark: a stream migrated while
        # replaying a crash recovery must keep suppressing already-seen
        # events on its new shard
        return self.shards[dst].import_stream(
            state, suppress_steps_until=self._cursor.get(stream_id))

    def decommission(self, shard: int) -> list[str]:
        """Drain shard ``shard``: remove it from routing and migrate every
        stream it holds to that stream's next-best rendezvous shard (HRW:
        streams on other shards are untouched).  The shard keeps ticking
        (it is empty) and can be brought back with :meth:`recommission`.
        Returns the migrated stream ids."""
        if not (0 <= shard < len(self.shards)):
            raise ValueError(f"no such shard: {shard}")
        self._routable[shard] = False
        if not any(self._routable):
            self._routable[shard] = True
            raise ValueError("cannot decommission the last routable shard")
        moved = [sid for sid, o in self._owner.items()
                 if o == shard and sid in self.shards[shard]._sessions]
        for sid in moved:
            state = self.shards[shard].export_stream(sid)
            dst = routing.route(sid, self.shard_keys, self._routable)
            self._owner[sid] = dst
            self._migrations += 1
            self.shards[dst].import_stream(
                state, suppress_steps_until=self._cursor.get(sid))
        if moved and self.obs.metrics is not None:
            self._m_migrations.inc(len(moved))
        return moved

    def recommission(self, shard: int) -> None:
        """Return a drained shard to the routing set.  Existing streams
        stay where they are; new streams whose rendezvous home is this
        shard land here again."""
        if not (0 <= shard < len(self.shards)):
            raise ValueError(f"no such shard: {shard}")
        self._routable[shard] = True

    # ------------------------------------------------------------------
    # Crash failover (snapshot + journal replay; see fleet/wire.py)
    # ------------------------------------------------------------------
    def snapshot_now(self) -> int:
        """Checkpoint every live shard-held stream: wire-encode a
        non-destructive :meth:`StreamingEngine.snapshot_stream` of each
        and store the blob (through the fault injector's snapshot filter,
        which may drop/duplicate/corrupt it).  A stream's replay journal
        is trimmed only when its snapshot is actually stored.  Returns
        the number of snapshots stored."""
        if self.config.snapshot_every is None:
            raise ValueError(
                "failover is disabled; construct the fleet with "
                "FleetConfig(snapshot_every=N) to enable snapshots")
        stored = 0
        for i, shard in enumerate(self.shards):
            # device-resident shards: pull every checkpointed resident
            # slot's h in ONE batched gather instead of a device
            # round-trip per stream (snapshot_stream then reads the
            # identity-keyed cache)
            shard.prefetch_h([s.slot for s in shard._sessions.values()
                              if s.slot >= 0])
            for sid in list(shard._sessions):
                blob = wire.encode_stream_state(shard.snapshot_stream(sid))
                self._snapshots_taken += 1
                out = (self._faults.filter_snapshot(i, sid, blob)
                       if self._faults is not None else (blob,))
                if not out:
                    self._snapshots_dropped += 1
                    continue
                self._snapshots_duplicated += len(out) - 1
                self._snapshots[sid] = out[-1]   # idempotent: last write wins
                ent = self._journal.get(sid)
                if ent is not None:
                    ent.chunks.clear()
                stored += 1
        return stored

    def crash_shard(self, shard: int, *, phase: str | None = None
                    ) -> dict[str, Any]:
        """Crash-fail shard ``shard``: its engine is dropped on the floor
        (no export, no drain — everything resident dies with it) and a
        fresh engine takes its place; every stream the fleet owned there
        is reconstructed from its last stored snapshot plus journal
        replay, with the replay cursor suppressing re-emission of events
        the consumer already saw.  Under the exact backend every
        recovered stream's subsequent output is bit-identical to an
        uninterrupted run (gated in ``tests/test_failover.py``).

        Returns a recovery report: streams recovered, samples queued for
        replay, wire bytes decoded."""
        if self.config.snapshot_every is None:
            raise ValueError(
                "failover is disabled; construct the fleet with "
                "FleetConfig(snapshot_every=N) before crashing shards")
        if not (0 <= shard < len(self.shards)):
            raise ValueError(f"no such shard: {shard}")
        old = self.shards[shard]
        num_crash = None
        mon = self.obs.numerics
        if mon is not None:
            # the dying shard's numeric-health child: fold its counters
            # into the retired accumulator and reset it — the replacement
            # engine resolves the SAME child (same shard index) and must
            # start from zero for conservation to hold
            child = mon.shard(shard)
            num_crash = child.snapshot()
            merge_site_counts(self._retired_numerics, num_crash["sites"])
            child.reset()
        self._retire(old.stats())
        victims = [sid for sid, o in self._owner.items()
                   if o == shard and sid in self._journal]
        new = self._make_shard(old.config.device, shard)
        self.shards[shard] = new
        g = self._group_of[shard]
        if self.config.fuse_ticks:    # rewire the fused-x view segment
            j = g.idxs.index(shard)
            new._x = g.x_big[g.offsets[j]:g.offsets[j + 1]]
        g.h_big = None                # fused-h adoption restarts from concat
        g.h_views = [None] * len(g.idxs)
        replayed = 0
        wire_bytes = 0
        d = new.kernel.input_dim
        for sid in victims:
            ent = self._journal[sid]
            blob = self._snapshots.get(sid)
            if blob is not None:
                state = wire.decode_stream_state(blob)
                wire_bytes += len(blob)
            else:   # never checkpointed: journal holds its whole history
                state = StreamState(
                    stream_id=sid,
                    h=np.zeros(new.kernel.hidden_dim, np.float32),
                    steps=0, wstep=0, total=ent.total,
                    samples=np.zeros((0, d), np.float32),
                    record_trajectory=ent.record_trajectory)
            replayed += len(state.samples)
            new.import_stream(
                state, suppress_steps_until=self._cursor.get(sid))
            for chunk in ent.chunks:
                new.feed(sid, chunk)
                replayed += len(chunk)
        self._failovers += 1
        self._replayed_samples += replayed
        report = {"shard": shard, "phase": phase,
                  "streams_recovered": len(victims),
                  "replayed_samples": replayed, "wire_bytes": wire_bytes}
        if self.obs.metrics is not None:
            self._m_failovers.inc()
        if self.obs.recorder is not None:
            # the black box: dump the tracer's pre-crash span ring plus
            # the last events each shard emitted, as a typed artifact
            counters = {"ticks": self._ticks,
                        "failovers": self._failovers,
                        "migrations": self._migrations,
                        "global_spills": self._global_spills}
            if num_crash is not None:
                # black-box numeric health at the moment of death: the
                # dead shard's own sites/drift, plus what was already
                # retired fleet-wide (deterministic snapshot — no clocks)
                counters["numerics"] = num_crash
                counters["retired_numerics"] = dict(sorted(
                    self._retired_numerics.items()))
            self.obs.recorder.record_crash(
                report, tick=self._ticks, counters=counters)
        return report

    def _fire(self, phase: str) -> list[int]:
        """Poll the fault injector at a tick phase; crash-fail whatever
        shards it names.  Returns the crashed shard indices."""
        if self._faults is None:
            return []
        crashed = []
        for s in self._faults.crashes(self, phase, self._ticks):
            self.crash_shard(int(s), phase=phase)
            crashed.append(int(s))
        return crashed

    def _deliver(self, events: list) -> None:
        """Record what the consumer has now seen: per-stream delivered-step
        watermarks (the replay cursor crash recovery suppresses up to) and
        final-event cleanup of failover state."""
        if self.config.snapshot_every is None:
            return
        for e in events:
            if isinstance(e, StreamEventBatch):
                for sid, st, fin in zip(e.stream_ids, e.steps, e.final):
                    self._note_delivery(sid, int(st), bool(fin))
            else:
                self._note_delivery(e.stream_id, e.step, e.kind == "final")

    def _note_delivery(self, sid: str, step: int, final: bool) -> None:
        if final:   # stream completed: nothing left to protect
            self._drop_failover_state(sid)
        elif step > self._cursor.get(sid, -1):
            self._cursor[sid] = step

    def _journal_feed(self, sid: str, coerced: np.ndarray) -> None:
        ent = self._journal.get(sid)
        if ent is not None and len(coerced):
            ent.chunks.append(coerced)

    def _drop_failover_state(self, sid: str) -> None:
        self._journal.pop(sid, None)
        self._snapshots.pop(sid, None)
        self._cursor.pop(sid, None)

    def _retire(self, st: dict) -> None:
        """Fold a crashed shard's monotonic counters into the retired
        accumulators so fleet totals stay conserved across the rebuild."""
        for k in self._retired:
            self._retired[k] += st[k]
        sc = st["scheduler"]
        for k in self._retired_sched:
            self._retired_sched[k] += sc[k]
        for k, v in st["transfers"].items():
            self._retired_transfers[k] += v

    def shard_of(self, stream_id: str) -> int:
        """Current shard of a stream, or -1 while fleet-spilled."""
        shard = self._owner.get(stream_id)
        if shard is not None and stream_id in self.shards[shard]._sessions:
            return shard
        if stream_id in self._spilled:
            return -1
        raise KeyError(f"stream {stream_id!r} is not attached")

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    @property
    def n_active(self) -> int:
        return sum(s.n_active for s in self.shards)

    @property
    def n_pending(self) -> int:
        return sum(s.n_pending for s in self.shards)

    @property
    def n_spilled(self) -> int:
        return len(self._spilled)

    @property
    def max_streams(self) -> int:
        """Total resident capacity: shards * slots-per-shard."""
        return sum(s.config.max_slots for s in self.shards)

    #: Workload / scheduler counter keys summed across shards by
    #: :meth:`stats` in one pass (monotonic keys also fold in the
    #: retired accumulators of crashed shards).
    _WORKLOAD_KEYS = ("active", "pending", "completed", "stream_steps",
                      "ring_spills", "replay_suppressed")
    _SCHED_KEYS = ("active", "pending", "peak_active", "admissions",
                   "recycles", "spills", "completed", "cancelled",
                   "evictions", "ticks")

    def stats(self) -> dict[str, Any]:
        """Fleet-wide roll-up: every scheduler/workload counter summed
        across shards (``scheduler`` mirrors the single engine's composed
        counter block), per-shard breakdown preserved under
        ``per_shard``, fleet-level counters alongside.

        Complexity contract: **O(shards)**, never O(streams) — one
        ``shard.stats()`` call per shard and a single accumulation pass
        over the per-shard dicts (locked in by a regression test that
        poisons stream-keyed containers).  With ``obs.debug`` set, the
        roll-up is checked against the counter-conservation invariant
        (:func:`repro.obs.invariants.assert_conservation`) before being
        returned."""
        per_shard = [s.stats() for s in self.shards]
        slots = self.max_streams

        tot = dict.fromkeys(self._WORKLOAD_KEYS, 0)
        sched_tot = dict.fromkeys(self._SCHED_KEYS, 0)
        for p in per_shard:                # the single O(shards) pass
            for k in self._WORKLOAD_KEYS:
                tot[k] += p[k]
            psc = p["scheduler"]
            for k in self._SCHED_KEYS:
                sched_tot[k] += psc[k]

        out = {
            "shards": len(self.shards),
            "routable": list(self._routable),
            "backend": self.config.stream.backend,
            "placement": self.config.placement,
            "devices": [str(d) if d is not None else "host"
                        for d in self._devices],
            "fuse_ticks": self.config.fuse_ticks,
            "device_resident": self._device_resident,
            "transfers": self._transfer_totals(),
            "max_streams": slots,
            "active": tot["active"],
            "pending": tot["pending"],
            "spilled": len(self._spilled),
            # monotonic workload counters include crashed shards' retired
            # totals, so conservation (fleet total == sum(per_shard) +
            # retired) holds under crash/recover lifecycles
            "completed": tot["completed"] + self._retired["completed"],
            "stream_steps": (tot["stream_steps"]
                             + self._retired["stream_steps"]),
            "ring_spills": tot["ring_spills"] + self._retired["ring_spills"],
            "replay_suppressed": (tot["replay_suppressed"]
                                  + self._retired["replay_suppressed"]),
            "ticks": self._ticks,
            "global_spills": self._global_spills,
            "migrations": self._migrations,
            "failover_enabled": self.config.snapshot_every is not None,
            "failovers": self._failovers,
            "replayed_samples": self._replayed_samples,
            "snapshots": {
                "taken": self._snapshots_taken,
                "dropped": self._snapshots_dropped,
                "duplicated": self._snapshots_duplicated,
                "protected_streams": len(self._snapshots),
                "journal_streams": len(self._journal),
            },
            "retired": {**self._retired,
                        "scheduler": dict(self._retired_sched)},
            **self._numerics_stats(),
            "scheduler": {
                "max_slots": slots,
                "active": sched_tot["active"],
                "pending": sched_tot["pending"],
                "occupancy": (sched_tot["active"] / slots) if slots else 0.0,
                "peak_active": sched_tot["peak_active"],
                **{k: sched_tot[k] + self._retired_sched[k]
                   for k in ("admissions", "recycles", "spills", "completed",
                             "cancelled", "evictions", "ticks")},
            },
            "per_shard": per_shard,
        }
        if self.obs.debug:
            assert_conservation(out)
        return out

    def _numerics_stats(self) -> dict[str, Any]:
        """The fleet's numeric-health stats block (empty when monitoring
        is off).  ``sites`` totals = live shard children + retired crashed
        shards, so conservation holds across crash/rebuild lifecycles
        (``obs.invariants.check_numerics_conservation``)."""
        mon = self.obs.numerics
        if mon is None:
            return {}
        snap = mon.snapshot(per_shard=True)
        totals = merge_site_counts(dict(snap["sites"]),
                                   self._retired_numerics)
        snap["sites"] = {k: totals[k] for k in sorted(totals)}
        snap["retired_sites"] = {
            k: self._retired_numerics[k]
            for k in sorted(self._retired_numerics)}
        return {"numerics": snap}

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _check_samples(self, stream_id: str, samples) -> np.ndarray:
        return coerce_samples(samples, self.shards[0].kernel.input_dim,
                              stream_id)

    def _shard_has_room(self, i: int) -> bool:
        if not self._routable[i]:
            return False
        shard, cap = self.shards[i], self.config.max_pending_per_shard
        if shard.n_active < shard.config.max_slots:
            return True
        return cap is None or shard.n_pending < cap

    def _pick_shard(self, stream_id: str) -> int | None:
        """Home shard if admissible, else the least-loaded admissible
        shard (deterministic tie-break by rendezvous rank), else None
        (fleet spillover)."""
        home = routing.route(stream_id, self.shard_keys, self._routable)
        if self._shard_has_room(home):
            return home
        order = routing.rank_shards(stream_id, self.shard_keys)
        candidates = [i for i in order if self._shard_has_room(i)]
        if not candidates:
            return None
        load = lambda i: (self.shards[i].n_active + self.shards[i].n_pending)
        return min(candidates, key=lambda i: (load(i), order.index(i)))

    def _flush_spill(self) -> None:
        """FIFO-drain the fleet spillover queue into shards with room.
        Head-of-line blocking is intentional: admission stays FIFO-fair
        fleet-wide (a later spill must not leapfrog an earlier one just
        because some shard freed a slot)."""
        while self._spilled:
            sid = next(iter(self._spilled))
            dst = self._pick_shard(sid)
            if dst is None:
                return
            entry = self._spilled.pop(sid)
            self.shards[dst].attach(
                sid, total_steps=entry.total,
                record_trajectory=entry.record_trajectory)
            for chunk in entry.chunks:
                self.shards[dst].feed(sid, chunk)
            self._owner[sid] = dst

    def _compact_owners(self) -> None:
        """Drop owner entries for streams that finished on their shard.
        A finishing stream releases shard-side only (the fleet is not in
        that loop), so without compaction an always-online fleet gains one
        dict entry per finished stream forever.  Entries whose shard still
        holds a recorded trajectory are kept so ``trajectory()`` works
        after completion, mirroring the single engine."""
        self._owner = {
            sid: shard for sid, shard in self._owner.items()
            if sid in self.shards[shard]._sessions
            or sid in self.shards[shard]._trajectories}

    def _reclaim(self, stream_id: str) -> None:
        """Drop a stale owner entry (stream finished on its shard), so the
        id becomes reusable — mirroring single-engine behaviour where a
        finished stream's id frees up."""
        shard = self._owner.get(stream_id)
        if shard is not None and stream_id not in self.shards[shard]._sessions:
            del self._owner[stream_id]

    def _stream_steps(self) -> int:
        # retired steps keep this monotonic across a crash-rebuild, which
        # drain()'s progress detection relies on
        return (sum(s._stream_steps for s in self.shards)
                + self._retired["stream_steps"])

    def _any_buffered(self) -> bool:
        if any(s._any_buffered() for s in self.shards):
            return True
        return any(e.chunks for e in self._spilled.values())

    def _transfer_totals(self) -> dict[str, int]:
        """Fleet-wide host<->device byte roll-up: every shard kernel's
        ledger (unfused / standalone paths) plus every group kernel's
        (fused dispatches).  The zero-copy regression gate reads the h
        sub-accounts' per-tick delta from here."""
        from repro.obs import sum_transfers
        return sum_transfers(
            [s.kernel.transfers.snapshot() for s in self.shards]
            + [k.transfers.snapshot() for k in self._group_kernels.values()]
            + [self._retired_transfers])


def classify_windows_fleet(fleet: FleetEngine, windows: np.ndarray,
                           ids: Iterable[str] | None = None) -> np.ndarray:
    """Fleet twin of :func:`repro.serve.streaming.classify_windows` —
    that helper also works directly on a FleetEngine (same surface); this
    alias exists so call sites read as fleet-scale on purpose."""
    from repro.serve.streaming import classify_windows
    return classify_windows(fleet, windows, ids)
