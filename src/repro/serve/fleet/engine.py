"""FleetEngine: one front door over N independent StreamingEngine shards.

The paper deploys one FastGRNN per device at 50 Hz; the cloud-side
complement is a process that serves *fleets* of such sensors — more
concurrent streams than one slot table should hold.  This module shards
the slot axis: N :class:`~repro.serve.streaming.StreamingEngine` shards,
each with its own :class:`~repro.serve.scheduler.SlotScheduler` (slot
table, pending FIFO, counters), composed behind one engine-shaped API.

Design
------
* **Routing** — deterministic rendezvous (HRW) hashing
  (``fleet/routing.py``): a stream's home shard is a pure function of its
  id and the eligible-shard set, stable across processes and under shard
  drain (removing a shard remaps only that shard's streams).
* **Admission** — shard-local: the home shard's scheduler places or
  queues the stream.  With ``max_pending_per_shard`` set, a saturated
  shard overflows into the fleet-level FIFO *spillover queue*; every tick
  drains it into the home shard when room frees, or the least-loaded
  eligible shard (deterministic tie-break) when the home stays hot.
* **Migration** — live and bit-exact: ``migrate()`` snapshots a stream
  off its shard (:meth:`StreamingEngine.export_stream` — hidden state,
  counters, unconsumed samples, trajectory tap) and re-attaches it on the
  destination (:meth:`~StreamingEngine.import_stream`).  Under the exact
  backend the continued trajectory is bit-identical to never having
  moved; ``decommission()`` uses this to drain a shard onto each
  stream's next-best rendezvous shard.
* **Fused ticks** — "batch across shards in one tick": shards run
  admission and sample-gather independently (`SlotScheduler.tick_begin` +
  `StreamingEngine._advance_begin`), then the fleet concatenates every
  co-located shard's (h, x, active) and makes ONE batched
  ``Q15StreamStep`` dispatch per device group, then each shard finishes
  its own bookkeeping.  The per-row math is row-independent, so fusion
  preserves the bit-exactness contract while amortizing per-dispatch
  overhead across shards — the measured source of near-linear shard
  scaling on CPU (``benchmarks/fleet_bench.py``).
* **Placement** — shards are assigned distinct jax devices when the
  process has them (``fleet/placement.py``; CPU runners fake them via
  ``--xla_force_host_platform_device_count``) and fall back to
  process-local NumPy shards otherwise.  The exact backend is always the
  NumPy fallback — that is the bit-identity contract surface.
* **Counters compose** — ``stats()`` sums every scheduler/workload
  counter across shards (admissions, recycles, spills, occupancy,
  evictions, …) and preserves the per-shard breakdown, plus fleet-level
  counters (``global_spills``, ``migrations``, fleet ticks).

Every stream remains **bit-identical** to the single-engine
``StreamingEngine`` reference regardless of shard count, routing, or
mid-stream migration (exact backend; asserted in ``tests/test_fleet.py``).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Iterable

import numpy as np

from repro.core import quantization as q
from repro.kernels.fastgrnn_cell.ops import Q15StreamStep
from repro.serve.scheduler import TickReport
from repro.serve.streaming import (StreamEvent, StreamState, StreamingConfig,
                                   StreamingEngine, coerce_qp,
                                   coerce_samples)
from . import placement, routing


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Fleet shape.  ``stream`` is the per-shard template —
    ``stream.max_slots`` is the *per-shard* resident width, so fleet
    capacity is ``shards * stream.max_slots`` resident streams."""
    shards: int = 4
    stream: StreamingConfig = dataclasses.field(
        default_factory=StreamingConfig)
    max_pending_per_shard: int | None = None  # None = shard FIFOs unbounded
    # (nothing ever reaches the fleet spillover queue)
    placement: str = "auto"      # "auto" | "devices" | "host"
    fuse_ticks: bool = True      # one kernel dispatch per device group/tick


@dataclasses.dataclass
class _SpillEntry:
    """A stream waiting in the fleet-level spillover queue (every shard it
    may route to is saturated).  Buffers samples until placement."""
    chunks: list
    total: int | None
    record_trajectory: bool


class FleetEngine:
    """Sharded multi-stream serving: StreamingEngine semantics at fleet
    scale.  The public surface mirrors :class:`StreamingEngine`
    (``attach / feed / step / drain / detach / trajectory / stats``) plus
    the fleet verbs (``migrate / decommission / recommission /
    shard_of``), so existing drivers — ``classify_windows``, the
    streaming benchmark — run unchanged against a fleet."""

    def __init__(self, params_or_qp, config: FleetConfig | None = None,
                 *, quant: q.QuantConfig | None = None,
                 act_scales: dict[str, float] | None = None,
                 naive_acts: bool = False):
        config = config or FleetConfig()
        if config.shards < 1:
            raise ValueError("shards must be >= 1")
        self.config = config
        self.qp = coerce_qp(params_or_qp, quant)
        devices = placement.shard_devices(
            config.shards, config.placement, config.stream.backend)
        self.shard_keys = [f"shard-{i}" for i in range(config.shards)]
        self.shards = [
            StreamingEngine(
                self.qp,
                dataclasses.replace(config.stream, device=devices[i]),
                act_scales=act_scales, naive_acts=naive_acts)
            for i in range(config.shards)]
        self._routable = [True] * config.shards
        # device groups for fused dispatch: co-located shards batch into
        # one kernel call per tick (keyed by device identity; None = the
        # process-local / default-device group)
        groups: dict[Any, list[int]] = {}
        for i, dev in enumerate(devices):
            groups.setdefault(dev, []).append(i)
        self._groups = groups
        self._group_kernels = {
            dev: Q15StreamStep(self.qp, act_scales=act_scales,
                               naive_acts=naive_acts,
                               backend=config.stream.backend,
                               interpret=config.stream.interpret,
                               device=dev)
            for dev in groups}
        self._devices = devices
        self._owner: dict[str, int] = {}   # stream -> shard (incl. pending)
        self._spilled: "collections.OrderedDict[str, _SpillEntry]" = \
            collections.OrderedDict()      # fleet-level FIFO spillover
        self._ticks = 0
        self._global_spills = 0
        self._migrations = 0
        # --- fused-tick fast path (single device group) ----------------
        # One (sum S_i, ...) buffer per kernel operand, with each shard's
        # segment handed out as a view: shards write their gathered
        # samples straight into the fused x operand (zero concat), and the
        # fused step's output h is adopted back as next tick's input when
        # no shard rebound its hidden state in between (steady state:
        # zero copies besides the kernel's own output).
        widths = [s.config.max_slots for s in self.shards]
        self._offsets = np.concatenate([[0], np.cumsum(widths)])
        self._h_big: np.ndarray | None = None
        self._h_views: list = [None] * config.shards
        if config.fuse_ticks and len(groups) == 1:
            d = self.shards[0].kernel.input_dim
            total = int(self._offsets[-1])
            self._x_big = np.zeros((total, d), np.float32)
            self._av_big = np.zeros(total, bool)
            for i, sh in enumerate(self.shards):
                sh._x = self._x_big[self._offsets[i]:self._offsets[i + 1]]
        else:
            self._x_big = None
            self._av_big = None

    @classmethod
    def from_artifact(cls, artifact, config: FleetConfig | None = None, *,
                      quantized_acts: bool = False,
                      naive_acts: bool = False) -> "FleetEngine":
        """Build the fleet from a compression-pipeline artifact — the same
        contract as :meth:`StreamingEngine.from_artifact`."""
        return cls(artifact, config,
                   act_scales=artifact.runtime_scales(quantized_acts),
                   naive_acts=naive_acts)

    # ------------------------------------------------------------------
    # Session lifecycle (StreamingEngine-shaped)
    # ------------------------------------------------------------------
    def attach(self, stream_id: str, samples: np.ndarray | None = None, *,
               total_steps: int | None = None,
               record_trajectory: bool = False) -> str:
        """Register a stream on its rendezvous home shard.  Returns
        ``"active"`` / ``"pending"`` (shard-local placement) or
        ``"spilled"`` when every admissible shard is saturated and the
        stream joined the fleet-level spillover queue."""
        self._reclaim(stream_id)
        if stream_id in self._owner or stream_id in self._spilled:
            raise ValueError(f"stream {stream_id!r} already attached")
        dst = self._pick_shard(stream_id)
        if dst is None:
            entry = _SpillEntry(chunks=[], total=total_steps,
                                record_trajectory=record_trajectory)
            if samples is not None:
                entry.chunks.append(self._check_samples(stream_id, samples))
            self._spilled[stream_id] = entry
            self._global_spills += 1
            return "spilled"
        status = self.shards[dst].attach(
            stream_id, samples, total_steps=total_steps,
            record_trajectory=record_trajectory)
        self._owner[stream_id] = dst
        return status

    def feed(self, stream_id: str, samples: np.ndarray) -> None:
        """Append samples to a stream, wherever it lives (shard-resident,
        shard-pending, or fleet-spilled)."""
        shard = self._owner.get(stream_id)
        if shard is not None and stream_id in self.shards[shard]._sessions:
            self.shards[shard].feed(stream_id, samples)
            return
        if stream_id in self._spilled:
            self._spilled[stream_id].chunks.append(
                self._check_samples(stream_id, samples))
            return
        raise KeyError(f"stream {stream_id!r} is not attached")

    def detach(self, stream_id: str) -> StreamEvent | None:
        """Terminate a stream (partial-window final event if it consumed
        samples since its last emission, exactly like the single engine)."""
        shard = self._owner.get(stream_id)
        if shard is not None and stream_id in self.shards[shard]._sessions:
            ev = self.shards[shard].detach(stream_id)
            del self._owner[stream_id]
            return ev
        if stream_id in self._spilled:
            del self._spilled[stream_id]
            return None
        self._owner.pop(stream_id, None)      # already finished: stale owner
        raise KeyError(f"stream {stream_id!r} is not attached")

    def trajectory(self, stream_id: str) -> np.ndarray:
        """(steps, H) hidden trajectory of a tapped stream — served by the
        shard that currently (or last) held it; migration carries the
        recorded prefix along, so the result spans shard moves."""
        shard = self._owner.get(stream_id)
        if shard is not None:
            return self.shards[shard].trajectory(stream_id)
        raise KeyError(f"stream {stream_id!r} was not tapped")

    # ------------------------------------------------------------------
    # Ticking
    # ------------------------------------------------------------------
    def step(self) -> list[StreamEvent]:
        """One fleet tick: drain the spillover queue into shards with
        room, then advance every shard — fused (one kernel dispatch per
        device group) or independently per shard.  Events are returned in
        shard order; per-stream ordering matches the single engine."""
        self._flush_spill()
        self._ticks += 1
        live = self.n_active + self.n_pending
        if len(self._owner) > 2 * live + 1024:
            self._compact_owners()       # bound stale finished-id entries
        if not self.config.fuse_ticks:
            events: list[StreamEvent] = []
            for shard in self.shards:
                events.extend(shard.step())
            return events
        return self._step_fused()

    def _step_fused(self) -> list[StreamEvent]:
        # phase 1: every shard runs admission + ring gather (no kernel)
        begun: list[tuple] = []
        for shard in self.shards:
            resident = shard._sched.tick_begin()
            handle = (shard._advance_begin(resident)
                      if resident is not None else None)
            begun.append((resident, handle))
        # phase 2: one batched kernel dispatch per device group
        h_out: dict[int, np.ndarray] = {}
        if self._x_big is not None:
            self._dispatch_single_group(begun, h_out)
        else:
            self._dispatch_groups(begun, h_out)
        # phase 3: per-shard bookkeeping + scheduler release accounting
        events: list[StreamEvent] = []
        for i, (resident, handle) in enumerate(begun):
            if resident is None:
                continue
            shard = self.shards[i]
            report = (shard._advance_finish(handle, h_out[i])
                      if handle is not None else TickReport())
            events.extend(shard._sched.tick_finish(report))
        return events

    def _dispatch_single_group(self, begun: list, h_out: dict) -> None:
        """Fused dispatch, zero-copy variant: every shard's ``_x`` is a
        view of one (sum S_i, d) operand, the active mask is assembled in
        a preallocated buffer, and last tick's fused output is adopted as
        this tick's h operand when every shard still holds its view of it
        (a shard rebinding ``_h`` — window reset, admission — falls back
        to one concatenate)."""
        n = len(self.shards)
        live = [i for i in range(n) if begun[i][1] is not None]
        if not live:
            return
        kern = next(iter(self._group_kernels.values()))
        off = self._offsets
        if len(live) == 1:
            i = live[0]
            sh, (avail, rows) = self.shards[i], begun[i][1]
            h_out[i] = kern.step_rows(sh._h, sh._x, avail, rows)
            self._h_big = None
            return
        av = self._av_big
        if len(live) < n:
            av[:] = False
        for i in live:
            av[off[i]:off[i + 1]] = begun[i][1][0]
        if (self._h_big is not None and
                all(self.shards[i]._h is self._h_views[i] for i in range(n))):
            h_cat = self._h_big              # steady state: no copy at all
        else:
            h_cat = np.concatenate([sh._h for sh in self.shards])
        h_new = kern.step_rows(h_cat, self._x_big, av, None)
        self._h_big = h_new
        for i in range(n):
            view = h_new[off[i]:off[i + 1]]
            self._h_views[i] = view
            if i in live:
                h_out[i] = view

    def _dispatch_groups(self, begun: list, h_out: dict) -> None:
        """Fused dispatch, one batched kernel call per device group
        (shards placed on distinct jax devices)."""
        for dev, idxs in self._groups.items():
            live = [i for i in idxs if begun[i][1] is not None]
            if not live:
                continue
            kern = self._group_kernels[dev]
            if len(live) == 1:
                i = live[0]
                sh, (avail, rows) = self.shards[i], begun[i][1]
                h_out[i] = kern.step_rows(sh._h, sh._x, avail, rows)
                continue
            h_cat = np.concatenate([self.shards[i]._h for i in live])
            x_cat = np.concatenate([self.shards[i]._x for i in live])
            av_cat = np.concatenate([begun[i][1][0] for i in live])
            h_new = kern.step_rows(h_cat, x_cat, av_cat, None)
            offset = 0
            for i in live:
                S = self.shards[i].config.max_slots
                h_out[i] = h_new[offset:offset + S]
                offset += S

    def drain(self) -> list[StreamEvent]:
        """Tick until no stream anywhere in the fleet can advance.  Open
        streams stay attached, exactly like the single engine."""
        events: list[StreamEvent] = []
        while self._any_buffered():
            before = self._stream_steps()
            out = self.step()
            events.extend(out)
            if not out and self._stream_steps() == before:
                break    # only unplaceable/pending streams hold samples
        return events

    # ------------------------------------------------------------------
    # Fleet verbs: migration, drain, decommission
    # ------------------------------------------------------------------
    def migrate(self, stream_id: str, dst: int | None = None) -> str:
        """Move a live stream to shard ``dst`` (default: its next-best
        rendezvous shard), bit-exactly: hidden state, counters, buffered
        samples and trajectory tap travel with it.  Returns the
        destination admission status (``"active"``/``"pending"``)."""
        src = self._owner.get(stream_id)
        if src is None or stream_id not in self.shards[src]._sessions:
            raise KeyError(f"stream {stream_id!r} is not on any shard")
        if dst is None:
            order = routing.rank_shards(stream_id, self.shard_keys)
            dst = next((i for i in order
                        if i != src and self._routable[i]), None)
            if dst is None:
                raise ValueError(
                    f"stream {stream_id!r}: no routable destination shard "
                    f"other than its current shard {src}")
        else:
            if not (0 <= dst < len(self.shards)):
                raise ValueError(f"no such shard: {dst}")
            if not self._routable[dst]:
                raise ValueError(
                    f"shard {dst} is decommissioned; recommission it "
                    "before migrating streams onto it")
        if dst == src:
            raise ValueError(f"stream {stream_id!r} is already on shard {src}")
        state = self.shards[src].export_stream(stream_id)
        self._owner[stream_id] = dst
        self._migrations += 1
        return self.shards[dst].import_stream(state)

    def decommission(self, shard: int) -> list[str]:
        """Drain shard ``shard``: remove it from routing and migrate every
        stream it holds to that stream's next-best rendezvous shard (HRW:
        streams on other shards are untouched).  The shard keeps ticking
        (it is empty) and can be brought back with :meth:`recommission`.
        Returns the migrated stream ids."""
        if not (0 <= shard < len(self.shards)):
            raise ValueError(f"no such shard: {shard}")
        self._routable[shard] = False
        if not any(self._routable):
            self._routable[shard] = True
            raise ValueError("cannot decommission the last routable shard")
        moved = [sid for sid, o in self._owner.items()
                 if o == shard and sid in self.shards[shard]._sessions]
        for sid in moved:
            state = self.shards[shard].export_stream(sid)
            dst = routing.route(sid, self.shard_keys, self._routable)
            self._owner[sid] = dst
            self._migrations += 1
            self.shards[dst].import_stream(state)
        return moved

    def recommission(self, shard: int) -> None:
        """Return a drained shard to the routing set.  Existing streams
        stay where they are; new streams whose rendezvous home is this
        shard land here again."""
        if not (0 <= shard < len(self.shards)):
            raise ValueError(f"no such shard: {shard}")
        self._routable[shard] = True

    def shard_of(self, stream_id: str) -> int:
        """Current shard of a stream, or -1 while fleet-spilled."""
        shard = self._owner.get(stream_id)
        if shard is not None and stream_id in self.shards[shard]._sessions:
            return shard
        if stream_id in self._spilled:
            return -1
        raise KeyError(f"stream {stream_id!r} is not attached")

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    @property
    def n_active(self) -> int:
        return sum(s.n_active for s in self.shards)

    @property
    def n_pending(self) -> int:
        return sum(s.n_pending for s in self.shards)

    @property
    def n_spilled(self) -> int:
        return len(self._spilled)

    @property
    def max_streams(self) -> int:
        """Total resident capacity: shards * slots-per-shard."""
        return sum(s.config.max_slots for s in self.shards)

    def stats(self) -> dict[str, Any]:
        """Fleet-wide roll-up: every scheduler/workload counter summed
        across shards (``scheduler`` mirrors the single engine's composed
        counter block), per-shard breakdown preserved under
        ``per_shard``, fleet-level counters alongside."""
        per_shard = [s.stats() for s in self.shards]
        slots = self.max_streams

        def tot(key):
            return sum(p[key] for p in per_shard)

        def sched_tot(key):
            return sum(p["scheduler"][key] for p in per_shard)

        return {
            "shards": len(self.shards),
            "routable": list(self._routable),
            "backend": self.config.stream.backend,
            "placement": self.config.placement,
            "devices": [str(d) if d is not None else "host"
                        for d in self._devices],
            "fuse_ticks": self.config.fuse_ticks,
            "max_streams": slots,
            "active": tot("active"),
            "pending": tot("pending"),
            "spilled": len(self._spilled),
            "completed": tot("completed"),
            "stream_steps": tot("stream_steps"),
            "ring_spills": tot("ring_spills"),
            "ticks": self._ticks,
            "global_spills": self._global_spills,
            "migrations": self._migrations,
            "scheduler": {
                "max_slots": slots,
                "active": sched_tot("active"),
                "pending": sched_tot("pending"),
                "occupancy": (sched_tot("active") / slots) if slots else 0.0,
                "peak_active": sched_tot("peak_active"),
                "admissions": sched_tot("admissions"),
                "recycles": sched_tot("recycles"),
                "spills": sched_tot("spills"),
                "completed": sched_tot("completed"),
                "cancelled": sched_tot("cancelled"),
                "evictions": sched_tot("evictions"),
                "ticks": sched_tot("ticks"),
            },
            "per_shard": per_shard,
        }

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _check_samples(self, stream_id: str, samples) -> np.ndarray:
        return coerce_samples(samples, self.shards[0].kernel.input_dim,
                              stream_id)

    def _shard_has_room(self, i: int) -> bool:
        if not self._routable[i]:
            return False
        shard, cap = self.shards[i], self.config.max_pending_per_shard
        if shard.n_active < shard.config.max_slots:
            return True
        return cap is None or shard.n_pending < cap

    def _pick_shard(self, stream_id: str) -> int | None:
        """Home shard if admissible, else the least-loaded admissible
        shard (deterministic tie-break by rendezvous rank), else None
        (fleet spillover)."""
        home = routing.route(stream_id, self.shard_keys, self._routable)
        if self._shard_has_room(home):
            return home
        order = routing.rank_shards(stream_id, self.shard_keys)
        candidates = [i for i in order if self._shard_has_room(i)]
        if not candidates:
            return None
        load = lambda i: (self.shards[i].n_active + self.shards[i].n_pending)
        return min(candidates, key=lambda i: (load(i), order.index(i)))

    def _flush_spill(self) -> None:
        """FIFO-drain the fleet spillover queue into shards with room.
        Head-of-line blocking is intentional: admission stays FIFO-fair
        fleet-wide (a later spill must not leapfrog an earlier one just
        because some shard freed a slot)."""
        while self._spilled:
            sid = next(iter(self._spilled))
            dst = self._pick_shard(sid)
            if dst is None:
                return
            entry = self._spilled.pop(sid)
            self.shards[dst].attach(
                sid, total_steps=entry.total,
                record_trajectory=entry.record_trajectory)
            for chunk in entry.chunks:
                self.shards[dst].feed(sid, chunk)
            self._owner[sid] = dst

    def _compact_owners(self) -> None:
        """Drop owner entries for streams that finished on their shard.
        A finishing stream releases shard-side only (the fleet is not in
        that loop), so without compaction an always-online fleet gains one
        dict entry per finished stream forever.  Entries whose shard still
        holds a recorded trajectory are kept so ``trajectory()`` works
        after completion, mirroring the single engine."""
        self._owner = {
            sid: shard for sid, shard in self._owner.items()
            if sid in self.shards[shard]._sessions
            or sid in self.shards[shard]._trajectories}

    def _reclaim(self, stream_id: str) -> None:
        """Drop a stale owner entry (stream finished on its shard), so the
        id becomes reusable — mirroring single-engine behaviour where a
        finished stream's id frees up."""
        shard = self._owner.get(stream_id)
        if shard is not None and stream_id not in self.shards[shard]._sessions:
            del self._owner[stream_id]

    def _stream_steps(self) -> int:
        return sum(s._stream_steps for s in self.shards)

    def _any_buffered(self) -> bool:
        if any(s._any_buffered() for s in self.shards):
            return True
        return any(e.chunks for e in self._spilled.values())


def classify_windows_fleet(fleet: FleetEngine, windows: np.ndarray,
                           ids: Iterable[str] | None = None) -> np.ndarray:
    """Fleet twin of :func:`repro.serve.streaming.classify_windows` —
    that helper also works directly on a FleetEngine (same surface); this
    alias exists so call sites read as fleet-scale on purpose."""
    from repro.serve.streaming import classify_windows
    return classify_windows(fleet, windows, ids)
