"""Shard -> device placement for the fleet.

Shards put their kernel dispatch on distinct jax devices when the process
has more than one (real accelerators, or CPU faked via
``--xla_force_host_platform_device_count=N`` — the setting tier-1 CI uses)
and fall back to process-local NumPy/default-device shards otherwise, so
the fleet runs everywhere tier-1 runs.

Placement modes:

* ``"auto"``    — distinct devices if the backend is jit/pallas and more
  than one jax device exists; host fallback otherwise.
* ``"devices"`` — force round-robin device assignment (raises if jax has
  no devices at all).
* ``"host"``    — everything on the default device / process-local NumPy.
  This is also the mode under which tick fusion batches every shard into
  ONE kernel dispatch (see ``fleet.engine``), which on a small-core host
  is the fastest configuration — per-dispatch latency amortizes across
  shards instead of repeating per shard.
"""
from __future__ import annotations

from typing import Any

PLACEMENTS = ("auto", "devices", "host")


def shard_devices(n_shards: int, placement: str = "auto",
                  backend: str = "exact") -> list[Any]:
    """Per-shard device assignment (round-robin over ``jax.devices()``),
    or ``[None] * n_shards`` for the process-local fallback.  The exact
    backend is vectorized NumPy by construction — its per-stream
    bit-identity contract does not involve a jax device — so it always
    takes the fallback."""
    if placement not in PLACEMENTS:
        raise ValueError(f"placement must be one of {PLACEMENTS}")
    if placement == "host" or backend == "exact":
        return [None] * n_shards
    try:
        import jax
        devs = jax.devices()
    except Exception:
        devs = []
    if not devs:
        if placement == "devices":
            raise ValueError("placement='devices' but jax has no devices")
        return [None] * n_shards
    if placement == "auto" and len(devs) < 2:
        return [None] * n_shards
    return [devs[i % len(devs)] for i in range(n_shards)]


def device_groups(devices: list[Any]) -> list[tuple[Any, list[int]]]:
    """Group shard indices by device identity, preserving shard order —
    the fleet's fused tick makes ONE kernel dispatch per group and, on
    the device-resident path, issues every group's dispatch before
    waiting on any (``fleet.engine._step_fused``).  ``None`` (the
    process-local fallback) is a single group."""
    groups: dict[Any, list[int]] = {}
    for i, dev in enumerate(devices):
        groups.setdefault(dev, []).append(i)
    return list(groups.items())
