"""Versioned wire format for :class:`~repro.serve.streaming.StreamState`.

A live stream is the fleet's unit of work — hidden state, step/window
counters, every buffered-but-unconsumed sample, and the trajectory tap.
PR 5 made that state *portable* in-process (``export_stream`` /
``import_stream``); this module makes it portable across processes and
crashes: ``encode_stream_state`` serializes a snapshot to deterministic
bytes and ``decode_stream_state`` reconstructs it bit-exactly, so a
replacement shard can resume the stream with outputs byte-identical to an
uninterrupted engine (the failover contract in ``serve/fleet/engine.py``).

The format reuses the ``.fgar`` idiom from ``compress/artifact.py`` —
canonical-JSON header + raw little-endian payload — with a stream-sized
preamble::

  +----------+------------------------------------------------------------+
  | preamble | ``FGSS``, u8 major, u8 minor, u32 header length,           |
  |          | u32 header crc32                                           |
  | header   | canonical JSON (sorted keys, compact separators): stream   |
  |          | identity + counters, per-tensor manifest (name, dtype,     |
  |          | shape), payload length + crc32                             |
  | payload  | raw little-endian float32 tensor bytes, manifest order     |
  |          | (``h``, then ``samples``, then ``trajectory``)             |
  +----------+------------------------------------------------------------+

Determinism contract (CI-gated in ``tests/test_wire.py``):

  * encode -> decode -> encode is byte-identical (canonical JSON pins key
    order and separators; tensors are serialized in one fixed order);
  * every truncation and every single-bit corruption of a valid blob
    raises a typed :class:`WireError` — never a silently-wrong
    ``StreamState`` (both the header and the payload carry a crc32, so a
    flipped counter bit is as detectable as a flipped sample bit).

Version policy: ``major`` changes are incompatible layout changes and are
rejected outright; ``minor`` changes are additive, so a reader rejects
only *newer* minors than it knows (``WIRE_MINOR``) — an old blob always
decodes, a blob from a newer writer fails with an explicit upgrade
message instead of dropping fields it cannot see.
"""
from __future__ import annotations

import json
import struct
import zlib

import numpy as np

from repro.compress.artifact import jsonify
from repro.serve.streaming import StreamState

MAGIC = b"FGSS"
WIRE_MAJOR = 1
WIRE_MINOR = 0

# magic, major, minor, header length, header crc32
_PREAMBLE = struct.Struct("<4sBBII")

# Tensors serialized in this fixed order (determinism: the manifest and
# payload cannot reorder between encodes of the same state):
_TENSORS = ("h", "samples", "trajectory")
_DTYPE = np.dtype("<f4")


class WireError(ValueError):
    """Base error for StreamState wire-format failures."""


class WireVersionError(WireError):
    """The blob's wire version is not decodable by this reader."""


class WireTruncatedError(WireError):
    """The blob ends before the structure it declares is complete."""


class WireCorruptError(WireError):
    """The blob is complete but fails an integrity check (crc32 or
    manifest/payload consistency)."""


def _canonical_json(obj) -> bytes:
    return json.dumps(jsonify(obj), sort_keys=True,
                      separators=(",", ":")).encode("utf-8")


def encode_stream_state(state: StreamState) -> bytes:
    """Serialize a :class:`StreamState` to deterministic wire bytes."""
    h = np.ascontiguousarray(np.asarray(state.h, np.float32))
    samples = np.ascontiguousarray(np.asarray(state.samples, np.float32))
    if samples.ndim != 2:
        raise WireError(
            f"stream {state.stream_id!r}: samples must be 2-d (k, d), "
            f"got shape {samples.shape}")
    traj_rows = list(state.trajectory)
    traj = (np.ascontiguousarray(np.stack(traj_rows).astype(np.float32))
            if traj_rows else np.zeros((0, h.shape[-1]), np.float32))
    tensors = {"h": h, "samples": samples, "trajectory": traj}
    payload = b"".join(tensors[name].astype(_DTYPE, copy=False).tobytes()
                       for name in _TENSORS)
    header = _canonical_json({
        "stream": {
            "id": state.stream_id,
            "steps": int(state.steps),
            "wstep": int(state.wstep),
            "total": None if state.total is None else int(state.total),
            "record_trajectory": bool(state.record_trajectory),
        },
        "tensors": [{"name": name, "dtype": "<f4",
                     "shape": list(tensors[name].shape)}
                    for name in _TENSORS],
        "payload": {"bytes": len(payload),
                    "crc32": zlib.crc32(payload) & 0xFFFFFFFF},
    })
    preamble = _PREAMBLE.pack(MAGIC, WIRE_MAJOR, WIRE_MINOR, len(header),
                              zlib.crc32(header) & 0xFFFFFFFF)
    return preamble + header + payload


def decode_stream_state(blob: bytes) -> StreamState:
    """Reconstruct a :class:`StreamState` from wire bytes, or raise a
    typed :class:`WireError` (version / truncation / corruption) — never
    return a partially-decoded state."""
    blob = bytes(blob)
    if len(blob) < _PREAMBLE.size:
        raise WireTruncatedError(
            f"StreamState blob is {len(blob)} bytes; the preamble alone "
            f"is {_PREAMBLE.size}")
    magic, major, minor, hlen, hcrc = _PREAMBLE.unpack_from(blob, 0)
    if magic != MAGIC:
        raise WireError(
            f"not a StreamState blob: magic {magic!r} != {MAGIC!r}")
    if major != WIRE_MAJOR:
        raise WireVersionError(
            f"unsupported StreamState wire major version {major} "
            f"(this reader supports major {WIRE_MAJOR})")
    if minor > WIRE_MINOR:
        raise WireVersionError(
            f"StreamState blob written by a newer minor version "
            f"{major}.{minor} (this reader supports up to "
            f"{WIRE_MAJOR}.{WIRE_MINOR}); upgrade the reader to decode it")
    hstart, hend = _PREAMBLE.size, _PREAMBLE.size + hlen
    if len(blob) < hend:
        raise WireTruncatedError(
            f"StreamState header declares {hlen} bytes but only "
            f"{len(blob) - hstart} are present")
    header_bytes = blob[hstart:hend]
    if (zlib.crc32(header_bytes) & 0xFFFFFFFF) != hcrc:
        raise WireCorruptError("StreamState header crc32 mismatch")
    try:
        header = json.loads(header_bytes.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireCorruptError(f"StreamState header is not valid "
                               f"canonical JSON: {e}") from e
    try:
        stream = header["stream"]
        manifest = header["tensors"]
        declared = header["payload"]
        nbytes, pcrc = int(declared["bytes"]), int(declared["crc32"])
    except (KeyError, TypeError) as e:
        raise WireCorruptError(
            f"StreamState header is missing required field: {e}") from e
    payload = blob[hend:]
    if len(payload) < nbytes:
        raise WireTruncatedError(
            f"StreamState payload declares {nbytes} bytes but only "
            f"{len(payload)} are present")
    if len(payload) > nbytes:
        raise WireError(
            f"StreamState blob has {len(payload) - nbytes} trailing bytes "
            "after the declared payload")
    if (zlib.crc32(payload) & 0xFFFFFFFF) != pcrc:
        raise WireCorruptError("StreamState payload crc32 mismatch")
    names = [t.get("name") for t in manifest]
    if names != list(_TENSORS):
        raise WireCorruptError(
            f"StreamState manifest order {names} != expected "
            f"{list(_TENSORS)}")
    tensors: dict[str, np.ndarray] = {}
    offset = 0
    for t in manifest:
        if t.get("dtype") != "<f4":
            raise WireCorruptError(
                f"tensor {t.get('name')!r}: unsupported dtype "
                f"{t.get('dtype')!r}")
        shape = tuple(int(s) for s in t["shape"])
        size = int(np.prod(shape, dtype=np.int64)) * _DTYPE.itemsize
        if offset + size > nbytes:
            raise WireCorruptError(
                f"tensor {t['name']!r} extends past the declared payload")
        tensors[t["name"]] = np.frombuffer(
            payload, _DTYPE, count=size // _DTYPE.itemsize,
            offset=offset).reshape(shape).copy()
        offset += size
    if offset != nbytes:
        raise WireCorruptError(
            f"StreamState manifest accounts for {offset} payload bytes "
            f"but {nbytes} are declared")
    traj = tensors["trajectory"]
    return StreamState(
        stream_id=str(stream["id"]),
        h=tensors["h"],
        steps=int(stream["steps"]),
        wstep=int(stream["wstep"]),
        total=None if stream["total"] is None else int(stream["total"]),
        samples=tensors["samples"],
        record_trajectory=bool(stream["record_trajectory"]),
        trajectory=[traj[i].copy() for i in range(traj.shape[0])])
