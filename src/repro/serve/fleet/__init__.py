"""Sharded fleet-serving subsystem: route 100k+ concurrent Q15 sensor
streams across per-shard slot schedulers behind one FleetEngine front
door, with wire-format stream checkpoints and bit-exact crash failover.
See ``docs/fleet.md`` for routing, migration, drain and failover
semantics and measured scaling."""
from .engine import FleetConfig, FleetEngine, classify_windows_fleet
from .faults import PHASES, FaultInjector, ScheduledFaults, crash_matrix
from .placement import shard_devices
from .routing import hrw_weight, rank_shards, route
from .wire import (WIRE_MAJOR, WIRE_MINOR, WireCorruptError, WireError,
                   WireTruncatedError, WireVersionError,
                   decode_stream_state, encode_stream_state)

__all__ = [
    "FleetConfig", "FleetEngine", "classify_windows_fleet",
    "shard_devices", "hrw_weight", "rank_shards", "route",
    "PHASES", "FaultInjector", "ScheduledFaults", "crash_matrix",
    "WIRE_MAJOR", "WIRE_MINOR", "WireError", "WireVersionError",
    "WireTruncatedError", "WireCorruptError",
    "encode_stream_state", "decode_stream_state",
]
