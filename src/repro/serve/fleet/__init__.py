"""Sharded fleet-serving subsystem: route 100k+ concurrent Q15 sensor
streams across per-shard slot schedulers behind one FleetEngine front
door.  See ``docs/fleet.md`` for routing, migration, drain semantics and
measured scaling."""
from .engine import FleetConfig, FleetEngine, classify_windows_fleet
from .placement import shard_devices
from .routing import hrw_weight, rank_shards, route

__all__ = [
    "FleetConfig", "FleetEngine", "classify_windows_fleet",
    "shard_devices", "hrw_weight", "rank_shards", "route",
]
