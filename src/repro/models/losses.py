"""Vocab-parallel cross-entropy (Megatron-style) via shard_map.

WHY: with the vocab TP-sharded, GSPMD mis-plans the unembed backward —
instead of a partial dot + small (V/tp, D) all-reduce it all-gathers the
full f32 d_logits over the batch axis (observed: 2 x 40 GB/device
all-gathers on qwen2 train_4k).  Writing the unembed + CE as an explicit
shard_map pins the communication pattern by construction:

  forward : local logits (B_loc, S, V/tp) -> pmax/psum over ``model`` for a
            stable distributed logsumexp; label pick via local one-hot
            reduce + psum (no gather/scatter anywhere).
  backward: AD through the shard_map keeps d_weight local-partial and the
            only cross-shard traffic is the tiny loss/lse cotangensum —
            d_table gets its psum over the batch axes from the in_spec
            transpose, sized (V/tp, D), not (B, S, V).

Falls back to the plain fused path when there is no mesh or the vocab does
not divide tp (hubert's V=504).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L


def _batch_spec(mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def plain_ce(logits, labels, z_loss):
    return L.cross_entropy(logits, labels, z_loss)


def vocab_parallel_ce(x, w, labels, *, mesh, tied: bool,
                      z_loss: float = 1e-4, compute_dtype=jnp.bfloat16):
    """x: (B,S,D) final hidden states; w: embed table (V,D) if tied else
    lm_head (D,V); labels: (B,S).  Returns scalar mean loss."""
    vocab = w.shape[0] if tied else w.shape[1]
    if (mesh is None or "model" not in mesh.axis_names
            or vocab % int(mesh.shape["model"]) != 0):
        if tied:
            logits = L.unembed_apply({"table": w}, x, compute_dtype)
        else:
            logits = L.dense_apply({"w": w}, x, compute_dtype=compute_dtype
                                   ).astype(jnp.float32)
        return plain_ce(logits, labels, z_loss)

    bspec = _batch_spec(mesh)
    w_spec = P("model", None) if tied else P(None, "model")

    def local(xl, wl, yl):
        v_loc = wl.shape[0] if tied else wl.shape[1]
        if tied:
            logits = jnp.einsum("bsd,vd->bsv", xl.astype(compute_dtype),
                                wl.astype(compute_dtype),
                                preferred_element_type=jnp.float32)
        else:
            logits = jnp.einsum("bsd,dv->bsv", xl.astype(compute_dtype),
                                wl.astype(compute_dtype),
                                preferred_element_type=jnp.float32)
        # stability max carries no gradient (pmax has no AD rule anyway)
        m = jax.lax.stop_gradient(
            jax.lax.pmax(jax.lax.stop_gradient(jnp.max(logits, axis=-1)),
                         "model"))                                 # (b,s)
        se = jnp.sum(jnp.exp(logits - m[..., None]), axis=-1)
        lse = m + jnp.log(jax.lax.psum(se, "model"))
        off = jax.lax.axis_index("model") * v_loc
        rel = yl - off                                            # (b,s)
        onehot = (rel[..., None] == jax.lax.broadcasted_iota(
            jnp.int32, yl.shape + (v_loc,), yl.ndim)).astype(jnp.float32)
        ll = jax.lax.psum(jnp.sum(logits * onehot, axis=-1), "model")
        loss = lse - ll
        if z_loss:
            loss = loss + z_loss * jnp.square(lse)
        loss = jnp.mean(loss)
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                loss = jax.lax.pmean(loss, ax)
        return loss

    fn = jax.shard_map(local, mesh=mesh,
                       in_specs=(P(bspec, None, None), w_spec, P(bspec, None)),
                       out_specs=P(), check_vma=False)
    return fn(x, w, labels)
