"""Shared neural building blocks for the LM zoo.

All modules are pure functions over explicit parameter pytrees (dicts), so
they compose with pjit/shard_map, jax.lax.scan over stacked layer params,
and the L-S-Q compression machinery (core/compression.py applies IHT masks
to these leaves; core/quantization.py quantizes them; low-rank Dense below
is the generalized  U = U1 @ U2^T  of the paper).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, std=0.02, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Dense (optionally low-rank factorized — the paper's W = W1 W2^T at LM scale)
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               rank: int | None = None, dtype=jnp.float32, std: float | None = None):
    std = std if std is not None else (1.0 / np.sqrt(d_in))
    if rank is None:
        p = {"w": truncated_normal(key, (d_in, d_out), std, dtype)}
    else:
        k1, k2 = jax.random.split(key)
        # product variance matched to the unfactored init
        s = float(np.sqrt(std / np.sqrt(rank)))
        p = {"w1": truncated_normal(k1, (d_in, rank), s, dtype),
             "w2": truncated_normal(k2, (rank, d_out), s, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p, x, *, compute_dtype=jnp.bfloat16):
    x = x.astype(compute_dtype)
    if "w" in p:
        y = x @ p["w"].astype(compute_dtype)
    else:
        y = (x @ p["w1"].astype(compute_dtype)) @ p["w2"].astype(compute_dtype)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p, x, eps: float = 1e-5):
    """Mean-of-squares reduced in f32 (fuses into the reduction); the
    elementwise rescale stays in x.dtype.  Keeping a full f32 (B,S,D)
    intermediate here makes XLA store the remat carry stack in f32 —
    observed +5.6 GB/device on the 4k-train dry-run."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return (x * inv) * p["scale"].astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, kind: str, *, bias: bool = False,
             rank: int | None = None, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {"w_gate": dense_init(ks[0], d_model, d_ff, bias=bias, rank=rank, dtype=dtype),
                "w_in": dense_init(ks[1], d_model, d_ff, bias=bias, rank=rank, dtype=dtype),
                "w_out": dense_init(ks[2], d_ff, d_model, bias=bias, rank=rank, dtype=dtype)}
    # relu2 (squared ReLU, nemotron) / gelu (hubert, internvl ViT-style)
    return {"w_in": dense_init(ks[0], d_model, d_ff, bias=bias, rank=rank, dtype=dtype),
            "w_out": dense_init(ks[1], d_ff, d_model, bias=bias, rank=rank, dtype=dtype)}


def mlp_apply(p, x, kind: str, *, compute_dtype=jnp.bfloat16, act_override=None):
    if kind == "swiglu":
        act = act_override or jax.nn.silu
        h = act(dense_apply(p["w_gate"], x, compute_dtype=compute_dtype)) \
            * dense_apply(p["w_in"], x, compute_dtype=compute_dtype)
    elif kind == "geglu":
        act = act_override or jax.nn.gelu
        h = act(dense_apply(p["w_gate"], x, compute_dtype=compute_dtype)) \
            * dense_apply(p["w_in"], x, compute_dtype=compute_dtype)
    elif kind == "relu2":
        h = dense_apply(p["w_in"], x, compute_dtype=compute_dtype)
        h = jnp.square(jax.nn.relu(h))
    elif kind == "gelu":
        act = act_override or jax.nn.gelu
        h = act(dense_apply(p["w_in"], x, compute_dtype=compute_dtype))
    else:
        raise ValueError(f"unknown mlp kind {kind!r}")
    return dense_apply(p["w_out"], h, compute_dtype=compute_dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {"table": truncated_normal(key, (vocab, d_model), 0.02, dtype)}


def embed_apply(p, tokens, compute_dtype=jnp.bfloat16):
    return jnp.take(p["table"], tokens, axis=0).astype(compute_dtype)


def unembed_apply(p, x, compute_dtype=jnp.bfloat16):
    """Tied unembedding: logits = x @ table^T, f32 accumulation."""
    return jnp.einsum("...d,vd->...v", x.astype(compute_dtype),
                      p["table"].astype(compute_dtype),
                      preferred_element_type=jnp.float32)


def cross_entropy(logits, labels, z_loss: float = 1e-4):
    """Stable CE with optional z-loss; logits f32 (..., V), labels (...).

    The label pick uses a one-hot reduction, NOT take_along_axis: the
    scatter in take_along_axis's backward defeats GSPMD when V is
    TP-sharded (observed: it all-gathers the full f32 d_logits over the
    batch axis — 40 GB/device at 4k x 256).  eq(iota)+multiply+reduce stays
    fused and shards cleanly on both batch and vocab axes."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    v = logits.shape[-1]
    onehot = (labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, labels.shape + (v,), labels.ndim)).astype(jnp.float32)
    ll = jnp.sum(logits * onehot, axis=-1)
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return loss.mean()
