"""Mixture-of-Experts layer: top-k routing, capacity-factor token gather,
expert parallelism (EP) over the ``model`` mesh axis.

Design (production-oriented, collective-explicit):

  * Activations between blocks are replicated over ``model`` (the TP
    convention after an o-proj/FFN-out psum) and sharded over
    (pod, data) on batch.
  * Each model shard owns E_loc = E/tp experts.  Dispatch is a purely LOCAL
    capacity-limited gather (sort-free ranking via one-hot cumsum over the
    shard's own experts), expert FFN is a dense (E_loc, C, D) einsum, and
    combine is a local scatter-add followed by one ``psum`` over ``model``
    — the same single all-reduce a TP FFN block would pay.  No giant
    (n, E, C) one-hot dispatch tensors, no all-to-all, FLOPs = expert FLOPs
    (keeps the roofline compute term honest).
  * ``moe_apply_local`` is the single code path: under ``shard_map`` it sees
    the device-local expert slice and psums; on a single device it sees all
    experts and the psum is a no-op (axis absent -> skipped).

Aux losses: Switch load-balance + router z-loss, computed from local
routing statistics (averaged over data shards by the outer loss mean).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L


def moe_init(key, d_model: int, d_ff: int, num_experts: int, kind: str = "swiglu",
             dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    def ex(k, d_in, d_out):
        return L.truncated_normal(k, (num_experts, d_in, d_out),
                                  1.0 / (d_in ** 0.5), dtype)
    p = {"router": L.dense_init(ks[0], d_model, num_experts, dtype=jnp.float32),
         "w_in": ex(ks[1], d_model, d_ff),
         "w_out": ex(ks[2], d_ff, d_model)}
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = ex(ks[3], d_model, d_ff)
    return p


def capacity(n_tokens: int, top_k: int, num_experts: int, cf: float) -> int:
    return int(max(top_k, round(cf * n_tokens * top_k / num_experts)))


def moe_apply_local(p_local, x, *, num_experts_global: int, expert_offset,
                    top_k: int, capacity_factor: float = 1.25,
                    kind: str = "swiglu", model_axis: str | None = None,
                    compute_dtype=jnp.bfloat16):
    """x: (B, S, D) local tokens (replicated over ``model``).

    ``p_local``: expert weights with local leading dim E_loc; the router is
    over the GLOBAL expert count.  ``expert_offset``: first global expert id
    owned by this shard (traced value under shard_map).
    """
    b, s, d = x.shape
    e_loc = p_local["w_in"].shape[0]
    n = b * s
    xt = x.reshape(n, d)

    gate_logits = L.dense_apply(p_local["router"], xt.astype(jnp.float32),
                                compute_dtype=jnp.float32)          # (n, E)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)               # (n, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9, None)

    # aux losses (global-expert statistics, local tokens)
    me = probs.mean(0)
    ce = jnp.zeros((num_experts_global,)).at[gate_idx.reshape(-1)].add(
        1.0 / (n * top_k), mode="drop")
    aux_loss = num_experts_global * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.scipy.special.logsumexp(gate_logits, -1)))

    # ---- local capacity-limited gather for the shard's own experts ------
    cap = capacity(n, top_k, num_experts_global, capacity_factor)
    flat_expert = gate_idx.reshape(-1)                              # (n*k,)
    flat_weight = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(n, dtype=jnp.int32), top_k)
    rel = flat_expert - expert_offset                               # (n*k,)
    mine = (rel >= 0) & (rel < e_loc)
    onehot = jax.nn.one_hot(jnp.where(mine, rel, e_loc), e_loc + 1,
                            dtype=jnp.int32)[:, :e_loc]             # (n*k, E_loc)
    rank = jnp.cumsum(onehot, axis=0) - onehot                      # slot within expert
    slot = jnp.sum(rank * onehot, axis=1)                           # (n*k,)
    keep = mine & (slot < cap)
    # scatter (expert, slot) -> token id / weight; OOB entries dropped
    e_sel = jnp.where(keep, rel, e_loc)                             # e_loc = OOB row
    idx = jnp.zeros((e_loc + 1, cap), jnp.int32).at[e_sel, slot].set(
        flat_token, mode="drop")[:e_loc]
    wgt = jnp.zeros((e_loc + 1, cap), jnp.float32).at[e_sel, slot].set(
        jnp.where(keep, flat_weight, 0.0), mode="drop")[:e_loc]
    filled = jnp.zeros((e_loc + 1, cap), jnp.bool_).at[e_sel, slot].set(
        keep, mode="drop")[:e_loc]

    xe = jnp.take(xt, idx, axis=0).astype(compute_dtype)            # (E_loc, C, D)
    xe = xe * filled[..., None].astype(compute_dtype)
    h = jnp.einsum("ecd,edf->ecf", xe, p_local["w_in"].astype(compute_dtype),
                   preferred_element_type=compute_dtype)
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        g = jnp.einsum("ecd,edf->ecf", xe, p_local["w_gate"].astype(compute_dtype),
                       preferred_element_type=compute_dtype)
        h = act(g) * h
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("ecf,efd->ecd", h, p_local["w_out"].astype(compute_dtype),
                    preferred_element_type=compute_dtype)
    ye = ye * wgt[..., None].astype(compute_dtype)
    y = jnp.zeros((n, d), compute_dtype).at[idx.reshape(-1)].add(
        ye.reshape(-1, d), mode="drop")
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
        aux_loss = jax.lax.pmean(aux_loss, model_axis)
        z_loss = jax.lax.pmean(z_loss, model_axis)
    return y.reshape(b, s, d).astype(x.dtype), {"aux_loss": aux_loss,
                                                "router_z_loss": z_loss}


def moe_apply(p, x, *, top_k: int, capacity_factor: float = 1.25,
              kind: str = "swiglu", compute_dtype=jnp.bfloat16):
    """Single-device path (all experts local) — used by smoke tests and as
    the oracle for the sharded path."""
    e = p["w_in"].shape[0]
    return moe_apply_local(
        p, x, num_experts_global=e, expert_offset=jnp.int32(0), top_k=top_k,
        capacity_factor=capacity_factor, kind=kind, model_axis=None,
        compute_dtype=compute_dtype)
