"""Unified LM assembly for all assigned families:

  dense | moe   : [ln -> GQA attn -> ln -> MLP/MoE] x L   (scan over layers)
  ssm           : [ln -> mamba2]  x L                      (scan over layers)
  hybrid(zamba2): mamba2 backbone + ONE shared attn+MLP block applied every
                  ``attn_every`` layers (weight reuse across depth)
  audio         : encoder-only (bidirectional) + frame-classification head;
                  frontend STUB: inputs are precomputed frame embeddings
  vlm           : dense decoder; frontend STUB: precomputed patch embeddings
                  prepended to the text embeddings

All forward passes are pure functions of (cfg, params, batch); layers are
stacked (leading L axis) and driven by jax.lax.scan with optional remat —
this keeps HLO size O(1) in depth, which matters for the 96-layer/340B
dry-run compile.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from . import attention as A
from . import moe as M
from . import mamba2 as S


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _block_init(cfg, key):
    ks = jax.random.split(key, 4)
    dt = cfg.pdtype
    if cfg.family == "ssm":
        return {"ln": L.rmsnorm_init(cfg.d_model, dt),
                "mamba": S.mamba_init(ks[0], cfg, dt)}
    if cfg.family == "hybrid":
        return {"ln": L.rmsnorm_init(cfg.d_model, dt),
                "mamba": S.mamba_init(ks[0], cfg, dt)}
    p = {"ln1": L.rmsnorm_init(cfg.d_model, dt),
         "attn": A.attn_init(ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                             cfg.head_dim, qkv_bias=cfg.qkv_bias, dtype=dt),
         "ln2": L.rmsnorm_init(cfg.d_model, dt)}
    if cfg.family == "moe":
        p["moe"] = M.moe_init(ks[1], cfg.d_model, cfg.d_ff, cfg.num_experts,
                              cfg.mlp_kind, dt)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind,
                              rank=cfg.lsq_rank, dtype=dt)
    return p


def init(cfg, key) -> dict[str, Any]:
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {}
    if cfg.family != "audio":
        p["embed"] = L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, cfg.pdtype)
    # stacked per-layer params
    layer_keys = jax.random.split(ks[1], cfg.num_layers)
    p["blocks"] = jax.vmap(lambda k: _block_init(cfg, k))(layer_keys)
    if cfg.family == "hybrid":
        p["shared"] = {
            "ln1": L.rmsnorm_init(cfg.d_model, cfg.pdtype),
            "attn": A.attn_init(ks[2], cfg.d_model, cfg.num_heads,
                                cfg.num_kv_heads, cfg.head_dim, dtype=cfg.pdtype),
            "ln2": L.rmsnorm_init(cfg.d_model, cfg.pdtype),
            "mlp": L.mlp_init(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp_kind,
                              dtype=cfg.pdtype),
        }
    p["final_norm"] = L.rmsnorm_init(cfg.d_model, cfg.pdtype)
    if cfg.family == "audio":
        p["lm_head"] = L.dense_init(ks[4], cfg.d_model, cfg.vocab_size,
                                    bias=True, dtype=cfg.pdtype)
    elif not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(ks[4], cfg.d_model, cfg.vocab_size,
                                    dtype=cfg.pdtype)
    return p


# ---------------------------------------------------------------------------
# Blocks (full-sequence)
# ---------------------------------------------------------------------------

def _attn_block(cfg, bp, x, positions, *, window=None, emit_cache=False):
    h, kv = A.attn_apply(bp["attn"], L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps),
                         positions, cfg, causal=cfg.causal, window=window,
                         compute_dtype=cfg.cdtype)
    x = x + h
    y = L.rmsnorm_apply(bp["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        m, aux = M.moe_apply(bp["moe"], y, top_k=cfg.top_k,
                             capacity_factor=cfg.capacity_factor,
                             kind=cfg.mlp_kind, compute_dtype=cfg.cdtype)
    else:
        m = L.mlp_apply(bp["mlp"], y, cfg.mlp_kind, compute_dtype=cfg.cdtype)
        aux = {"aux_loss": jnp.zeros(()), "router_z_loss": jnp.zeros(())}
    return x + m, aux, (kv if emit_cache else None)


def _mamba_block(cfg, bp, x):
    out = S.mamba_apply(bp["mamba"], L.rmsnorm_apply(bp["ln"], x, cfg.norm_eps),
                        cfg, chunk=cfg.ssd_chunk, compute_dtype=cfg.cdtype)
    y, state = out
    return x + y, state


def _shared_block(cfg, sp, x, positions, *, window=None):
    h, kv = A.attn_apply(sp["attn"], L.rmsnorm_apply(sp["ln1"], x, cfg.norm_eps),
                         positions, cfg, causal=True, window=window,
                         compute_dtype=cfg.cdtype)
    x = x + h
    m = L.mlp_apply(sp["mlp"], L.rmsnorm_apply(sp["ln2"], x, cfg.norm_eps),
                    cfg.mlp_kind, compute_dtype=cfg.cdtype)
    return x + m, kv


# ---------------------------------------------------------------------------
# Backbone forward (training / prefill)
# ---------------------------------------------------------------------------

def _embed_inputs(cfg, params, batch):
    """-> (x (B,S',D), positions (B,S'), text_offset)."""
    if cfg.family == "audio":
        x = batch["frames"].astype(cfg.cdtype)
        b, s = x.shape[:2]
        return x, jnp.broadcast_to(jnp.arange(s)[None], (b, s)), 0
    x = L.embed_apply(params["embed"], batch["tokens"], cfg.cdtype)
    if cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(cfg.cdtype)
        x = jnp.concatenate([patches, x], axis=1)
        off = patches.shape[1]
    else:
        off = 0
    b, s = x.shape[:2]
    return x, jnp.broadcast_to(jnp.arange(s)[None], (b, s)), off


def _seq_specs(cfg, mesh):
    from jax.sharding import PartitionSpec as P
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    return bspec, P(bspec, "model", None)


def _seq_scan_mamba(cfg, mesh, blocks, x):
    """Sequence-parallel scan over mamba blocks via shard_map
    (context-parallel SSD — see models/mamba2.mamba_apply_seq)."""
    from jax.sharding import PartitionSpec as P
    bspec, xspec = _seq_specs(cfg, mesh)

    def local(blocks_loc, x_loc):
        def body(carry, bp):
            h = L.rmsnorm_apply(bp["ln"], carry, cfg.norm_eps)
            y, st = S.mamba_apply_seq(bp["mamba"], h, cfg,
                                      chunk=cfg.ssd_chunk,
                                      compute_dtype=cfg.cdtype)
            return carry + y, st
        body = jax.checkpoint(body) if cfg.remat else body
        return jax.lax.scan(body, x_loc, blocks_loc)

    pspec = jax.tree.map(lambda _: P(), blocks)
    d_inner, pdim, nh, g, n = S.mamba_dims(cfg)
    out_state_spec = {"ssm": P(None, bspec, None, None, None),
                      "conv": {"x": P(None, bspec, None, None),
                               "B": P(None, bspec, None, None),
                               "C": P(None, bspec, None, None)}}
    fn = jax.shard_map(local, mesh=mesh, in_specs=(pspec, xspec),
                       out_specs=(xspec, out_state_spec), check_vma=False)
    return fn(blocks, x)


def _seq_scan_dense(cfg, mesh, blocks, x):
    """Megatron-style sequence parallelism for dense/vlm/audio blocks via
    shard_map (EXPERIMENTS.md Sec. Perf D):

      * residual stream sequence-sharded over `model` — norms/residuals
        local, NO per-layer TP all-reduce;
      * per block: all-gather(x) [bf16] -> TP attention (local Q heads,
        KV local when divisible, else replicated-computed) + TP MLP ->
        partial outputs reduce-scattered back to sequence shards [bf16].
        2 AG + 2 RS per layer replaces 2 all-reduces, halving wire bytes
        AND forcing bf16 (XLA otherwise reduces the f32 dot outputs);
      * explicit ZeRO: weights arrive FSDP-sharded over `data` and are
        all-gathered per layer INSIDE the scan; AD transposes that gather
        into a reduce-scatter of the gradients (ZeRO-2 semantics).
    """
    from jax.sharding import PartitionSpec as P
    bspec, xspec = _seq_specs(cfg, mesh)
    tp = int(mesh.shape["model"])
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    h_loc = H // tp
    kv_shardable = (KV % tp == 0)
    has_data = "data" in mesh.axis_names

    def gather_w(w, axis=0):  # explicit FSDP gather over `data`
        if has_data:
            return jax.lax.all_gather(w, "data", axis=axis, tiled=True)
        return w

    def local(blocks_loc, x_loc):
        nsh = jax.lax.axis_size("model")
        me = jax.lax.axis_index("model")
        b, s_loc, d = x_loc.shape
        s = s_loc * nsh
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        def body(carry, bp):
            x = carry
            # --- attention (TP over heads, full sequence) --------------
            h_ln = L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps)
            g = jax.lax.all_gather(h_ln, "model", axis=1, tiled=True)
            cd = cfg.cdtype
            wq = gather_w(bp["attn"]["q"]["w"]).astype(cd)
            q = (g.astype(cd) @ wq).reshape(b, s, h_loc, hd)
            wk = gather_w(bp["attn"]["k"]["w"]).astype(cd)
            wv = gather_w(bp["attn"]["v"]["w"]).astype(cd)
            k = (g.astype(cd) @ wk)
            v = (g.astype(cd) @ wv)
            if kv_shardable:
                kv_loc = KV // tp
                k = k.reshape(b, s, kv_loc, hd)
                v = v.reshape(b, s, kv_loc, hd)
                rep = h_loc // kv_loc
            else:  # replicated KV compute (KV small, e.g. GQA kv=8)
                k = k.reshape(b, s, KV, hd)
                v = v.reshape(b, s, KV, hd)
                # map local q heads to their kv groups
                qh = me * h_loc + jnp.arange(h_loc)
                kv_idx = qh * KV // H
                k = jnp.take(k, kv_idx, axis=2)
                v = jnp.take(v, kv_idx, axis=2)
                rep = 1
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            kr = A._repeat_kv(k, rep)
            vr = A._repeat_kv(v, rep)
            o = A.chunked_attention(q, kr, vr, cfg.causal, None)
            wo = gather_w(bp["attn"]["o"]["w"], axis=1).astype(cd)
            partial = o.reshape(b, s, h_loc * hd) @ wo          # (b,S,D) partial
            attn_out = jax.lax.psum_scatter(partial, "model",
                                            scatter_dimension=1, tiled=True)
            x = x + attn_out.astype(x.dtype)
            # --- MLP (TP over d_ff, full sequence) ---------------------
            h2 = L.rmsnorm_apply(bp["ln2"], x, cfg.norm_eps)
            g2 = jax.lax.all_gather(h2, "model", axis=1, tiled=True).astype(cd)
            w_in = gather_w(bp["mlp"]["w_in"]["w"]).astype(cd)
            hmid = g2 @ w_in
            if cfg.mlp_kind in ("swiglu", "geglu"):
                act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu
                w_g = gather_w(bp["mlp"]["w_gate"]["w"]).astype(cd)
                hmid = act(g2 @ w_g) * hmid
            elif cfg.mlp_kind == "relu2":
                hmid = jnp.square(jax.nn.relu(hmid))
            else:
                hmid = jax.nn.gelu(hmid)
            w_out = gather_w(bp["mlp"]["w_out"]["w"], axis=1).astype(cd)
            partial2 = hmid @ w_out
            mlp_out = jax.lax.psum_scatter(partial2, "model",
                                           scatter_dimension=1, tiled=True)
            return x + mlp_out.astype(x.dtype), None

        body = jax.checkpoint(body) if cfg.remat else body
        x_loc, _ = jax.lax.scan(body, x_loc, blocks_loc)
        return x_loc

    # in_specs: weights FSDP over data (dim0 after the stacked L dim) and
    # TP over model on their output/input dim per Megatron convention
    d_ax = "data" if has_data else None

    def wspec(path_leaf):
        tokens, leaf = path_leaf
        name = tokens[-1]
        if "attn" in tokens:
            proj = tokens[tokens.index("attn") + 1]
            if proj == "q" and name == "w":
                return P(None, d_ax, "model")
            if proj in ("k", "v") and name == "w":
                return P(None, d_ax, "model" if kv_shardable else None)
            if proj == "o" and name == "w":
                return P(None, "model", d_ax)
        if "mlp" in tokens:
            proj = tokens[tokens.index("mlp") + 1]
            if proj in ("w_in", "w_gate") and name == "w":
                return P(None, d_ax, "model")
            if proj == "w_out" and name == "w":
                return P(None, "model", d_ax)
        return P(*([None] * leaf.ndim))

    import re as _re
    flat, treedef = jax.tree_util.tree_flatten_with_path(blocks)
    specs = []
    for path, leaf in flat:
        tokens = _re.findall(r"\['([^']+)'\]", jax.tree_util.keystr(path))
        specs.append(wspec((tokens, leaf)))
    pspec = jax.tree_util.tree_unflatten(treedef, specs)

    fn = jax.shard_map(local, mesh=mesh, in_specs=(pspec, xspec),
                       out_specs=xspec, check_vma=False)
    return fn(blocks, x)


def _stacked_forward(cfg, params, x, positions, *, window=None, mesh=None,
                     seq_parallel=False):
    """scan over homogeneous stacked blocks.  Returns (x, aux, caches)."""
    aux0 = {"aux_loss": jnp.zeros(()), "router_z_loss": jnp.zeros(())}

    if seq_parallel and cfg.family in ("dense", "vlm", "audio"):
        x = _seq_scan_dense(cfg, mesh, params["blocks"], x)
        return x, aux0, {"k": None, "v": None}

    if cfg.family in ("ssm",):
        if seq_parallel:
            x, states = _seq_scan_mamba(cfg, mesh, params["blocks"], x)
            return x, aux0, {"ssm": states["ssm"], "conv": states["conv"]}
        def body(carry, bp):
            y, state = _mamba_block(cfg, bp, carry)
            return y, state
        body = jax.checkpoint(body) if cfg.remat else body
        x, states = jax.lax.scan(body, x, params["blocks"])
        return x, aux0, {"ssm": states["ssm"], "conv": states["conv"]}

    if cfg.family == "hybrid":
        return _hybrid_forward(cfg, params, x, positions, window=window,
                               mesh=mesh, seq_parallel=seq_parallel)

    def body(carry, bp):
        x, aux = carry
        y, a, kv = _attn_block(cfg, bp, x, positions, window=window,
                               emit_cache=True)
        aux = {k: aux[k] + a[k] for k in aux}
        return (y, aux), kv
    body = jax.checkpoint(body) if cfg.remat else body
    (x, aux), kvs = jax.lax.scan(body, (x, aux0), params["blocks"])
    return x, aux, {"k": kvs[0], "v": kvs[1]}


def _hybrid_groups(cfg):
    n_full = cfg.num_layers // cfg.attn_every
    tail = cfg.num_layers - n_full * cfg.attn_every
    return n_full, tail


def _hybrid_forward(cfg, params, x, positions, *, window=None, mesh=None,
                    seq_parallel=False):
    n_full, tail = _hybrid_groups(cfg)
    per = cfg.attn_every
    aux0 = {"aux_loss": jnp.zeros(()), "router_z_loss": jnp.zeros(())}

    def mbody(carry, bp):
        y, state = _mamba_block(cfg, bp, carry)
        return y, state
    mbody = jax.checkpoint(mbody) if cfg.remat else mbody

    def run_group(x, sl):
        if seq_parallel:
            return _seq_scan_mamba(cfg, mesh, sl, x)
        return jax.lax.scan(mbody, x, sl)

    states, kvs = [], []
    for gi in range(n_full):
        sl = jax.tree.map(lambda a: a[gi * per:(gi + 1) * per], params["blocks"])
        x, st = run_group(x, sl)
        states.append(st)
        x, kv = _shared_block(cfg, params["shared"], x, positions, window=window)
        if seq_parallel:  # keep the residual stream sequence-sharded
            from jax.sharding import NamedSharding
            _, xspec = _seq_specs(cfg, mesh)
            x = jax.lax.with_sharding_constraint(x, NamedSharding(mesh, xspec))
        kvs.append(kv)
    if tail:
        sl = jax.tree.map(lambda a: a[n_full * per:], params["blocks"])
        x, st = run_group(x, sl)
        states.append(st)
    stacked_states = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *states)
    caches = {
        "ssm": stacked_states["ssm"],
        "conv": stacked_states["conv"],
        "k": jnp.stack([k for k, _ in kvs]) if kvs else None,
        "v": jnp.stack([v for _, v in kvs]) if kvs else None,
    }
    return x, aux0, caches


def backbone(cfg, params, batch, *, window=None, mesh=None,
             seq_parallel=False):
    """-> (final normed hidden states, aux, caches, vlm text offset)."""
    x, positions, off = _embed_inputs(cfg, params, batch)
    x, aux, caches = _stacked_forward(cfg, params, x, positions,
                                      window=window, mesh=mesh,
                                      seq_parallel=seq_parallel)
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return x, aux, caches, off


def forward(cfg, params, batch, *, window=None, emit_caches=False,
            mesh=None, seq_parallel=False):
    """-> (logits f32, aux, caches)."""
    x, aux, caches, off = backbone(cfg, params, batch, window=window,
                                   mesh=mesh, seq_parallel=seq_parallel)
    if cfg.family == "audio" or not cfg.tie_embeddings:
        logits = L.dense_apply(params["lm_head"], x, compute_dtype=cfg.cdtype)
        logits = logits.astype(jnp.float32)
    else:
        logits = L.unembed_apply(params["embed"], x, cfg.cdtype)
    if cfg.family == "vlm" and off:
        logits = logits[:, off:]
    return logits, aux, (caches if emit_caches else None)


def train_loss(cfg, params, batch, mesh=None, seq_parallel=False):
    """CE via the vocab-parallel shard_map path when a mesh is given
    (see models/losses.py for why GSPMD needs the help).  Under sequence
    parallelism the vocab stays replicated and CE is position-local, so
    the plain path is already optimal."""
    from . import losses
    x, aux, _, off = backbone(cfg, params, batch, mesh=mesh,
                              seq_parallel=seq_parallel)
    if seq_parallel and cfg.uses_mamba:
        mesh = None  # vocab replicated in the ssm seq mode: plain CE
    if cfg.family == "vlm" and off:
        x = x[:, off:]
    if cfg.family == "audio":
        # classifier head has a bias and tiny vocab: plain path
        logits = L.dense_apply(params["lm_head"], x, compute_dtype=cfg.cdtype
                               ).astype(jnp.float32)
        loss = losses.plain_ce(logits, batch["labels"], cfg.z_loss)
    else:
        tied = cfg.tie_embeddings
        w = params["embed"]["table"] if tied else params["lm_head"]["w"]
        loss = losses.vocab_parallel_ce(x, w, batch["labels"], mesh=mesh,
                                        tied=tied, z_loss=cfg.z_loss,
                                        compute_dtype=cfg.cdtype)
    total = loss + cfg.aux_loss_weight * (aux["aux_loss"] + aux["router_z_loss"])
    return total, {"ce": loss, **aux}


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with KV / SSM caches
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size: int, max_len: int, dtype=jnp.bfloat16):
    c: dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}
    Lr = cfg.num_layers
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        c["k"] = jnp.zeros((Lr, batch_size, max_len, cfg.num_kv_heads, cfg.head_dim), dtype)
        c["v"] = jnp.zeros_like(c["k"])
    elif cfg.family == "ssm":
        d_inner, pdim, nh, g, n = S.mamba_dims(cfg)
        c["ssm"] = jnp.zeros((Lr, batch_size, nh, n, pdim), jnp.float32)
        c["conv"] = _conv_cache(cfg, Lr, batch_size, dtype)
    elif cfg.family == "hybrid":
        d_inner, pdim, nh, g, n = S.mamba_dims(cfg)
        n_full, _ = _hybrid_groups(cfg)
        c["ssm"] = jnp.zeros((Lr, batch_size, nh, n, pdim), jnp.float32)
        c["conv"] = _conv_cache(cfg, Lr, batch_size, dtype)
        c["k"] = jnp.zeros((n_full, batch_size, max_len, cfg.num_kv_heads, cfg.head_dim), dtype)
        c["v"] = jnp.zeros_like(c["k"])
    return c


def _conv_cache(cfg, Lr, batch_size, dtype):
    d_inner, pdim, nh, g, n = S.mamba_dims(cfg)
    w = S.CONV_W - 1
    return {"x": jnp.zeros((Lr, batch_size, w, d_inner), dtype),
            "B": jnp.zeros((Lr, batch_size, w, g * n), dtype),
            "C": jnp.zeros((Lr, batch_size, w, g * n), dtype)}


def prefill(cfg, params, batch, max_len: int | None = None, *, window=None,
            mesh=None, seq_parallel=False):
    """Full-sequence forward emitting caches sized to max_len."""
    logits, _, caches = forward(cfg, params, batch, window=window,
                                emit_caches=True, mesh=mesh,
                                seq_parallel=seq_parallel)
    b = logits.shape[0]
    s = (batch["tokens"] if "tokens" in batch else batch["frames"]).shape[1]
    if cfg.family == "vlm":
        s += batch["patch_embeds"].shape[1]
    max_len = max_len or s
    cache = init_cache(cfg, b, max_len, dtype=cfg.cdtype)
    if caches.get("k") is not None:
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], caches["k"].astype(cache["k"].dtype), (0, 0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], caches["v"].astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    if caches.get("ssm") is not None:
        cache["ssm"] = caches["ssm"].astype(cache["ssm"].dtype)
        cache["conv"] = jax.tree.map(lambda dst, src: src.astype(dst.dtype),
                                     cache["conv"], caches["conv"])
    cache["len"] = jnp.asarray(s, jnp.int32)
    return logits, cache


def decode_step(cfg, params, cache, tokens, *, window=None, mesh=None,
                splitkv=False):
    """tokens: (B, 1) int32 -> (logits (B,1,V) f32, updated cache).
    ``splitkv`` (with ``mesh``): flash-decoding over a sequence-sharded
    KV cache (attention.attn_decode_splitkv)."""
    x = L.embed_apply(params["embed"], tokens, cfg.cdtype)
    clen = cache["len"]

    if cfg.family in ("dense", "moe", "vlm"):
        def body(x, xs):
            bp, ck, cv = xs
            h = L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps)
            if splitkv:
                h, nk, nv = A.attn_decode_splitkv(
                    bp["attn"], h, ck, cv, clen, cfg, mesh=mesh,
                    window=window, compute_dtype=cfg.cdtype)
            else:
                h, nk, nv = A.attn_decode(bp["attn"], h, ck, cv, clen, cfg,
                                          window=window, compute_dtype=cfg.cdtype)
            x = x + h
            y = L.rmsnorm_apply(bp["ln2"], x, cfg.norm_eps)
            if cfg.family == "moe":
                # serving must never drop a token: capacity covers the
                # worst case (all tokens routed to one expert).
                m, _ = M.moe_apply(bp["moe"], y, top_k=cfg.top_k,
                                   capacity_factor=cfg.num_experts / cfg.top_k,
                                   kind=cfg.mlp_kind, compute_dtype=cfg.cdtype)
            else:
                m = L.mlp_apply(bp["mlp"], y, cfg.mlp_kind, compute_dtype=cfg.cdtype)
            return x + m, (nk, nv)
        x, (nk, nv) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        cache = dict(cache, k=nk, v=nv, len=clen + 1)

    elif cfg.family == "ssm":
        def body(x, xs):
            bp, conv, ssm = xs
            h = L.rmsnorm_apply(bp["ln"], x, cfg.norm_eps)
            y, nconv, nssm = S.mamba_decode(bp["mamba"], h, conv, ssm, cfg,
                                            compute_dtype=cfg.cdtype)
            return x + y, (nconv, nssm)
        x, (nconv, nssm) = jax.lax.scan(body, x, (params["blocks"], cache["conv"], cache["ssm"]))
        cache = dict(cache, conv=nconv, ssm=nssm, len=clen + 1)

    elif cfg.family == "hybrid":
        n_full, tail = _hybrid_groups(cfg)
        per = cfg.attn_every
        def body(x, xs):
            bp, conv, ssm = xs
            h = L.rmsnorm_apply(bp["ln"], x, cfg.norm_eps)
            y, nconv, nssm = S.mamba_decode(bp["mamba"], h, conv, ssm, cfg,
                                            compute_dtype=cfg.cdtype)
            return x + y, (nconv, nssm)
        convs, ssms, ks, vs = [], [], [], []
        sp = params["shared"]
        for gi in range(n_full):
            sl = lambda a, g=gi: a[g * per:(g + 1) * per]
            x, (nc, ns) = jax.lax.scan(
                body, x, (jax.tree.map(sl, params["blocks"]),
                          jax.tree.map(sl, cache["conv"]), sl(cache["ssm"])))
            convs.append(nc); ssms.append(ns)
            h = L.rmsnorm_apply(sp["ln1"], x, cfg.norm_eps)
            h, nk, nv = A.attn_decode(sp["attn"], h, cache["k"][gi], cache["v"][gi],
                                      clen, cfg, window=window, compute_dtype=cfg.cdtype)
            x = x + h
            x = x + L.mlp_apply(sp["mlp"], L.rmsnorm_apply(sp["ln2"], x, cfg.norm_eps),
                                cfg.mlp_kind, compute_dtype=cfg.cdtype)
            ks.append(nk); vs.append(nv)
        if tail:
            sl = lambda a: a[n_full * per:]
            x, (nc, ns) = jax.lax.scan(
                body, x, (jax.tree.map(sl, params["blocks"]),
                          jax.tree.map(sl, cache["conv"]), sl(cache["ssm"])))
            convs.append(nc); ssms.append(ns)
        cache = dict(cache,
                     conv=jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *convs),
                     ssm=jnp.concatenate(ssms, 0),
                     k=jnp.stack(ks), v=jnp.stack(vs), len=clen + 1)
    else:
        raise ValueError(f"no decode path for family {cfg.family!r}")

    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if not cfg.tie_embeddings and "lm_head" in params:
        logits = L.dense_apply(params["lm_head"], x, compute_dtype=cfg.cdtype).astype(jnp.float32)
    else:
        logits = L.unembed_apply(params["embed"], x, cfg.cdtype)
    return logits, cache


# ---------------------------------------------------------------------------
# Slotted caches: per-slot fill levels for continuous batching
# (serve/engine.py rides serve/scheduler.SlotScheduler over these)
# ---------------------------------------------------------------------------

def init_slot_cache(cfg, n_slots: int, max_len: int, dtype=jnp.bfloat16):
    """Slot-table decode cache: identical per-family layout to
    :func:`init_cache`, but with a per-slot fill level ``cache["pos"]``
    ((S,) int32) instead of the single shared ``cache["len"]`` — the
    state layout that lets a finished sequence's slot be re-prefilled
    while its neighbours keep decoding."""
    c = init_cache(cfg, n_slots, max_len, dtype)
    del c["len"]
    c["pos"] = jnp.zeros((n_slots,), jnp.int32)
    return c


def reset_cache_slot(cfg, cache, slot: int):
    """Zero one slot's rows of a slotted cache (recycling hygiene: the SSM
    carry is additive and MUST be cleared; KV is cleared too so stale keys
    can never leak past an off-by-one in the position mask)."""
    c = dict(cache)
    c["pos"] = cache["pos"].at[slot].set(0)
    for name in ("k", "v", "ssm"):
        if cache.get(name) is not None:
            c[name] = cache[name].at[:, slot].set(0)
    if cache.get("conv") is not None:
        c["conv"] = jax.tree.map(lambda a: a.at[:, slot].set(0), cache["conv"])
    return c


def prefill_into_slot(cfg, params, cache, batch, slot, *, window=None,
                      return_hidden=False):
    """Prefill ONE sequence (leading batch dim 1) and write its caches into
    row ``slot`` of a slotted cache — the admission half of continuous
    batching: a freed slot is re-prefilled without touching the other
    residents.  ``slot`` may be a traced scalar, so the whole function jits
    once per prompt length.  Returns ``(logits (1, s, V) f32, new cache)``
    — or the final normed hidden states ``(1, s, D)`` with
    ``return_hidden=True`` (quantized-head serving applies its own head)."""
    x, _, caches, off = backbone(cfg, params, batch, window=window)
    if return_hidden:
        out = x
    else:
        if not cfg.tie_embeddings and "lm_head" in params:
            out = L.dense_apply(params["lm_head"], x,
                                compute_dtype=cfg.cdtype).astype(jnp.float32)
        else:
            out = L.unembed_apply(params["embed"], x, cfg.cdtype)
        if cfg.family == "vlm" and off:
            out = out[:, off:]
    s = x.shape[1]                       # includes vlm patch positions
    slot = jnp.asarray(slot, jnp.int32)
    c = dict(cache)
    if caches.get("k") is not None:
        c["k"] = jax.lax.dynamic_update_slice(
            cache["k"], caches["k"].astype(cache["k"].dtype),
            (0, slot, 0, 0, 0))
        c["v"] = jax.lax.dynamic_update_slice(
            cache["v"], caches["v"].astype(cache["v"].dtype),
            (0, slot, 0, 0, 0))
    if caches.get("ssm") is not None:
        c["ssm"] = jax.lax.dynamic_update_slice(
            cache["ssm"], caches["ssm"].astype(cache["ssm"].dtype),
            (0, slot, 0, 0, 0))
        c["conv"] = jax.tree.map(
            lambda dst, src: jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), (0, slot, 0, 0)),
            cache["conv"], caches["conv"])
    c["pos"] = jax.lax.dynamic_update_slice(
        cache["pos"], jnp.asarray([s], jnp.int32), (slot,))
    return out, c


def decode_step_slotted(cfg, params, cache, tokens, active=None, *,
                        window=None, return_hidden=False):
    """One decode tick over a slotted cache.  tokens: (S, 1) int32 ->
    ``(logits (S, 1, V) f32, new cache)`` — or final normed hidden states
    ``(S, 1, D)`` with ``return_hidden=True``.

    Unlike :func:`decode_step`, every slot advances at its own
    ``cache["pos"][b]``: row ``b`` writes its K/V (or SSM update) at its
    own position and attends over its own prefix.  ``active``: (S,) bool —
    inactive slots (free, or awaiting admission) keep cache AND ``pos``
    bit-for-bit; their outputs are computed-and-discarded so the tick stays
    one fixed-shape jit call regardless of occupancy."""
    x = L.embed_apply(params["embed"], tokens, cfg.cdtype)
    pos = cache["pos"]
    if active is None:
        active = jnp.ones((tokens.shape[0],), bool)
    active = active.astype(bool)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(x, xs):
            bp, ck, cv = xs
            h = L.rmsnorm_apply(bp["ln1"], x, cfg.norm_eps)
            h, nk, nv = A.attn_decode_slotted(
                bp["attn"], h, ck, cv, pos, cfg, active=active,
                window=window, compute_dtype=cfg.cdtype)
            x = x + h
            y = L.rmsnorm_apply(bp["ln2"], x, cfg.norm_eps)
            if cfg.family == "moe":
                m, _ = M.moe_apply(bp["moe"], y, top_k=cfg.top_k,
                                   capacity_factor=cfg.num_experts / cfg.top_k,
                                   kind=cfg.mlp_kind, compute_dtype=cfg.cdtype)
            else:
                m = L.mlp_apply(bp["mlp"], y, cfg.mlp_kind,
                                compute_dtype=cfg.cdtype)
            return x + m, (nk, nv)
        x, (nk, nv) = jax.lax.scan(body, x,
                                   (params["blocks"], cache["k"], cache["v"]))
        cache = dict(cache, k=nk, v=nv)

    elif cfg.family == "ssm":
        def body(x, xs):
            bp, conv, ssm = xs
            h = L.rmsnorm_apply(bp["ln"], x, cfg.norm_eps)
            y, nconv, nssm = S.mamba_decode(bp["mamba"], h, conv, ssm, cfg,
                                            compute_dtype=cfg.cdtype)
            nconv = jax.tree.map(
                lambda new, old: jnp.where(active[:, None, None], new, old),
                nconv, conv)
            nssm = jnp.where(active[:, None, None, None], nssm, ssm)
            return x + y, (nconv, nssm)
        x, (nconv, nssm) = jax.lax.scan(
            body, x, (params["blocks"], cache["conv"], cache["ssm"]))
        cache = dict(cache, conv=nconv, ssm=nssm)

    elif cfg.family == "hybrid":
        n_full, tail = _hybrid_groups(cfg)
        per = cfg.attn_every
        def body(x, xs):
            bp, conv, ssm = xs
            h = L.rmsnorm_apply(bp["ln"], x, cfg.norm_eps)
            y, nconv, nssm = S.mamba_decode(bp["mamba"], h, conv, ssm, cfg,
                                            compute_dtype=cfg.cdtype)
            nconv = jax.tree.map(
                lambda new, old: jnp.where(active[:, None, None], new, old),
                nconv, conv)
            nssm = jnp.where(active[:, None, None, None], nssm, ssm)
            return x + y, (nconv, nssm)
        convs, ssms, ks, vs = [], [], [], []
        sp = params["shared"]
        for gi in range(n_full):
            sl = lambda a, g=gi: a[g * per:(g + 1) * per]
            x, (nc, ns) = jax.lax.scan(
                body, x, (jax.tree.map(sl, params["blocks"]),
                          jax.tree.map(sl, cache["conv"]), sl(cache["ssm"])))
            convs.append(nc); ssms.append(ns)
            h = L.rmsnorm_apply(sp["ln1"], x, cfg.norm_eps)
            h, nk, nv = A.attn_decode_slotted(
                sp["attn"], h, cache["k"][gi], cache["v"][gi], pos, cfg,
                active=active, window=window, compute_dtype=cfg.cdtype)
            x = x + h
            x = x + L.mlp_apply(sp["mlp"],
                                L.rmsnorm_apply(sp["ln2"], x, cfg.norm_eps),
                                cfg.mlp_kind, compute_dtype=cfg.cdtype)
            ks.append(nk); vs.append(nv)
        if tail:
            sl = lambda a: a[n_full * per:]
            x, (nc, ns) = jax.lax.scan(
                body, x, (jax.tree.map(sl, params["blocks"]),
                          jax.tree.map(sl, cache["conv"]), sl(cache["ssm"])))
            convs.append(nc); ssms.append(ns)
        cache = dict(cache,
                     conv=jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *convs),
                     ssm=jnp.concatenate(ssms, 0),
                     k=jnp.stack(ks), v=jnp.stack(vs))
    else:
        raise ValueError(f"no slotted decode path for family {cfg.family!r}")

    cache["pos"] = pos + active.astype(jnp.int32)
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, cache
    if not cfg.tie_embeddings and "lm_head" in params:
        logits = L.dense_apply(params["lm_head"], x,
                               compute_dtype=cfg.cdtype).astype(jnp.float32)
    else:
        logits = L.unembed_apply(params["embed"], x, cfg.cdtype)
    return logits, cache
