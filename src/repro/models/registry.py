"""Registry: per-architecture step functions + abstract input specs.

The dry-run, trainer, and serving engine all consume this one interface:

  * ``abstract_params(cfg)``           — eval_shape of init
  * ``input_specs(cfg, shape)``        — ShapeDtypeStruct batch stand-ins
  * ``abstract_cache(cfg, shape)``     — decode-cache stand-ins
  * ``make_train_step(cfg, acfg)``     — (params, opt, batch) -> ...
  * ``make_prefill_step(cfg, window)`` — (params, batch) -> (logits, cache)
  * ``make_decode_step(cfg, window)``  — (params, cache, tokens) -> ...

``long_*`` decode shapes pass ``window=cfg.sliding_window`` so hybrid
attention stays sub-quadratic per the assignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.train import optimizer as opt_mod
from . import transformer as T


def init(cfg: ModelConfig, key):
    return T.init(cfg, key)


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: T.init(cfg, k), jax.random.PRNGKey(0))


def abstract_opt(cfg: ModelConfig, acfg: opt_mod.AdamConfig):
    ap = abstract_params(cfg)
    return jax.eval_shape(lambda p: opt_mod.init(p, acfg), ap)


def _window_for(cfg: ModelConfig, shape: ShapeConfig):
    if shape.name == "long_500k" and cfg.family == "hybrid":
        return cfg.sliding_window
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Batch ShapeDtypeStructs for one (arch x shape) cell.  Weak-type
    correct, shardable, no device allocation."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    out: dict = {}
    if shape.kind == "decode":
        out["tokens"] = sds((B, 1), jnp.int32)
        return out
    if cfg.family == "audio":
        out["frames"] = sds((B, S, cfg.d_model), jnp.bfloat16)
    else:
        out["tokens"] = sds((B, S), jnp.int32)
    if cfg.family == "vlm":
        out["patch_embeds"] = sds((B, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    if shape.kind == "train":
        out["labels"] = sds((B, S), jnp.int32)
    return out


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig):
    """Decode-cache stand-ins with max_len = shape.seq_len (the assignment:
    'one new token with a KV cache of seq_len')."""
    return jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len,
                             dtype=cfg.cdtype))


def make_train_step(cfg: ModelConfig, acfg: opt_mod.AdamConfig, mesh=None,
                    seq_parallel: bool = False):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: T.train_loss(cfg, p, batch, mesh=mesh,
                                   seq_parallel=seq_parallel),
            has_aux=True)(params)
        params, opt_state, om = opt_mod.update(params, grads, opt_state, acfg)
        return params, opt_state, {"loss": loss, **metrics, **om}
    return train_step


def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig | None = None,
                      mesh=None, seq_parallel: bool = False):
    window = _window_for(cfg, shape) if shape else None

    def prefill_step(params, batch):
        if cfg.is_encoder:
            logits, _, _ = T.forward(cfg, params, batch, window=window)
            return logits
        return T.prefill(cfg, params, batch, window=window, mesh=mesh,
                         seq_parallel=seq_parallel)
    return prefill_step


def make_decode_step(cfg: ModelConfig, shape: ShapeConfig | None = None,
                     mesh=None, splitkv: bool = False):
    window = _window_for(cfg, shape) if shape else None

    def decode_step(params, cache, tokens):
        return T.decode_step(cfg, params, cache, tokens, window=window,
                             mesh=mesh, splitkv=splitkv)
    return decode_step


def abstract_quantized_params(cfg: ModelConfig, bits: int = 8):
    """(qparams, scales) ShapeDtypeStructs for the L-S-Q serving path:
    every >=2D float leaf becomes int8/int16 + a per-tensor f32 scale.
    Decode is HBM-bound on weight reads, so int8 halves the dominant
    roofline term (EXPERIMENTS.md Sec. Perf C)."""
    ap = abstract_params(cfg)
    dt = jnp.int8 if bits == 8 else jnp.int16

    def q(leaf):
        if leaf.ndim >= 2 and jnp.issubdtype(leaf.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(leaf.shape, dt)
        return leaf
    qp = jax.tree.map(q, ap)
    scales = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((), jnp.float32), ap)
    return qp, scales


def make_decode_step_quantized(cfg: ModelConfig, shape: ShapeConfig | None = None,
                               bits: int = 8, mesh=None, splitkv: bool = False):
    """Decode with int-quantized weights: int8/int16 leaves stream from
    HBM and the convert+scale fuses into the consuming matmuls (the Pallas
    q15_matmul kernel is the explicit-VMEM-tile version of the same
    contract)."""
    from repro.compress.tree import dequantize_tree
    window = _window_for(cfg, shape) if shape else None

    def decode_step(qparams, scales, cache, tokens):
        params = dequantize_tree(qparams, scales)
        return T.decode_step(cfg, params, cache, tokens, window=window,
                             mesh=mesh, splitkv=splitkv)
    return decode_step


def step_flops_model(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS for the roofline's usefulness ratio:
    6*N*D (train, dense), 6*N_active*D (MoE), 2*N per decoded token."""
    n_active = active_param_count(cfg)
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def param_count(cfg: ModelConfig) -> int:
    import numpy as np
    ap = abstract_params(cfg)
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(ap)))


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top_k of num_experts)."""
    import numpy as np
    ap = abstract_params(cfg)
    total = 0
    flat = jax.tree_util.tree_flatten_with_path(ap)[0]
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        key = jax.tree_util.keystr(path)
        if "moe" in key and "router" not in key:
            n = n * cfg.top_k // cfg.num_experts
        if "embed" in key or ("lm_head" in key and leaf.ndim >= 2):
            # embeddings: lookup is O(d); unembed matmul does count
            if "embed" in key:
                continue
        total += n
    return total
