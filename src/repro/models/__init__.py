from . import layers, attention, moe, mamba2, transformer, baselines  # noqa: F401
