"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD formulation (the paper's quadratic-intra / linear-inter split):
within chunks of length Q the recurrence is evaluated as a masked,
decay-weighted attention-like matmul (MXU-friendly); across chunks a
sequential state recurrence carries (H, P, N) states.  This jnp version is
the oracle for the Pallas kernel in ``repro/kernels/ssd_scan``.

TP note: the reference implementation fuses z/x/B/C/dt into one in_proj of
width 2*d_inner + 2*G*N + H, which is NOT divisible by tp=16 for the
assigned configs.  We keep the identical math but store the projection as
five column-blocks (z, x, B, C, dt) so each output is cleanly shardable:
z/x/dt on ``model`` (head-aligned), B/C replicated (they are shared across
heads, G groups only).  A checkpoint converter would simply split the
fused matrix by columns.  The depthwise conv is split the same way —
depthwise = per-channel, so sharding follows the channel blocks with no
extra communication.

Shapes: x (B,S,H,P) heads*headdim = d_inner; dt (B,S,H); A (H,) negative;
B,C (B,S,G,N) with G groups broadcast over H//G heads each.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L


def ssd_chunked(x, dt, A, B, C, *, chunk: int = 256, h0=None,
                return_cs: bool = False):
    """Returns (y, final_state[, cs]).  y: (B,S,H,P); state: (B,H,N,P);
    cs (when requested): (B,S,H) inclusive cumsum of dt*A over the whole
    span — the sequence-parallel correction needs exp(cs) (see
    mamba_seq_forward: y(h0) = y(0) + C_i exp(cs_i) h0 by linearity)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    pad = (-s) % chunk
    if pad:
        zf = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, B, C = zf(x), zf(dt), zf(B), zf(C)
    sp = s + pad
    nc = sp // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)

    dA = dtc * A.astype(jnp.float32)                     # (b,nc,Q,h), negative
    cs = jnp.cumsum(dA, axis=2)                          # inclusive cumsum
    # intra-chunk decay L[i,j] = exp(cs_i - cs_j) for i >= j.  Clamp the
    # masked (i < j) entries BEFORE the exp: cs_i - cs_j > 0 there and
    # exp overflows, which poisons the backward (d/dx where(m, exp(x), 0)
    # evaluates exp at the masked points -> inf * 0 = NaN).
    li = cs[:, :, :, None, :] - cs[:, :, None, :, :]     # (b,nc,Q,Q,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    li = jnp.where(mask[None, None, :, :, None], li, -1e30)
    Ldec = jnp.exp(li)

    Bh = jnp.repeat(Bc, rep, axis=3)                     # (b,nc,Q,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Ch.astype(jnp.float32),
                        Bh.astype(jnp.float32))
    M = scores * Ldec * dtc[:, :, None, :, :]            # weight by dt_j
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", M.astype(x.dtype), xc)

    # chunk-final states: S_c[h,n,p] = sum_j exp(cs_last - cs_j) dt_j B_j x_j
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)        # (b,nc,Q,h)
    dBx = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp",
                     (decay_to_end * dtc).astype(jnp.float32),
                     Bh.astype(jnp.float32), xc.astype(jnp.float32))

    # inter-chunk recurrence (sequential over nc)
    chunk_decay = jnp.exp(cs[:, :, -1, :])               # (b,nc,h)
    def step(carry, inp):
        dec, s_new = inp                                 # (b,h), (b,h,n,p)
        h_prev = carry
        h_next = dec[:, :, None, None] * h_prev + s_new
        return h_next, h_prev                            # emit state BEFORE chunk
    init = jnp.zeros((b, h, n, p), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        step, init, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(dBx, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                # (b,nc,h,n,p)

    # inter-chunk contribution: y_off_i = C_i . (exp(cs_i) * H_prev)
    y_off = jnp.einsum("bcihn,bcih,bchnp->bcihp", Ch.astype(jnp.float32),
                       jnp.exp(cs), h_prevs).astype(x.dtype)
    y = (y_diag + y_off).reshape(b, sp, h, p)[:, :s]
    if return_cs:
        # global (span-)cumsum: within-chunk cs + closed prior-chunk sums
        prior = jnp.cumsum(cs[:, :, -1, :], axis=1) - cs[:, :, -1, :]
        cs_full = (cs + prior[:, :, None, :]).reshape(b, sp, h)[:, :s]
        return y, h_final.astype(jnp.float32), cs_full
    return y, h_final.astype(jnp.float32)


def ssd_decode_step(state, x, dt, A, B, C):
    """One-token SSD update.  state: (B,H,N,P); x: (B,H,P); dt: (B,H);
    B,C: (B,G,N).  Returns (y (B,H,P), new_state)."""
    h, g = x.shape[1], B.shape[1]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1).astype(jnp.float32)   # (B,H,N)
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dec = jnp.exp(dtf * A.astype(jnp.float32))            # (B,H)
    upd = jnp.einsum("bh,bhn,bhp->bhnp", dtf, Bh, x.astype(jnp.float32))
    new_state = dec[:, :, None, None] * state + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Full Mamba2 block: [z|x|B|C|dt]_proj -> conv(x,B,C) -> SSD -> gated norm
# -> out_proj
# ---------------------------------------------------------------------------

CONV_W = 4


def mamba_dims(cfg):
    d_inner = 2 * cfg.d_model
    headdim = cfg.mamba_headdim
    return d_inner, headdim, d_inner // headdim, cfg.mamba_groups, cfg.ssm_state


def mamba_init(key, cfg, dtype=jnp.float32):
    d_inner, pdim, n_heads, g, n = mamba_dims(cfg)
    ks = jax.random.split(key, 9)
    return {
        "z_proj": L.dense_init(ks[0], cfg.d_model, d_inner, dtype=dtype),
        "x_proj": L.dense_init(ks[1], cfg.d_model, d_inner, dtype=dtype),
        "B_proj": L.dense_init(ks[2], cfg.d_model, g * n, dtype=dtype),
        "C_proj": L.dense_init(ks[3], cfg.d_model, g * n, dtype=dtype),
        "dt_proj": L.dense_init(ks[4], cfg.d_model, n_heads, dtype=dtype),
        "conv_x": L.truncated_normal(ks[5], (CONV_W, d_inner), 0.1, dtype),
        "conv_x_b": jnp.zeros((d_inner,), dtype),
        "conv_B": L.truncated_normal(ks[6], (CONV_W, g * n), 0.1, dtype),
        "conv_B_b": jnp.zeros((g * n,), dtype),
        "conv_C": L.truncated_normal(ks[7], (CONV_W, g * n), 0.1, dtype),
        "conv_C_b": jnp.zeros((g * n,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(jnp.float32)),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "gn": L.rmsnorm_init(d_inner, dtype),
        "out_proj": L.dense_init(ks[8], d_inner, cfg.d_model, dtype=dtype),
    }


def _causal_conv(u, w, b):
    """Depthwise causal conv.  u: (B,S,C); w: (W,C)."""
    W = w.shape[0]
    up = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    y = sum(up[:, i:i + u.shape[1], :] * w[i] for i in range(W))
    return y + b


def _projections(p, xin, cfg, compute_dtype):
    cd = compute_dtype
    z = L.dense_apply(p["z_proj"], xin, compute_dtype=cd)
    xr = L.dense_apply(p["x_proj"], xin, compute_dtype=cd)
    Br = L.dense_apply(p["B_proj"], xin, compute_dtype=cd)
    Cr = L.dense_apply(p["C_proj"], xin, compute_dtype=cd)
    dt = L.dense_apply(p["dt_proj"], xin, compute_dtype=cd)
    return z, xr, Br, Cr, dt


def mamba_apply(p, xin, cfg, *, chunk: int = 256, compute_dtype=jnp.bfloat16,
                ssm_impl=ssd_chunked):
    """Full-sequence Mamba2 block.  xin: (B,S,D) -> (out, states dict)."""
    b, s, _ = xin.shape
    d_inner, pdim, n_heads, g, n = mamba_dims(cfg)
    cd = compute_dtype
    z, xr, Br, Cr, dt = _projections(p, xin, cfg, cd)
    conv_tails = {"x": xr[:, -(CONV_W - 1):], "B": Br[:, -(CONV_W - 1):],
                  "C": Cr[:, -(CONV_W - 1):]}
    xr = jax.nn.silu(_causal_conv(xr, p["conv_x"].astype(cd), p["conv_x_b"].astype(cd)))
    Br = jax.nn.silu(_causal_conv(Br, p["conv_B"].astype(cd), p["conv_B_b"].astype(cd)))
    Cr = jax.nn.silu(_causal_conv(Cr, p["conv_C"].astype(cd), p["conv_C_b"].astype(cd)))
    x = xr.reshape(b, s, n_heads, pdim)
    B = Br.reshape(b, s, g, n)
    C = Cr.reshape(b, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssm_impl(x, dt, A, B, C, chunk=chunk)
    y = y + p["D"].astype(cd)[None, None, :, None] * x
    y = y.reshape(b, s, d_inner)
    y = L.rmsnorm_apply(p["gn"], y * jax.nn.silu(z), cfg.norm_eps)
    out = L.dense_apply(p["out_proj"], y, compute_dtype=cd)
    return out, {"ssm": state, "conv": conv_tails}


# ---------------------------------------------------------------------------
# Sequence-parallel (context-parallel) block — runs INSIDE shard_map with
# the sequence sharded over ``axis`` and ALL weights replicated.
#
# Insight: the SSD recurrence is associative in (decay, state), and y is
# LINEAR in the incoming state h0:  y(h0) = y(0) + C_i * exp(cs_i) * h0.
# So each device runs its local span with h0 = 0, the (total_decay,
# final_state) pairs — (B,H) + (B,H,N,P), ~1.6 MB — are all-gathered, each
# device folds its predecessors locally, and adds the correction term.
# Replaces the per-layer 400 MB TP all-reduce of (B,S,D) activations with
# a ~2 MB state exchange (+ a 3-sample conv halo ppermute): the fix for
# the collective-bound mamba2/zamba2 cells (EXPERIMENTS.md Sec. Perf A2).
# ---------------------------------------------------------------------------

def _conv_with_context(u, ctx, w, b):
    """Causal conv where the first W-1 inputs come from the left
    neighbor's span tail (zeros on device 0 = true sequence start)."""
    y = _causal_conv(jnp.concatenate([ctx, u], axis=1), w, b)
    return y[:, ctx.shape[1]:]


def mamba_apply_seq(p, xin, cfg, *, axis: str = "model", chunk: int = 256,
                    compute_dtype=jnp.bfloat16):
    """Sequence-parallel Mamba2 block body (shard_map context).
    xin: (B, S_loc, D) local span.  Returns (out, states dict) where the
    ssm state is the GLOBAL final state (replicated) and conv is the
    global tail (nonzero only on the last shard; psum-combined)."""
    b, s, _ = xin.shape
    d_inner, pdim, n_heads, g, n = mamba_dims(cfg)
    cd = compute_dtype
    nsh = jax.lax.axis_size(axis)
    me = jax.lax.axis_index(axis)
    perm = [(i, i + 1) for i in range(nsh - 1)]

    z, xr, Br, Cr, dt = _projections(p, xin, cfg, cd)
    tails = {"x": xr[:, -(CONV_W - 1):], "B": Br[:, -(CONV_W - 1):],
             "C": Cr[:, -(CONV_W - 1):]}

    def conv_sp(t, wname, bname):
        ctx = jax.lax.ppermute(t[:, -(CONV_W - 1):], axis, perm)
        return jax.nn.silu(_conv_with_context(
            t, ctx, p[wname].astype(cd), p[bname].astype(cd)))

    xr = conv_sp(xr, "conv_x", "conv_x_b")
    Br = conv_sp(Br, "conv_B", "conv_B_b")
    Cr = conv_sp(Cr, "conv_C", "conv_C_b")
    x = xr.reshape(b, s, n_heads, pdim)
    B = Br.reshape(b, s, g, n)
    C = Cr.reshape(b, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y0, state, cs = ssd_chunked(x, dt, A, B, C, chunk=chunk, return_cs=True)
    decay_span = jnp.exp(cs[:, -1])                         # (b,h)
    dg = jax.lax.all_gather(decay_span, axis)               # (nsh,b,h)
    sg = jax.lax.all_gather(state, axis)                    # (nsh,b,h,n,p)
    run = jnp.zeros_like(state)
    h_in = jnp.zeros_like(state)
    for d in range(nsh):                                    # tiny local fold
        h_in = jnp.where(me == d, run, h_in)
        run = dg[d][:, :, None, None] * run + sg[d]
    Ch = jnp.repeat(C, n_heads // g, axis=2).astype(jnp.float32)
    y_corr = jnp.einsum("bshn,bsh,bhnp->bshp", Ch, jnp.exp(cs), h_in)
    y = y0 + y_corr.astype(y0.dtype)
    y = y + p["D"].astype(cd)[None, None, :, None] * x
    y = y.reshape(b, s, d_inner)
    y = L.rmsnorm_apply(p["gn"], y * jax.nn.silu(z), cfg.norm_eps)
    out = L.dense_apply(p["out_proj"], y, compute_dtype=cd)
    # global caches: final state = full fold (same on all shards);
    # conv tail lives on the LAST shard -> mask + psum
    last = (me == nsh - 1)
    tails = jax.tree.map(
        lambda t: jax.lax.psum(jnp.where(last, t, jnp.zeros_like(t)), axis),
        tails)
    return out, {"ssm": run, "conv": tails}


def mamba_decode(p, xin, conv_state, ssm_state, cfg, *, compute_dtype=jnp.bfloat16):
    """One-token decode.  xin: (B,1,D); conv_state: dict of (B,W-1,*);
    ssm_state: (B,H,N,P).  Returns (out (B,1,D), new_conv, new_ssm)."""
    b = xin.shape[0]
    d_inner, pdim, n_heads, g, n = mamba_dims(cfg)
    cd = compute_dtype
    z, xr, Br, Cr, dt = _projections(p, xin[:, 0], cfg, cd)

    def conv_step(state, new, w, bias):
        seq = jnp.concatenate([state.astype(cd), new[:, None, :]], axis=1)
        y = jnp.einsum("bwc,wc->bc", seq, w.astype(cd)) + bias.astype(cd)
        return jax.nn.silu(y), seq[:, 1:]

    xr, ncx = conv_step(conv_state["x"], xr, p["conv_x"], p["conv_x_b"])
    Br, ncB = conv_step(conv_state["B"], Br, p["conv_B"], p["conv_B_b"])
    Cr, ncC = conv_step(conv_state["C"], Cr, p["conv_C"], p["conv_C_b"])
    x = xr.reshape(b, n_heads, pdim)
    B = Br.reshape(b, g, n)
    C = Cr.reshape(b, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    yo, new_ssm = ssd_decode_step(ssm_state, x, dt, A, B, C)
    yo = yo + p["D"].astype(cd)[None, :, None] * x
    yo = yo.reshape(b, d_inner)
    yo = L.rmsnorm_apply(p["gn"], yo * jax.nn.silu(z), cfg.norm_eps)
    out = L.dense_apply(p["out_proj"], yo, compute_dtype=cd)
    return out[:, None, :], {"x": ncx, "B": ncB, "C": ncC}, new_ssm
