"""Grouped-query attention: training (full/sliding-window causal or
bidirectional) and serving (prefill -> KV cache -> single-token decode).

Sharding notes (see launch/sharding.py): QKV/O projections are TP-sharded on
the flattened head dim; per-head activations get an explicit
sharding_constraint on the head axis only when num_heads % tp == 0 —
otherwise heads stay as XLA lays them out (GSPMD resharding), which is the
documented fallback for minitron (24H) and qwen2 (12H) at tp=16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import layers as L


def attn_init(key, d_model: int, num_heads: int, num_kv_heads: int,
              head_dim: int, *, qkv_bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "q": L.dense_init(ks[0], d_model, num_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "k": L.dense_init(ks[1], d_model, num_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "v": L.dense_init(ks[2], d_model, num_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "o": L.dense_init(ks[3], num_heads * head_dim, d_model, bias=False, dtype=dtype),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _repeat_kv(k, n_rep: int):
    """(B, S, KV, hd) -> (B, S, KV*n_rep, hd) by repetition (GQA)."""
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)).reshape(b, s, kv * n_rep, hd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def chunked_attention(q, k, v, causal: bool = True, window: int | None = None,
                      q_chunk: int = 512, k_chunk: int = 1024):
    """Flash-style attention: online softmax over KV chunks, never
    materializing the (Sq, Sk) score matrix.  Memory per device drops from
    O(S^2) to O(S * k_chunk) — the fix that makes the 32k-prefill and
    4k-train cells fit HBM (see EXPERIMENTS.md Sec. Dry-run).

    custom_vjp: the backward recomputes score blocks chunk-by-chunk
    (saving only (q, k, v, out, lse)), exactly like the FlashAttention-2
    backward — without it, jax.lax.scan AD would stash O(S^2/chunk)
    per-step residuals and reintroduce the memory cliff.

    q: (B, Sq, H, hd); k,v: (B, Sk, H, hd) -> (B, Sq, H, hd).
    """
    out, _ = _flash_fwd(q, k, v, causal, window, q_chunk, k_chunk)
    return out


def _flash_fwd(q, k, v, causal, window, q_chunk, k_chunk):
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    qc = min(q_chunk, sq)
    kc = min(k_chunk, sk)
    # pad to multiples
    qpad, kpad = (-sq) % qc, (-sk) % kc
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    nq, nk = (sq + qpad) // qc, (sk + kpad) // kc
    scale = hd ** -0.5
    qb = q.reshape(b, nq, qc, h, hd)
    kb = k.reshape(b, nk, kc, h, hd)
    vb = v.reshape(b, nk, kc, h, hd)

    def q_block(qi, qx):
        # qx: (b, qc, h, hd); online softmax over kv chunks
        def kv_step(carry, kj):
            m, l, acc = carry
            kx = jax.lax.dynamic_index_in_dim(kb, kj, 1, keepdims=False)
            vx = jax.lax.dynamic_index_in_dim(vb, kj, 1, keepdims=False)
            s = jnp.einsum("bqhd,bkhd->bhqk", qx, kx,
                           preferred_element_type=jnp.float32) * scale
            # additive (qc,kc) bias, NOT a boolean select on the full
            # (b,h,qc,kc) block: XLA hoists/widens per-step pred masks into
            # O(S^2) buffers (observed: 12.9 GB of pred[...] in the 4k-train
            # HLO).  A small f32 bias fuses into the add.  Fully-masked
            # chunks self-correct through the online-softmax `corr` factor.
            qpos = qi * qc + jnp.arange(qc)[:, None]
            kpos = kj * kc + jnp.arange(kc)[None, :]
            msk = kpos < sk
            if causal:
                msk &= kpos <= qpos
            if window is not None:
                msk &= kpos > qpos - window
            s = s + jnp.where(msk, 0.0, -1e30)[None, None]
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = corr * l + p.sum(-1)
            acc_new = corr[..., None] * acc + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vx.dtype), vx).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, qc), jnp.float32)
        a0 = jnp.zeros((b, h, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))            # (b, h, qc)
        return jnp.moveaxis(out, 1, 2), lse                 # (b, qc, h, hd)

    def outer(_, qi):
        o, lse = q_block(qi, jax.lax.dynamic_index_in_dim(qb, qi, 1, keepdims=False))
        return None, (o, lse)

    _, (blocks, lses) = jax.lax.scan(outer, None, jnp.arange(nq))
    out = jnp.moveaxis(blocks, 0, 1).reshape(b, nq * qc, h, hd)[:, :sq]
    lse = jnp.moveaxis(lses, 0, 2).reshape(b, h, nq * qc)[..., :sq]  # (b,h,Sq)
    return out.astype(q.dtype), lse


def _flash_fwd_vjp(q, k, v, causal, window, q_chunk, k_chunk):
    out, lse = _flash_fwd(q, k, v, causal, window, q_chunk, k_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_chunk, k_chunk, res, dout):
    """FlashAttention-2-style backward: one scan over KV chunks; per chunk
    the full-Q score block (Sq x kc) is recomputed from (q, lse)."""
    q, k, v, out, lse = res
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kc = min(k_chunk, sk)
    kpad = (-sk) % kc
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    nk = (sk + kpad) // kc
    scale = hd ** -0.5
    kb = k.reshape(b, nk, kc, h, hd)
    vb = v.reshape(b, nk, kc, h, hd)
    doutf = dout.astype(jnp.float32)
    delta = jnp.einsum("bqhd,bqhd->bhq", doutf, out.astype(jnp.float32))
    qpos = jnp.arange(sq)[:, None]

    def kv_step(dq_acc, kj):
        kx = jax.lax.dynamic_index_in_dim(kb, kj, 1, keepdims=False)
        vx = jax.lax.dynamic_index_in_dim(vb, kj, 1, keepdims=False)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kx,
                       preferred_element_type=jnp.float32) * scale
        kpos = kj * kc + jnp.arange(kc)[None, :]
        msk = kpos < sk
        if causal:
            msk = msk & (kpos <= qpos)
        if window is not None:
            msk = msk & (kpos > qpos - window)
        s = s + jnp.where(msk, 0.0, -1e30)[None, None]   # (Sq,kc) bias only
        p = jnp.exp(s - lse[..., None])                  # masked -> exp(-1e30)=0
        dv = jnp.einsum("bhqk,bqhd->bkhd", p, doutf)
        dp = jnp.einsum("bqhd,bkhd->bhqk", doutf, vx.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq_blk = jnp.einsum("bhqk,bkhd->bqhd", ds, kx.astype(jnp.float32))
        dk = jnp.einsum("bhqk,bqhd->bkhd", ds, q.astype(jnp.float32))
        return dq_acc + dq_blk, (dk, dv)

    dq0 = jnp.zeros((b, sq, h, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_step, dq0, jnp.arange(nk))
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, nk * kc, h, hd)[:, :sk]
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, nk * kc, h, hd)[:, :sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


chunked_attention.defvjp(_flash_fwd_vjp, _flash_bwd)


def attention_scores(q, k, v, *, causal: bool, window: int | None = None,
                     q_offset: int = 0, kv_len_mask=None):
    """q: (B, Sq, H, hd); k,v: (B, Sk, H, hd).  Returns (B, Sq, H, hd).

    ``q_offset``: absolute position of q[0] (decode: offset = cache length).
    ``kv_len_mask``: optional (B, Sk) bool of valid cache slots.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    if kv_len_mask is not None:
        logits = jnp.where(kv_len_mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


CHUNKED_THRESHOLD = 2048  # use flash-style path for S >= this


def attn_apply(p, x, positions, cfg, *, causal=True, window=None,
               compute_dtype=jnp.bfloat16):
    """Full-sequence attention (training / prefill). x: (B, S, D)."""
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _split_heads(L.dense_apply(p["q"], x, compute_dtype=compute_dtype), H, hd)
    k = _split_heads(L.dense_apply(p["k"], x, compute_dtype=compute_dtype), KV, hd)
    v = _split_heads(L.dense_apply(p["v"], x, compute_dtype=compute_dtype), KV, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    kr, vr = _repeat_kv(k, H // KV), _repeat_kv(v, H // KV)
    if x.shape[1] >= CHUNKED_THRESHOLD:
        o = chunked_attention(q, kr, vr, causal, window)
    else:
        o = attention_scores(q, kr, vr, causal=causal, window=window)
    o = L.dense_apply(p["o"], o.reshape(x.shape[:-1] + (H * hd,)),
                      compute_dtype=compute_dtype)
    return o, (k, v)  # caller may keep (k, v) as the prefill cache


def attn_decode_splitkv(p, x, cache_k, cache_v, cache_len, cfg, *, mesh,
                        window=None, compute_dtype=jnp.bfloat16):
    """Flash-decoding for KV-head counts that do not divide tp: the cache
    shards its SEQUENCE dim over ``model`` (zero padding, balanced memory);
    each shard attends over its local span and the partials merge with a
    log-sum-exp psum of (m, l, acc) — (B,H)+(B,H,hd) sized, ~100 KB — per
    layer, instead of GSPMD's involuntary full-cache rematerialization
    (measured 22 GB/device/step on nemotron decode_32k; EXPERIMENTS.md
    Sec. Perf C2).  The new token's K/V is written by the owning shard.
    """
    from jax.sharding import PartitionSpec as P
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s_max = cache_k.shape[1]
    b = x.shape[0]
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    q = _split_heads(L.dense_apply(p["q"], x, compute_dtype=compute_dtype), H, hd)
    k = _split_heads(L.dense_apply(p["k"], x, compute_dtype=compute_dtype), KV, hd)
    v = _split_heads(L.dense_apply(p["v"], x, compute_dtype=compute_dtype), KV, hd)
    q = L.apply_rope(q, pos, cfg.rope_theta)
    k = L.apply_rope(k, pos, cfg.rope_theta)

    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    cspec = P(bspec, "model", None, None)

    def local(qx, kx, vx, ck, cv, clen):
        nsh = jax.lax.axis_size("model")
        me = jax.lax.axis_index("model")
        s_loc = ck.shape[1]
        # write the new token into the owning shard's span
        lpos = clen - me * s_loc
        owner = (lpos >= 0) & (lpos < s_loc)
        lp = jnp.clip(lpos, 0, s_loc - 1)
        ck_new = jax.lax.dynamic_update_slice(ck, kx.astype(ck.dtype),
                                              (0, lp, 0, 0))
        cv_new = jax.lax.dynamic_update_slice(cv, vx.astype(cv.dtype),
                                              (0, lp, 0, 0))
        ck_new = jnp.where(owner, ck_new, ck)
        cv_new = jnp.where(owner, cv_new, cv)
        # local span attention (all heads, local keys)
        kr = _repeat_kv(ck_new.astype(compute_dtype), H // KV)
        vr = _repeat_kv(cv_new.astype(compute_dtype), H // KV)
        s = jnp.einsum("bqhd,bkhd->bhqk", qx, kr,
                       preferred_element_type=jnp.float32) * (hd ** -0.5)
        gpos = me * s_loc + jnp.arange(s_loc)
        valid = gpos <= clen
        if window is not None:
            valid &= gpos > clen - window
        s = s + jnp.where(valid, 0.0, -1e30)[None, None, None, :]
        m_loc = jnp.max(s, axis=-1)                       # (b,h,1)
        p_ = jnp.exp(s - m_loc[..., None])
        l_loc = jnp.sum(p_, axis=-1)
        acc = jnp.einsum("bhqk,bkhd->bhqd", p_.astype(vr.dtype), vr
                         ).astype(jnp.float32)
        # LSE merge across the sequence shards (tiny)
        m = jax.lax.pmax(m_loc, "model")
        corr = jnp.exp(m_loc - m)
        l = jax.lax.psum(corr * l_loc, "model")
        acc = jax.lax.psum(corr[..., None] * acc, "model")
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(out, 1, 2).astype(compute_dtype), ck_new, cv_new

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec), P(bspec), P(bspec), cspec, cspec, P()),
        out_specs=(P(bspec), cspec, cspec), check_vma=False)
    o, new_k, new_v = fn(q, k, v, cache_k, cache_v, cache_len)
    o = L.dense_apply(p["o"], o.reshape(b, 1, H * hd),
                      compute_dtype=compute_dtype)
    return o, new_k, new_v


def attn_decode_slotted(p, x, cache_k, cache_v, pos, cfg, *, active=None,
                        window=None, compute_dtype=jnp.bfloat16):
    """Per-slot single-token decode (continuous batching).  x: (B, 1, D);
    cache_k/v: (B, S_max, KV, hd); ``pos``: (B,) int32 — each row's own
    cache fill level, so sequences admitted at different times decode in
    one batch.  The new token's K/V lands at ``pos[b]`` via a one-hot
    select (a per-row ``dynamic_update_slice`` is not expressible; the
    select writes the same bytes) and row ``b`` attends over its own
    prefix ``0..pos[b]``.  ``active``: optional (B,) bool — inactive rows
    write nothing (cache bit-for-bit preserved; their outputs are
    discarded by the caller).  Returns (out, new_k, new_v)."""
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s_max = cache_k.shape[1]
    q = _split_heads(L.dense_apply(p["q"], x, compute_dtype=compute_dtype), H, hd)
    k = _split_heads(L.dense_apply(p["k"], x, compute_dtype=compute_dtype), KV, hd)
    v = _split_heads(L.dense_apply(p["v"], x, compute_dtype=compute_dtype), KV, hd)
    pos = pos.astype(jnp.int32)
    q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
    k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
    write = jnp.arange(s_max)[None, :] == pos[:, None]          # (B, S_max)
    if active is not None:
        write &= active[:, None]
    m = write[:, :, None, None]
    new_k = jnp.where(m, k.astype(cache_k.dtype), cache_k)
    new_v = jnp.where(m, v.astype(cache_v.dtype), cache_v)
    valid = jnp.arange(s_max)[None, :] <= pos[:, None]
    if window is not None:
        valid &= jnp.arange(s_max)[None, :] > (pos[:, None] - window)
    kr = _repeat_kv(new_k.astype(compute_dtype), H // KV)
    vr = _repeat_kv(new_v.astype(compute_dtype), H // KV)
    o = attention_scores(q, kr, vr, causal=False, q_offset=0,
                         kv_len_mask=valid)
    o = L.dense_apply(p["o"], o.reshape(x.shape[:-1] + (H * hd,)),
                      compute_dtype=compute_dtype)
    return o, new_k, new_v


def attn_decode(p, x, cache_k, cache_v, cache_len, cfg, *,
                window=None, compute_dtype=jnp.bfloat16):
    """Single-token decode.  x: (B, 1, D); cache_k/v: (B, S_max, KV, hd);
    cache_len: scalar int32 — current fill level.  Returns (out, new_k,
    new_v).  The cache is updated in place via dynamic_update_slice (callers
    donate the cache buffers so XLA aliases them)."""
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s_max = cache_k.shape[1]
    pos = jnp.full((x.shape[0], 1), cache_len, jnp.int32)
    q = _split_heads(L.dense_apply(p["q"], x, compute_dtype=compute_dtype), H, hd)
    k = _split_heads(L.dense_apply(p["k"], x, compute_dtype=compute_dtype), KV, hd)
    v = _split_heads(L.dense_apply(p["v"], x, compute_dtype=compute_dtype), KV, hd)
    q = L.apply_rope(q, pos, cfg.rope_theta)
    k = L.apply_rope(k, pos, cfg.rope_theta)
    new_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, cache_len, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, cache_len, 0, 0))
    valid = (jnp.arange(s_max) <= cache_len)[None, :]
    if window is not None:
        valid = valid & (jnp.arange(s_max) > cache_len - window)[None, :]
    kr = _repeat_kv(new_k.astype(compute_dtype), H // KV)
    vr = _repeat_kv(new_v.astype(compute_dtype), H // KV)
    o = attention_scores(q, kr, vr, causal=False, q_offset=0,
                         kv_len_mask=jnp.broadcast_to(valid, (x.shape[0], s_max)))
    o = L.dense_apply(p["o"], o.reshape(x.shape[:-1] + (H * hd,)),
                      compute_dtype=compute_dtype)
    return o, new_k, new_v
