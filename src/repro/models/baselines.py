"""Paper Table IV baselines: MLP (measured), LSTM and GRU cells
(theoretical parameter counts at H=16, d=3; also runnable for the warm-up
comparison the paper lists as future work)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# MLP baseline: flatten(128x3=384) -> 32 relu -> 6.
# Params: 384*32+32 + 32*6+6 = 12,518  (matches Table IV exactly).
# ---------------------------------------------------------------------------

def mlp_init(key, window: int = 128, d: int = 3, hidden: int = 32, classes: int = 6):
    k1, k2 = jax.random.split(key)
    din = window * d
    return {"w1": 0.1 * jax.random.normal(k1, (din, hidden)),
            "b1": jnp.zeros((hidden,)),
            "w2": 0.1 * jax.random.normal(k2, (hidden, classes)),
            "b2": jnp.zeros((classes,))}


def mlp_forward(params, xs):
    """xs: (T, B, d) window -> (B, C) logits."""
    x = jnp.transpose(xs, (1, 0, 2)).reshape(xs.shape[1], -1)
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def mlp_loss(params, xs, labels):
    logits = mlp_forward(params, xs)
    logp = jax.nn.log_softmax(logits, -1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def mlp_param_count(window: int = 128, d: int = 3, hidden: int = 32, classes: int = 6) -> int:
    return window * d * hidden + hidden + hidden * classes + classes


# ---------------------------------------------------------------------------
# LSTM / GRU cells (H=16, d=3): Table IV theoretical counts 1280 / 960.
# ---------------------------------------------------------------------------

def lstm_init(key, d: int = 3, H: int = 16):
    ks = jax.random.split(key, 8)
    g = lambda k, shape: 0.1 * jax.random.normal(k, shape)
    p = {}
    for i, gate in enumerate(("i", "f", "g", "o")):
        p[f"W_{gate}"] = g(ks[2 * i], (H, d))
        p[f"U_{gate}"] = g(ks[2 * i + 1], (H, H))
        p[f"b_{gate}"] = jnp.zeros((H,))
    return p


def lstm_step(p, carry, x):
    h, c = carry
    gates = {}
    for gate in ("i", "f", "g", "o"):
        gates[gate] = x @ p[f"W_{gate}"].T + h @ p[f"U_{gate}"].T + p[f"b_{gate}"]
    i, f = jax.nn.sigmoid(gates["i"]), jax.nn.sigmoid(gates["f"])
    g_, o = jnp.tanh(gates["g"]), jax.nn.sigmoid(gates["o"])
    c = f * c + i * g_
    h = o * jnp.tanh(c)
    return (h, c), h


def lstm_param_count(d: int = 3, H: int = 16) -> int:
    return 4 * (H * d + H * H) + 4 * H   # 1,280 at H=16, d=3


def gru_init(key, d: int = 3, H: int = 16):
    ks = jax.random.split(key, 6)
    g = lambda k, shape: 0.1 * jax.random.normal(k, shape)
    p = {}
    for i, gate in enumerate(("r", "z", "n")):
        p[f"W_{gate}"] = g(ks[2 * i], (H, d))
        p[f"U_{gate}"] = g(ks[2 * i + 1], (H, H))
        p[f"b_{gate}"] = jnp.zeros((H,))
    return p


def gru_step(p, h, x):
    r = jax.nn.sigmoid(x @ p["W_r"].T + h @ p["U_r"].T + p["b_r"])
    z = jax.nn.sigmoid(x @ p["W_z"].T + h @ p["U_z"].T + p["b_z"])
    n = jnp.tanh(x @ p["W_n"].T + r * (h @ p["U_n"].T) + p["b_n"])
    return (1 - z) * n + z * h, None


def gru_param_count(d: int = 3, H: int = 16) -> int:
    return 3 * (H * d + H * H) + 3 * H   # 960 at H=16, d=3


def rnn_run(step_fn, params, xs, carry0):
    """Generic scan driver returning the (T, ..., H) hidden trajectory."""
    def body(carry, x):
        carry, out = step_fn(params, carry, x)
        h = out if out is not None else (carry[0] if isinstance(carry, tuple) else carry)
        return carry, h
    _, traj = jax.lax.scan(body, carry0, xs)
    return traj
