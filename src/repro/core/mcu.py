"""MCU cycle-cost latency model (paper Tables VII + Sec. V-G).

No MCU hardware exists in this container, so per-sample latency is
reproduced through a structural cycle model:

    t_step = (N_mac * c_mac + N_act * c_act + c_fixed) / f_clk

with op counts N_mac/N_act derived from the architecture (low-rank factored
matvecs, 2H activation calls per step) and per-platform cycle constants
c_mac/c_act FITTED to the paper's measured endpoints (9.21 ms Arduino-LUT,
13.87 ms MSP430-LUT, 421 ms MSP430-no-LUT, 1.51x Arduino LUT speedup).
The fitted constants are physically plausible (see comments) and the model
then *predicts* unmeasured configurations (H=32, full-rank, Q7...).

This is a MODEL, not a measurement — labeled as such everywhere it is
reported.
"""
from __future__ import annotations

import dataclasses

from .fastgrnn import FastGRNNConfig


F_CLK_HZ = 16_000_000  # both targets run at 16 MHz


@dataclasses.dataclass(frozen=True)
class PlatformCosts:
    name: str
    c_mac: float      # cycles per dequant+FP32 multiply-accumulate
    c_act_sw: float   # cycles per software sigma/tanh (transcendental)
    c_act_lut: float  # cycles per LUT activation (index+load+saturate)
    c_fixed: float    # per-step fixed overhead (gate arithmetic, loop)


# Fitted to the paper's measured endpoints (see module docstring):
#  - AVR has a HW 8x8 multiplier -> soft-FP32 mul ~140 cyc, add ~160,
#    dequant int16->f32 ~100  => c_mac ~ 480.  avr-libc tanhf ~ 2.5k cyc.
#  - MSP430G2553 has NO multiplier: every 16x16 mult is software (~180 cyc)
#    => FP32 MAC ~ 730 cyc.  TI libm tanhf/expf with soft multiply is the
#    paper's bottleneck; the 421 ms/step measurement implies ~2.0e5 cyc per
#    transcendental call, which is what makes the LUT worth 30.5x.
ARDUINO = PlatformCosts("Arduino Uno R3 (ATmega328P)",
                        c_mac=364.0, c_act_sw=2500.0, c_act_lut=150.0, c_fixed=1500.0)
MSP430 = PlatformCosts("MSP430G2553",
                       c_mac=548.0, c_act_sw=203_765.0, c_act_lut=200.0, c_fixed=2000.0)


# ---------------------------------------------------------------------------
# Deployment platform profiles (paper Table I): memory capacities and ISA
# facts the export compiler (repro/deploy) audits a packed weight image
# against.  ``flash_capacity`` / ``sram_capacity`` are the physical part
# limits; the image + runtime working set must fit with code headroom.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlatformProfile:
    key: str                      # export-target key ("avr" | "msp430" | "host")
    name: str
    costs: PlatformCosts | None
    flash_capacity: int           # bytes of program flash
    sram_capacity: int            # bytes of data RAM
    has_multiplier: bool          # MSP430G2553 has no HW multiply (paper V-G)
    word_bits: int
    # fraction of flash reserved for code/runtime (not weights/LUTs); the
    # paper's fastgrnn.cpp translation unit is ~2-6 KB of code per target.
    code_reserve: int = 6 * 1024


AVR_PROFILE = PlatformProfile(
    key="avr", name="Arduino Uno R3 (ATmega328P)", costs=ARDUINO,
    flash_capacity=32 * 1024, sram_capacity=2 * 1024,
    has_multiplier=True, word_bits=8)
MSP430_PROFILE = PlatformProfile(
    key="msp430", name="MSP430G2553", costs=MSP430,
    flash_capacity=16 * 1024, sram_capacity=512,
    has_multiplier=False, word_bits=16, code_reserve=4 * 1024)
HOST_PROFILE = PlatformProfile(
    key="host", name="host cc (parity oracle)", costs=None,
    flash_capacity=1 << 30, sram_capacity=1 << 30,
    has_multiplier=True, word_bits=64, code_reserve=0)

PLATFORMS: dict[str, PlatformProfile] = {
    p.key: p for p in (AVR_PROFILE, MSP430_PROFILE, HOST_PROFILE)}


def platform(key: str) -> PlatformProfile:
    if key not in PLATFORMS:
        raise KeyError(f"unknown platform {key!r}; have {sorted(PLATFORMS)}")
    return PLATFORMS[key]


def audit_budget(image_bytes: int, sram_needed: int,
                 profile: PlatformProfile) -> dict[str, object]:
    """Check a packed weight image + runtime working set against a platform's
    memory budgets.  Returns the audit record; raises if either budget is
    blown (export should fail loudly, not ship an unflashable image)."""
    flash_avail = profile.flash_capacity - profile.code_reserve
    rec = {
        "platform": profile.key,
        "flash_capacity": profile.flash_capacity,
        "code_reserve": profile.code_reserve,
        "image_bytes": image_bytes,
        "flash_headroom": flash_avail - image_bytes,
        "sram_capacity": profile.sram_capacity,
        "sram_needed": sram_needed,
        "sram_headroom": profile.sram_capacity - sram_needed,
        "fits": image_bytes <= flash_avail and sram_needed <= profile.sram_capacity,
    }
    if not rec["fits"]:
        raise ValueError(
            f"image does not fit {profile.name}: "
            f"flash {image_bytes}/{flash_avail} B, "
            f"sram {sram_needed}/{profile.sram_capacity} B")
    return rec


def step_op_counts(cfg: FastGRNNConfig) -> dict[str, int]:
    """Per-sample op counts for one fastgrnn_step()."""
    d, H = cfg.input_dim, cfg.hidden_dim
    if cfg.rank_w is None:
        mac_w = H * d
    else:
        mac_w = cfg.rank_w * d + H * cfg.rank_w
    if cfg.rank_u is None:
        mac_u = H * H
    else:
        mac_u = cfg.rank_u * H + H * cfg.rank_u
    elementwise = 6 * H            # gate interpolation arithmetic
    return {"mac": mac_w + mac_u + elementwise, "act": 2 * H}


def step_latency_s(cfg: FastGRNNConfig, platform: PlatformCosts, lut: bool = True) -> float:
    n = step_op_counts(cfg)
    c_act = platform.c_act_lut if lut else platform.c_act_sw
    cycles = n["mac"] * platform.c_mac + n["act"] * c_act + platform.c_fixed
    return cycles / F_CLK_HZ


def window_latency_s(cfg: FastGRNNConfig, platform: PlatformCosts,
                     lut: bool = True, window: int = 128) -> float:
    return window * step_latency_s(cfg, platform, lut)


def budget_use(cfg: FastGRNNConfig, platform: PlatformCosts,
               lut: bool = True, budget_s: float = 0.020) -> float:
    return step_latency_s(cfg, platform, lut) / budget_s


def lut_speedup(cfg: FastGRNNConfig, platform: PlatformCosts) -> float:
    return step_latency_s(cfg, platform, lut=False) / step_latency_s(cfg, platform, lut=True)


def flash_bytes(cfg: FastGRNNConfig, nonzero_params: int | None = None,
                itemsize: int = 2, lut_tables: int = 2) -> int:
    """Deployed image weight+LUT footprint (paper: 566 B weights + 2 KB LUT)."""
    n = nonzero_params if nonzero_params is not None else (
        cfg.cell_param_count() + cfg.head_param_count())
    return n * itemsize + lut_tables * 256 * 4


def sram_bytes(cfg: FastGRNNConfig) -> int:
    """Runtime working set: h, z, h~, pre, logits, scratch (~300 B, paper)."""
    H, C = cfg.hidden_dim, cfg.num_classes
    floats = 4 * H + C + max(cfg.rank_w or 0, cfg.rank_u or 0, cfg.input_dim)
    return floats * 4 + 48  # + loop/bookkeeping
