"""MCU cycle-cost latency model (paper Tables VII + Sec. V-G).

No MCU hardware exists in this container, so per-sample latency is
reproduced through a structural cycle model:

    t_step = (N_mac * c_mac + N_act * c_act + c_fixed) / f_clk

with op counts N_mac/N_act derived from the architecture (low-rank factored
matvecs, 2H activation calls per step) and per-platform cycle constants
c_mac/c_act FITTED to the paper's measured endpoints (9.21 ms Arduino-LUT,
13.87 ms MSP430-LUT, 421 ms MSP430-no-LUT, 1.51x Arduino LUT speedup).
The fitted constants are physically plausible (see comments) and the model
then *predicts* unmeasured configurations (H=32, full-rank, Q7...).

This is a MODEL, not a measurement — labeled as such everywhere it is
reported.
"""
from __future__ import annotations

import dataclasses

from .fastgrnn import FastGRNNConfig


F_CLK_HZ = 16_000_000  # both targets run at 16 MHz


@dataclasses.dataclass(frozen=True)
class PlatformCosts:
    name: str
    c_mac: float      # cycles per dequant+FP32 multiply-accumulate
    c_act_sw: float   # cycles per software sigma/tanh (transcendental)
    c_act_lut: float  # cycles per LUT activation (index+load+saturate)
    c_fixed: float    # per-step fixed overhead (gate arithmetic, loop)


# Fitted to the paper's measured endpoints (see module docstring):
#  - AVR has a HW 8x8 multiplier -> soft-FP32 mul ~140 cyc, add ~160,
#    dequant int16->f32 ~100  => c_mac ~ 480.  avr-libc tanhf ~ 2.5k cyc.
#  - MSP430G2553 has NO multiplier: every 16x16 mult is software (~180 cyc)
#    => FP32 MAC ~ 730 cyc.  TI libm tanhf/expf with soft multiply is the
#    paper's bottleneck; the 421 ms/step measurement implies ~2.0e5 cyc per
#    transcendental call, which is what makes the LUT worth 30.5x.
ARDUINO = PlatformCosts("Arduino Uno R3 (ATmega328P)",
                        c_mac=364.0, c_act_sw=2500.0, c_act_lut=150.0, c_fixed=1500.0)
MSP430 = PlatformCosts("MSP430G2553",
                       c_mac=548.0, c_act_sw=203_765.0, c_act_lut=200.0, c_fixed=2000.0)


def step_op_counts(cfg: FastGRNNConfig) -> dict[str, int]:
    """Per-sample op counts for one fastgrnn_step()."""
    d, H = cfg.input_dim, cfg.hidden_dim
    if cfg.rank_w is None:
        mac_w = H * d
    else:
        mac_w = cfg.rank_w * d + H * cfg.rank_w
    if cfg.rank_u is None:
        mac_u = H * H
    else:
        mac_u = cfg.rank_u * H + H * cfg.rank_u
    elementwise = 6 * H            # gate interpolation arithmetic
    return {"mac": mac_w + mac_u + elementwise, "act": 2 * H}


def step_latency_s(cfg: FastGRNNConfig, platform: PlatformCosts, lut: bool = True) -> float:
    n = step_op_counts(cfg)
    c_act = platform.c_act_lut if lut else platform.c_act_sw
    cycles = n["mac"] * platform.c_mac + n["act"] * c_act + platform.c_fixed
    return cycles / F_CLK_HZ


def window_latency_s(cfg: FastGRNNConfig, platform: PlatformCosts,
                     lut: bool = True, window: int = 128) -> float:
    return window * step_latency_s(cfg, platform, lut)


def budget_use(cfg: FastGRNNConfig, platform: PlatformCosts,
               lut: bool = True, budget_s: float = 0.020) -> float:
    return step_latency_s(cfg, platform, lut) / budget_s


def lut_speedup(cfg: FastGRNNConfig, platform: PlatformCosts) -> float:
    return step_latency_s(cfg, platform, lut=False) / step_latency_s(cfg, platform, lut=True)


def flash_bytes(cfg: FastGRNNConfig, nonzero_params: int | None = None,
                itemsize: int = 2, lut_tables: int = 2) -> int:
    """Deployed image weight+LUT footprint (paper: 566 B weights + 2 KB LUT)."""
    n = nonzero_params if nonzero_params is not None else (
        cfg.cell_param_count() + cfg.head_param_count())
    return n * itemsize + lut_tables * 256 * 4


def sram_bytes(cfg: FastGRNNConfig) -> int:
    """Runtime working set: h, z, h~, pre, logits, scratch (~300 B, paper)."""
    H, C = cfg.hidden_dim, cfg.num_classes
    floats = 4 * H + C + max(cfg.rank_w or 0, cfg.rank_u or 0, cfg.input_dim)
    return floats * 4 + 48  # + loop/bookkeeping
