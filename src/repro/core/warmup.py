"""Recurrent warm-up latency characterization (paper Sec. VI-A, Fig. 8).

For each window, find the first step t* at which the per-step prediction
equals the final-window prediction AND remains stable for every subsequent
step.  The paper reports, over 100 random test windows: median 74 samples
(1.48 s at 50 Hz), IQR 40-86, worst case 125 (2.50 s).

The harness is generic over any "streaming classifier" that exposes a
per-step prediction trajectory — used for FastGRNN (paper protocol) and
for the SSM-state warm-up of Mamba2/Zamba2 decode (beyond-paper, Sec. VI-A
hypothesizes this for other recurrent cells).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class WarmupStats:
    median_samples: float
    iqr_lo: float
    iqr_hi: float
    worst_case: int
    mean: float
    n_windows: int
    sample_rate_hz: float = 50.0

    @property
    def median_seconds(self) -> float:
        return self.median_samples / self.sample_rate_hz

    @property
    def worst_seconds(self) -> float:
        return self.worst_case / self.sample_rate_hz

    def row(self) -> str:
        return (f"median {self.median_samples:.0f} samples "
                f"({self.median_seconds:.2f} s), IQR {self.iqr_lo:.0f}-{self.iqr_hi:.0f}, "
                f"worst {self.worst_case} ({self.worst_seconds:.2f} s) "
                f"over {self.n_windows} windows")


def stabilization_step(step_preds: np.ndarray) -> int:
    """First step t* such that pred[t] == pred[-1] for all t >= t*.

    Returns a 1-based sample count (paper reports 'samples', t*=1 means the
    prediction was stable from the first sample).
    """
    final = step_preds[-1]
    mismatch = np.nonzero(step_preds != final)[0]
    if mismatch.size == 0:
        return 1
    return int(mismatch[-1]) + 2  # first stable index (0-based +1), 1-based +1


def characterize(per_step_predictions: np.ndarray, sample_rate_hz: float = 50.0) -> WarmupStats:
    """per_step_predictions: (N_windows, T) int predictions per step."""
    t_star = np.array([stabilization_step(p) for p in per_step_predictions])
    return WarmupStats(
        median_samples=float(np.median(t_star)),
        iqr_lo=float(np.percentile(t_star, 25)),
        iqr_hi=float(np.percentile(t_star, 75)),
        worst_case=int(np.max(t_star)),
        mean=float(np.mean(t_star)),
        n_windows=len(t_star),
        sample_rate_hz=sample_rate_hz,
    )


def trajectory_predictions(params, windows, head_fn, run_fn) -> np.ndarray:
    """Generic helper: run_fn(params, window)->(T,H) traj; head_fn->logits."""
    out = []
    for w in windows:
        traj = run_fn(params, w)
        logits = head_fn(params, traj)          # (T, C)
        out.append(np.argmax(np.asarray(logits), axis=-1))
    return np.stack(out)
