"""LUT-based activations (paper Sec. III-E, Appendix C).

256-entry tables over [-8, +8], each entry sampled at the *center* of its
bucket (the (i + 0.5) offset — the max-likelihood estimate for a uniform
sub-bucket input, avoiding the half-bucket bias).  Inputs outside the
domain saturate, which is exact to float precision for sigma/tanh tails.

The paper text (Sec. III-E) describes linear interpolation between adjacent
entries while the deployed Appendix-C runtime does a nearest-bucket load; we
implement both.  ``mode="nearest"`` matches the deployed C engine (and is
what the deterministic qruntime uses); ``mode="lerp"`` matches Sec. III-E.

These jnp implementations are the oracles for the Pallas kernel in
``repro/kernels/lut_act``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


LUT_SIZE = 256
INPUT_MIN = -8.0
INPUT_MAX = 8.0
BUCKET_WIDTH = (INPUT_MAX - INPUT_MIN) / LUT_SIZE
LUT_INPUT_SCALE = 1.0 / BUCKET_WIDTH


def _np_sigmoid(x):
    return 1.0 / (1.0 + np.exp(-x))


_GENERATORS = {
    "sigmoid": _np_sigmoid,
    "tanh": np.tanh,
    "silu": lambda x: x * _np_sigmoid(x),
    "gelu": lambda x: 0.5 * x * (1.0 + np.tanh(np.sqrt(2.0 / np.pi) * (x + 0.044715 * x**3))),
    "softplus": lambda x: np.log1p(np.exp(-np.abs(x))) + np.maximum(x, 0.0),
}

# Saturation values outside [-8, 8].  For sigma/tanh these equal f(+-8) to
# float precision (paper).  For the unbounded fns (silu/gelu/softplus ~ x,
# or 0) the linear tail is handled explicitly in lut_eval.
_LINEAR_TAILS = {"silu", "gelu", "softplus"}


def make_lut(fn: str, size: int = LUT_SIZE, lo: float = INPUT_MIN, hi: float = INPUT_MAX) -> np.ndarray:
    """Bucket-center table, Appendix C."""
    bw = (hi - lo) / size
    centers = lo + (np.arange(size) + 0.5) * bw
    return _GENERATORS[fn](centers).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class LUTActivations:
    """A pair (or set) of generated tables with eval helpers."""
    size: int = LUT_SIZE
    lo: float = INPUT_MIN
    hi: float = INPUT_MAX
    mode: str = "nearest"  # "nearest" (Appendix C) | "lerp" (Sec. III-E)

    def table(self, fn: str) -> jnp.ndarray:
        return jnp.asarray(make_lut(fn, self.size, self.lo, self.hi))

    def __call__(self, fn: str, x: jax.Array) -> jax.Array:
        return lut_eval(self.table(fn), x, lo=self.lo, hi=self.hi,
                        mode=self.mode, linear_tail=(fn in _LINEAR_TAILS))


@partial(jax.jit, static_argnames=("lo", "hi", "mode", "linear_tail"))
def lut_eval(
    table: jax.Array,
    x: jax.Array,
    *,
    lo: float = INPUT_MIN,
    hi: float = INPUT_MAX,
    mode: str = "nearest",
    linear_tail: bool = False,
) -> jax.Array:
    """Vectorized LUT activation.  Matches the Appendix-C runtime:

    - x <= lo  -> table[0]      (or linear tail)
    - x >= hi  -> table[-1]     (or linear tail)
    - else     -> table[(x - lo) * scale]   (nearest), or lerp of adjacent.
    """
    size = table.shape[0]
    bw = (hi - lo) / size
    xf = x.astype(jnp.float32)
    if mode == "nearest":
        idx = jnp.clip(((xf - lo) * (1.0 / bw)).astype(jnp.int32), 0, size - 1)
        y = jnp.take(table, idx)
    elif mode == "lerp":
        # continuous position against bucket centers
        pos = (xf - lo) / bw - 0.5
        i0 = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, size - 1)
        i1 = jnp.clip(i0 + 1, 0, size - 1)
        frac = jnp.clip(pos - i0.astype(jnp.float32), 0.0, 1.0)
        y = (1.0 - frac) * jnp.take(table, i0) + frac * jnp.take(table, i1)
    else:
        raise ValueError(f"unknown LUT mode {mode!r}")
    below, above = xf <= lo, xf >= hi
    if linear_tail:
        # silu/gelu -> x for x>>0, -> 0 for x<<0 ; softplus -> x / 0.
        y = jnp.where(above, xf, jnp.where(below, 0.0, y))
    else:
        y = jnp.where(above, table[size - 1], jnp.where(below, table[0], y))
    return y.astype(x.dtype)


def lut_sigmoid(x: jax.Array, mode: str = "nearest") -> jax.Array:
    return lut_eval(jnp.asarray(make_lut("sigmoid")), x, mode=mode)


def lut_tanh(x: jax.Array, mode: str = "nearest") -> jax.Array:
    return lut_eval(jnp.asarray(make_lut("tanh")), x, mode=mode)


def make_lut_q15(fn: str, size: int = LUT_SIZE, lo: float = INPUT_MIN,
                 hi: float = INPUT_MAX) -> np.ndarray:
    """Bucket-center table quantized to int16 Q15 (value = q / 32767).

    This is the storage format of the pure-integer deployment path
    (repro/deploy): sigma/tanh are bounded by 1, so the unit Q15 scale is
    exact and the two tables shrink from 2 KB (f32) to 1 KB of flash.
    Only valid for generators bounded by [-1, 1].
    """
    if fn in _LINEAR_TAILS:
        raise ValueError(f"{fn!r} is unbounded; Q15 unit-scale LUT needs |f|<=1")
    f = make_lut(fn, size, lo, hi).astype(np.float64)
    return np.clip(np.round(f * 32767.0), -32768, 32767).astype(np.int16)


def flash_bytes(n_tables: int = 2, size: int = LUT_SIZE, itemsize: int = 4) -> int:
    """Paper: 'The two tables together occupy 2 KB of Flash'."""
    return n_tables * size * itemsize


def max_abs_error(fn: str, mode: str = "nearest", n: int = 100_000) -> float:
    """Worst-case LUT error over the domain (used in tests/benchmarks)."""
    xs = np.linspace(INPUT_MIN, INPUT_MAX, n).astype(np.float32)
    ref = _GENERATORS[fn](xs.astype(np.float64))
    got = np.asarray(lut_eval(jnp.asarray(make_lut(fn)), jnp.asarray(xs), mode=mode))
    return float(np.max(np.abs(got - ref)))
