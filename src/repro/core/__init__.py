# The paper's primary contribution: FastGRNN + the L-S-Q compression
# pipeline (low-rank, IHT sparsity, calibrated Q15 PTQ), LUT activations,
# the deterministic integer runtime, warm-up characterization, and the
# energy/latency models.
from . import fastgrnn, compression, quantization, lut, qruntime, pipeline, warmup, energy, mcu  # noqa: F401
