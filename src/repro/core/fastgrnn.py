"""FastGRNN cell (Kusupati et al., NeurIPS'18) — paper Eq. (1)-(3).

z_t   = sigma(W x_t + U h_{t-1} + b_z)
h~_t  = tanh (W x_t + U h_{t-1} + b_h)
h_t   = (zeta * (1 - z_t) + nu) * h~_t + z_t * h_{t-1}

The weight pair (W, U) is shared between the gate and the candidate — the
defining feature of the cell.  zeta, nu in (0,1) are learned scalars,
parameterized here as sigmoid(raw) exactly as in the reference EdgeML
implementation.

Low-rank support (paper Sec. III-B): W = W1 @ W2^T (W1: HxRw, W2: dxRw),
U = U1 @ U2^T (U1, U2: HxRu).  Full-rank cells store W, U directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FastGRNNConfig:
    input_dim: int = 3          # d — tri-axial acceleration
    hidden_dim: int = 16        # H
    num_classes: int = 6
    rank_w: int | None = None   # r_w; None = full rank
    rank_u: int | None = None   # r_u; None = full rank
    # paper Sec. VI-E future direction 1: U_eff = LowRank(r_u) + diag(alpha)
    # — a diagonal residual lets a static DC-like signal pass through while
    # the low-rank branch carries the dynamic pattern (+H params).
    diag_residual: bool = False
    zeta_init: float = 1.0      # raw (pre-sigmoid) init, EdgeML default
    nu_init: float = -4.0       # raw (pre-sigmoid) init, EdgeML default

    @property
    def low_rank(self) -> bool:
        return self.rank_w is not None or self.rank_u is not None

    def cell_param_count(self) -> int:
        """Paper Eq. (4) for full rank; factored count for low rank."""
        d, H = self.input_dim, self.hidden_dim
        if self.rank_w is None:
            n_w = H * d
        else:
            n_w = H * self.rank_w + d * self.rank_w
        if self.rank_u is None:
            n_u = H * H
        else:
            n_u = 2 * H * self.rank_u
        if self.diag_residual:
            n_u += H
        return n_w + n_u + 2 * H + 2  # + b_z, b_h, zeta, nu

    def head_param_count(self) -> int:
        return self.hidden_dim * self.num_classes + self.num_classes


def init_params(cfg: FastGRNNConfig, key: jax.Array) -> dict[str, Any]:
    """Initialize a FastGRNN + dense classifier-head parameter pytree."""
    d, H = cfg.input_dim, cfg.hidden_dim
    ks = jax.random.split(key, 8)

    def _mat(k, shape):
        # EdgeML uses N(0, 0.1) init for factor matrices.
        return 0.1 * jax.random.normal(k, shape, dtype=jnp.float32)

    p: dict[str, Any] = {}
    if cfg.rank_w is None:
        p["W"] = _mat(ks[0], (H, d))
    else:
        p["W1"] = _mat(ks[0], (H, cfg.rank_w))
        p["W2"] = _mat(ks[1], (d, cfg.rank_w))
    if cfg.rank_u is None:
        p["U"] = _mat(ks[2], (H, H))
    else:
        p["U1"] = _mat(ks[2], (H, cfg.rank_u))
        p["U2"] = _mat(ks[3], (H, cfg.rank_u))
    if cfg.diag_residual:
        p["alpha"] = 0.1 * jnp.ones((H,), jnp.float32)
    p["b_z"] = jnp.ones((H,), jnp.float32)
    p["b_h"] = jnp.zeros((H,), jnp.float32)
    p["zeta"] = jnp.asarray(cfg.zeta_init, jnp.float32)
    p["nu"] = jnp.asarray(cfg.nu_init, jnp.float32)
    p["head_w"] = _mat(ks[4], (H, cfg.num_classes))
    p["head_b"] = jnp.zeros((cfg.num_classes,), jnp.float32)
    return p


def effective_W(params: dict[str, Any]) -> jax.Array:
    if "W" in params:
        return params["W"]
    return params["W1"] @ params["W2"].T


def effective_U(params: dict[str, Any]) -> jax.Array:
    if "U" in params:
        u = params["U"]
    else:
        u = params["U1"] @ params["U2"].T
    if "alpha" in params:
        u = u + jnp.diag(params["alpha"])
    return u


def cell_step(
    params: dict[str, Any],
    h: jax.Array,
    x: jax.Array,
    *,
    sigma=jax.nn.sigmoid,
    tanh=jnp.tanh,
) -> jax.Array:
    """One FastGRNN step.  h: (..., H), x: (..., d).

    ``sigma``/``tanh`` are injectable so the LUT path (core/lut.py) and
    Pallas kernels can share this definition as their oracle.
    """
    if "W" in params:
        wx = x @ params["W"].T
    else:
        wx = (x @ params["W2"]) @ params["W1"].T  # W1 (W2^T x): 2 thin matmuls
    if "U" in params:
        uh = h @ params["U"].T
    else:
        uh = (h @ params["U2"]) @ params["U1"].T
    if "alpha" in params:
        uh = uh + params["alpha"] * h      # diagonal residual (Sec. VI-E)
    pre = wx + uh
    z = sigma(pre + params["b_z"])
    h_tilde = tanh(pre + params["b_h"])
    zeta = jax.nn.sigmoid(params["zeta"])
    nu = jax.nn.sigmoid(params["nu"])
    return (zeta * (1.0 - z) + nu) * h_tilde + z * h


def run_sequence(
    params: dict[str, Any],
    xs: jax.Array,
    h0: jax.Array | None = None,
    *,
    sigma=jax.nn.sigmoid,
    tanh=jnp.tanh,
    return_trajectory: bool = False,
):
    """Run a full window.  xs: (T, ..., d) time-major.  Returns final h
    (and the (T, ..., H) trajectory if requested)."""
    H = params["b_z"].shape[0]
    if h0 is None:
        batch_shape = xs.shape[1:-1]
        h0 = jnp.zeros(batch_shape + (H,), xs.dtype)

    def body(h, x):
        h_next = cell_step(params, h, x, sigma=sigma, tanh=tanh)
        return h_next, (h_next if return_trajectory else None)

    h_final, traj = jax.lax.scan(body, h0, xs)
    if return_trajectory:
        return h_final, traj
    return h_final


def logits_from_hidden(params: dict[str, Any], h: jax.Array) -> jax.Array:
    return h @ params["head_w"] + params["head_b"]


def forward_window(params, xs, **kw):
    """(T, ..., d) window -> (..., C) logits from the final hidden state."""
    return logits_from_hidden(params, run_sequence(params, xs, **kw))


def loss_fn(params, xs, labels, **kw):
    """Cross-entropy over windows. xs: (T, B, d), labels: (B,)."""
    logits = forward_window(params, xs, **kw)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).squeeze(-1)
    return nll.mean()


def count_params(params: dict[str, Any]) -> int:
    return int(sum(np.prod(v.shape) for v in jax.tree.leaves(params)))


def count_nonzero(params: dict[str, Any]) -> int:
    return int(sum(int(jnp.sum(v != 0)) for v in jax.tree.leaves(params)))
