"""L-S-Q stage 3: per-tensor Q15/Q7 post-training quantization with
explicit activation calibration (paper Sec. III-D, Appendix B).

Weight quantization (paper Eq. (8) + Appendix B):
    scale_l = max_ij |W_ij| / 32767            (Q15; 127 for Q7)
    Wq      = clip(round(W / scale_l), -2^15, 2^15 - 1)
    dequant = float(Wq) * scale_l

Activation calibration: run N calibration mini-batches through the FP32
model, record the empirical max |t| of every intermediate tensor, apply a
10% headroom, and assign each activation its own scale.  This is the
paper's key dividing line between lossless and catastrophic deployment.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


Q15_MAX = 32767
Q7_MAX = 127


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    bits: int = 16                      # 16 -> Q15 (int16), 8 -> Q7 (int8)
    calibration_batches: int = 5        # paper Table X
    headroom: float = 0.10              # paper Table X: 10%
    # Leaves kept in float (paper keeps biases in the FP32 accumulate path).
    float_leaves: tuple[str, ...] = ("b_z", "b_h", "zeta", "nu", "head_b")

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1

    @property
    def dtype(self):
        return jnp.int16 if self.bits == 16 else jnp.int8


def quantize_tensor(w: jax.Array, qmax: int):
    """Per-tensor symmetric quantization.  Returns (int tensor, scale)."""
    amax = jnp.max(jnp.abs(w))
    scale = jnp.where(amax > 0, amax / qmax, 1.0 / qmax)
    q = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax)
    return q, scale


def dequantize_tensor(q: jax.Array, scale) -> jax.Array:
    return q.astype(jnp.float32) * scale


@dataclasses.dataclass
class QuantizedParams:
    """Q-weights + per-tensor scales + float passthrough leaves."""
    q: dict[str, Any]                   # name -> int16/int8 array
    scales: dict[str, Any]              # name -> float scale
    fp: dict[str, Any]                  # name -> float array (not quantized)
    bits: int = 16

    def dequantize(self) -> dict[str, Any]:
        out = {k: dequantize_tensor(v, self.scales[k]) for k, v in self.q.items()}
        out.update(self.fp)
        return out

    def nbytes(self) -> int:
        itemsize = 2 if self.bits == 16 else 1
        return int(sum(np.prod(v.shape) for v in self.q.values())) * itemsize

    def nonzero(self) -> int:
        n = sum(int(jnp.sum(v != 0)) for v in self.q.values())
        n += sum(int(jnp.sum(v != 0)) for v in self.fp.values())
        return int(n)

    # -- layout introspection (export compiler) -------------------------
    CANONICAL_ORDER = ("W", "U", "W1", "W2", "U1", "U2", "head_w")

    def tensor_order(self) -> tuple[str, ...]:
        """Deterministic packing order of the quantized tensors: canonical
        names first (cell factors, then head), then any extras sorted —
        byte-identical images require a fixed order, not dict order."""
        known = [n for n in self.CANONICAL_ORDER if n in self.q]
        extra = sorted(n for n in self.q if n not in self.CANONICAL_ORDER)
        return tuple(known + extra)

    def layout(self) -> list[dict[str, Any]]:
        """Per-tensor packing records: name, shape, dtype, scale, nbytes."""
        itemsize = 2 if self.bits == 16 else 1
        out = []
        for name in self.tensor_order():
            t = np.asarray(self.q[name])
            out.append({
                "name": name, "shape": tuple(int(s) for s in t.shape),
                "dtype": f"int{8 * itemsize}",
                "scale": float(self.scales[name]),
                "nbytes": int(np.prod(t.shape)) * itemsize,
            })
        return out


def quantize_params(params: dict[str, Any], cfg: QuantConfig) -> QuantizedParams:
    q, scales, fp = {}, {}, {}
    for name, w in params.items():
        if name in cfg.float_leaves or getattr(w, "ndim", 0) == 0:
            fp[name] = jnp.asarray(w, jnp.float32)
        else:
            qi, s = quantize_tensor(jnp.asarray(w, jnp.float32), cfg.qmax)
            q[name] = qi.astype(cfg.dtype)
            scales[name] = s
    return QuantizedParams(q=q, scales=scales, fp=fp, bits=cfg.bits)


# ---------------------------------------------------------------------------
# Activation calibration (paper Sec. III-D)
# ---------------------------------------------------------------------------

def calibrate_activations(
    record_fn,
    batches,
    *,
    headroom: float = 0.10,
) -> dict[str, float]:
    """Run ``record_fn(batch) -> dict[name, tensor]`` over calibration batches
    and return per-activation scales sized to (1+headroom) * empirical max.

    ``record_fn`` returns every intermediate tensor of interest (pre-
    activations, hidden state, logits...).  The returned scales map each
    activation name -> Q15 scale = (1+headroom)*max|t| / 32767.
    """
    maxima: dict[str, float] = {}
    for batch in batches:
        acts = record_fn(batch)
        for name, t in acts.items():
            m = float(jnp.max(jnp.abs(t)))
            maxima[name] = max(maxima.get(name, 0.0), m)
    return {
        name: ((1.0 + headroom) * m) / Q15_MAX if m > 0 else 1.0 / Q15_MAX
        for name, m in maxima.items()
    }


def fake_quant_activation(t: jax.Array, scale: float) -> jax.Array:
    """Simulate Q15 storage of an activation: quantize -> clip -> dequantize.

    With a *naive* scale (1/32767, i.e. assuming range [-1,1)) this
    reproduces the paper's catastrophic collapse; with a calibrated scale it
    is lossless to rounding noise.
    """
    q = jnp.clip(jnp.round(t / scale), -Q15_MAX - 1, Q15_MAX)
    return q * scale


NAIVE_ACT_SCALE = 1.0 / Q15_MAX  # the naive Q15 [-1, 1) assumption
