"""Energy model (paper Sec. V-H, Tables VIII-IX).

No INA226 / MCU rail exists in this environment, so this module encodes the
paper's MEASURED constants and reproduces every DERIVED quantity in Tables
VIII-IX exactly (the benchmark asserts the arithmetic), plus a TPU-side
analytic energy estimate driven by the roofline terms.

Paper measurement setup: INA226 high-side shunt (0.1 ohm, addr 0x44) on the
MSP430G2553 LaunchPad VCC rail, steady-state means after 60 s, TEST_MODE 3
silent firmware (no UART/LED/I2C).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class RailMeasurement:
    """One row of Table VIII."""
    vcc_v: float
    i_idle_ma: float       # upper bound (below INA226 resolution floor)
    i_50hz_ma: float | None
    i_cont_ma: float

    @property
    def p_active_mw(self) -> float:
        return self.vcc_v * self.i_cont_ma

    @property
    def p_idle_mw(self) -> float:
        return self.vcc_v * self.i_idle_ma


# Table VIII, measured:
MSP430_LUT = RailMeasurement(vcc_v=3.478, i_idle_ma=0.025, i_50hz_ma=5.14, i_cont_ma=5.10)
MSP430_NO_LUT = RailMeasurement(vcc_v=3.478, i_idle_ma=0.025, i_50hz_ma=None, i_cont_ma=5.08)

WINDOW_SAMPLES = 128
SAMPLE_PERIOD_S = 0.020           # 50 Hz
WINDOW_S = WINDOW_SAMPLES * SAMPLE_PERIOD_S  # 2.56 s
BATTERY_WH = 7.4                  # 2000 mAh x 3.7 V Li-Ion


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    """Table IX derivations for one build."""
    p_active_mw: float
    t_step_s: float

    @property
    def e_inference_uj(self) -> float:
        """E/inference = P_cont * t_step."""
        return self.p_active_mw * 1e-3 * self.t_step_s * 1e6

    @property
    def e_window_mj(self) -> float:
        """E/window = 128 * E/inference (50 Hz streaming, LPM between steps)."""
        return WINDOW_SAMPLES * self.e_inference_uj * 1e-3

    @property
    def p_stream_eff_mw(self) -> float:
        """Effective streaming power = E/window over the 2.56 s window."""
        return self.e_window_mj / WINDOW_S

    def battery_hours(self, continuous: bool) -> float:
        p_mw = self.p_active_mw if continuous else self.p_stream_eff_mw
        return BATTERY_WH * 1000.0 / p_mw

    @property
    def meets_50hz(self) -> bool:
        return self.t_step_s <= SAMPLE_PERIOD_S


# t_step from the paper: 13 ms avg measured (Table VII); for the energy
# table the paper's 246 uJ at 17.74 mW implies t_step = 13.87 ms (the
# inference-only portion, excluding loop pacing).  The no-LUT ablation:
# 421 ms/step -> 54 s/window -> the 30.5x factor.
T_STEP_LUT_S = 0.01387
T_STEP_NO_LUT_S = 0.421

LUT_BUILD = EnergyReport(p_active_mw=MSP430_LUT.p_active_mw, t_step_s=T_STEP_LUT_S)
NO_LUT_BUILD = EnergyReport(p_active_mw=MSP430_NO_LUT.p_active_mw, t_step_s=T_STEP_NO_LUT_S)


def lut_speedup() -> float:
    """~30.5x (paper Sec. V-G)."""
    return T_STEP_NO_LUT_S / T_STEP_LUT_S


def window_energy_reduction() -> float:
    """~96.7% (paper abstract / conclusion)."""
    e_no = NO_LUT_BUILD.e_inference_uj * WINDOW_SAMPLES * 1e-3  # mJ
    e_lut = LUT_BUILD.e_window_mj
    return 1.0 - e_lut / e_no


# ---------------------------------------------------------------------------
# TPU-side analytic energy (beyond-paper): estimate J/step from roofline terms.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TPUChipPower:
    """Rough TPU v5e envelope for the analytic model (public figures)."""
    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # B/s
    tdp_w: float = 200.0              # per-chip board power, active
    idle_w: float = 50.0
    pj_per_flop: float = 0.35e-12 * 1e12 / 1e12  # ~0.35 pJ/bf16 FLOP
    pj_per_byte_hbm: float = 60e-12 * 1e12 / 1e12  # ~60 pJ/B HBM access


def tpu_energy_per_step(flops: float, hbm_bytes: float, step_time_s: float,
                        chips: int = 1, chip: TPUChipPower = TPUChipPower()) -> float:
    """J/step = dynamic (compute + HBM) + static (idle * time * chips)."""
    dynamic = flops * 0.35e-12 + hbm_bytes * 60e-12
    static = chip.idle_w * step_time_s * chips
    return dynamic + static
