"""L-S-Q stage 1+2: low-rank factorization and IHT sparsification.

Generic over any parameter pytree — used by the FastGRNN HAR pipeline and
by the LM-framework compression feature (models/ factorized Dense layers).

IHT (paper Sec. III-C): at each step retain the top-k magnitude entries of
every *sparsifiable* tensor and zero the rest; target sparsity follows the
cubic ramp  s_e = s * min(1, e/e_ramp)^3,  then the mask freezes for the
fine-tune phase.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class IHTConfig:
    target_sparsity: float = 0.5        # s: fraction of entries REMOVED
    ramp_epochs: int = 50               # e_ramp (cubic schedule)
    finetune_epochs: int = 50           # mask frozen afterwards
    # Predicate selecting which leaves are sparsified.  The paper sparsifies
    # the four factor matrices only (not biases, scalars, or the head).
    leaf_filter: Callable[[str], bool] = staticmethod(
        lambda name: name in ("W", "U", "W1", "W2", "U1", "U2")
    )


def sparsity_at_epoch(cfg: IHTConfig, epoch: int) -> float:
    """Paper Eq. (7): cubic ramp to the target sparsity."""
    frac = min(1.0, epoch / max(cfg.ramp_epochs, 1))
    return cfg.target_sparsity * frac ** 3


def topk_mask(x: jax.Array, keep: int) -> jax.Array:
    """Boolean mask retaining the ``keep`` largest-|x| entries of x."""
    if keep >= x.size:
        return jnp.ones_like(x, dtype=bool)
    if keep <= 0:
        return jnp.zeros_like(x, dtype=bool)
    flat = jnp.abs(x).reshape(-1)
    # threshold = keep-th largest magnitude
    thresh = jax.lax.top_k(flat, keep)[0][-1]
    mask = jnp.abs(x) >= thresh
    # Tie-break: if ties push us over ``keep``, drop surplus deterministically.
    # (Ties at the threshold are astronomically unlikely for float32 training
    # but hypothesis finds them; enforce exact count via ranking.)
    order = jnp.argsort(-flat, stable=True)
    rank = jnp.zeros_like(order).at[order].set(jnp.arange(flat.size))
    exact = (rank < keep).reshape(x.shape)
    return jnp.where(jnp.sum(mask) == keep, mask, exact)


def compute_masks(params: dict[str, Any], cfg: IHTConfig, sparsity: float):
    """Per-leaf boolean masks at the given sparsity level (flat dict params)."""
    masks = {}
    for name, w in params.items():
        if cfg.leaf_filter(name) and hasattr(w, "size") and w.size > 1:
            keep = int(round(w.size * (1.0 - sparsity)))
            masks[name] = topk_mask(w, keep)
        else:
            masks[name] = jnp.ones_like(w, dtype=bool) if hasattr(w, "shape") else True
    return masks


def apply_masks(params: dict[str, Any], masks: dict[str, Any]):
    return {
        k: (jnp.where(masks[k], v, 0.0) if isinstance(masks[k], jax.Array) else v)
        for k, v in params.items()
    }


# ---------------------------------------------------------------------------
# Generic pytree variant for LM models (nested dicts, path-based filter).
# ---------------------------------------------------------------------------

def compute_masks_tree(params, sparsity: float, path_filter=None):
    """Masks over an arbitrary pytree; path_filter(path_str, leaf) -> bool."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    masks = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        sparsify = (leaf.ndim >= 2) if path_filter is None else path_filter(name, leaf)
        if sparsify:
            keep = int(round(leaf.size * (1.0 - sparsity)))
            masks.append(topk_mask(leaf, keep))
        else:
            masks.append(jnp.ones_like(leaf, dtype=bool))
    return jax.tree_util.tree_unflatten(treedef, masks)


def apply_masks_tree(params, masks):
    return jax.tree.map(lambda w, m: jnp.where(m, w, jnp.zeros_like(w)), params, masks)


def deployed_param_count(params, masks) -> int:
    """Stored-parameter accounting (paper 'nonzero' column): sparsified
    leaves store their kept slots (mask.sum()); dense leaves store every
    entry regardless of value (a zero-initialized bias still occupies its
    2 bytes in the deployed image)."""
    total = 0
    for k, v in params.items():
        m = masks.get(k, True)
        if isinstance(m, jax.Array) and m.dtype == bool and not bool(m.all()):
            total += int(m.sum())
        else:
            total += int(v.size) if hasattr(v, "size") else 1
    return total


def sparsity_of(params, leaf_filter=None) -> float:
    """Realized sparsity over the sparsifiable leaves."""
    total = nz = 0
    if isinstance(params, dict) and leaf_filter is not None:
        items = [(k, v) for k, v in params.items() if leaf_filter(k)]
    else:
        items = [("", v) for v in jax.tree.leaves(params) if v.ndim >= 2]
    for _, v in items:
        total += v.size
        nz += int(jnp.sum(v != 0))
    return 1.0 - nz / max(total, 1)
