"""End-to-end L-S-Q pipeline orchestration (paper Fig. 1):

  float training -> low-rank -> IHT sparsity (cubic ramp + frozen finetune)
  -> per-tensor Q15 PTQ + activation calibration -> deterministic qruntime.

This is the MCU-scale instantiation of the framework's compression feature,
reproducing Tables I-V.  The LM-scale instantiation lives in
repro/train/ + repro/serve/ (same QuantConfig / IHT machinery).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import fastgrnn as fg
from . import compression as comp
from . import quantization as q
from .qruntime import QRuntime


@dataclasses.dataclass
class TrainResult:
    params: dict[str, Any]
    history: list[dict[str, float]]
    masks: dict[str, Any] | None = None


def _adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def _adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    def upd(p, m_, v_):
        mhat = m_ / (1 - b1 ** tf)
        vhat = v_ / (1 - b2 ** tf)
        return p - lr * mhat / (jnp.sqrt(vhat) + eps)
    return jax.tree.map(upd, params, m, v), {"m": m, "v": v, "t": t}


def train_fastgrnn(
    cfg: fg.FastGRNNConfig,
    train_windows: np.ndarray,          # (N, T, d)
    train_labels: np.ndarray,
    *,
    epochs: int = 100,
    batch_size: int = 64,
    lr: float = 1e-3,
    seed: int = 0,
    iht: comp.IHTConfig | None = None,
    eval_fn=None,
    eval_every: int = 10,
) -> TrainResult:
    """Adam training with optional in-loop IHT (paper Sec. IV-B protocol)."""
    key = jax.random.PRNGKey(seed)
    params = fg.init_params(cfg, key)
    opt = _adam_init(params)

    @jax.jit
    def step(params, opt, xs, ys):
        loss, grads = jax.value_and_grad(fg.loss_fn)(params, xs, ys)
        params, opt = _adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    @jax.jit
    def mask_step(params, opt, xs, ys, masks):
        loss, grads = jax.value_and_grad(fg.loss_fn)(params, xs, ys)
        params, opt = _adam_update(params, grads, opt, lr=lr)
        params = comp.apply_masks(params, masks)
        return params, opt, loss

    xs_all = np.transpose(train_windows, (1, 0, 2))  # time-major (T, N, d)
    n = len(train_labels)
    history: list[dict[str, float]] = []
    masks = None

    for epoch in range(epochs):
        rng = np.random.default_rng(seed * 1000 + epoch)
        order = rng.permutation(n)
        losses = []
        if iht is not None:
            # recompute masks THROUGH epoch == ramp_epochs so the frozen
            # mask is at the full target sparsity (paper: 'reaching the
            # target sparsity at epoch 50 and remaining frozen')
            if epoch <= iht.ramp_epochs or masks is None:
                s_e = comp.sparsity_at_epoch(iht, epoch)
                masks = comp.compute_masks(params, iht, s_e)
            params = comp.apply_masks(params, masks)
        for i in range(0, n - batch_size + 1, batch_size):
            j = order[i:i + batch_size]
            xb = jnp.asarray(xs_all[:, j])
            yb = jnp.asarray(train_labels[j])
            if iht is not None:
                params, opt, loss = mask_step(params, opt, xb, yb, masks)
            else:
                params, opt, loss = step(params, opt, xb, yb)
            losses.append(float(loss))
        rec = {"epoch": epoch, "loss": float(np.mean(losses))}
        if eval_fn is not None and (epoch % eval_every == 0 or epoch == epochs - 1):
            rec.update(eval_fn(params))
        history.append(rec)
    return TrainResult(params=params, history=history, masks=masks)


# ---------------------------------------------------------------------------
# Evaluation
# ---------------------------------------------------------------------------

def predict_fp32(params, windows: np.ndarray, batch: int = 512,
                 sigma=jax.nn.sigmoid, tanh=jnp.tanh) -> np.ndarray:
    outs = []
    fwd = jax.jit(lambda xs: fg.forward_window(params, xs, sigma=sigma, tanh=tanh))
    for i in range(0, len(windows), batch):
        xs = jnp.asarray(np.transpose(windows[i:i + batch], (1, 0, 2)))
        outs.append(np.argmax(np.asarray(fwd(xs)), axis=-1))
    return np.concatenate(outs).astype(np.int32)


def macro_f1(y_true: np.ndarray, y_pred: np.ndarray, n_classes: int = 6) -> float:
    f1s = []
    for c in range(n_classes):
        tp = np.sum((y_pred == c) & (y_true == c))
        fp = np.sum((y_pred == c) & (y_true != c))
        fn = np.sum((y_pred != c) & (y_true == c))
        denom = 2 * tp + fp + fn
        f1s.append(2 * tp / denom if denom > 0 else 0.0)
    return float(np.mean(f1s))


def per_class_f1(y_true, y_pred, n_classes: int = 6) -> list[float]:
    out = []
    for c in range(n_classes):
        tp = np.sum((y_pred == c) & (y_true == c))
        fp = np.sum((y_pred == c) & (y_true != c))
        fn = np.sum((y_pred != c) & (y_true == c))
        denom = 2 * tp + fp + fn
        out.append(float(2 * tp / denom) if denom > 0 else 0.0)
    return out


def accuracy(y_true, y_pred) -> float:
    return float(np.mean(y_true == y_pred))


# ---------------------------------------------------------------------------
# Deployment (compression passes -> ModelArtifact -> QRuntime)
# ---------------------------------------------------------------------------

def deploy(params, calib_windows: np.ndarray, *,
           quant: q.QuantConfig = q.QuantConfig(),
           quantize_activations: bool = False,
           naive_activations: bool = False) -> QRuntime:
    """Quantize weights, run the 5-minibatch calibration pass, return the
    deterministic integer runtime (the 'deployed' model).  Built on the
    ``repro.compress`` pass API; numerically identical to the historical
    direct ``quantize_params`` + ``calibrate`` handoff."""
    from repro.compress import (CalibrateActivations, ModelArtifact,
                                QuantizePTQ)
    art = QuantizePTQ.from_config(quant).apply(
        ModelArtifact.from_params(params))
    if naive_activations:
        return QRuntime.from_artifact(art, naive_acts=True)
    if quantize_activations:
        art = CalibrateActivations(
            windows=np.asarray(calib_windows, np.float32),
            headroom=quant.headroom, scope="storage").apply(art)
        return QRuntime.from_artifact(art, quantized_acts=True)
    # deployed config: Q15 weights + FP32 acts through LUT
    return QRuntime.from_artifact(art)


def agreement(pred_a: np.ndarray, pred_b: np.ndarray) -> float:
    return float(np.mean(pred_a == pred_b))
