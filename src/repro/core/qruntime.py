"""Deterministic 'C-equivalent' integer inference runtime (paper Sec. IV-D,
V-F, VI-B).

Mirrors the deployed ~200-line fastgrnn.cpp translation unit:

  * weights stored as int16 Q15 + per-tensor float scale
  * dequantize-on-use:  float w = (float) W_q15[i] * scale   (Appendix B)
  * FP32 accumulate in a FIXED evaluation order (matvec as an ordered
    dot-product loop -> bit-stable across IEEE-754 implementations)
  * activations through the 256-entry nearest-bucket LUT (Appendix C)
  * optional calibrated Q15 *activation* storage between steps — the
    'calibrated Q15 acts' counterfactual of Table V.

Three execution paths are provided, matching the paper's verification
protocol: (1) FP32 reference (core/fastgrnn.py), (2) this NumPy
C-equivalent, (3) the Pallas fastgrnn_cell kernel (interpret mode).  The
cross-platform agreement benchmark compares argmax predictions of all
three over the full test set.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from .lut import make_lut, LUT_SIZE, INPUT_MIN, INPUT_MAX
from .quantization import QuantizedParams, Q15_MAX


_SIG_LUT = make_lut("sigmoid")
_TANH_LUT = make_lut("tanh")
_BW = (INPUT_MAX - INPUT_MIN) / LUT_SIZE
_INV_BW = 1.0 / _BW


def _lut_eval_scalar(lut: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Vector-of-scalars nearest-bucket LUT, identical to Appendix C."""
    x = np.asarray(x, np.float32)
    idx = np.clip(((x - INPUT_MIN) * _INV_BW).astype(np.int32), 0, LUT_SIZE - 1)
    y = lut[idx]
    y = np.where(x >= INPUT_MAX, lut[LUT_SIZE - 1], y)
    y = np.where(x <= INPUT_MIN, lut[0], y)
    return y.astype(np.float32)


def _deq(qp: QuantizedParams, name: str) -> np.ndarray:
    """Dequantize one tensor the way the C engine does (elementwise f32)."""
    q = np.asarray(qp.q[name], np.int32)
    s = np.float32(qp.scales[name])
    return (q.astype(np.float32) * s).astype(np.float32)


def _matvec(A: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Fixed-order FP32 matvec: out[i] = sum_j A[i,j]*x[j], j ascending.

    np.dot on contiguous float32 uses pairwise summation whose order can
    differ across BLAS builds; an explicit fori loop is the bit-stable
    reference.  For speed we use einsum on small dims — verified in tests to
    be bit-identical to the loop at these sizes — falling back to the loop
    if shapes are large enough for BLAS kernels to reorder.
    """
    out = np.zeros(A.shape[0], np.float32)
    for j in range(A.shape[1]):
        out += A[:, j] * np.float32(x[j])
    return out.astype(np.float32)


@dataclasses.dataclass
class QRuntime:
    """Deployed-model runtime: Q15 weights + scales (+ optional act quant)."""
    qp: QuantizedParams
    act_scales: dict[str, float] | None = None  # calibrated Q15 activations
    naive_acts: bool = False                     # naive Q15 [-1,1) activations

    @classmethod
    def from_artifact(cls, artifact, *, quantized_acts: bool = False,
                      naive_acts: bool = False) -> "QRuntime":
        """Build the runtime from a :class:`repro.compress.ModelArtifact`.

        Defaults to the deployed configuration (FP32 activations through
        the LUTs); ``quantized_acts=True`` selects the Table V
        calibrated-Q15-activation counterfactual via the artifact's
        ``storage_scales`` — see ``ModelArtifact.runtime_scales``, the one
        gate shared with ``StreamingEngine.from_artifact``."""
        return cls(artifact.require_qp(),
                   act_scales=artifact.runtime_scales(quantized_acts),
                   naive_acts=naive_acts)

    def __post_init__(self):
        self.low_rank = "W1" in self.qp.q or "W1" in self.qp.fp
        names = (["W1", "W2", "U1", "U2"] if self.low_rank else ["W", "U"])
        self._w = {n: _deq(self.qp, n) for n in names + ["head_w"]}
        f32 = lambda n: np.asarray(self.qp.fp[n], np.float32)
        self._b_z, self._b_h = f32("b_z"), f32("b_h")
        self._head_b = f32("head_b")
        self._zeta = np.float32(1.0 / (1.0 + np.exp(-float(self.qp.fp["zeta"]))))
        self._nu = np.float32(1.0 / (1.0 + np.exp(-float(self.qp.fp["nu"]))))

    # -- activation storage quantization (Table V modes) ------------------
    def _store(self, name: str, t: np.ndarray) -> np.ndarray:
        if self.naive_acts:
            scale = np.float32(1.0 / Q15_MAX)
        elif self.act_scales is not None and name in self.act_scales:
            scale = np.float32(self.act_scales[name])
        else:
            return t
        q = np.clip(np.round(t / scale), -Q15_MAX - 1, Q15_MAX)
        return (q * scale).astype(np.float32)

    # -- public introspection (export compiler / parity harness) -----------
    @property
    def hidden_dim(self) -> int:
        return int(self._b_z.shape[0])

    @property
    def input_dim(self) -> int:
        return int(self._w["W2"].shape[0] if self.low_rank
                   else self._w["W"].shape[1])

    @property
    def num_classes(self) -> int:
        return int(self._head_b.shape[0])

    def weights(self) -> dict[str, np.ndarray]:
        """Dequantized f32 weights in deployment order (copy-free view)."""
        return dict(self._w)

    def constants(self) -> dict[str, np.ndarray | np.float32]:
        """Float leaves as the deployed engine holds them (zeta/nu are the
        post-sigmoid scalars, matching the C translation unit)."""
        return {"b_z": self._b_z, "b_h": self._b_h, "head_b": self._head_b,
                "zeta": self._zeta, "nu": self._nu}

    def step(self, h: np.ndarray, x: np.ndarray) -> np.ndarray:
        """One fastgrnn_step() — mirrors the C translation unit."""
        if self.low_rank:
            wx = _matvec(self._w["W1"], _matvec(self._w["W2"].T, x))
            uh = _matvec(self._w["U1"], _matvec(self._w["U2"].T, h))
        else:
            wx = _matvec(self._w["W"], x)
            uh = _matvec(self._w["U"], h)
        pre = self._store("pre", wx + uh)
        z = _lut_eval_scalar(_SIG_LUT, pre + self._b_z)
        h_tilde = _lut_eval_scalar(_TANH_LUT, pre + self._b_h)
        z = self._store("z", z)
        h_tilde = self._store("h_tilde", h_tilde)
        h_new = (self._zeta * (1.0 - z) + self._nu) * h_tilde + z * h
        return self._store("h", h_new.astype(np.float32))

    def run_window(self, xs: np.ndarray, return_trajectory: bool = False):
        """xs: (T, d) -> logits (C,) [+ (T, H) hidden trajectory]."""
        H = self._b_z.shape[0]
        h = np.zeros(H, np.float32)
        traj = np.zeros((xs.shape[0], H), np.float32) if return_trajectory else None
        for t in range(xs.shape[0]):
            h = self.step(h, xs[t])
            if return_trajectory:
                traj[t] = h
        logits = _matvec(self._w["head_w"].T, h) + self._head_b
        logits = self._store("logits", logits)
        return (logits, traj) if return_trajectory else logits

    def predict(self, xs: np.ndarray) -> int:
        return int(np.argmax(self.run_window(xs)))

    def predict_batch(self, windows: np.ndarray) -> np.ndarray:
        """windows: (N, T, d) -> (N,) predictions."""
        return np.array([self.predict(w) for w in windows], np.int32)


def _record_maxima(rt: QRuntime, xs: np.ndarray, deploy: bool) -> dict[str, float]:
    """One pass of the FP32 recurrence, recording per-tensor max-abs.

    ``deploy=False`` records the activation-storage tensors (Table V
    modes: pre, z, h_tilde, h, logits).  ``deploy=True`` additionally
    records what the fixed-point export compiler must scale:

      * ``x``    — raw input samples (the qvm quantizes inputs once at the
        boundary, so the input scale is part of the weight image);
      * ``wx1`` / ``uh1`` — the low-rank intermediate vectors W2^T x and
        U2^T h, which the integer engine requantizes between the two
        factored matvecs;
      * ``pre``  — widened to cover pre+b_z and pre+b_h, because the
        integer engine adds the (pre-scale-quantized) biases *before* the
        LUT lookup and the bias-inclusive value must be representable.
    """
    H = rt.hidden_dim
    h = np.zeros(H, np.float32)
    maxima: dict[str, float] = {}

    def upd(name, t):
        maxima[name] = max(maxima.get(name, 0.0), float(np.max(np.abs(t))))

    if deploy:
        upd("x", xs)
    for t in range(xs.shape[0]):
        if rt.low_rank:
            wx1 = _matvec(rt._w["W2"].T, xs[t])
            uh1 = _matvec(rt._w["U2"].T, h)
            if deploy:
                upd("wx1", wx1)
                upd("uh1", uh1)
            wx = _matvec(rt._w["W1"], wx1)
            uh = _matvec(rt._w["U1"], uh1)
        else:
            wx = _matvec(rt._w["W"], xs[t])
            uh = _matvec(rt._w["U"], h)
        pre = wx + uh
        if deploy:
            upd("pre", pre + rt._b_z)
            upd("pre", pre + rt._b_h)
        z = _lut_eval_scalar(_SIG_LUT, pre + rt._b_z)
        h_tilde = _lut_eval_scalar(_TANH_LUT, pre + rt._b_h)
        h = (rt._zeta * (1.0 - z) + rt._nu) * h_tilde + z * h
        for n, v in (("pre", pre), ("z", z), ("h_tilde", h_tilde), ("h", h)):
            upd(n, v)
    logits = _matvec(rt._w["head_w"].T, h) + rt._head_b
    upd("logits", logits)
    return maxima


def record_activations(rt: QRuntime, xs: np.ndarray, *,
                       deploy: bool = False) -> dict[str, float]:
    """Collect per-tensor max-abs over one window — THE recorder behind
    both calibration scopes.  ``deploy=False`` records the activation-
    storage tensors (Table V); ``deploy=True`` additionally records the
    export-compiler scales (x, low-rank intermediates, bias-inclusive
    pre) — see ``_record_maxima``."""
    return _record_maxima(rt, xs, deploy)


def calibrate(rt: QRuntime, windows: np.ndarray, headroom: float = 0.10, *,
              deploy: bool = False) -> dict[str, float]:
    """Paper Sec. III-D: max-abs calibration with headroom — the ONE
    parameterized implementation behind both scopes.  ``deploy=False``
    yields the Table V activation-storage scales; ``deploy=True`` yields
    every scale the fixed-point export compiler packs into the weight
    image (what ``repro.compress.CalibrateActivations`` and
    ``deploy/image.build_image`` consume)."""
    maxima: dict[str, float] = {}
    for w in windows:
        for k, v in _record_maxima(rt, w, deploy).items():
            maxima[k] = max(maxima.get(k, 0.0), v)
    return {k: ((1.0 + headroom) * v) / Q15_MAX if v > 0 else 1.0 / Q15_MAX
            for k, v in maxima.items()}


def record_activations_deploy(rt: QRuntime, xs: np.ndarray) -> dict[str, float]:
    """Thin alias: ``record_activations(rt, xs, deploy=True)``."""
    return record_activations(rt, xs, deploy=True)


def calibrate_deploy(rt: QRuntime, windows: np.ndarray,
                     headroom: float = 0.10) -> dict[str, float]:
    """Thin alias: ``calibrate(rt, windows, headroom, deploy=True)``."""
    return calibrate(rt, windows, headroom, deploy=True)
