"""Pallas TPU kernel: 256-entry LUT activation (paper Sec. III-E).

MCU -> TPU adaptation (DESIGN.md Sec. 2): the table lives in Flash on the
MSP430 and is re-read per call; here it is pinned in VMEM for the whole
tile sweep and the lookup vectorizes on the VPU.  On TPU the win is
determinism/precision control rather than speed — quantified in
benchmarks/lut_speedup.py.

Tiling: the input is processed in (BLOCK_R, 128) VMEM tiles (lane dim 128
hardware-aligned); the 256 x f32 table (1 KB) is replicated to every grid
step via a constant index_map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256          # sublane-dim tile rows
BLOCK_C = 128          # lane dim (VPU width)


def _lut_kernel(table_ref, x_ref, o_ref, *, lo: float, hi: float,
                lerp: bool, linear_tail: bool):
    x = x_ref[...].astype(jnp.float32)
    table = table_ref[...]
    size = table.shape[0]
    bw = (hi - lo) / size
    if lerp:
        pos = (x - lo) / bw - 0.5
        i0 = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, size - 1)
        i1 = jnp.clip(i0 + 1, 0, size - 1)
        frac = jnp.clip(pos - i0.astype(jnp.float32), 0.0, 1.0)
        y = (1.0 - frac) * jnp.take(table, i0) + frac * jnp.take(table, i1)
    else:
        idx = jnp.clip(((x - lo) * (1.0 / bw)).astype(jnp.int32), 0, size - 1)
        y = jnp.take(table, idx)
    if linear_tail:
        y = jnp.where(x >= hi, x, jnp.where(x <= lo, 0.0, y))
    else:
        y = jnp.where(x >= hi, table[size - 1], jnp.where(x <= lo, table[0], y))
    o_ref[...] = y.astype(o_ref.dtype)


# detlint: ignore[det-jit-pallas] fixed block-padded shapes (ops.py pads pre-call); tolerance-gated, not bit-exact
@functools.partial(jax.jit, static_argnames=("lo", "hi", "mode",
                                             "linear_tail", "interpret"))
def lut_act_2d(table, x2d, *, lo: float, hi: float, mode: str = "nearest",
               linear_tail: bool = False, interpret: bool = True):
    """x2d: (R, C) padded to (BLOCK_R, BLOCK_C) multiples by ops.py."""
    r, c = x2d.shape
    grid = (r // BLOCK_R, c // BLOCK_C)
    return pl.pallas_call(
        functools.partial(_lut_kernel, lo=lo, hi=hi, lerp=(mode == "lerp"),
                          linear_tail=linear_tail),
        grid=grid,
        in_specs=[
            pl.BlockSpec((table.shape[0],), lambda i, j: (0,)),
            pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK_R, BLOCK_C), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
    )(table, x2d)
