"""jit'd public wrapper: arbitrary-shape LUT activations via the Pallas
kernel (pad -> 2D tiles -> unpad)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.lut import make_lut, INPUT_MIN, INPUT_MAX
from .kernel import lut_act_2d, BLOCK_R, BLOCK_C

_LINEAR_TAILS = ("silu", "gelu", "softplus")


def lut_act(x, fn: str = "tanh", *, mode: str = "nearest",
            lo: float = INPUT_MIN, hi: float = INPUT_MAX,
            interpret: bool = True):
    table = jnp.asarray(make_lut(fn, 256, lo, hi))
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = BLOCK_C
    rows = -(-n // cols)
    rpad = -rows % BLOCK_R
    total = (rows + rpad) * cols
    flat = jnp.pad(flat, (0, total - n))
    x2d = flat.reshape(rows + rpad, cols)
    y = lut_act_2d(table, x2d, lo=lo, hi=hi, mode=mode,
                   linear_tail=(fn in _LINEAR_TAILS), interpret=interpret)
    return y.reshape(-1)[:n].reshape(x.shape)


def lut_sigmoid(x, **kw):
    return lut_act(x, "sigmoid", **kw)


def lut_tanh(x, **kw):
    return lut_act(x, "tanh", **kw)
