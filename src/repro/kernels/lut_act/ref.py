"""Pure-jnp oracle for the LUT activation kernel = core/lut.py lut_eval."""
from repro.core.lut import lut_eval, make_lut, INPUT_MIN, INPUT_MAX, LUT_SIZE  # noqa: F401


def lut_act_ref(table, x, lo=INPUT_MIN, hi=INPUT_MAX, mode="nearest"):
    return lut_eval(table, x, lo=lo, hi=hi, mode=mode)
