"""Pallas TPU kernel: blocked matmul with fused Q15/Q7 weight dequant.

The paper's Appendix-B runtime dequantizes each int16 weight on use
(``float w = (float) W_q15[i] * scale``).  TPU adaptation (DESIGN.md
Sec. 2): weights stream HBM->VMEM as int8/int16 (2-4x fewer HBM bytes than
f32 — decode is HBM-bound, so this moves the dominant roofline term
directly), convert to bf16 INSIDE the VMEM tile, hit the MXU, and apply
the per-tensor scale once to the f32 accumulator on the way out (the
scale commutes with the contraction).

Grid (M/bm, N/bn, K/bk), K innermost; f32 accumulation in a VMEM scratch
tile across the K steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM, BN, BK = 128, 128, 128


def _mm_kernel(x_ref, w_ref, scale_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xb = x_ref[...].astype(jnp.bfloat16)
    wb = w_ref[...].astype(jnp.bfloat16)          # int -> bf16 in-tile
    acc_ref[...] += jnp.dot(xb, wb, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _():
        o_ref[...] = (acc_ref[...] * scale_ref[0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "out_dtype"))  # detlint: ignore[det-jit-pallas] fixed block-padded shapes (ops.py pads pre-call); tolerance-gated, not bit-exact
def q15_matmul_padded(x, wq, scale, *, out_dtype=jnp.float32,
                      interpret: bool = True):
    """x: (M, K) bf16/f32; wq: (K, N) int8/int16; scale: (1,) f32.
    M, N, K must be multiples of the block sizes (ops.py pads)."""
    m, k = x.shape
    _, n = wq.shape
    grid = (m // BM, n // BN, k // BK)
    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((BK, BN), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=interpret,
    )(x, wq, scale)
