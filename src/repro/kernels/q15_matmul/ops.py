"""jit'd wrapper: pad to (128,128,128) blocks, run, slice back.  Also the
serving entry point ``quantized_dense`` used by the L-S-Q serving path."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import q15_matmul_padded, BM, BN, BK


def q15_matmul(x, wq, scale, *, out_dtype=jnp.float32, interpret: bool = True):
    """x: (..., K); wq: (K, N) int8/int16; scale: scalar -> (..., N)."""
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = wq.shape[1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    mp, kp, np_ = -m % BM, -k % BK, -n % BN
    x2 = jnp.pad(x2.astype(jnp.float32), ((0, mp), (0, kp)))
    wqp = jnp.pad(wq, ((0, kp), (0, np_)))
    out = q15_matmul_padded(x2, wqp, jnp.asarray([scale], jnp.float32),
                            out_dtype=out_dtype, interpret=interpret)
    return out[:m, :n].reshape(lead + (n,))


def quantized_dense(p_q, p_scale, x, *, interpret: bool = True):
    """Drop-in for layers.dense_apply with a quantized weight leaf."""
    y = q15_matmul(x, p_q["w"], p_scale["w"], out_dtype=jnp.float32,
                   interpret=interpret)
    if "b" in p_q:
        y = y + p_q["b"]
    return y
