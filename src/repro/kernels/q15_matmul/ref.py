"""Pure-jnp oracle: dequantize-then-matmul (per-tensor symmetric scale)."""
from __future__ import annotations

import jax.numpy as jnp


def q15_matmul_ref(x, wq, scale, out_dtype=jnp.float32):
    """x: (M, K) float; wq: (K, N) int8/int16; scale: scalar.
    Per-tensor scale commutes with the contraction:
        x @ (wq * s) == s * (x @ wq_as_float)."""
    w = wq.astype(jnp.float32) * scale
    return jnp.dot(x.astype(jnp.float32), w).astype(out_dtype)
