"""jit'd wrapper: (B,S,H,P)/(B,S,G,N) model layout -> per-head kernel
layout (broadcast groups, fold B x H into the grid), pad S to the chunk."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import ssd_scan_heads


def ssd_scan(x, dt, A, B, C, *, chunk: int = 64, interpret: bool = True):
    """Same signature/semantics as models.mamba2.ssd_chunked (h0=None).
    x: (b,S,H,P); dt: (b,S,H); A: (H,); B,C: (b,S,G,N)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    pad = (-s) % chunk
    if pad:
        zf = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, B, C = zf(x), zf(dt), zf(B), zf(C)
    sp = s + pad
    xh = jnp.moveaxis(x, 2, 1).reshape(b * h, sp, p)
    dth = jnp.moveaxis(dt, 2, 1).reshape(b * h, sp, 1)
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)
    Bh = jnp.moveaxis(Bh, 2, 1).reshape(b * h, sp, n)
    Ch = jnp.moveaxis(Ch, 2, 1).reshape(b * h, sp, n)
    Ah = jnp.tile(A.astype(jnp.float32), b).reshape(b * h, 1)
    y, hf = ssd_scan_heads(xh, dth, Ah, Bh, Ch, chunk=chunk,
                           interpret=interpret)
    y = jnp.moveaxis(y.reshape(b, h, sp, p), 1, 2)[:, :s]
    state = hf.reshape(b, h, n, p)
    return y.astype(x.dtype), state
