"""Pure-jnp oracle for the SSD scan kernel = models/mamba2.ssd_chunked."""
from repro.models.mamba2 import ssd_chunked as ssd_scan_ref  # noqa: F401
