"""Pallas TPU kernel: Mamba2 chunked SSD scan (arXiv:2405.21060).

One program per (batch, head) pair; the kernel walks the chunk sequence
with a fori_loop, holding the running (N, P) state in a VMEM scratch —
the inter-chunk recurrence never touches HBM.  Per chunk the intra-chunk
term is the masked decay-weighted (Q, Q) matmul pair (MXU work), matching
models/mamba2.ssd_chunked exactly.

Layout per program: x (S, P), dt (S, 1), B/C (S, N) for ONE head (groups
are pre-broadcast by ops.py).  Q (chunk) is a multiple of 8; N, P are
128-lane-aligned by ops.py padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref,
                state_ref, *, nc: int, q: int):
    a = a_ref[0]                                   # scalar A (negative)
    state_ref[...] = jnp.zeros_like(state_ref)

    def chunk(ci, _):
        sl = pl.dslice(ci * q, q)
        xq = x_ref[sl, :].astype(jnp.float32)      # (Q, P)
        dtq = dt_ref[sl, 0].astype(jnp.float32)    # (Q,)
        bq = b_ref[sl, :].astype(jnp.float32)      # (Q, N)
        cq = c_ref[sl, :].astype(jnp.float32)      # (Q, N)
        dA = dtq * a
        cs = jnp.cumsum(dA)                        # (Q,)
        # intra-chunk: L[i,j] = exp(cs_i - cs_j) for i >= j.  Clamp the
        # masked (i < j) entries BEFORE the exp — cs is decreasing so
        # cs_i - cs_j > 0 there, and once the chunk accumulates enough
        # |dA| (large chunks, or zero-padded tails pinning cs flat while
        # real rows keep decaying) exp overflows to inf and inf * 0 from
        # the post-hoc mask multiply poisons the whole row with NaN.
        # Same fix as the jnp oracle (models/mamba2.ssd_chunked).
        li = cs[:, None] - cs[None, :]
        mask = jnp.tril(jnp.ones((q, q), bool))
        Ldec = jnp.exp(jnp.where(mask, li, -1e30))
        scores = jnp.dot(cq, bq.T, preferred_element_type=jnp.float32)
        M = scores * Ldec * dtq[None, :]
        y_diag = jnp.dot(M, xq, preferred_element_type=jnp.float32)
        # inter-chunk: y_off = C_i exp(cs_i) . H_prev
        h_prev = state_ref[...]                    # (N, P)
        y_off = jnp.exp(cs)[:, None] * jnp.dot(
            cq, h_prev, preferred_element_type=jnp.float32)
        y_ref[sl, :] = (y_diag + y_off).astype(y_ref.dtype)
        # state update: H = exp(sum dA) H_prev + sum_j w_j B_j x_j^T
        decay_to_end = jnp.exp(cs[-1] - cs)        # (Q,)
        w = decay_to_end * dtq
        s_new = jnp.dot(bq.T * w[None, :], xq,
                        preferred_element_type=jnp.float32)
        state_ref[...] = jnp.exp(cs[-1]) * h_prev + s_new
        return 0

    jax.lax.fori_loop(0, nc, chunk, 0)
    hout_ref[...] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))  # detlint: ignore[det-jit-pallas] fixed chunk-padded shapes (ops.py pads pre-call); tolerance-gated, not bit-exact
def ssd_scan_heads(x, dt, A, B, C, *, chunk: int = 64, interpret: bool = True):
    """Per-head layout: x (BH, S, P); dt (BH, S, 1); A (BH, 1); B/C
    (BH, S, N).  S % chunk == 0 (ops.py pads).  Returns (y, final_state)."""
    bh, s, p = x.shape
    n = B.shape[2]
    nc = s // chunk
    grid = (bh,)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc, q=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, s, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, s, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, 1), lambda i: (i, 0)),
            pl.BlockSpec((None, s, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, s, n), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, s, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, n, p), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
