# Pallas TPU kernels for the compute hot-spots of the L-S-Q deployment
# path (paper Sec. III-E / V-G, adapted MCU->TPU per DESIGN.md Sec. 2):
#   lut_act       — 256-entry sigma/tanh LUT activations, VMEM-resident table
#   fastgrnn_cell — fused full-window FastGRNN scan (weights pinned in VMEM)
#   q15_matmul    — dequant-fused int16/int8 x bf16 blocked matmul (serving)
#   ssd_scan      — Mamba2 chunked SSD scan (state carried across grid steps)
# Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# wrapper with shape plumbing), ref.py (pure-jnp oracle).  All validated in
# interpret mode on CPU; TPU is the lowering target.
from . import lut_act, fastgrnn_cell, q15_matmul, ssd_scan  # noqa: F401
