"""Batched single-step Q15 FastGRNN cell math, shared by every backend.

This is the streaming-inference hot path: one FastGRNN step for a whole
batch of independent streams (one hidden state per slot), written once and
parameterized over the array namespace ``xp`` so the identical op sequence
runs as

  * vectorized NumPy        (``xp=numpy`` — the *exact* backend),
  * eager / jit jax.numpy   (``xp=jax.numpy``),
  * the Pallas kernel body  (``xp=jax.numpy`` inside ``pl.pallas_call``).

Bit-stability contract (paper Sec. IV-D / Table VI, lifted to batch scale):
every function here is the batched image of the scalar reference in
``core/qruntime.py`` — the fixed ascending-j matvec loop, dequantize-on-use
weights, nearest-bucket LUT activations, and the gate combine are the same
scalar IEEE-754 float32 ops applied per stream row.  Under NumPy that makes
each stream bit-identical to ``QRuntime.step``.  Under **jit-compiled** XLA
CPU it does not: XLA's emitter contracts ``a*b + c`` into an FMA (even
through ``lax.optimization_barrier`` / select guards, measured drift ~1e-9
per step), which is why the streaming engine defaults to the NumPy backend
for the agreement contract and offers the jit/Pallas backends for
throughput.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.lut import make_lut, LUT_SIZE, INPUT_MIN, INPUT_MAX
from repro.core.quantization import QuantizedParams, Q15_MAX

_INV_BW = LUT_SIZE / (INPUT_MAX - INPUT_MIN)   # exact python float (16.0)

LOW_RANK_NAMES = ("W1", "W2", "U1", "U2")
FULL_RANK_NAMES = ("W", "U")


@dataclasses.dataclass
class StepWeights:
    """Deployment-time constants for the batched step, mirroring
    ``QRuntime.__post_init__``: dequantized f32 weights, raw Q15 tensors +
    scales (for backends that dequantize on use), float biases, post-sigmoid
    zeta/nu scalars, and the two activation LUTs."""
    low_rank: bool
    w: dict[str, np.ndarray]            # dequantized float32 (incl. head_w)
    q: dict[str, np.ndarray]            # raw int16 Q15 tensors
    scales: dict[str, float]            # per-tensor dequant scales
    b_z: np.ndarray
    b_h: np.ndarray
    head_b: np.ndarray
    zeta: np.float32                    # sigmoid(raw), f32 — as deployed
    nu: np.float32
    sig_lut: np.ndarray                 # (256,) f32
    tanh_lut: np.ndarray
    act_scales: dict[str, float] | None = None   # calibrated Q15 act storage
    naive_acts: bool = False                     # naive [-1,1) act storage

    @property
    def input_dim(self) -> int:
        return self.w["W2"].shape[0] if self.low_rank else self.w["W"].shape[1]

    @property
    def hidden_dim(self) -> int:
        return self.b_z.shape[0]

    @property
    def num_classes(self) -> int:
        return self.head_b.shape[0]

    @classmethod
    def from_quantized(cls, qp: QuantizedParams, *,
                       act_scales: dict[str, float] | None = None,
                       naive_acts: bool = False) -> "StepWeights":
        low_rank = "W1" in qp.q or "W1" in qp.fp
        names = list(LOW_RANK_NAMES if low_rank else FULL_RANK_NAMES) + ["head_w"]
        w, q, scales = {}, {}, {}
        for n in names:
            qi = np.asarray(qp.q[n], np.int32)
            s = np.float32(qp.scales[n])
            q[n] = np.asarray(qp.q[n], np.int16)
            scales[n] = float(s)
            w[n] = (qi.astype(np.float32) * s).astype(np.float32)
        f32 = lambda n: np.asarray(qp.fp[n], np.float32)
        return cls(
            low_rank=low_rank, w=w, q=q, scales=scales,
            b_z=f32("b_z"), b_h=f32("b_h"), head_b=f32("head_b"),
            zeta=np.float32(1.0 / (1.0 + np.exp(-float(qp.fp["zeta"])))),
            nu=np.float32(1.0 / (1.0 + np.exp(-float(qp.fp["nu"])))),
            sig_lut=make_lut("sigmoid"), tanh_lut=make_lut("tanh"),
            act_scales=dict(act_scales) if act_scales else None,
            naive_acts=naive_acts,
        )

    def arrays(self, xp) -> dict[str, "object"]:
        """All array constants converted into namespace ``xp`` (f32)."""
        out = {n: xp.asarray(a) for n, a in self.w.items()}
        out.update(b_z=xp.asarray(self.b_z), b_h=xp.asarray(self.b_h),
                   head_b=xp.asarray(self.head_b),
                   sig_lut=xp.asarray(self.sig_lut),
                   tanh_lut=xp.asarray(self.tanh_lut))
        return out

    def store_scale(self, name: str) -> np.float32 | None:
        """Activation-storage scale for ``name`` (Table V modes), or None
        when the tensor stays FP32 (the deployed configuration)."""
        if self.naive_acts:
            return np.float32(1.0 / Q15_MAX)
        if self.act_scales is not None and name in self.act_scales:
            return np.float32(self.act_scales[name])
        return None


# ---------------------------------------------------------------------------
# Generic math (xp = numpy | jax.numpy)
# ---------------------------------------------------------------------------

def matvec_batched(xp, A, x):
    """out[b, i] = sum_j A[i, j] * x[b, j], j ascending.

    The batched image of ``qruntime._matvec``: per row the multiply and the
    accumulate are the same two scalar f32 ops in the same order, so each
    stream is bit-identical to the scalar loop (under a non-contracting
    executor; see module docstring).
    """
    out = xp.zeros((x.shape[0], A.shape[0]), xp.float32)
    for j in range(A.shape[1]):
        out = out + x[:, j:j + 1] * A[:, j][None, :]
    return out


def lut_eval_batched(xp, table, v):
    """Nearest-bucket LUT over (B, H), identical to qruntime._lut_eval_scalar."""
    idx = xp.clip(((v - INPUT_MIN) * _INV_BW).astype(xp.int32), 0, LUT_SIZE - 1)
    y = table[idx]
    y = xp.where(v >= INPUT_MAX, table[LUT_SIZE - 1], y)
    y = xp.where(v <= INPUT_MIN, table[0], y)
    return y.astype(xp.float32)


def store_batched(xp, t, scale):
    """Q15 activation-storage fake-quant (qruntime._store); scale may be None."""
    if scale is None:
        return t
    q = xp.clip(xp.round(t / scale), -Q15_MAX - 1, Q15_MAX)
    return (q * scale).astype(xp.float32)


#: Bound-check slack for the tally fast path: the elementwise ``pre + b``
#: sums round in float32, so the (float64) ``max(pre) + max(b)`` bound can
#: undershoot an elementwise result by up to half a float32 ulp.  1e-3 at
#: a threshold of 8.0 is ~1000x that — conservative, never unsound.
_TALLY_SLACK = 1e-3


def tally_step_events(events: dict, pre, z_in, ht_in,
                      bias_ext: tuple | None = None) -> None:
    """Accumulate numeric-health tallies from one NumPy step's already
    materialized intermediates (see :mod:`repro.obs.numerics`).

    ``act.*.idx`` counts LUT boundary hits with the float-path semantic:
    a pre-activation at or beyond ``INPUT_MAX`` / ``INPUT_MIN`` takes the
    ``where``-override branch in :func:`lut_eval_batched` (the qvm's
    integer twin counts the index-clip instead — the two agree except on
    exact-boundary ties, which the float path treats as saturated).
    ``pre`` range is tallied as (vmin, vmax, n, n_over) against the
    optional ``events["pre_limit"]`` amplitude so the engine can feed
    ``NumericsMonitor.note_range`` without re-touching the values.

    Fast path: the ``pre`` min/max this function needs anyway, plus the
    precomputed bias extremes (``bias_ext = (bz_lo, bz_hi, bh_lo,
    bh_hi)``), bound every elementwise count from above — the O(B*H)
    comparisons only run in the rare tick whose bounds approach a
    threshold, so a healthy monitored stream pays two reductions per
    step and nothing else."""
    pmin, pmax = float(pre.min()), float(pre.max())
    if bias_ext is None:
        bias_ext = (0.0, 0.0, 0.0, 0.0)
        near_z = near_ht = True
    else:
        bz_lo, bz_hi, bh_lo, bh_hi = bias_ext
        near_z = (pmax + bz_hi >= INPUT_MAX - _TALLY_SLACK
                  or pmin + bz_lo <= INPUT_MIN + _TALLY_SLACK)
        near_ht = (pmax + bh_hi >= INPUT_MAX - _TALLY_SLACK
                   or pmin + bh_lo <= INPUT_MIN + _TALLY_SLACK)
    if near_z:
        events["act.z.idx"] = events.get("act.z.idx", 0) + int(
            np.count_nonzero(z_in >= INPUT_MAX)
            + np.count_nonzero(z_in <= INPUT_MIN))
    if near_ht:
        events["act.ht.idx"] = events.get("act.ht.idx", 0) + int(
            np.count_nonzero(ht_in >= INPUT_MAX)
            + np.count_nonzero(ht_in <= INPUT_MIN))
    lim = events.get("pre_limit")
    # exact comparisons on pre itself: bounds inside +-lim imply zero over
    n_over = int(np.count_nonzero(np.abs(pre) > lim)) \
        if lim and (pmax > lim or pmin < -lim) else 0
    vmin, vmax, n, over = events.get("pre_range", (0.0, 0.0, 0, 0))
    if n == 0:
        events["pre_range"] = (pmin, pmax, int(pre.size), n_over)
    else:
        events["pre_range"] = (min(vmin, pmin), max(vmax, pmax),
                               n + int(pre.size), over + n_over)


def step_batched(xp, arrs, sw: StepWeights, h, x, events=None):
    """One batched FastGRNN step.  h: (B, H), x: (B, d) -> h_new (B, H).

    Mirrors ``QRuntime.step`` line for line; ``arrs`` is ``sw.arrays(xp)``.
    ``events`` (NumPy path only — pass None under a tracer) is a mutable
    dict that :func:`tally_step_events` fills from the intermediates this
    call materializes anyway, so monitored and unmonitored runs execute
    the same FP op sequence and stay byte-identical.
    """
    if sw.low_rank:
        wx = matvec_batched(xp, arrs["W1"], matvec_batched(xp, arrs["W2"].T, x))
        uh = matvec_batched(xp, arrs["U1"], matvec_batched(xp, arrs["U2"].T, h))
    else:
        wx = matvec_batched(xp, arrs["W"], x)
        uh = matvec_batched(xp, arrs["U"], h)
    pre = store_batched(xp, wx + uh, sw.store_scale("pre"))
    z_in = pre + arrs["b_z"]
    ht_in = pre + arrs["b_h"]
    z = lut_eval_batched(xp, arrs["sig_lut"], z_in)
    h_tilde = lut_eval_batched(xp, arrs["tanh_lut"], ht_in)
    if events is not None:
        ext = events.get("_bias_ext")
        if ext is None:
            ext = events["_bias_ext"] = (
                float(arrs["b_z"].min()), float(arrs["b_z"].max()),
                float(arrs["b_h"].min()), float(arrs["b_h"].max()))
        tally_step_events(events, pre, z_in, ht_in, ext)
    z = store_batched(xp, z, sw.store_scale("z"))
    h_tilde = store_batched(xp, h_tilde, sw.store_scale("h_tilde"))
    h_new = (sw.zeta * (1.0 - z) + sw.nu) * h_tilde + z * h
    return store_batched(xp, h_new.astype(xp.float32), sw.store_scale("h"))


def logits_batched(xp, arrs, sw: StepWeights, h):
    """Classifier head, the batched image of ``qruntime.run_window``'s
    ``_matvec(head_w.T, h) + head_b`` (+ optional Q15 logit storage)."""
    out = matvec_batched(xp, arrs["head_w"].T, h)
    return store_batched(xp, out + arrs["head_b"], sw.store_scale("logits"))
