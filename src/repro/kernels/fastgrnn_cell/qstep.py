"""Batched single-step Q15 FastGRNN cell math, shared by every backend.

This is the streaming-inference hot path: one FastGRNN step for a whole
batch of independent streams (one hidden state per slot), written once and
parameterized over the array namespace ``xp`` so the identical op sequence
runs as

  * vectorized NumPy        (``xp=numpy`` — the *exact* backend),
  * eager / jit jax.numpy   (``xp=jax.numpy``),
  * the Pallas kernel body  (``xp=jax.numpy`` inside ``pl.pallas_call``).

Bit-stability contract (paper Sec. IV-D / Table VI, lifted to batch scale):
every function here is the batched image of the scalar reference in
``core/qruntime.py`` — the fixed ascending-j matvec loop, dequantize-on-use
weights, nearest-bucket LUT activations, and the gate combine are the same
scalar IEEE-754 float32 ops applied per stream row.  Under NumPy that makes
each stream bit-identical to ``QRuntime.step``.  Under **jit-compiled** XLA
CPU it does not: XLA's emitter contracts ``a*b + c`` into an FMA (even
through ``lax.optimization_barrier`` / select guards, measured drift ~1e-9
per step), which is why the streaming engine defaults to the NumPy backend
for the agreement contract and offers the jit/Pallas backends for
throughput.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.lut import make_lut, LUT_SIZE, INPUT_MIN, INPUT_MAX
from repro.core.quantization import QuantizedParams, Q15_MAX

_INV_BW = LUT_SIZE / (INPUT_MAX - INPUT_MIN)   # exact python float (16.0)

LOW_RANK_NAMES = ("W1", "W2", "U1", "U2")
FULL_RANK_NAMES = ("W", "U")


@dataclasses.dataclass
class StepWeights:
    """Deployment-time constants for the batched step, mirroring
    ``QRuntime.__post_init__``: dequantized f32 weights, raw Q15 tensors +
    scales (for backends that dequantize on use), float biases, post-sigmoid
    zeta/nu scalars, and the two activation LUTs."""
    low_rank: bool
    w: dict[str, np.ndarray]            # dequantized float32 (incl. head_w)
    q: dict[str, np.ndarray]            # raw int16 Q15 tensors
    scales: dict[str, float]            # per-tensor dequant scales
    b_z: np.ndarray
    b_h: np.ndarray
    head_b: np.ndarray
    zeta: np.float32                    # sigmoid(raw), f32 — as deployed
    nu: np.float32
    sig_lut: np.ndarray                 # (256,) f32
    tanh_lut: np.ndarray
    act_scales: dict[str, float] | None = None   # calibrated Q15 act storage
    naive_acts: bool = False                     # naive [-1,1) act storage

    @property
    def input_dim(self) -> int:
        return self.w["W2"].shape[0] if self.low_rank else self.w["W"].shape[1]

    @property
    def hidden_dim(self) -> int:
        return self.b_z.shape[0]

    @property
    def num_classes(self) -> int:
        return self.head_b.shape[0]

    @classmethod
    def from_quantized(cls, qp: QuantizedParams, *,
                       act_scales: dict[str, float] | None = None,
                       naive_acts: bool = False) -> "StepWeights":
        low_rank = "W1" in qp.q or "W1" in qp.fp
        names = list(LOW_RANK_NAMES if low_rank else FULL_RANK_NAMES) + ["head_w"]
        w, q, scales = {}, {}, {}
        for n in names:
            qi = np.asarray(qp.q[n], np.int32)
            s = np.float32(qp.scales[n])
            q[n] = np.asarray(qp.q[n], np.int16)
            scales[n] = float(s)
            w[n] = (qi.astype(np.float32) * s).astype(np.float32)
        f32 = lambda n: np.asarray(qp.fp[n], np.float32)
        return cls(
            low_rank=low_rank, w=w, q=q, scales=scales,
            b_z=f32("b_z"), b_h=f32("b_h"), head_b=f32("head_b"),
            zeta=np.float32(1.0 / (1.0 + np.exp(-float(qp.fp["zeta"])))),
            nu=np.float32(1.0 / (1.0 + np.exp(-float(qp.fp["nu"])))),
            sig_lut=make_lut("sigmoid"), tanh_lut=make_lut("tanh"),
            act_scales=dict(act_scales) if act_scales else None,
            naive_acts=naive_acts,
        )

    def arrays(self, xp) -> dict[str, "object"]:
        """All array constants converted into namespace ``xp`` (f32)."""
        out = {n: xp.asarray(a) for n, a in self.w.items()}
        out.update(b_z=xp.asarray(self.b_z), b_h=xp.asarray(self.b_h),
                   head_b=xp.asarray(self.head_b),
                   sig_lut=xp.asarray(self.sig_lut),
                   tanh_lut=xp.asarray(self.tanh_lut))
        return out

    def store_scale(self, name: str) -> np.float32 | None:
        """Activation-storage scale for ``name`` (Table V modes), or None
        when the tensor stays FP32 (the deployed configuration)."""
        if self.naive_acts:
            return np.float32(1.0 / Q15_MAX)
        if self.act_scales is not None and name in self.act_scales:
            return np.float32(self.act_scales[name])
        return None


# ---------------------------------------------------------------------------
# Generic math (xp = numpy | jax.numpy)
# ---------------------------------------------------------------------------

def matvec_batched(xp, A, x):
    """out[b, i] = sum_j A[i, j] * x[b, j], j ascending.

    The batched image of ``qruntime._matvec``: per row the multiply and the
    accumulate are the same two scalar f32 ops in the same order, so each
    stream is bit-identical to the scalar loop (under a non-contracting
    executor; see module docstring).
    """
    out = xp.zeros((x.shape[0], A.shape[0]), xp.float32)
    for j in range(A.shape[1]):
        out = out + x[:, j:j + 1] * A[:, j][None, :]
    return out


def lut_eval_batched(xp, table, v):
    """Nearest-bucket LUT over (B, H), identical to qruntime._lut_eval_scalar."""
    idx = xp.clip(((v - INPUT_MIN) * _INV_BW).astype(xp.int32), 0, LUT_SIZE - 1)
    y = table[idx]
    y = xp.where(v >= INPUT_MAX, table[LUT_SIZE - 1], y)
    y = xp.where(v <= INPUT_MIN, table[0], y)
    return y.astype(xp.float32)


def store_batched(xp, t, scale):
    """Q15 activation-storage fake-quant (qruntime._store); scale may be None."""
    if scale is None:
        return t
    q = xp.clip(xp.round(t / scale), -Q15_MAX - 1, Q15_MAX)
    return (q * scale).astype(xp.float32)


def step_batched(xp, arrs, sw: StepWeights, h, x):
    """One batched FastGRNN step.  h: (B, H), x: (B, d) -> h_new (B, H).

    Mirrors ``QRuntime.step`` line for line; ``arrs`` is ``sw.arrays(xp)``.
    """
    if sw.low_rank:
        wx = matvec_batched(xp, arrs["W1"], matvec_batched(xp, arrs["W2"].T, x))
        uh = matvec_batched(xp, arrs["U1"], matvec_batched(xp, arrs["U2"].T, h))
    else:
        wx = matvec_batched(xp, arrs["W"], x)
        uh = matvec_batched(xp, arrs["U"], h)
    pre = store_batched(xp, wx + uh, sw.store_scale("pre"))
    z = lut_eval_batched(xp, arrs["sig_lut"], pre + arrs["b_z"])
    h_tilde = lut_eval_batched(xp, arrs["tanh_lut"], pre + arrs["b_h"])
    z = store_batched(xp, z, sw.store_scale("z"))
    h_tilde = store_batched(xp, h_tilde, sw.store_scale("h_tilde"))
    h_new = (sw.zeta * (1.0 - z) + sw.nu) * h_tilde + z * h
    return store_batched(xp, h_new.astype(xp.float32), sw.store_scale("h"))


def logits_batched(xp, arrs, sw: StepWeights, h):
    """Classifier head, the batched image of ``qruntime.run_window``'s
    ``_matvec(head_w.T, h) + head_b`` (+ optional Q15 logit storage)."""
    out = matvec_batched(xp, arrs["head_w"].T, h)
    return store_batched(xp, out + arrs["head_b"], sw.store_scale("logits"))
