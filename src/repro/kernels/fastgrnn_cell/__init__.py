from . import kernel, ops, qstep, ref  # noqa: F401
