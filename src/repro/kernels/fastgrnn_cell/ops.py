"""jit'd wrapper: FastGRNN params pytree -> padded kernel layout -> run.

Padding to hardware-aligned tiles: H=16, d=3 pads to Hp=Dp=128 lanes; the
zero lanes are inert (zero weights, zero state).  Low-rank factors are
pre-multiplied into effective W^T/U^T once per deployment (the MCU code
does the same factor-order trick at runtime; on TPU the 128x128 effective
matmul is a single MXU op, so pre-multiplying is strictly better)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import fastgrnn as fg
from repro.core.lut import make_lut
from .kernel import fastgrnn_window, B_TILE

HP = 128


def _pad2(a, r, c):
    return jnp.pad(jnp.asarray(a, jnp.float32),
                   ((0, r - a.shape[0]), (0, c - a.shape[1])))


def _pad1(a, n):
    return jnp.pad(jnp.asarray(a, jnp.float32), (0, n - a.shape[0]))


def fastgrnn_window_kernel(params, xs, *, interpret: bool = True):
    """xs: (T, B, d) -> (h_final (B, H), traj (T, B, H)) via the Pallas
    kernel, LUT-activated (nearest mode, matching the deployed C engine)."""
    T, B, d = xs.shape
    H = params["b_z"].shape[0]
    W = fg.effective_W(params)      # (H, d)
    U = fg.effective_U(params)      # (H, H)
    zeta = 1.0 / (1.0 + np.exp(-float(params["zeta"])))
    nu = 1.0 / (1.0 + np.exp(-float(params["nu"])))

    bpad = -B % B_TILE
    xs_p = jnp.pad(jnp.asarray(xs, jnp.float32),
                   ((0, 0), (0, bpad), (0, HP - d)))
    h, traj = fastgrnn_window(
        jnp.asarray(make_lut("sigmoid")), jnp.asarray(make_lut("tanh")),
        xs_p,
        _pad2(W.T, HP, HP), _pad2(U.T, HP, HP),
        _pad1(params["b_z"], HP), _pad1(params["b_h"], HP),
        jnp.asarray([zeta, nu], jnp.float32),
        T=T, interpret=interpret)
    return h[:B, :H], traj[:, :B, :H]
